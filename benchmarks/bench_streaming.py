"""Streaming sufficient statistics — chunked vs monolithic throughput and
multi-series batch scaling.

Three questions:
  * how much does chunked ingestion (the streaming monoid) cost relative
    to the one-shot serial / blocked autocovariance paths on the same data;
  * how does per-chunk update cost scale with chunk size (carried context
    is only ``max_lag`` samples, so cost should be ~linear in the chunk);
  * how does the vmapped multi-series batch axis scale (time per series
    should *fall* as the batch fills the device).

Emits ``BENCH_streaming.json`` at the repo root (via `benchmarks.run`) so
the streaming ingest cost enters the tracked perf trajectory —
`benchmarks.check_regression` diffs it against the committed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.stats import (
    autocovariance,
    autocovariance_blocked,
    lag_sum_engine,
    streaming_autocovariance,
)

from .common import row, time_call, write_bench_json

N, D, H, BS = 400_000, 8, 8, 8192


def _stream_all(engine, update, x, chunk: int):
    st = engine.init()
    n = x.shape[0] - x.shape[0] % chunk  # equal chunks → one jit program
    for off in range(0, n, chunk):
        st = update(st, jax.lax.dynamic_slice_in_dim(x, off, chunk, axis=0))
    return st


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    results = []

    def record(name, us, derived):
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(name, us, derived)

    serial = jax.jit(lambda x: autocovariance(x, H))
    blocked = jax.jit(lambda x: autocovariance_blocked(x, H, BS))
    us_serial = time_call(serial, x)
    us_blocked = time_call(blocked, x)
    record("stream_baseline_serial", us_serial, f"N={N};d={D};H={H}")
    record("stream_baseline_blocked", us_blocked, f"block_size={BS}")

    engine = lag_sum_engine(H, D)
    update = engine.update_jit  # cached program — no per-call retrace
    for chunk in (1024, 8192, 65536):
        us = time_call(lambda: _stream_all(engine, update, x, chunk))
        n_eff = N - N % chunk
        st = _stream_all(engine, update, x, chunk)
        err = float(
            jnp.max(
                jnp.abs(
                    streaming_autocovariance(engine, st) - serial(x[:n_eff])
                )
            )
        )
        record(
            f"stream_chunked_{chunk}",
            us,
            f"chunk={chunk};samples_per_s={n_eff / (us * 1e-6):.3e};err={err:.1e}",
        )

    # Scan-driven ingest of the same stream: one lax.scan device program.
    chunk = 8192
    stack = x[: N - N % chunk].reshape(-1, chunk, D)

    def scan_ingest():
        return engine.consume(engine.init(), stack).stat

    us_scan = time_call(scan_ingest)
    record(
        "stream_scan_ingest",
        us_scan,
        f"chunk={chunk};chunks={stack.shape[0]};"
        f"samples_per_s={(N - N % chunk) / (us_scan * 1e-6):.3e}",
    )

    # Multi-series batch axis: B independent series, one vmapped update pass
    # per chunk.  Throughput is reported per series.
    n_b, chunk_b = 16_384, 2048
    for b in (1, 64, 512):
        xb = jax.random.normal(jax.random.PRNGKey(1), (b, n_b, D))
        upd_b = engine.update_batch

        def stream_batch():
            st = engine.init_batch(b)
            for off in range(0, n_b, chunk_b):
                st = upd_b(st, xb[:, off : off + chunk_b])
            return st

        us = time_call(stream_batch)
        record(
            f"stream_multi_series_{b}",
            us,
            f"batch={b};n={n_b};us_per_series={us / b:.1f}",
        )

    write_bench_json(
        "BENCH_streaming.json",
        {"shapes": {"n": N, "d": D, "max_lag": H, "block_size": BS}, "results": results},
    )


if __name__ == "__main__":
    run()
