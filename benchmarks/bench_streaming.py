"""Streaming sufficient statistics — chunked vs monolithic throughput and
multi-series batch scaling.

Three questions:
  * how much does chunked ingestion (the streaming monoid) cost relative
    to the one-shot serial / blocked autocovariance paths on the same data;
  * how does per-chunk update cost scale with chunk size (carried context
    is only ``max_lag`` samples, so cost should be ~linear in the chunk);
  * how does the vmapped multi-series batch axis scale (time per series
    should *fall* as the batch fills the device).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.stats import (
    autocovariance,
    autocovariance_blocked,
    lag_sum_engine,
    streaming_autocovariance,
)

from .common import row, time_call

N, D, H, BS = 400_000, 8, 8, 8192


def _stream_all(engine, update, x, chunk: int):
    st = engine.init()
    n = x.shape[0] - x.shape[0] % chunk  # equal chunks → one jit program
    for off in range(0, n, chunk):
        st = update(st, jax.lax.dynamic_slice_in_dim(x, off, chunk, axis=0))
    return st


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))

    serial = jax.jit(lambda x: autocovariance(x, H))
    blocked = jax.jit(lambda x: autocovariance_blocked(x, H, BS))
    us_serial = time_call(serial, x)
    us_blocked = time_call(blocked, x)
    row("stream_baseline_serial", us_serial, f"N={N};d={D};H={H}")
    row("stream_baseline_blocked", us_blocked, f"block_size={BS}")

    engine = lag_sum_engine(H, D)
    update = jax.jit(engine.update)
    for chunk in (1024, 8192, 65536):
        us = time_call(lambda: _stream_all(engine, update, x, chunk))
        n_eff = N - N % chunk
        st = _stream_all(engine, update, x, chunk)
        err = float(
            jnp.max(
                jnp.abs(
                    streaming_autocovariance(engine, st) - serial(x[:n_eff])
                )
            )
        )
        row(
            "stream_chunked",
            us,
            f"chunk={chunk};samples_per_s={n_eff / (us * 1e-6):.3e};err={err:.1e}",
        )

    # Multi-series batch axis: B independent series, one vmapped update pass
    # per chunk.  Throughput is reported per series.
    n_b, chunk_b = 16_384, 2048
    for b in (1, 64, 512):
        xb = jax.random.normal(jax.random.PRNGKey(1), (b, n_b, D))
        upd_b = jax.jit(engine.update_batch)

        def stream_batch():
            st = engine.init_batch(b)
            for off in range(0, n_b, chunk_b):
                st = upd_b(st, xb[:, off : off + chunk_b])
            return st

        us = time_call(stream_batch)
        row(
            "stream_multi_series",
            us,
            f"batch={b};n={n_b};us_per_series={us / b:.1f}",
        )


if __name__ == "__main__":
    run()
