"""Backend-registry shootout on the lag-sum hot loop (tentpole perf table).

Times the same primitives through the "jnp" backend and the "pallas"
backend (interpret mode on CPU — tiling-faithful but interpreted, so CPU
numbers measure correctness cost, not the TPU speedup) on fixed shapes, and
writes ``BENCH_backends.json`` at the repo root so the perf trajectory of
the backend dispatch starts populating per commit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import get_backend
from repro.core.estimators.stats import lag_sum_engine, streaming_autocovariance

from .common import row, time_call, write_bench_json

# Interpret-mode Pallas is python-slow; shapes are sized so the full suite
# stays in seconds while the grid still covers many tiles.
N, D, H = 65_536, 8, 8
BANDED_D, BANDED_B, BANDED_RHS = 16_384, 8, 4
CHUNK = 8_192


def run() -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    diags = jax.random.normal(jax.random.PRNGKey(1), (BANDED_D, 2 * BANDED_B + 1))
    v = jax.random.normal(jax.random.PRNGKey(2), (BANDED_RHS, BANDED_D))

    results = []

    def bench(name, backend, fn, *args, derived=""):
        us = time_call(fn, *args)
        results.append(
            {"name": name, "backend": backend, "us_per_call": us, "derived": derived}
        )
        row(f"backends_{name}_{backend}", us, derived)
        return us

    for be_name in ["jnp", "pallas"]:
        be = get_backend(be_name)
        fn = jax.jit(lambda xx, b=be: b.lagged_sums(xx, H))
        bench("lag_sums", be_name, fn, x, derived=f"N={N};d={D};H={H}")

        fn = jax.jit(lambda dd, vv, b=be: b.banded_matvec(dd, vv))
        bench(
            "banded_matvec", be_name, fn, diags, v,
            derived=f"d={BANDED_D};b={BANDED_B};nrhs={BANDED_RHS}",
        )

        # the streaming serving hot path: one chunked update
        eng = lag_sum_engine(H, D, backend=be)
        state = eng.update(eng.init(), x[:CHUNK])
        fn = jax.jit(eng.update)
        bench(
            "streaming_update", be_name, fn, state, x[CHUNK : 2 * CHUNK],
            derived=f"chunk={CHUNK};H={H};d={D}",
        )

    # cross-backend agreement recorded alongside the timings
    g_j = streaming_autocovariance(
        *(lambda e: (e, e.update(e.init(), x[:CHUNK])))(lag_sum_engine(H, D, "jnp"))
    )
    g_p = streaming_autocovariance(
        *(lambda e: (e, e.update(e.init(), x[:CHUNK])))(lag_sum_engine(H, D, "pallas"))
    )
    err = float(jnp.max(jnp.abs(g_j - g_p)))
    row("backends_parity_check", 0.0, f"err={err:.1e};interpret={jax.default_backend() != 'tpu'}")

    write_bench_json(
        "BENCH_backends.json",
        {
            "pallas_interpret": jax.default_backend() != "tpu",
            "shapes": {
                "lag_sums": {"n": N, "d": D, "max_lag": H},
                "banded_matvec": {
                    "d": BANDED_D, "bandwidth": BANDED_B, "nrhs": BANDED_RHS
                },
                "streaming_update": {"chunk": CHUNK, "max_lag": H, "d": D},
            },
            "parity_max_abs_err": err,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
