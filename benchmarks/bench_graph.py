"""Paper §11 — time-series graphs: traffic DBN simulation + cross-product
overlapping partitioning (Fig. 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graphs import (
    grid_graph,
    graph_window_map_reduce,
    line_graph,
    make_graph_partition,
    simulate_traffic_dbn,
)

from .common import row, time_call


def run():
    g = line_graph(4096)
    x0 = jnp.full((4096,), 0.4)
    sim = jax.jit(
        lambda x0, k: simulate_traffic_dbn(g, x0, 256, k), static_argnums=()
    )
    us = time_call(sim, x0, jax.random.PRNGKey(0))
    row("sec11_traffic_dbn_4096v_256steps", us, "order(1,1)_DBN")

    gg = grid_graph(32, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1024, 4))
    for parts in (4, 16):
        part = make_graph_partition(gg, parts, k=1)
        kern = lambda xc, nb, m: jnp.outer(xc, jnp.sum(jnp.where(m[:, None], nb, 0.0), 0))
        fn = jax.jit(lambda x, part=part: graph_window_map_reduce(kern, x, gg, part))
        us = time_call(fn, x)
        halo = part.padded.shape[1] * parts - 1024
        row(
            f"fig8_graph_mapreduce_P{parts}",
            us,
            f"V=1024;k_hop=1;replicated_vertices={halo}",
        )


if __name__ == "__main__":
    run()
