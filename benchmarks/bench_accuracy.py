"""Paper §2 — 1/√N convergence of the weak-memory estimators.

Error-vs-N for Yule-Walker AR and innovation MA fits; derived column
reports the fitted convergence exponent (should be ≈ −0.5).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.estimators.innovation import fit_ma
from repro.core.estimators.stats import autocovariance
from repro.core.estimators.yule_walker import yule_walker
from repro.timeseries import random_invertible_ma, random_stable_var, simulate_var, simulate_vma

from .common import row


def run():
    A = random_stable_var(jax.random.PRNGKey(0), 2, 4, radius=0.6)
    errs, ns = [], [4_000, 16_000, 64_000, 256_000]
    for n in ns:
        xs = simulate_var(jax.random.PRNGKey(1), A, n)
        g = autocovariance(xs, 3, normalization="standard")
        Ah, _ = yule_walker(g, 2)
        errs.append(float(jnp.max(jnp.abs(Ah - A))))
    slope = np.polyfit(np.log(ns), np.log(errs), 1)[0]
    row(
        "sec2_yw_convergence",
        0.0,
        ";".join(f"N{n}={e:.4f}" for n, e in zip(ns, errs)) + f";exponent={slope:.2f}",
    )

    B = random_invertible_ma(jax.random.PRNGKey(2), 1, 2, radius=0.4)
    errs2 = []
    for n in ns:
        xs = simulate_vma(jax.random.PRNGKey(3), B, n)
        g = autocovariance(xs, 16, normalization="standard")
        Bh, _ = fit_ma(g, 1, m=16)
        errs2.append(float(jnp.max(jnp.abs(Bh - B))))
    slope2 = np.polyfit(np.log(ns), np.log(errs2), 1)[0]
    row(
        "sec3_ma_convergence",
        0.0,
        ";".join(f"N{n}={e:.4f}" for n, e in zip(ns, errs2)) + f";exponent={slope2:.2f}",
    )


if __name__ == "__main__":
    run()
