"""Data-plane integrity: compensated-accumulation drift + sentinel cost.

Two questions, one per phase (PR 10):

* **Drift** — how far does a long plain-f32 streaming ingest wander from
  the exact (float64 numpy) answer on offset data, and how much of that
  wander does opt-in Neumaier compensation (``fused_engine(...,
  compensated=True)``) recover?  The workload is deliberately hostile to
  naive accumulation: a ~1e3 mean offset so every chunk-boundary ⊕-fold
  adds a large partial sum into a much larger running total, which is
  exactly where f32 rounding compounds.  The bench pins
  ``drift_ratio = plain_drift / compensated_drift ≥ 10`` — the reason the
  compensated mode exists at all.

* **Sentinel** — what does the all-finite ingest verdict cost per
  coalesced gateway tick?  One fused jitted program per tick (no extra
  host syncs beyond the (k,) verdict), so the pin is
  ``p99_on / p99_off ≤ 1.2`` tick overhead.

Emits ``BENCH_integrity.json`` at the repo root (via `benchmarks.run`);
`benchmarks.check_regression` diffs the timing rows against the blessed
baseline.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core.frame import FrameSession
from repro.core.plan import autocovariance_request, fused_engine, moments_request
from repro.serving.gateway import GatewayConfig, StatsGateway

from .common import row, write_bench_json

# ---- drift phase workload -------------------------------------------------
N = 1 << 17             # samples in the stream
D = 2
H = 2                   # autocovariance max lag
MOM_W = 8               # moments window
CHUNK = 512             # ingest granularity → N/CHUNK boundary ⊕-folds
OFFSET = 1e3            # the hostile part: large mean, small variance

# ---- sentinel phase workload ----------------------------------------------
GW_USERS = 256
GW_CHUNK = 64
GW_TICKS = 200           # enough samples that p99 isn't just the max
                         # (a scheduler hiccup on 25 ticks flips the ratio)
GW_REPEATS = 5           # p99 is reported as the median across repeats —
                         # one pass's tail is still scheduler-dominated


def _requests():
    return [autocovariance_request(H), moments_request(MOM_W)]


def _stream(plan, x, chunk):
    states = plan.init()
    for off in range(0, x.shape[0], chunk):
        states = plan.update_jit(states, x[off:off + chunk])
    return states


def _oracle(x64: np.ndarray) -> dict:
    """The exact answers in float64 numpy (serial, no blocking)."""
    n = x64.shape[0]
    # autocovariance, "paper" normalization: S(h)/(max(n-h-1, 1))
    gammas = np.empty((H + 1, D, D))
    for h in range(H + 1):
        s = x64[: n - h].T @ x64[h:]
        gammas[h] = s / max(n - h - 1, 1)
    # windowed moments: every full window of MOM_W contributes its samples
    count = n - MOM_W + 1
    weights = np.minimum.reduce(
        [
            np.arange(1, n + 1, dtype=np.float64),
            np.arange(n, 0, -1, dtype=np.float64),
            np.full(n, float(MOM_W)),
            np.full(n, float(count)),
        ]
    )
    total = count * MOM_W
    m1 = (weights[:, None] * x64).sum(0) / total
    m2 = (weights[:, None] * x64 * x64).sum(0) / total
    return {
        "autocovariance": gammas,
        "mean": m1,
        "var": np.maximum(m2 - m1 * m1, 0.0),
    }


def _drift(results: dict, oracle: dict) -> float:
    """Worst relative error across the plan members vs the f64 oracle."""
    worst = 0.0
    got_ac = np.asarray(results["autocovariance"], np.float64)
    worst = max(
        worst,
        float(
            np.max(
                np.abs(got_ac - oracle["autocovariance"])
                / np.abs(oracle["autocovariance"])
            )
        ),
    )
    mom = results["moments"]
    for key in ("mean", "var"):
        got = np.asarray(mom[key], np.float64)
        worst = max(
            worst,
            float(np.max(np.abs(got - oracle[key]) / np.abs(oracle[key]))),
        )
    return worst


def _drift_phase(results: list) -> dict:
    rng = np.random.RandomState(0)
    x = (OFFSET + rng.randn(N, D)).astype(np.float32)
    oracle = _oracle(x.astype(np.float64))

    out = {}
    for mode, compensated in (("plain", False), ("compensated", True)):
        plan = fused_engine(_requests(), d=D, backend="jnp",
                            compensated=compensated)
        # warm-up traces the chunk update AND the finalize programs (stat
        # shapes are n-independent, so a short prefix compiles everything
        # the timed full stream runs)
        warm = _stream(plan, x[: 4 * CHUNK], CHUNK)
        np.asarray(plan.finalize(warm)["autocovariance"])
        t0 = time.perf_counter()
        states = _stream(plan, x, CHUNK)
        fin = plan.finalize(states)
        np.asarray(fin["autocovariance"])        # block
        us = (time.perf_counter() - t0) * 1e6
        drift = _drift(fin, oracle)
        out[mode] = drift
        results.append({
            "name": f"ingest_{mode}",
            "us_per_call": us,
            "derived": f"n={N};chunk={CHUNK};offset={OFFSET:g};"
                       f"drift={drift:.3e}",
        })
        row(f"integrity_ingest_{mode}", us, f"drift={drift:.3e}")
    out["ratio"] = out["plain"] / max(out["compensated"], 1e-300)
    row("integrity_drift_ratio", 0.0,
        f"plain/compensated={out['ratio']:.1f}x;ungated-accuracy")
    return out


async def _sentinel_phase(results: list) -> dict:
    rng = np.random.RandomState(1)
    chunks = rng.randn(GW_USERS, GW_CHUNK, D).astype(np.float32)

    def make(sentinel: bool) -> StatsGateway:
        sess = FrameSession(d=D, num_users=GW_USERS, backend="jnp")
        sess.autocovariance(H)
        sess.moments(MOM_W)
        return StatsGateway(sess, GatewayConfig(sentinel=sentinel))

    async def one_tick(gw: StatsGateway, i: int) -> float:
        futs = [gw.submit_ingest(u, chunks[u] + i) for u in range(GW_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        await asyncio.gather(*futs)
        return dt

    # the two gateways alternate tick-by-tick, so a scheduler/GC hiccup
    # lands on both distributions equally instead of flipping the ratio
    # depending on which phase it struck; the p99 of any single pass is
    # still tail-noise-dominated, so the reported p99 is the median of
    # GW_REPEATS independent passes
    gws = {"off": make(False), "on": make(True)}
    mins = {"off": [], "on": []}
    p99s = {"off": [], "on": []}
    for label, gw in gws.items():           # compile-dominated warm-up
        await one_tick(gw, 0)
    for rep in range(GW_REPEATS):
        durations = {"off": [], "on": []}
        for i in range(1, GW_TICKS + 1):
            for label, gw in gws.items():
                durations[label].append(await one_tick(gw, i))
        for label, d in durations.items():
            mins[label].append(min(d) * 1e6)
            p99s[label].append(float(np.percentile(np.asarray(d), 99)) * 1e6)

    out = {}
    for label in ("off", "on"):
        await gws[label].stop()
        us_min = min(mins[label])
        p99 = float(np.median(p99s[label]))
        out[label] = {"min_us": us_min, "p99_us": p99}
        results.append({
            "name": f"sentinel_tick_{label}",
            "us_per_call": us_min,
            "derived": f"users={GW_USERS};chunk={GW_CHUNK};"
                       f"p99_us={p99:.1f}",
        })
        row(f"integrity_sentinel_tick_{label}", us_min, f"p99_us={p99:.1f}")
    out["overhead_ratio"] = out["on"]["p99_us"] / out["off"]["p99_us"]
    row("integrity_sentinel_overhead", 0.0,
        f"p99_on/p99_off={out['overhead_ratio']:.2f}x;ungated-ratio")
    return out


def run() -> None:
    results: list = []
    drift = _drift_phase(results)
    sentinel = asyncio.run(_sentinel_phase(results))
    write_bench_json(
        "BENCH_integrity.json",
        {
            "workload": {
                "n": N, "d": D, "max_lag": H, "moments_window": MOM_W,
                "chunk": CHUNK, "offset": OFFSET,
                "gateway_users": GW_USERS, "gateway_chunk": GW_CHUNK,
                "timed_ticks": GW_TICKS, "tick_repeats": GW_REPEATS,
            },
            "drift": drift,
            "sentinel": sentinel,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
