"""Beyond-paper: halo materialization — replication vs collective-permute.

Runs in a subprocess with 8 host devices and parses the optimized HLO for
collective bytes: the paper's pre-replication pays (P−1)·H·d extra storage
and ZERO wire bytes per sweep; exchange mode pays ~2·H·d wire bytes per
sweep and zero storage.  (The crossover rule-of-thumb lands in
EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from .common import row

_CODE = """
import jax, jax.numpy as jnp
from repro.timeseries.dataset import TimeSeriesStore
from repro.launch.roofline import parse_collectives
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
x = jax.random.normal(jax.random.PRNGKey(0), (8*4096, 8))
kern = lambda w: jnp.outer(w[0], w[-1])
for mode in ("replicate", "exchange"):
    st = TimeSeriesStore.from_series(x, 4096, 4, 4, mesh=mesh, halo_mode=mode)
    # lower the sweep and count wire bytes
    def sweep(blocks):
        st2 = TimeSeriesStore(blocks=blocks, spec=st.spec, mesh=mesh, axis="data", halo_mode=mode)
        return st2.map_reduce(kern)
    compiled = jax.jit(sweep).lower(st.blocks).compile()
    coll = parse_collectives(compiled.as_text())
    extra = st.blocks.size - x.size if mode == "replicate" else 0
    print(f"RESULT {mode} wire={coll.wire_bytes:.0f} counts={sum(coll.counts.values())} extra_elems={extra}")
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CODE)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if r.returncode != 0:
        row("halo_modes", 0.0, f"ERROR:{r.stderr[-200:]}")
        return
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            _, mode, wire, counts, extra = line.split()
            row(f"halo_{mode}", 0.0, f"{wire};{counts};{extra};P=8;H=4")


if __name__ == "__main__":
    run()
