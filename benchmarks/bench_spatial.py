"""Paper §6 — banded spatial AR: O(d·(2b+1)) predictor vs O(d²) dense.

The paper's scalability claim for very-high-d systems with banded
transitions, plus the partitioned-gradient fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.spatial import (
    SpatialPartition,
    banded_predict,
    banded_predict_partitioned,
    banded_to_dense,
)

from .common import row, time_call


def run():
    b = 4
    for d in (1024, 8192, 32768):
        diags = jax.random.normal(jax.random.PRNGKey(0), (d, 2 * b + 1)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(1), (d,))
        banded = jax.jit(lambda dg, x: banded_predict(dg, x))
        us_b = time_call(banded, diags, x)
        derived = f"d={d};b={b};flops={2*d*(2*b+1)}"
        if d <= 8192:
            dense = banded_to_dense(diags)
            densef = jax.jit(lambda A, x: A @ x)
            us_d = time_call(densef, dense, x)
            derived += f";dense_us={us_d:.1f};speedup={us_d/us_b:.1f}x"
        row(f"sec6_banded_matvec_d{d}", us_b, derived)

    d = 8192
    diags = jax.random.normal(jax.random.PRNGKey(2), (d, 2 * b + 1)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(3), (d,))
    part = SpatialPartition(d=d, num_parts=16, bandwidth=b)
    pfn = jax.jit(lambda dg, x: banded_predict_partitioned(dg, x, part))
    us_p = time_call(pfn, diags, x)
    err = float(jnp.max(jnp.abs(pfn(diags, x) - banded_predict(diags, x))))
    row("sec6_banded_partitioned_P16", us_p, f"d={d};err={err:.1e}")


if __name__ == "__main__":
    run()
