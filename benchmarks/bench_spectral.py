"""Spectral primitive + fused Welch plan benchmarks (PR 5).

Three questions:

  * what does the ``segment_fft_power`` primitive cost through each backend
    (jnp rfft vs the Pallas twiddle-matmul kernel — interpret mode on CPU,
    so the CPU pallas number measures tiling correctness cost, not the TPU
    speedup);
  * what does a fused plan containing a Welch member cost vs the eager
    sequential calls it replaces (welch_psd + autocovariance + moments) —
    now that the spectral primitive is a first-class backend citizen the
    whole plan rides one traversal;
  * what does a streamed Welch cost per scan-consumed chunk stack.

Emits ``BENCH_spectral.json`` at the repo root (via `benchmarks.run`);
`benchmarks.check_regression` diffs it against the committed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import get_backend
from repro.core.estimators.spectral import streaming_welch, welch_engine, welch_psd
from repro.core.plan import (
    StatPlan,
    autocovariance_request,
    moments_request,
    welch_request,
)
from repro.core.estimators.stats import (
    autocovariance,
    moment_engine,
    streaming_window_moments,
)

from .common import row, time_call, write_bench_json

# Interpret-mode Pallas is python-slow; shapes keep the suite in seconds.
S_SEGS, L, D = 512, 256, 4
N, H, MOM_W = 262_144, 16, 64
CHUNK, N_CHUNKS = 4_096, 16


def run() -> None:
    results = []

    def bench(name, fn, *args, backend="", derived=""):
        us = time_call(fn, *args)
        entry = {"name": name, "us_per_call": us, "derived": derived}
        if backend:
            entry["backend"] = backend
        results.append(entry)
        row(f"spectral_{name}" + (f"_{backend}" if backend else ""), us, derived)
        return us

    # -- the primitive, per backend -----------------------------------------
    segs = jax.random.normal(jax.random.PRNGKey(0), (S_SEGS, L, D))
    taper = 0.5 - 0.5 * jnp.cos(2 * jnp.pi * jnp.arange(L) / L)
    for be_name in ["jnp", "pallas"]:
        be = get_backend(be_name)
        fn = jax.jit(lambda ss, b=be: b.segment_fft_power(ss, taper))
        bench(
            "segment_power", fn, segs, backend=be_name,
            derived=f"S={S_SEGS};L={L};d={D}",
        )

    # -- fused Welch plan vs eager sequential calls -------------------------
    # Both sides timed steady-state: the plan (and its jitted traversal) is
    # built once, exactly as the eager estimators reuse their module-level
    # jit caches — what's measured is the traversal, not the trace.
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    plan = StatPlan(
        [welch_request(L), autocovariance_request(H), moments_request(MOM_W)],
        d=D,
        backend="jnp",
    )
    traverse = jax.jit(plan.from_chunk)

    def fused_collect():
        return plan.finalize(traverse(x), cache=False)

    def eager_three():
        welch_psd(x, L, backend="jnp")
        autocovariance(x, H, backend="jnp")
        me = moment_engine(MOM_W, D, backend="jnp")
        return streaming_window_moments(me, me.from_chunk(x))

    us_fused = bench(
        "welch_fused_collect", fused_collect,
        derived=f"N={N};L={L};H={H};mom_w={MOM_W}",
    )
    us_eager = bench("welch_eager_3stats", eager_three)
    row(
        "spectral_fused_vs_eager", 0.0,
        f"eager/fused={us_eager / us_fused:.2f}x",
    )

    # -- streamed Welch (scan-consumed chunk stack) -------------------------
    eng = welch_engine(L, d=D, backend="jnp")
    stack = x[: CHUNK * N_CHUNKS].reshape(N_CHUNKS, CHUNK, D)

    def consume_stack():
        state = eng.consume(eng.init(), stack)
        return streaming_welch(eng, state)

    us_stream = bench(
        "welch_stream_consume", consume_stack,
        derived=f"chunks={N_CHUNKS};chunk={CHUNK}",
    )
    results[-1]["derived"] += f";us_per_chunk={us_stream / N_CHUNKS:.1f}"

    write_bench_json(
        "BENCH_spectral.json",
        {
            "pallas_interpret": jax.default_backend() != "tpu",
            "shapes": {
                "segment_power": {"S": S_SEGS, "L": L, "d": D},
                "welch_plan": {"n": N, "L": L, "max_lag": H, "mom_w": MOM_W},
                "stream": {"chunks": N_CHUNKS, "chunk": CHUNK},
            },
            "speedup_eager_vs_fused": us_eager / us_fused,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
