"""Benchmark helpers: timing, CSV row emission, BENCH json trajectories."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# Every-leaf blocker shared with the calibration measurements — one
# definition of "the call is finished" for both timing harnesses.
from repro.core.calibrate import block_all  # noqa: E402


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (blocks on every output leaf)."""
    for _ in range(warmup):
        block_all(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_all(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def write_bench_json(filename: str, payload: dict) -> None:
    """Write a BENCH_*.json perf-trajectory file at the repo root.

    Every payload gets the ``platform`` stamp `benchmarks.check_regression`
    keys on; entries in ``payload["results"]`` are expected as
    ``{"name", "us_per_call", "derived", [optional "backend"]}`` dicts.
    """
    payload.setdefault("platform", jax.default_backend())
    with open(os.path.join(_REPO_ROOT, filename), "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
