"""Benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
