"""SeriesFrame session API — lazy-batched collect vs eager per-call (PR 4).

Four questions, answered on the jnp backend (CPU numbers; the saved
traversals are HBM reads on TPU):

  * what does ONE lazy-batched ``collect()`` of four deferred statistics
    (with TWO distinct moment windows — the multi-window fused primitive)
    cost vs the four eager per-call estimators it replaces;
  * what does the memoized re-collect cost (per-member results cached
    between queries — should be ~free);
  * what does append-ingest throughput look like: chunks folding into the
    carried fused PartialState (never re-reading history), vs the
    recompute-from-scratch a non-incremental API would pay;
  * how many passes over the data each path makes (counted, not asserted).

Emits ``BENCH_frame.json`` at the repo root (via `benchmarks.run`) so the
session-layer perf trajectory populates per commit —
`benchmarks.check_regression` diffs it against the committed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import SeriesFrame
from repro.core.backend import get_backend
from repro.core.estimators.stats import (
    autocovariance,
    moment_engine,
    streaming_window_moments,
)
from repro.core.estimators.yule_walker import yule_walker

from .common import row, time_call, write_bench_json

N, D, H = 400_000, 8, 16
MOM_W1, MOM_W2 = 64, 256
CHUNK, N_CHUNKS = 2_048, 64  # append-ingest stream shape


def _defer_four(frame):
    frame.autocovariance(H)
    frame.yule_walker(H)
    frame.moments(MOM_W1)
    frame.moments(MOM_W2)
    return frame


class _CountingBackend:
    """Counts series-sized traversals (mirrors tests/test_frame.py)."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.walks = 0

    def __getattr__(self, prim):
        fn = getattr(self._inner, prim)
        masked = prim in ("masked_lagged_sums", "fused_lagged_moments")

        def wrapped(*args, **kwargs):
            lead = args[1].shape[0] if masked else args[0].shape[0]
            if prim != "segment_fft_power" and lead >= N:
                self.walks += 1
            return fn(*args, **kwargs)

        return wrapped


def _eager_four(x, backend):
    autocovariance(x, H, backend=backend)
    yule_walker(x, H, backend=backend)
    for w in (MOM_W1, MOM_W2):
        me = moment_engine(w, D, backend=backend)
        streaming_window_moments(me, me.from_chunk(x))


def run() -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    results = []

    def bench(name, fn, *args, derived=""):
        us = time_call(fn, *args)
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"frame_{name}", us, derived)
        return us

    # -- lazy-batched collect vs eager per-call -----------------------------
    def collect_fresh():
        frame = _defer_four(SeriesFrame.from_array(x, backend="jnp"))
        return frame.collect()

    us_collect = bench(
        "collect_4stats", collect_fresh,
        derived=f"N={N};d={D};H={H};mom_windows=({MOM_W1},{MOM_W2})",
    )
    us_eager = bench("eager_4stats", lambda: _eager_four(x, "jnp"))

    counting = _CountingBackend(get_backend("jnp"))
    _defer_four(SeriesFrame.from_array(x, backend=counting)).collect()
    passes_frame = counting.walks
    counting = _CountingBackend(get_backend("jnp"))
    _eager_four(x, counting)
    passes_eager = counting.walks
    row(
        "frame_speedup_vs_eager",
        0.0,
        f"eager/collect={us_eager / us_collect:.2f}x;"
        f"passes_frame={passes_frame};passes_eager={passes_eager}",
    )

    # -- memoized re-collect -------------------------------------------------
    warm = _defer_four(SeriesFrame.from_array(x, backend="jnp"))
    warm.collect()
    us_memo = bench("recollect_memoized", warm.collect)
    row("frame_memo_vs_collect", 0.0,
        f"collect/memoized={us_collect / max(us_memo, 1e-9):.0f}x")

    # -- append-ingest throughput -------------------------------------------
    stack = x[: CHUNK * N_CHUNKS].reshape(N_CHUNKS, CHUNK, D)
    base = _defer_four(SeriesFrame.from_array(x, backend="jnp"))
    base.collect()

    def append_stream():
        for i in range(N_CHUNKS):
            base.append(stack[i])
        return base.collect()

    us_append = time_call(append_stream, warmup=0, iters=1)
    derived = (
        f"chunks={N_CHUNKS};chunk={CHUNK};us_per_chunk={us_append / N_CHUNKS:.1f}"
    )
    results.append(
        {"name": "append_ingest", "us_per_call": us_append, "derived": derived}
    )
    row("frame_append_ingest", us_append, derived)
    # the non-incremental alternative: a full recompute per arrival batch
    row(
        "frame_append_vs_recompute",
        0.0,
        f"recompute/append={us_collect * N_CHUNKS / us_append:.1f}x"
        f" (recompute-per-chunk extrapolated)",
    )

    write_bench_json(
        "BENCH_frame.json",
        {
            "shapes": {
                "collect": {
                    "n": N, "d": D, "max_lag": H,
                    "moments_windows": [MOM_W1, MOM_W2],
                },
                "append": {"chunks": N_CHUNKS, "chunk": CHUNK},
            },
            "speedup_eager_vs_collect": us_eager / us_collect,
            "passes_over_data": {"frame": passes_frame, "eager": passes_eager},
            "memoized_recollect_us": us_memo,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
