"""Paper Fig. 4 — overlapping partitioning: block-count sweep.

Wall time of the blocked estimator and the storage overhead (P−1)·H/N as
the partition count grows: the paper's claim is flat compute with
overhead linear in P (and tiny for H ≪ block_size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.stats import autocovariance_blocked
from repro.core.overlap import OverlapSpec, replication_overhead

from .common import row, time_call

N, D, H = 262_144, 8, 8


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    for bs in (65536, 16384, 4096, 1024):
        fn = jax.jit(lambda x, bs=bs: autocovariance_blocked(x, H, bs))
        us = time_call(fn, x)
        ov = replication_overhead(OverlapSpec(n=N, block_size=bs, h_left=0, h_right=H))
        row(
            f"fig4_overlap_P{N//bs}",
            us,
            f"block={bs};replication_overhead={ov:.5f}",
        )


if __name__ == "__main__":
    run()
