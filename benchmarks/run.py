"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmark contract).

Two kinds of modules run here:

* **Trajectory benches** — emit a ``BENCH_<name>.json`` at the repo root
  so the perf trajectory populates per commit, and
  ``python -m benchmarks.check_regression`` diffs them against the
  committed baselines (fails on >1.5× slowdowns; re-bless with
  ``--update-baselines`` after an intentional trade-off):
  ``bench_backends`` (kernel-backend shootout), ``bench_spectral``
  (spectral primitive + fused Welch), ``bench_fused`` (N-statistic
  plans), ``bench_megakernel`` (persistent fused-plan kernel),
  ``bench_frame`` (SeriesFrame session API), ``bench_streaming``
  (streaming monoid ingest), ``bench_gateway`` (async serving gateway),
  ``bench_chaos`` (fault-injection overhead + breaker recovery), ``bench_forecast``
  (served forecasts/sec + accuracy-vs-horizon), and ``bench_integrity``
  (compensated-accumulation drift + ingest-sentinel tick overhead).

* **Standalone paper-figure benches** — CSV rows only, NO JSON: they
  reproduce a specific paper table/figure or answer a one-off design
  question, and their numbers are workload narratives rather than
  regression surfaces (several sweep sizes/shapes, so a single
  us_per_call baseline would be meaningless): ``bench_autocov``
  (Fig. 2 / Fig. 9), ``bench_overlap_scaling`` (Fig. 4), ``bench_mle``
  (§5 / §7.2 Z-estimators), ``bench_spatial`` (§6 banded high-d),
  ``bench_graph`` (§11 / Fig. 8), ``bench_accuracy`` (§2 1/√N
  convergence — a statistical check, not a timing), ``bench_halo``
  (beyond-paper halo exchange vs replication study), and ``bench_lm``
  (framework micro-benchmarks).
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "bench_autocov",        # paper Fig. 2 (+ Fig. 9 kernel check)
    "bench_backends",       # compute-registry shootout → BENCH_backends.json
    "bench_spectral",       # spectral primitive + fused Welch → BENCH_spectral.json
    "bench_fused",          # fused N-statistic plans → BENCH_fused.json
    "bench_megakernel",     # fused-plan megakernel → BENCH_megakernel.json
    "bench_frame",          # SeriesFrame session API → BENCH_frame.json
    "bench_streaming",      # streaming monoid → BENCH_streaming.json
    "bench_gateway",        # async serving gateway → BENCH_gateway.json
    "bench_chaos",          # fault-injection overhead + breaker recovery → BENCH_chaos.json
    "bench_forecast",       # served forecasts + anomaly scoring → BENCH_forecast.json
    "bench_integrity",      # compensated drift + ingest sentinel → BENCH_integrity.json
    "bench_overlap_scaling",  # paper Fig. 4
    "bench_mle",            # paper §5 / §7.2 Z-estimators
    "bench_spatial",        # paper §6 banded high-d
    "bench_graph",          # paper §11 / Fig. 8 graphs
    "bench_accuracy",       # paper §2 1/√N convergence
    "bench_halo",           # beyond-paper halo exchange vs replication
    "bench_lm",             # framework micro-benchmarks
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for mod in MODULES:
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["run"])
            m.run()
        except Exception:
            failures.append(mod)
            print(f"{mod},0.0,ERROR")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
