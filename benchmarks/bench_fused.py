"""Fused statistics plans — one traversal for N estimators (tentpole table).

Three questions, answered on the jnp backend (CPU numbers; the Pallas tile
fusion pays off again on TPU where the saved traversals are HBM reads):

  * how much does serving FOUR statistics from ONE fused traversal save
    over four sequential single-statistic passes (the acceptance target is
    ≥2× — the lag-family members share one contraction, the moments ride
    the same fused primitive);
  * how does the fused plan's per-chunk ingest cost grow from 1 tracked
    statistic to 4 (the marginal statistic should be nearly free);
  * what does scan-driven ingest (one lax.scan program) save over the
    per-chunk Python dispatch loop on a ≥64-chunk stream.

Emits ``BENCH_fused.json`` at the repo root (via `benchmarks.run`) so the
fused-plan perf trajectory populates per commit —
`benchmarks.check_regression` diffs it against the committed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import get_backend
from repro.core.estimators.arma import fit_arma
from repro.core.estimators.stats import (
    autocovariance,
    lag_sum_engine,
    moment_engine,
    streaming_window_moments,
)
from repro.core.estimators.yule_walker import yule_walker
from repro.core.plan import (
    arma_request,
    autocovariance_request,
    fused_engine,
    moments_request,
    yule_walker_request,
)

from .common import row, time_call, write_bench_json

N, D, H, MOM_W = 400_000, 8, 16, 64
CHUNK, N_CHUNKS = 2_048, 128  # scan-vs-loop stream shape

FOUR_REQUESTS = [
    autocovariance_request(H),
    yule_walker_request(H),
    arma_request(2, 2, m=H),
    moments_request(MOM_W),
]


class _CountingBackend:
    """Counts series-sized traversals so passes-over-data is measured, not
    asserted (mirrors tests/test_plan.py)."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.walks = 0

    def __getattr__(self, prim):
        fn = getattr(self._inner, prim)
        masked = prim in ("masked_lagged_sums", "fused_lagged_moments")

        def wrapped(*args, **kwargs):
            lead = args[1].shape[0] if masked else args[0].shape[0]
            if prim != "segment_fft_power" and lead >= N:
                self.walks += 1
            return fn(*args, **kwargs)

        return wrapped


def _count_passes(fn):
    counting = _CountingBackend(get_backend("jnp"))
    fn(counting)
    return counting.walks


def run() -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    results = []

    def bench(name, fn, *args, derived=""):
        us = time_call(fn, *args)
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"fused_{name}", us, derived)
        return us

    # -- fused plan vs sequential single-statistic passes -------------------
    plan4 = fused_engine(FOUR_REQUESTS, d=D, backend="jnp")
    fused_fn = jax.jit(lambda xx: plan4.finalize(plan4.from_chunk(xx)))
    us_fused = bench("plan_4stats", fused_fn, x, derived=f"N={N};d={D};H={H}")

    seq_fns = [
        jax.jit(lambda xx: autocovariance(xx, H, backend="jnp")),
        jax.jit(lambda xx: yule_walker(xx, H, backend="jnp")),
        jax.jit(lambda xx: fit_arma(xx, 2, 2, m=H, backend="jnp")),
    ]
    me = moment_engine(MOM_W, D, backend="jnp")
    seq_fns.append(jax.jit(lambda xx: streaming_window_moments(me, me.from_chunk(xx))))
    us_seq = sum(
        bench(f"sequential_{nm}", fn, x)
        for nm, fn in zip(["autocov", "yule_walker", "arma", "moments"], seq_fns)
    )
    speedup = us_seq / us_fused
    passes_fused = _count_passes(
        lambda be: (lambda p: p.finalize(p.from_chunk(x)))(
            fused_engine(FOUR_REQUESTS, d=D, backend=be)
        )
    )
    passes_seq = _count_passes(
        lambda be: (
            autocovariance(x, H, backend=be),
            yule_walker(x, H, backend=be),
            fit_arma(x, 2, 2, m=H, backend=be),
            (lambda m: streaming_window_moments(m, m.from_chunk(x)))(
                moment_engine(MOM_W, D, backend=be)
            ),
        )
    )
    row(
        "fused_speedup_4stats",
        0.0,
        f"sequential/fused={speedup:.2f}x;passes_fused={passes_fused};"
        f"passes_sequential={passes_seq}",
    )

    # -- marginal statistic cost: 1 vs 4 members per ingested chunk ---------
    stack = x[: CHUNK * N_CHUNKS].reshape(N_CHUNKS, CHUNK, D)
    plan1 = fused_engine([autocovariance_request(H)], d=D, backend="jnp")

    def bench_ingest(name, fn):
        us = time_call(fn)
        derived = f"chunks={N_CHUNKS};chunk={CHUNK};us_per_chunk={us / N_CHUNKS:.1f}"
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"fused_{name}", us, derived)
        return us

    bench_ingest("ingest_plan_1stat", lambda: plan1.consume(plan1.init(), stack))
    bench_ingest("ingest_plan_4stats", lambda: plan4.consume(plan4.init(), stack))

    # -- scan-driven ingest vs per-chunk Python dispatch --------------------
    engine = lag_sum_engine(H, D, backend="jnp")

    def loop_ingest():
        st = engine.init()
        for i in range(N_CHUNKS):
            st = engine.update_jit(st, stack[i])
        return st.stat

    def scan_ingest():
        return engine.consume(engine.init(), stack).stat

    us_loop = bench_ingest("ingest_python_loop", loop_ingest)
    us_scan = bench_ingest("ingest_scan", scan_ingest)
    row("fused_scan_vs_loop", 0.0, f"loop/scan={us_loop / us_scan:.2f}x")

    write_bench_json(
        "BENCH_fused.json",
        {
            "shapes": {
                "plan": {"n": N, "d": D, "max_lag": H, "moments_window": MOM_W},
                "ingest": {"chunks": N_CHUNKS, "chunk": CHUNK},
            },
            "speedup_fused_vs_sequential": speedup,
            "passes_over_data": {"fused": passes_fused, "sequential": passes_seq},
            "speedup_scan_vs_loop": us_loop / us_scan,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
