"""Paper Fig. 2 — map-reduce autocovariance estimation.

Serial estimator vs the embarrassingly-parallel overlapping-block path vs
the Pallas window_stats formulation (interpret mode on CPU): identical
results, per-call wall time, and the replication overhead actually paid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.stats import autocovariance, autocovariance_blocked
from repro.core.overlap import OverlapSpec, replication_overhead
from repro.kernels.window_stats import ops as ws

from .common import row, time_call

N, D, H, BS = 400_000, 8, 8, 8192


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    serial = jax.jit(lambda x: autocovariance(x, H))
    blocked = jax.jit(lambda x: autocovariance_blocked(x, H, BS))
    us_serial = time_call(serial, x)
    us_blocked = time_call(blocked, x)
    err = float(jnp.max(jnp.abs(serial(x) - blocked(x))))
    ov = replication_overhead(OverlapSpec(n=N, block_size=BS, h_left=0, h_right=H))
    row("fig2_autocov_serial", us_serial, f"N={N};d={D};H={H}")
    row(
        "fig2_autocov_blocked",
        us_blocked,
        f"err={err:.1e};replication_overhead={ov:.4f};blocks={N//BS}",
    )
    # MXU-form kernel (functional check; CPU interpret timing not meaningful)
    g_k = ws.autocovariance(x[:65536], H, block_t=4096, interpret=True)
    g_r = autocovariance(x[:65536], H)
    row(
        "fig9_window_stats_allclose",
        0.0,
        f"err={float(jnp.max(jnp.abs(g_k - g_r))):.1e};interpret=True",
    )


if __name__ == "__main__":
    run()
