"""Fused-plan megakernel — one staging pass vs the per-family launch path.

The PR 7 acceptance question: does collapsing a 3-family plan chunk update
(lagged sums + multi-window moments + Welch segment power) into ONE
``fused_plan_update`` call cost anything over the legacy path that walks
the chunk once per family (``fused_lagged_moments`` + the Welch member's
own candidate gather + FFT)?  On CPU both paths lower to jnp — the fused
composition must be no slower; on TPU the fused path is the one that
halves HBM traffic (each tile staged into VMEM once, all families fed).

Also times the interpret-mode Pallas megakernel on a small chunk — a
validation vehicle (~100× slow), recorded for trajectory only, excluded
from the regression gate by the MIN_US floor sizing.

Emits ``BENCH_megakernel.json`` at the repo root;
`benchmarks.check_regression` diffs it against the committed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import PallasBackend, get_backend
from repro.core.plan import (
    StatPlan,
    autocovariance_request,
    moments_request,
    welch_request,
)

from .common import row, time_call, write_bench_json

N, D, H, MOM_W = 262_144, 8, 16, 64
NPERSEG, OVERLAP = 256, 128

REQUESTS = [
    autocovariance_request(H),
    moments_request(MOM_W),
    welch_request(nperseg=NPERSEG, overlap=OVERLAP),
]


def _three_family_plan(backend, use_megakernel):
    plan = StatPlan(REQUESTS, d=D, backend=backend)
    (group,) = plan.groups
    group._use_megakernel = use_megakernel and group._use_megakernel
    return plan, group


def run() -> None:
    results = []

    def bench(name, fn, *args, derived=""):
        us = time_call(fn, *args)
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"megakernel_{name}", us, derived)
        return us

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N, D))
    shape = f"N={N};d={D};H={H};w={MOM_W};nperseg={NPERSEG}"

    # -- fused single-call chunk update vs the per-family launch path -------
    be = get_backend("jnp")
    plan_fused, g_fused = _three_family_plan(be, use_megakernel=True)
    plan_legacy, g_legacy = _three_family_plan(be, use_megakernel=False)
    assert g_fused._use_megakernel and not g_legacy._use_megakernel

    fused_fn = jax.jit(lambda xx: plan_fused.update(plan_fused.init(), xx))
    legacy_fn = jax.jit(lambda xx: plan_legacy.update(plan_legacy.init(), xx))
    us_fused = bench("chunk_update_fused", fused_fn, x, derived=shape)
    us_legacy = bench("chunk_update_per_family", legacy_fn, x, derived=shape)
    ratio = us_legacy / us_fused
    row("megakernel_fused_vs_per_family", 0.0, f"per_family/fused={ratio:.2f}x")

    # full evaluate-and-finalize, both paths (the user-visible latency)
    fused_fin = jax.jit(lambda xx: plan_fused.finalize(plan_fused.from_chunk(xx)))
    legacy_fin = jax.jit(
        lambda xx: plan_legacy.finalize(plan_legacy.from_chunk(xx))
    )
    bench("finalize_fused", fused_fin, x, derived=shape)
    bench("finalize_per_family", legacy_fin, x, derived=shape)

    # -- interpret-mode Pallas megakernel (validation vehicle, small chunk) --
    n_small = 4_096
    xs = x[: n_small + MOM_W]
    mask = jnp.ones((n_small,), jnp.bool_)
    z0 = jnp.asarray(0, jnp.int32)
    pal = PallasBackend(interpret=True)
    taper = jnp.hanning(NPERSEG)
    bench(
        "pallas_interpret_small",
        lambda: pal.fused_plan_update(
            xs, mask, z0, H, (MOM_W,), (NPERSEG,), (NPERSEG - OVERLAP,), (taper,)
        ),
        derived=f"N={n_small};interpret=True",
    )

    write_bench_json(
        "BENCH_megakernel.json",
        {
            "shapes": {
                "plan": {
                    "n": N,
                    "d": D,
                    "max_lag": H,
                    "moments_window": MOM_W,
                    "nperseg": NPERSEG,
                    "overlap": OVERLAP,
                },
            },
            "speedup_fused_vs_per_family": ratio,
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
