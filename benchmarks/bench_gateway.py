"""Async serving gateway under a ≥1000-tenant workload (PR 6).

Drives `repro.serving.gateway.StatsGateway` with 1024 simulated users all
submitting concurrently through the asyncio front door, and answers:

  * what does ONE coalescing tick cost when every tenant ingests a chunk
    (1024 concurrent clients → one donated scatter program);
  * what does ONE tick cost when every tenant queries (one gather/⊕-fold
    plus one jit-cached vmapped fused finalize);
  * the same for a mixed tick (everyone ingests AND queries);
  * the client-observed p50/p99 submit→resolve latencies the gateway's
    own metrics surface reports under that load.

Emits ``BENCH_gateway.json`` at the repo root (via `benchmarks.run`) so
the serving-layer perf trajectory populates per commit —
`benchmarks.check_regression` diffs it against the blessed baseline.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core.frame import FrameSession
from repro.serving.gateway import StatsGateway

from .common import row, write_bench_json

N_USERS = 1024          # ≥1000 simulated tenants, all active per tick
D = 4
CHUNK = 64              # samples per ingest chunk
H, MOM_W = 8, 32        # deferred statistics: autocovariance(H), moments(W)
TICKS = 9               # timed ticks per phase (median reported)


def _session() -> FrameSession:
    sess = FrameSession(d=D, num_users=N_USERS, backend="jnp")
    sess.autocovariance(H)
    sess.moments(MOM_W)
    return sess


async def _drive() -> tuple:
    gw = StatsGateway(_session())
    rng = np.random.RandomState(0)
    chunks = rng.randn(N_USERS, CHUNK, D).astype(np.float32)

    async def ingest_tick(offset: float) -> float:
        futs = [gw.submit_ingest(u, chunks[u] + offset) for u in range(N_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        await asyncio.gather(*futs)
        return dt

    async def query_tick() -> float:
        futs = [gw.submit_query(u) for u in range(N_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        await asyncio.gather(*futs)
        return dt

    async def mixed_tick(offset: float) -> float:
        ifuts = [gw.submit_ingest(u, chunks[u] + offset) for u in range(N_USERS)]
        qfuts = [gw.submit_query(u) for u in range(N_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        await asyncio.gather(*ifuts, *qfuts)
        return dt

    # warm-up: traces the scatter + finalize programs once; drop those
    # compile-dominated samples from the latency windows so the reported
    # percentiles are steady-state serving, not first-trace waits
    await ingest_tick(0.0)
    await query_tick()
    gw._lat_ingest.clear()
    gw._lat_query.clear()

    ing = [await ingest_tick(1.0 + i) for i in range(TICKS)]
    qry = [await query_tick() for _ in range(TICKS)]
    mixed = [await mixed_tick(100.0 + i) for i in range(TICKS)]
    metrics = gw.metrics()
    await gw.stop()
    return ing, qry, mixed, metrics


def run() -> None:
    ing, qry, mixed, metrics = asyncio.run(_drive())
    results = []

    def bench(name: str, us: float, derived: str) -> None:
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"gateway_{name}", us, derived)

    # min over the timed ticks: the per-tick work is identical, so min is
    # the real cost and the spread is GC / scheduler noise — gating the
    # median flaked ~1.5× run-to-run on shared hardware
    us_ing = min(ing) * 1e6
    bench(
        "ingest_tick", us_ing,
        f"users={N_USERS};chunk={CHUNK};programs=1;"
        f"users_per_s={N_USERS / (us_ing / 1e6):.0f}",
    )
    us_qry = min(qry) * 1e6
    bench(
        "query_tick", us_qry,
        f"users={N_USERS};programs=1;"
        f"queries_per_s={N_USERS / (us_qry / 1e6):.0f}",
    )
    us_mixed = min(mixed) * 1e6
    bench(
        "mixed_tick", us_mixed,
        f"users={N_USERS};chunk={CHUNK};programs=2;"
        f"requests_per_s={2 * N_USERS / (us_mixed / 1e6):.0f}",
    )
    # client-observed submit→resolve latencies (include the admission /
    # python fan-in overhead the tick timers above exclude).  Reported —
    # CSV rows + payload — but not gated results entries: percentiles of
    # a Python-side distribution where one stalled tick shifts ~1k
    # samples are too noisy for a 1.5× regression gate.
    latency = {}
    for kind in ("ingest", "query"):
        p50, p99 = metrics[kind]["p50_us"], metrics[kind]["p99_us"]
        latency[kind] = {"p50_us": p50, "p99_us": p99}
        row(f"gateway_{kind}_latency_p50", p50,
            f"users={N_USERS};client-observed;ungated")
        row(f"gateway_{kind}_latency_p99", p99,
            f"users={N_USERS};client-observed;ungated")

    assert metrics["ingest"]["programs"] == TICKS * 2 + 1  # coalescing held
    assert metrics["query"]["programs"] == TICKS * 2 + 1

    write_bench_json(
        "BENCH_gateway.json",
        {
            "workload": {
                "users": N_USERS, "d": D, "chunk": CHUNK,
                "max_lag": H, "moments_window": MOM_W,
                "timed_ticks_per_phase": TICKS,
            },
            "batch_occupancy": metrics["batch_occupancy"],
            "client_latency_us": latency,
            "straggler_ticks": metrics["straggler_ticks"],
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
