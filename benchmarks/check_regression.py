"""Perf-regression gate over the committed BENCH_*.json trajectories.

Diffs the working-tree benchmark JSONs (the ones `benchmarks.run` just
wrote) against the **baseline**: the blessed snapshot in
``benchmarks/baselines/<file>`` when one exists, else the version committed
at HEAD (``git show HEAD:<file>``).  FAILS — nonzero exit — when any named
entry slowed down by more than ``THRESHOLD`` (1.5×).  Speedups and new
entries pass; an entry present in the baseline but missing from the fresh
run fails (a silently dropped benchmark is how perf coverage rots).

Usage:
    PYTHONPATH=src python -m benchmarks.check_regression [--threshold 1.5]
    PYTHONPATH=src python -m benchmarks.check_regression --update-baselines

``--update-baselines`` blesses the current working-tree JSONs: they are
copied into ``benchmarks/baselines/`` (shown against the old baseline
first, never gated), and committing that directory pins them as the
reference for every later run.  Use it after an intentional perf trade-off
or a hardware change, not to silence a regression you have not read.

Meant to run right after ``python -m benchmarks.run`` in CI: the blessed
JSONs are the trajectory, the fresh ones are the candidate, and the gate
keeps a PR from landing a >1.5× slowdown on any tracked hot path.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import subprocess
import sys

THRESHOLD = 1.5
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO_ROOT, "benchmarks", "baselines")

# Every tracked trajectory file; entries are matched by (name, backend).
BENCH_FILES = [
    "BENCH_backends.json",
    "BENCH_spectral.json",
    "BENCH_fused.json",
    "BENCH_megakernel.json",
    "BENCH_frame.json",
    "BENCH_streaming.json",
    "BENCH_gateway.json",
    "BENCH_chaos.json",
    "BENCH_forecast.json",
    "BENCH_integrity.json",
]


def discover_files() -> list:
    """The default ``--files`` set: the tracked list UNIONED with every
    ``BENCH_*.json`` found in the repo root or the baselines directory.

    The union is what lets a brand-new benchmark participate before anyone
    remembers to add it to ``BENCH_FILES``: a fresh working-tree JSON is
    picked up (and blessed by ``--update-baselines``), and a blessed file
    whose working-tree copy was not regenerated still gates."""
    found = set(BENCH_FILES)
    for path in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")):
        found.add(os.path.basename(path))
    if os.path.isdir(BASELINE_DIR):
        for fname in os.listdir(BASELINE_DIR):
            if fname.startswith("BENCH_") and fname.endswith(".json"):
                found.add(fname)
    return sorted(found)
# Timing rows with us_per_call below this are jitter, not signal — a 1.5×
# blowup of a 50µs dispatch round-trip is noise on shared CI hardware.
MIN_US = 1_000.0


def _entry_key(entry: dict) -> tuple:
    return (entry["name"], entry.get("backend", ""))


def _load_entries(payload: dict) -> dict:
    return {
        _entry_key(e): float(e["us_per_call"])
        for e in payload.get("results", [])
        if float(e.get("us_per_call", 0.0)) > 0.0
    }


def _committed(fname: str):
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{fname}"],
            cwd=REPO_ROOT,
            capture_output=True,
            check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None  # not committed yet — nothing to regress against
    try:
        return json.loads(blob)
    except ValueError:
        print(f"{fname}: HEAD-committed copy is not valid JSON — "
              "treating as no baseline", file=sys.stderr)
        return None


def _baseline(fname: str):
    """Baseline payload: the blessed benchmarks/baselines snapshot when one
    exists (and parses), the HEAD-committed file otherwise.  A torn or
    hand-mangled blessed file degrades to the committed copy with a warning
    rather than crashing the whole gate."""
    blessed = os.path.join(BASELINE_DIR, fname)
    if os.path.exists(blessed):
        try:
            with open(blessed) as f:
                return json.load(f)
        except (OSError, ValueError) as exc:
            print(f"{fname}: blessed baseline unreadable ({exc}) — "
                  "falling back to HEAD", file=sys.stderr)
    return _committed(fname)


def update_baselines(files) -> int:
    """Copy the working-tree BENCH files into benchmarks/baselines/."""
    os.makedirs(BASELINE_DIR, exist_ok=True)
    missing = []
    for fname in files:
        src = os.path.join(REPO_ROOT, fname)
        if not os.path.exists(src):
            missing.append(fname)
            continue
        shutil.copyfile(src, os.path.join(BASELINE_DIR, fname))
        print(f"blessed {fname} -> benchmarks/baselines/{fname}")
    if missing:
        print(f"not blessed (missing from working tree): {missing}",
              file=sys.stderr)
    return 0


def check_file(fname: str, threshold: float) -> list:
    """Returns a list of human-readable failure strings for one file."""
    path = os.path.join(REPO_ROOT, fname)
    base_payload = _baseline(fname)
    if not os.path.exists(path):
        if base_payload is None:
            # A bench that exists in neither place (e.g. freshly added to
            # BENCH_FILES before its first run) is a to-do, not a failure.
            print(f"{fname}: no working-tree run and no baseline — skipping "
                  "(run benchmarks, then --update-baselines to bless it)")
            return []
        return [f"{fname}: missing from working tree (benchmarks not run?)"]
    if base_payload is None:
        print(f"{fname}: no blessed or committed baseline — skipping "
              "(use --update-baselines to bless this run)")
        return []
    try:
        with open(path) as f:
            fresh_payload = json.load(f)
    except (OSError, ValueError) as exc:
        return [f"{fname}: working-tree copy unreadable ({exc})"]
    if fresh_payload.get("platform") != base_payload.get("platform"):
        # A TPU run vs a committed CPU baseline (or vice versa) is a
        # platform change, not a regression — only like-for-like gates.
        print(
            f"{fname}: platform changed "
            f"({base_payload.get('platform')} -> {fresh_payload.get('platform')})"
            " — skipping"
        )
        return []
    fresh = _load_entries(fresh_payload)
    base = _load_entries(base_payload)

    failures = []
    for key, base_us in sorted(base.items()):
        name = ":".join(k for k in key if k)
        if key not in fresh:
            failures.append(f"{fname}: entry {name!r} disappeared from the run")
            continue
        if base_us < MIN_US:
            continue
        ratio = fresh[key] / base_us
        status = "OK" if ratio <= threshold else "REGRESSION"
        print(
            f"{fname}: {name:<40s} {base_us:>12.1f}us -> {fresh[key]:>12.1f}us "
            f"({ratio:.2f}x) {status}"
        )
        if ratio > threshold:
            failures.append(
                f"{fname}: {name!r} slowed {ratio:.2f}x "
                f"({base_us:.0f}us -> {fresh[key]:.0f}us, limit {threshold}x)"
            )
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--threshold", type=float, default=THRESHOLD)
    parser.add_argument(
        "--files", nargs="*", default=None,
        help="BENCH json filenames (repo-root relative) to check; default "
             "is the tracked list plus every BENCH_*.json discovered in "
             "the repo root or benchmarks/baselines/",
    )
    parser.add_argument(
        "--update-baselines", action="store_true",
        help="bless the working-tree JSONs as the new baseline "
             "(benchmarks/baselines/); shows diffs, never fails",
    )
    args = parser.parse_args(argv)
    if args.files is None:
        args.files = discover_files()

    if args.update_baselines:
        # Show the diff being blessed — including disappeared entries: a
        # benchmark silently baked out of the baseline is exactly the
        # coverage rot the gate exists to prevent.  Blessing proceeds (the
        # flag is for intentional changes) but never silently.
        warnings = []
        for fname in args.files:
            warnings.extend(check_file(fname, args.threshold))
        if warnings:
            print("\nBLESSING OVER THESE DIFFERENCES:", file=sys.stderr)
            for w in warnings:
                print(f"  {w}", file=sys.stderr)
        return update_baselines(args.files)

    failures = []
    for fname in args.files:
        failures.extend(check_file(fname, args.threshold))
    if failures:
        print("\nPERF REGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nno perf regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
