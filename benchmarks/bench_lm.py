"""Framework-side microbenchmarks: reduced-config train-step and decode-step
latency for representative assigned architectures (CPU wall time — the TPU
numbers live in the dry-run roofline, results/dryrun/)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import cache_spec, decode_step, init_params
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

from .common import row, time_call

ARCH_SET = ("qwen3-0.6b", "llama4-maverick-400b-a17b", "zamba2-7b", "xlstm-125m")


def run():
    for name in ARCH_SET:
        r = ARCHS[name].reduced()
        params = init_params(jax.random.PRNGKey(0), r, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, r.vocab)
        if r.family == "vlm":
            continue
        batch = {"tokens": toks, "labels": toks}
        step = jax.jit(make_train_step(r, lr_fn=1e-3))
        opt = adamw_init(params)
        us = time_call(step, params, opt, batch, iters=3)
        n_par = sum(x.size for x in jax.tree.leaves(params))
        row(f"train_step_{name}", us, f"reduced;params={n_par};tokens=256")

        spec = cache_spec(r, 4, 128, dtype=jnp.float32)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        dfn = jax.jit(
            lambda p, c, t, q: decode_step(p, c, {"tokens": t, "pos": q}, r)
        )
        us = time_call(
            dfn, params, cache, jnp.zeros((4,), jnp.int32), jnp.asarray(64, jnp.int32)
        )
        row(f"decode_step_{name}", us, "reduced;batch=4;cache=128")


if __name__ == "__main__":
    run()
