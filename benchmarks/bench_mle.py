"""Paper §5/§7.2 — Z-estimator (conditional MLE) benchmarks.

Full-batch gradient descent with the §6.3 optimal step size vs SGD with
hyperbolic decay: time per sweep and parameter error after a fixed budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.estimators.mle import ar_nll_and_grad_blocked, fit_ar_mle, fit_ar_sgd
from repro.timeseries import random_stable_var, simulate_var

from .common import row, time_call

N, D, P = 100_000, 8, 2


def run():
    A = random_stable_var(jax.random.PRNGKey(0), P, D, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(1), A, N)

    prec = jnp.eye(D)
    grad_fn = jax.jit(
        lambda a: ar_nll_and_grad_blocked(a, prec, xs, block_size=8192)
    )
    us = time_call(grad_fn, jnp.zeros((P, D, D)))
    row("z_est_fullbatch_grad_sweep", us, f"N={N};d={D};p={P};blocks={N//8192}")

    res = fit_ar_mle(xs, P, n_steps=80, block_size=8192)
    err = float(jnp.max(jnp.abs(res.A - A)))
    row("z_est_gd_80steps", 0.0, f"param_err={err:.4f};nll={float(res.nll_trace[-1]):.4f}")

    res2 = fit_ar_sgd(xs, P, n_steps=800, batch=256)
    err2 = float(jnp.max(jnp.abs(res2.A - A)))
    row("z_est_sgd_800steps", 0.0, f"param_err={err2:.4f}")


if __name__ == "__main__":
    run()
