"""Chaos overhead and recovery benchmarks (PR 8).

Answers the two operational questions the fault-injection subsystem
raises:

  * what does a serving tick cost with the injector OFF vs a seeded 1%
    stall-rate schedule on ``gateway.tick`` (p99 — the number a tick
    deadline must be provisioned against);
  * how long does a breaker trip take to heal: wall time from the first
    failing primary dispatch through the cooldown to the recovering probe
    (`repro.core.backend.CircuitBreakerBackend`, call-counted cooldown).

The faulty-phase schedule is seeded, so the stalled ticks — and therefore
the p99 — replay identically run to run.  Emits ``BENCH_chaos.json`` at
the repo root; `benchmarks.check_regression` gates it like every other
trajectory (entries under its jitter floor are reported, not gated).
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core.backend import CircuitBreakerBackend, JnpBackend
from repro.core.frame import FrameSession
from repro.runtime import chaos
from repro.runtime.chaos import FaultInjector
from repro.serving.gateway import StatsGateway

from .common import row, write_bench_json

N_USERS = 64
D = 4
CHUNK = 16
H, MOM_W = 4, 8
TICKS = 300             # timed ticks per phase (seeded 1% → ~3 stalls)
STALL_S = 0.02          # injected straggler stall per faulty tick
FAULT_RATE = 0.01
COOLDOWN = 8            # breaker cooldown (dispatch calls) for recovery


def _session() -> FrameSession:
    sess = FrameSession(d=D, num_users=N_USERS, backend="jnp")
    sess.autocovariance(H)
    sess.moments(MOM_W)
    return sess


async def _tick_phase() -> list:
    """TICKS mixed ticks (every tenant ingests + queries); per-tick wall
    times, steady-state (the tracing warm-up tick is dropped)."""
    gw = StatsGateway(_session())
    rng = np.random.RandomState(0)
    chunks = rng.randn(N_USERS, CHUNK, D).astype(np.float32)

    async def mixed_tick() -> float:
        ifuts = [gw.submit_ingest(u, chunks[u]) for u in range(N_USERS)]
        qfuts = [gw.submit_query(u) for u in range(N_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        await asyncio.gather(*ifuts, *qfuts)
        return dt

    await mixed_tick()                 # warm-up: traces both programs
    times = [await mixed_tick() for _ in range(TICKS)]
    await gw.stop()
    return times


def _breaker_recovery_us() -> tuple:
    """Wall time from the tripping dispatch to the recovering probe."""
    br = CircuitBreakerBackend(
        primary=JnpBackend(), fallback=JnpBackend(),
        trip_after=1, cooldown_calls=COOLDOWN,
    )
    import jax.numpy as jnp

    x = jnp.asarray(
        np.random.RandomState(1).randn(256, D).astype(np.float32)
    )
    np.asarray(br.lagged_sums(x, H))   # warm the dispatch + compute path
    br.reset()
    inj = FaultInjector(seed=0).fail("backend.lagged_sums", calls={1})
    with chaos.scoped(inj):
        np.asarray(br.lagged_sums(x, H))           # healthy call (site 0)
        t0 = time.perf_counter()
        # call 1 fails → trips; COOLDOWN-1 calls ride the open fallback;
        # the next call probes the primary and closes the breaker
        calls = 0
        while br.breaker_metrics()["recoveries"] == 0:
            np.asarray(br.lagged_sums(x, H))
            calls += 1
        dt = time.perf_counter() - t0
    st = br.breaker_metrics()["primitives"]["lagged_sums"]
    assert st["trips"] == 1 and st["state"] == "closed"
    return dt * 1e6, calls


def run() -> None:
    clean = asyncio.run(_tick_phase())

    # seed 11 draws 7 stalls over the 300 ticks — comfortably more than
    # the 3 samples p99 needs, so the reported tail is the injected stalls
    # (deterministic), not whichever clean tick the scheduler jittered
    inj = FaultInjector(seed=11).stall(
        "gateway.tick", rate=FAULT_RATE, seconds=STALL_S
    )
    with chaos.scoped(inj):
        faulty = asyncio.run(_tick_phase())
    n_stalls = sum(1 for (_, _, a) in inj.log if a == "stall")

    recovery_us, recovery_calls = _breaker_recovery_us()

    results = []

    def bench(name: str, us: float, derived: str) -> None:
        results.append({"name": name, "us_per_call": us, "derived": derived})
        row(f"chaos_{name}", us, derived)

    p99_clean = float(np.percentile(clean, 99)) * 1e6
    p99_faulty = float(np.percentile(faulty, 99)) * 1e6
    # gated entries are the stable measures: min clean tick (identical
    # per-tick work, spread is scheduler noise), the stall-dominated
    # faulty p99 (the seeded 20ms stalls ARE the tail), and the breaker's
    # trip→recovery span.  The clean p99 is host-jitter by construction —
    # reported (rows + payload) but not gated.
    bench(
        "tick_min_clean", float(np.min(clean)) * 1e6,
        f"users={N_USERS};ticks={TICKS};injector=off",
    )
    bench(
        "tick_p99_faulty", p99_faulty,
        f"users={N_USERS};ticks={TICKS};rate={FAULT_RATE};"
        f"stall_ms={STALL_S * 1e3:.0f};stalled={n_stalls};seeded",
    )
    bench(
        "breaker_recovery", recovery_us,
        f"trip_after=1;cooldown_calls={COOLDOWN};"
        f"dispatches={recovery_calls};fallback=jnp",
    )
    med_clean = float(np.median(clean)) * 1e6
    med_faulty = float(np.median(faulty)) * 1e6
    row("chaos_tick_p99_clean", p99_clean,
        f"users={N_USERS};injector=off;ungated")
    row("chaos_tick_p50_clean", med_clean, f"users={N_USERS};ungated")
    row("chaos_tick_p50_faulty", med_faulty,
        f"users={N_USERS};rate={FAULT_RATE};ungated")

    write_bench_json(
        "BENCH_chaos.json",
        {
            "workload": {
                "users": N_USERS, "d": D, "chunk": CHUNK,
                "max_lag": H, "moments_window": MOM_W,
                "ticks_per_phase": TICKS,
                "fault_rate": FAULT_RATE, "stall_s": STALL_S,
                "stalled_ticks": n_stalls,
            },
            "tick_p50_us": {"clean": med_clean, "faulty": med_faulty},
            "tick_p99_us": {"clean": p99_clean, "faulty": p99_faulty},
            "breaker": {
                "cooldown_calls": COOLDOWN,
                "recovery_dispatches": recovery_calls,
            },
            "results": results,
        },
    )


if __name__ == "__main__":
    run()
