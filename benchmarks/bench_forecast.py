"""Served forecasting throughput + accuracy-vs-horizon (PR 9).

Drives the forecast subsystem the way production would: 1024 tenants with
seasonal VAR traffic behind `StatsGateway`, every tenant asking for
multi-horizon predictions (``model="auto"`` — period detected per tenant
from the plan's Welch member) plus anomaly scores, all coalesced into ONE
vmapped finalize per tick.  Reports:

  * forecasts/sec for a full-occupancy query tick (gated timing);
  * mean-absolute-error vs horizon against the noiseless seasonal truth,
    and the fraction of tenants whose period was detected exactly
    (reported in the derived column / payload — accuracy, not time, so
    it rides along ungated).

Emits ``BENCH_forecast.json`` at the repo root (via `benchmarks.run`) so
`benchmarks.check_regression` can diff the serving-forecast trajectory
against the blessed baseline.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core.frame import FrameSession
from repro.serving.gateway import StatsGateway

from .common import row, write_bench_json

N_USERS = 1024
D = 2
CHUNK = 192             # enough history for welch(64) + the lag carry
PERIOD = 8
HORIZON = 16
NPERSEG = 64
TICKS = 7               # timed query ticks (min reported)


def _session() -> FrameSession:
    sess = FrameSession(d=D, num_users=N_USERS, backend="jnp")
    sess.welch(NPERSEG)
    sess.forecast(HORIZON, model="auto", p=2, max_period=16)
    sess.anomaly_scores(model="ar", p=2)
    return sess


def _seasonal_chunks(rng: np.random.RandomState) -> tuple:
    """Per-tenant seasonal VAR traffic: a shared period, random phase per
    tenant, plus AR(1) noise — and the noiseless continuation for scoring."""
    t = np.arange(CHUNK)
    phases = rng.uniform(0, 2 * np.pi, size=N_USERS)
    base = np.sin(2 * np.pi * t[None, :] / PERIOD + phases[:, None])
    noise = np.zeros((N_USERS, CHUNK, D), np.float32)
    e = 0.1 * rng.randn(N_USERS, CHUNK, D).astype(np.float32)
    for k in range(1, CHUNK):
        noise[:, k] = 0.4 * noise[:, k - 1] + e[:, k]
    chunks = (base[:, :, None] + noise).astype(np.float32)
    t_next = CHUNK + np.arange(HORIZON)
    truth = np.sin(
        2 * np.pi * t_next[None, :] / PERIOD + phases[:, None]
    ).astype(np.float32)  # (N, HORIZON), same for every dim
    return chunks, truth


async def _drive() -> tuple:
    gw = StatsGateway(_session())
    rng = np.random.RandomState(0)
    chunks, truth = _seasonal_chunks(rng)

    async def ingest_tick() -> None:
        futs = [gw.submit_ingest(u, chunks[u]) for u in range(N_USERS)]
        await gw.tick()
        await asyncio.gather(*futs)

    async def forecast_tick() -> tuple:
        futs = [gw.submit_query(u) for u in range(N_USERS)]
        t0 = time.perf_counter()
        await gw.tick()
        dt = time.perf_counter() - t0
        return dt, await asyncio.gather(*futs)

    await ingest_tick()
    await forecast_tick()  # warm-up: traces the vmapped finalize once

    times, results = [], None
    for _ in range(TICKS):
        dt, results = await forecast_tick()
        times.append(dt)
    await gw.stop()
    return times, results, truth


def run() -> None:
    times, results, truth = asyncio.run(_drive())

    preds = np.stack(
        [np.asarray(r["forecast"]["pred"]) for r in results]
    )  # (N, HORIZON, D)
    periods = np.asarray([int(r["forecast"]["period"]) for r in results])
    period_hit = float((periods == PERIOD).mean())
    mae_h = np.abs(preds - truth[:, :, None]).mean(axis=(0, 2))

    payload_results = []

    def bench(name: str, us: float, derived: str) -> None:
        payload_results.append(
            {"name": name, "us_per_call": us, "derived": derived}
        )
        row(f"forecast_{name}", us, derived)

    # min over identical timed ticks — the spread is scheduler/GC noise
    us_tick = min(times) * 1e6
    bench(
        "query_tick", us_tick,
        f"users={N_USERS};horizon={HORIZON};model=auto;programs=1;"
        f"forecasts_per_s={N_USERS / (us_tick / 1e6):.0f}",
    )
    # accuracy rows are informational (CSV + payload), not timing-gated:
    # MAE against the noiseless seasonal truth cannot regress with the
    # clock, so it lives in derived/payload instead of us_per_call
    for h in (1, 4, 8, HORIZON):
        row(f"forecast_mae_h{h}", 0.0,
            f"mae={mae_h[h - 1]:.4f};users={N_USERS};ungated")
    row("forecast_period_detection", 0.0,
        f"hit_rate={period_hit:.3f};period={PERIOD};ungated")

    assert period_hit > 0.95, f"period detection collapsed: {period_hit}"
    assert mae_h[0] < 0.5, f"h=1 MAE blew up: {mae_h[0]}"

    write_bench_json(
        "BENCH_forecast.json",
        {
            "workload": {
                "users": N_USERS, "d": D, "chunk": CHUNK,
                "period": PERIOD, "horizon": HORIZON,
                "nperseg": NPERSEG, "timed_ticks": TICKS,
            },
            "accuracy": {
                "mae_vs_horizon": {
                    str(h): float(mae_h[h - 1]) for h in range(1, HORIZON + 1)
                },
                "period_detection_rate": period_hit,
            },
            "results": payload_results,
        },
    )


if __name__ == "__main__":
    run()
