"""Assemble EXPERIMENTS.md from results/dryrun + results/dryrun_opt + the
handwritten §Perf narrative.  Rerun after refreshing dry-run JSONs.

  PYTHONPATH=src python tools_build_experiments.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

from repro.launch import report
from repro.launch.dryrun import RESULTS_DIR

OPT_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun_opt")

HEADER = """# EXPERIMENTS

Paper: *Embarrassingly Parallel Time Series Analysis for Large Scale Weak
Memory Systems* (Belletti et al.).  This file records (§Repro) the
paper-claim validations, (§Dry-run) the multi-pod compile proof for all 40
assigned (arch × shape) cells on both production meshes, (§Roofline) the
three-term analysis per cell, and (§Perf) the hypothesis→change→measure log
— paper-faithful baseline and beyond-paper optimized variants SEPARATELY.

Hardware model (TPU v5e, per brief): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
4×50 GB/s ICI links/chip.  This container is CPU-only: all numbers are
derived from AOT-compiled artifacts (`.lower().compile()`), not wall time.

**Methodology caveats (verified, see DESIGN.md §8 and launch/costing.py):**
1. `cost_analysis()` counts scan bodies once — all FLOP/byte/wire numbers
   below are *calibrated* by lowering each cell python-unrolled at two
   depths and extrapolating (exact for these homogeneous stacks).
2. `bytes accessed` from the CPU backend is fusion-blind and inserts
   bf16→f32 weight converts that TPUs don't need (MXU reads bf16 natively);
   measured ×~3 inflation on decode cells.  T_memory is therefore an upper
   bound; *relative* changes remain meaningful and are what §Perf reports.
3. Collective wire bytes are parsed from post-SPMD HLO with ring-algorithm
   multipliers (all-reduce 2×, gather/scatter 1×, permute 1×).

## §Repro — paper-claim validation (CPU-run, tests + benchmarks)

| paper claim | result | where |
|---|---|---|
| overlapping blocks reconstruct the series exactly | exact (property-tested over all geometries) | tests/test_overlap.py, test_property_hypothesis.py |
| block map-reduce ≡ serial estimator (the central claim) | exact to f32 roundoff, any (N, P, H), nonlinear kernels incl. | tests/test_mapreduce.py |
| replication overhead = (P−1)·H/N | 2.48% at P=25, H=6, N=200k | examples/quickstart.py |
| autocovariance → Yule-Walker recovers VAR(p) | ‖Â−A‖∞ = 0.0057 at N=2e5 (≈1/√N) | tests/test_estimators.py |
| 1/√N convergence (§2) | fitted exponent −0.49 (YW), −0.43 (MA) | benchmarks/bench_accuracy.py (bench_output.txt) |
| innovation algorithm fits MA(q) (§3.3) | B̂ = 0.5001 vs 0.5, Σ̂ = 0.997 vs 1 at N=3e5 | tests/test_estimators.py |
| ARMA via innovations+Toeplitz (§3.4) | exact from true Ψ; ≤0.05 statistical at N=3e5 | tests/test_estimators.py |
| PACF cuts off after p (§3.1) | AR(2): PACF(3..5) < 0.02 | tests/test_estimators.py |
| Z-estimator GD with 2/(m+L) step (§6.3) | monotone NLL descent, matches least-squares | tests/test_estimators.py |
| SGD with hyperbolic decay (§5.1.3) | ‖Â−A‖∞ < 0.05 in 1200 steps | tests/test_estimators.py |
| banded predictor partition-exact (§6.1) | bit-exact across 2/4/8 partitions | tests/test_spatial_graphs.py |
| block-diag precision separates likelihood (§6.2) | exact | tests/test_spatial_graphs.py |
| graph (H,K) map-reduce ≡ serial (§9) | exact on grid/line graphs | tests/test_spatial_graphs.py |
| traffic DBN is (1,1)-local (§11.1.1) | far perturbations don't affect local updates | tests/test_spatial_graphs.py |
| GPU shared-memory windows (§12, Fig. 9) → VMEM | Pallas window_stats ≡ oracle (interpret=True) | tests/test_kernels.py |
| long-memory reduction by finite-support kernel (§10.3) | truncated (1−L)^d whitens ARFIMA(0,0.4,0): max ρ 0.60 → <0.05 | tests/test_system.py |
| overlap structure reused for spectral estimation (beyond-paper) | Welch PSD: Parseval ±5%, AR(1) spectrum ±10% | tests/test_spectral.py |
| halo exchange ≡ pre-replication (beyond-paper) | bit-identical on 8-device mesh | tests/test_distributed.py |

"""

PERF = open(os.path.join(os.path.dirname(__file__), "EXPERIMENTS_PERF.md")).read()


def cap(fn, *a):
    import io
    from contextlib import redirect_stdout

    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a)
    return buf.getvalue()


def main():
    out = [HEADER]
    out.append("## §Dry-run — baseline (paper-faithful code path)\n")
    out.append("Every runnable cell lowers AND compiles on both meshes; 7 cells/mesh are\n"
               "skipped by the brief's long_500k rule (noted per row).  'fits v5e?' uses\n"
               "peak = args+temp+out vs 16 GB; MoE-400B-class training genuinely needs\n"
               ">256 chips — the dry-run proves the sharding is coherent, the memory row\n"
               "says how much hardware the cell actually requires.\n")
    out.append("### single pod 16×16 (256 chips)\n")
    out.append("\n".join(report.dryrun_table("pod16x16")))
    out.append("\n### multi-pod 2×16×16 (512 chips)\n")
    out.append("\n".join(report.dryrun_table("pod2x16x16")))

    out.append("\n## §Roofline — baseline, single pod, calibrated\n")
    out.append("\n".join(report.roofline_table()))
    out.append("\n### collective schedule (calibrated per-step counts)\n")
    out.append("\n".join(report.collective_table("pod16x16")))

    # optimized tables if present
    if os.path.isdir(OPT_DIR) and len(os.listdir(OPT_DIR)) > 10:
        old = report.RESULTS_DIR
        report.RESULTS_DIR = OPT_DIR
        try:
            out.append("\n## §Dry-run / §Roofline — OPTIMIZED code path "
                       "(sort-dispatch MoE, non-absorbed-MLA train, fused CE, donation)\n")
            out.append("### single pod 16×16\n")
            out.append("\n".join(report.dryrun_table("pod16x16")))
            out.append("\n### multi-pod 2×16×16\n")
            out.append("\n".join(report.dryrun_table("pod2x16x16")))
            out.append("\n### roofline (optimized, calibrated)\n")
            out.append("\n".join(report.roofline_table()))
        finally:
            report.RESULTS_DIR = old

    out.append("\n" + PERF)
    path = os.path.join(os.path.dirname(__file__), "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write("\n".join(out))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
