"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.banded_matvec import ops as bmv
from repro.kernels.swa_attention import ops as swa
from repro.kernels.window_stats import ops as ws

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



# ------------------------------------------------------- window_stats --


@pytest.mark.parametrize("n", [64, 1000, 4097])
@pytest.mark.parametrize("d", [1, 8])
@pytest.mark.parametrize("max_lag", [0, 7])
def test_window_stats_shapes(n, d, max_lag):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    got = ws.lagged_sums(x, max_lag, block_t=128, interpret=True)
    ref = ws.lagged_sums_reference(x, max_lag)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=5e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_window_stats_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 4)).astype(dtype)
    got = ws.lagged_sums(x, 5, block_t=128, interpret=True)
    ref = ws.lagged_sums_reference(x, 5)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-2)


def test_window_stats_lag_equals_block():
    x = jax.random.normal(jax.random.PRNGKey(2), (300, 3))
    got = ws.lagged_sums(x, 16, block_t=16, interpret=True)
    ref = ws.lagged_sums_reference(x, 16)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=5e-3)


def test_window_stats_autocov_matches_core():
    from repro.core.estimators.stats import autocovariance

    x = jax.random.normal(jax.random.PRNGKey(3), (2048, 6))
    got = ws.autocovariance(x, 9, block_t=256, interpret=True)
    ref = autocovariance(x, 9)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------ swa_attention --


@pytest.mark.parametrize("window", [1, 16, 70, 4096])
def test_swa_window_sweep(window):
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 256, 32))
    got = swa.swa_attention(q, k, v, window, block_q=64, block_k=64, interpret=True)
    ref = swa.swa_attention_reference(q, k, v, window)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("s,bq,bk", [(300, 64, 64), (128, 128, 128), (250, 128, 64)])
def test_swa_shape_sweep(s, bq, bk):
    q = jax.random.normal(jax.random.PRNGKey(7), (2, 4, s, 16))
    k = jax.random.normal(jax.random.PRNGKey(8), (2, 4, s, 16))
    v = jax.random.normal(jax.random.PRNGKey(9), (2, 4, s, 16))
    got = swa.swa_attention(q, k, v, 50, block_q=bq, block_k=bk, interpret=True)
    ref = swa.swa_attention_reference(q, k, v, 50)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(10), (1, 2, 128, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(11), (1, 2, 128, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(12), (1, 2, 128, 32)).astype(dtype)
    got = swa.swa_attention(q, k, v, 32, interpret=True)
    ref = swa.swa_attention_reference(q, k, v, 32)
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_swa_matches_chunked_model_path():
    """Kernel == the model's differentiable chunked-halo attention."""
    from repro.models.attention import _chunked_attention

    b, h, s, hd, w = 1, 4, 256, 16, 48
    q = jax.random.normal(jax.random.PRNGKey(13), (b, h, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(14), (b, h, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(15), (b, h, s, hd))
    got = swa.swa_attention(q, k, v, w, block_q=64, block_k=64, interpret=True)
    qg = jnp.moveaxis(q, 1, 2).reshape(b, s, h, 1, hd)  # kvh=h, g=1
    kk = jnp.moveaxis(k, 1, 2)
    vv = jnp.moveaxis(v, 1, 2)
    ref = _chunked_attention(qg, kk, vv, hd**-0.5, window=w, chunk=64)
    ref = jnp.moveaxis(ref.reshape(b, s, h, hd), 1, 2)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=2e-5)


# ------------------------------------------------------ banded_matvec --


@pytest.mark.parametrize("d,b,rows", [(500, 5, 128), (64, 1, 64), (1000, 0, 256), (100, 30, 64)])
def test_banded_sweep(d, b, rows):
    diags = jax.random.normal(jax.random.PRNGKey(16), (d, 2 * b + 1))
    x = jax.random.normal(jax.random.PRNGKey(17), (d, 2))
    got = bmv.banded_matvec(diags, x, block_rows=rows, interpret=True)
    ref = bmv.banded_matvec_reference(diags, x)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_banded_1d_and_dense_oracle():
    from repro.core.estimators.spatial import banded_to_dense

    d, b = 200, 3
    diags = jax.random.normal(jax.random.PRNGKey(18), (d, 2 * b + 1)) * 0.3
    rows = np.arange(d)[:, None]
    cols = rows + np.arange(-b, b + 1)[None, :]
    diags = diags * jnp.asarray((cols >= 0) & (cols < d))
    x = jax.random.normal(jax.random.PRNGKey(19), (d,))
    got = bmv.banded_matvec(diags, x, block_rows=64, interpret=True)
    dense = banded_to_dense(diags)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)
