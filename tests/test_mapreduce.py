"""Weak-memory map-reduce engine — the paper's central equivalence:
block-parallel reduction over overlapping partitions == serial estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapreduce import (
    block_window_map_reduce,
    serial_window_map_reduce,
)
from repro.core.overlap import OverlapSpec


def kernels():
    return {
        "outer": lambda w: jnp.outer(w[0], w[-1]),
        "nonlinear": lambda w: jnp.sum(jnp.tanh(w)) ** 2,
        "pytree": lambda w: {"a": jnp.sum(w), "b": (w[0] * w[-1], jnp.max(w))},
    }


@pytest.mark.parametrize("name", ["outer", "nonlinear"])
@pytest.mark.parametrize("n,bs,hl,hr", [(500, 64, 2, 3), (500, 100, 0, 8), (333, 50, 5, 0)])
def test_blocked_equals_serial(name, n, bs, hl, hr):
    kern = kernels()[name]
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 3))
    s = serial_window_map_reduce(kern, x, hl, hr)
    b = block_window_map_reduce(kern, x, OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr))
    jax.tree.map(lambda a, c: np.testing.assert_allclose(a, c, rtol=2e-5, atol=2e-4), s, b)


def test_pytree_kernel():
    kern = kernels()["pytree"]
    x = jax.random.normal(jax.random.PRNGKey(1), (200, 2))
    s = serial_window_map_reduce(kern, x, 1, 1)
    b = block_window_map_reduce(kern, x, OverlapSpec(n=200, block_size=32, h_left=1, h_right=1))
    np.testing.assert_allclose(s["a"], b["a"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(s["b"][0], b["b"][0], rtol=1e-5, atol=1e-4)


def test_gradient_flows_through_blocked_path():
    """Z-estimators need d/dθ of the blocked reduction (paper §7.2)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (300, 2))
    spec = OverlapSpec(n=300, block_size=64, h_left=2, h_right=0)

    def obj(a):
        kern = lambda w: jnp.sum((w[-1] - a @ w[0]) ** 2)
        return block_window_map_reduce(kern, x, spec)

    def obj_serial(a):
        kern = lambda w: jnp.sum((w[-1] - a @ w[0]) ** 2)
        return serial_window_map_reduce(kern, x, 2, 0)

    a0 = jnp.eye(2) * 0.3
    g1 = jax.grad(obj)(a0)
    g2 = jax.grad(obj_serial)(a0)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-4)
