"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core.differencing import difference, integrate
from repro.core.mapreduce import block_window_map_reduce, serial_window_map_reduce
from repro.core.overlap import OverlapSpec, make_overlapping_blocks, reconstruct
from repro.training.compression import compress_int8, decompress_int8

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(8, 300),
    bs=st.integers(1, 64),
    hl=st.integers(0, 8),
    hr=st.integers(0, 8),
    d=st.integers(1, 4),
)
@settings(**SETTINGS)
def test_overlap_roundtrip_any_geometry(n, bs, hl, hr, d):
    """make_overlapping_blocks ∘ reconstruct == id for every admissible spec."""
    x = jax.random.normal(jax.random.PRNGKey(n * 7 + bs), (n, d))
    spec = OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    blocks, _ = make_overlapping_blocks(x, spec)
    np.testing.assert_array_equal(np.asarray(reconstruct(blocks, spec)), np.asarray(x))


@given(
    n=st.integers(20, 200),
    bs=st.integers(4, 50),
    hl=st.integers(0, 5),
    hr=st.integers(0, 5),
)
@settings(**SETTINGS)
def test_blocked_reduction_equals_serial_any_geometry(n, bs, hl, hr):
    """The paper's central claim, as a property over all geometries."""
    if n - hl - hr <= 0:
        return
    x = jax.random.normal(jax.random.PRNGKey(n * 13 + bs), (n, 2))
    kern = lambda w: (jnp.sum(w * w), jnp.outer(w[0], w[-1]))
    s = serial_window_map_reduce(kern, x, hl, hr)
    b = block_window_map_reduce(
        kern, x, OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    )
    np.testing.assert_allclose(s[0], b[0], rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(s[1], b[1], rtol=1e-4, atol=1e-3)


@given(order=st.integers(1, 3), n=st.integers(10, 100))
@settings(**SETTINGS)
def test_difference_integrate_inverse(order, n):
    if n <= order:
        return
    x = jnp.cumsum(jax.random.normal(jax.random.PRNGKey(n), (n, 2)), axis=0)
    dx = difference(x, order)
    initial = jnp.stack([difference(x, k)[0] for k in range(order)])
    back = integrate(dx, initial, order)
    # repeated f32 cumsum amplifies roundoff with order; scale the tolerance
    scale = float(jnp.max(jnp.abs(x))) * n ** (order - 1) + 1.0
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5 * scale)


@given(scale=st.floats(1e-3, 1e3), n=st.integers(10, 2000))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(scale, n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    codes, s = compress_int8(x)
    back = decompress_int8(codes, s, x.shape)
    blockmax = np.asarray(s).reshape(-1) * 127.0
    err = np.abs(np.asarray(back - x))
    per_block_bound = np.repeat(np.asarray(s).reshape(-1), 256)[:n] * 0.5 + 1e-9
    assert (err <= per_block_bound).all()


@given(
    dims=st.lists(st.sampled_from([2, 3, 4, 6, 8, 16, 30]), min_size=1, max_size=3)
)
@settings(**SETTINGS)
def test_logical_spec_divisibility_fallback(dims):
    """logical_to_spec never produces a spec whose mesh axes don't divide."""
    import math

    from repro.parallel.sharding import abstract_mesh, logical_to_spec, mesh_axis_size

    mesh = abstract_mesh((2, 2), ("data", "model"))
    spec = logical_to_spec(["batch", "heads", "ff"][: len(dims)], dims, mesh)
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        assert dim % mesh_axis_size(mesh, names) == 0


# -------------------------------------------------- data-plane integrity


def _integrity_plan(compensated=False):
    from repro.core.plan import (
        autocovariance_request,
        fused_engine,
        moments_request,
    )

    return fused_engine(
        [autocovariance_request(2), moments_request(4)],
        d=2,
        backend="jnp",
        compensated=compensated,
    )


def _finite_mask(states):
    """The poisoned-lane fingerprint: finiteness of every stat leaf."""
    return [
        np.isfinite(np.asarray(leaf, np.float64))
        for st_ in states
        for leaf in jax.tree.leaves(st_.stat)
    ]


@given(
    scales=st.lists(
        st.sampled_from([1.0, 1e30, 1e-30, -1e30, float("nan"), float("inf")]),
        min_size=3,
        max_size=6,
    ),
    seed=st.integers(0, 100),
)
@settings(**SETTINGS)
def test_merge_order_never_changes_which_lanes_are_poisoned(scales, seed):
    """⊕ is a monoid even at the edge of f32: whatever non-finiteness a
    chunk introduces (NaN/Inf data, or ±1e30 squaring to overflow inside
    the chunk kernel), the set of poisoned stat entries after folding is a
    property of the CHUNKS, not of the fold shape — left fold, right fold,
    and balanced merge trees all poison exactly the same entries.  This is
    what makes `audit()`'s verdict deterministic under re-sharding."""
    plan = _integrity_plan()
    rng = np.random.RandomState(seed)
    chunks = [
        jnp.asarray((rng.randn(16, 2) * s).astype(np.float32)) for s in scales
    ]
    parts = [plan.from_chunk(c) for c in chunks]

    def fold_left(ps):
        acc = ps[0]
        for p in ps[1:]:
            acc = plan.merge(acc, p)
        return acc

    def fold_right(ps):
        acc = ps[-1]
        for p in ps[-2::-1]:
            acc = plan.merge(p, acc)
        return acc

    def fold_tree(ps):
        while len(ps) > 1:
            nxt = [
                plan.merge(ps[i], ps[i + 1]) if i + 1 < len(ps) else ps[i]
                for i in range(0, len(ps), 2)
            ]
            ps = nxt
        return ps[0]

    masks = [_finite_mask(fold(list(parts)))
             for fold in (fold_left, fold_right, fold_tree)]
    for other in masks[1:]:
        for a, b in zip(masks[0], other):
            np.testing.assert_array_equal(a, b)


@given(
    n_chunks=st.integers(4, 64),
    offset=st.floats(100.0, 5000.0),
    seed=st.integers(0, 50),
)
@settings(**SETTINGS)
def test_compensated_tracks_f64_oracle(n_chunks, offset, seed):
    """Neumaier-compensated chunked ingest of hostile (large-offset) data
    stays within f32-roundoff-of-the-*answer* of the exact float64 serial
    lag sums, independent of how many chunk-boundary ⊕-folds the stream
    crossed — the drift a plain f32 fold accumulates per merge is exactly
    what the error companions recapture."""
    chunk = 64
    rng = np.random.RandomState(seed)
    x = (offset + rng.randn(n_chunks * chunk, 2)).astype(np.float32)
    plan = _integrity_plan(compensated=True)
    states = plan.init()
    for off in range(0, x.shape[0], chunk):
        states = plan.update_jit(states, jnp.asarray(x[off:off + chunk]))
    got = np.asarray(plan.finalize(states)["autocovariance"], np.float64)

    x64 = x.astype(np.float64)
    n = x64.shape[0]
    want = np.stack(
        [(x64[: n - h].T @ x64[h:]) / max(n - h - 1, 1) for h in range(3)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(h=st.integers(0, 6), n=st.integers(30, 120))
@settings(**SETTINGS)
def test_autocov_transpose_symmetry(h, n):
    """γ̂(-h) = γ̂(h)ᵀ consistency: raw sums S(h) of x equal S(h)ᵀ of reversed x."""
    from repro.core.estimators.stats import raw_lag_sums

    if h >= n - 1:
        return
    x = jax.random.normal(jax.random.PRNGKey(h * 31 + n), (n, 3))
    s = raw_lag_sums(x, h)[-1]
    s_rev = raw_lag_sums(x[::-1], h)[-1]
    np.testing.assert_allclose(s, s_rev.T, rtol=1e-4, atol=1e-3)
