"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core.differencing import difference, integrate
from repro.core.mapreduce import block_window_map_reduce, serial_window_map_reduce
from repro.core.overlap import OverlapSpec, make_overlapping_blocks, reconstruct
from repro.training.compression import compress_int8, decompress_int8

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(8, 300),
    bs=st.integers(1, 64),
    hl=st.integers(0, 8),
    hr=st.integers(0, 8),
    d=st.integers(1, 4),
)
@settings(**SETTINGS)
def test_overlap_roundtrip_any_geometry(n, bs, hl, hr, d):
    """make_overlapping_blocks ∘ reconstruct == id for every admissible spec."""
    x = jax.random.normal(jax.random.PRNGKey(n * 7 + bs), (n, d))
    spec = OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    blocks, _ = make_overlapping_blocks(x, spec)
    np.testing.assert_array_equal(np.asarray(reconstruct(blocks, spec)), np.asarray(x))


@given(
    n=st.integers(20, 200),
    bs=st.integers(4, 50),
    hl=st.integers(0, 5),
    hr=st.integers(0, 5),
)
@settings(**SETTINGS)
def test_blocked_reduction_equals_serial_any_geometry(n, bs, hl, hr):
    """The paper's central claim, as a property over all geometries."""
    if n - hl - hr <= 0:
        return
    x = jax.random.normal(jax.random.PRNGKey(n * 13 + bs), (n, 2))
    kern = lambda w: (jnp.sum(w * w), jnp.outer(w[0], w[-1]))
    s = serial_window_map_reduce(kern, x, hl, hr)
    b = block_window_map_reduce(
        kern, x, OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    )
    np.testing.assert_allclose(s[0], b[0], rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(s[1], b[1], rtol=1e-4, atol=1e-3)


@given(order=st.integers(1, 3), n=st.integers(10, 100))
@settings(**SETTINGS)
def test_difference_integrate_inverse(order, n):
    if n <= order:
        return
    x = jnp.cumsum(jax.random.normal(jax.random.PRNGKey(n), (n, 2)), axis=0)
    dx = difference(x, order)
    initial = jnp.stack([difference(x, k)[0] for k in range(order)])
    back = integrate(dx, initial, order)
    # repeated f32 cumsum amplifies roundoff with order; scale the tolerance
    scale = float(jnp.max(jnp.abs(x))) * n ** (order - 1) + 1.0
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5 * scale)


@given(scale=st.floats(1e-3, 1e3), n=st.integers(10, 2000))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(scale, n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    codes, s = compress_int8(x)
    back = decompress_int8(codes, s, x.shape)
    blockmax = np.asarray(s).reshape(-1) * 127.0
    err = np.abs(np.asarray(back - x))
    per_block_bound = np.repeat(np.asarray(s).reshape(-1), 256)[:n] * 0.5 + 1e-9
    assert (err <= per_block_bound).all()


@given(
    dims=st.lists(st.sampled_from([2, 3, 4, 6, 8, 16, 30]), min_size=1, max_size=3)
)
@settings(**SETTINGS)
def test_logical_spec_divisibility_fallback(dims):
    """logical_to_spec never produces a spec whose mesh axes don't divide."""
    import math

    from repro.parallel.sharding import abstract_mesh, logical_to_spec, mesh_axis_size

    mesh = abstract_mesh((2, 2), ("data", "model"))
    spec = logical_to_spec(["batch", "heads", "ff"][: len(dims)], dims, mesh)
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        assert dim % mesh_axis_size(mesh, names) == 0


@given(h=st.integers(0, 6), n=st.integers(30, 120))
@settings(**SETTINGS)
def test_autocov_transpose_symmetry(h, n):
    """γ̂(-h) = γ̂(h)ᵀ consistency: raw sums S(h) of x equal S(h)ᵀ of reversed x."""
    from repro.core.estimators.stats import raw_lag_sums

    if h >= n - 1:
        return
    x = jax.random.normal(jax.random.PRNGKey(h * 31 + n), (n, 3))
    s = raw_lag_sums(x, h)[-1]
    s_rev = raw_lag_sums(x[::-1], h)[-1]
    np.testing.assert_allclose(s, s_rev.T, rtol=1e-4, atol=1e-3)
