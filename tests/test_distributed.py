"""Distributed tests — run in a subprocess with 8 host devices so the main
pytest process keeps its single device (brief requirement)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=900,
        env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_store_and_halo_modes():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.timeseries.dataset import TimeSeriesStore
        from repro.core.mapreduce import serial_window_map_reduce
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (8*128, 3))
        kern = lambda w: jnp.outer(w[0], w[-1])
        s0 = serial_window_map_reduce(kern, x, 2, 3)
        for mode in ("replicate", "exchange"):
            st = TimeSeriesStore.from_series(x, 128, 2, 3, mesh=mesh, halo_mode=mode)
            r = st.map_reduce(kern)
            err = float(jnp.max(jnp.abs(r - s0)))
            assert err < 1e-3, (mode, err)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_autocovariance_exact():
    out = _run("""
        import jax
        import jax.numpy as jnp
        from repro.core.estimators.stats import autocovariance, autocovariance_sharded
        from repro.timeseries.dataset import TimeSeriesStore
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(1), (8*256, 4))
        st = TimeSeriesStore.from_series(x, 256, 0, 6, mesh=mesh)
        g = autocovariance_sharded(st.blocks, st.spec, 6, mesh)
        ref = autocovariance(x, 6)
        assert float(jnp.max(jnp.abs(g - ref))) < 1e-4
        print("OK")
    """)
    assert "OK" in out


def test_halo_exchange_equals_replication():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.halo import halo_exchange
        from repro.core.overlap import OverlapSpec, make_overlapping_blocks
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        n, d = 8*64, 3
        x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
        hl, hr = 4, 5
        # replication-mode blocks with block_size = local shard size
        spec = OverlapSpec(n=n, block_size=64, h_left=hl, h_right=hr)
        blocks_ref, _ = make_overlapping_blocks(x, spec)
        def f(x_local):
            return halo_exchange(x_local, hl, hr, "data")
        padded = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False))(x)
        padded = padded.reshape(8, hl + 64 + hr, d)
        assert float(jnp.max(jnp.abs(padded - blocks_ref))) == 0.0
        print("OK")
    """)
    assert "OK" in out


def test_train_step_on_mesh():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCHS
        from repro.models import init_params
        from repro.parallel import sharding as shr
        from repro.training.optimizer import adamw_init
        from repro.training.train_step import make_train_step
        mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
        r = ARCHS["qwen3-0.6b"].reduced()
        with mesh, jax.sharding.set_mesh(mesh):
            params = init_params(jax.random.PRNGKey(0), r, dtype=jnp.float32)
            pspecs = shr.param_pspecs(params, mesh)
            params = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
            opt = adamw_init(params)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, r.vocab)
            batch = {"tokens": jax.device_put(toks, NamedSharding(mesh, P("data", None))),
                     "labels": jax.device_put(toks, NamedSharding(mesh, P("data", None)))}
            step = jax.jit(make_train_step(r, lr_fn=1e-3))
            params, opt, m = step(params, opt, batch)
            assert jnp.isfinite(m["loss"])
            # loss equals single-device computation
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out


def test_sharded_matches_single_device_loss():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCHS
        from repro.models import init_params
        from repro.training.train_step import loss_fn
        r = ARCHS["qwen3-0.6b"].reduced()
        params = init_params(jax.random.PRNGKey(0), r, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, r.vocab)
        batch = {"tokens": toks, "labels": toks}
        l_single, _ = jax.jit(lambda p, b: loss_fn(p, b, r))(params, batch)
        mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with mesh, jax.sharding.set_mesh(mesh):
            pb = {k: jax.device_put(v, NamedSharding(mesh, P("data", None))) for k, v in batch.items()}
            l_mesh, _ = jax.jit(lambda p, b: loss_fn(p, b, r))(params, pb)
        diff = abs(float(l_single) - float(l_mesh))
        assert diff < 1e-3, diff
        print("OK", diff)
    """)
    assert "OK" in out


def test_build_cell_lowers_on_test_mesh():
    """Miniature dry-run inside the test suite: one cell per step kind."""
    out = _run("""
        import dataclasses, jax
        from repro.configs.registry import QWEN3_0_6B
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import build_cell
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(4, 2)
        cfg = dataclasses.replace(QWEN3_0_6B, n_layers=2)
        for shape in (ShapeConfig("t", 256, 8, "train"),
                      ShapeConfig("p", 256, 8, "prefill"),
                      ShapeConfig("d", 256, 8, "decode"),
                      ShapeConfig("sp", 2048, 1, "decode")):
            cell = build_cell(cfg, shape, mesh)
            compiled = cell.lower().compile()
            assert compiled.cost_analysis() is not None
        print("OK")
    """)
    assert "OK" in out
