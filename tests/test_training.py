"""Training substrate: optimizer math, accumulation equivalence, loss descent,
gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.tokens import SyntheticTokenPipeline
from repro.models import init_params
from repro.training.compression import compress_int8, decompress_int8
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule, global_norm
from repro.training.train_step import make_train_step

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def test_adamw_first_step_is_lr_signed():
    """With bias correction, |Δp| of step 1 ≈ lr·sign(g) (wd=0)."""
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    st = adamw_init(p)
    new_p, _ = adamw_update(g, st, p, lr=0.01, weight_decay=0.0, clip_norm=None)
    np.testing.assert_allclose(
        np.abs(np.asarray(p["w"] - new_p["w"])), 0.01, rtol=1e-3
    )


def test_grad_clipping():
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw_init(p)
    _, st2 = adamw_update(g, st, p, lr=0.0, clip_norm=1.0)
    assert float(global_norm(st2.m)) <= 0.11  # (1-b1)·clipped ≤ 0.1·1.0


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=110)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-9)


def test_microbatch_accumulation_matches_full_batch():
    r = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(jax.random.PRNGKey(0), r, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, r.vocab)
    batch = {"tokens": toks, "labels": toks}
    opt = adamw_init(params)
    p1, _, m1 = jax.jit(make_train_step(r, lr_fn=1e-3, accum=1))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(r, lr_fn=1e-3, accum=2))(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 1e-4


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "xlstm-125m"])
def test_loss_decreases(arch):
    r = ARCHS[arch].reduced()
    pipe = SyntheticTokenPipeline(vocab=r.vocab, seq_len=32, global_batch=8, seed=1)
    params = init_params(jax.random.PRNGKey(2), r, dtype=jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(r, lr_fn=3e-3))
    losses = []
    for i in range(30):
        hb = pipe.host_batch(i)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_pipeline_determinism():
    p1 = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
    p2 = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
    b1, b2 = p1.host_batch(42), p2.host_batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.host_batch(43)["tokens"], b1["tokens"])


# ------------------------------------------------------- compression --


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(3), (1000,)) * 3.0
    codes, scale = compress_int8(x)
    back = decompress_int8(codes, scale, x.shape)
    # error per element bounded by half a quantization step of its block
    err = np.abs(np.asarray(back - x))
    step = np.repeat(np.asarray(scale).reshape(-1), 256)[: x.size]
    assert (err <= step * 0.5 + 1e-7).all()


def test_error_feedback_allreduce_unbiased_over_steps():
    """Mean compressed gradient + residual carry ≈ exact mean over time."""
    from repro.training.compression import error_feedback_allreduce

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device psum: axis of size 1 via shard_map on a trivial mesh
    mesh = jax.make_mesh((1,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (512,))}
    r = {"w": jnp.zeros((512,))}

    def f(g, r):
        return error_feedback_allreduce(g, r, "d")

    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import shard_map_compat

    fm = jax.jit(
        shard_map_compat(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    )
    acc_exact = jnp.zeros((512,))
    acc_comp = jnp.zeros((512,))
    for i in range(10):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(10 + i), (512,))}
        red, r = fm(gi, r)
        acc_exact += gi["w"]
        acc_comp += red["w"]
    # accumulated compressed-with-feedback sum tracks the exact sum closely
    rel = float(jnp.linalg.norm(acc_comp + r["w"] - acc_exact) / jnp.linalg.norm(acc_exact))
    assert rel < 1e-2
