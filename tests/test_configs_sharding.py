"""Assigned-config exactness (brief numbers) + sharding rule unit tests."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES_BY_NAME, get_arch
from repro.parallel import sharding as shr


def test_brief_numbers_exact():
    c = ARCHS["llama4-maverick-400b-a17b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == (48, 5120, 40, 8, 202048)
    assert (c.moe.num_experts, c.moe.top_k, c.moe.d_ff_expert) == (128, 1, 8192)

    c = ARCHS["deepseek-v2-236b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128, 102400)
    assert (c.mla.kv_lora_rank, c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (512, 160, 6, 2)
    assert c.d_ff == 1536

    c = ARCHS["glm4-9b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (40, 4096, 32, 2, 13696, 151552)

    c = ARCHS["qwen3-0.6b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (28, 1024, 16, 8, 3072, 151936)
    assert c.qk_norm

    c = ARCHS["h2o-danube-1.8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (24, 2560, 32, 8, 6912, 32000)
    assert c.swa_window is not None

    c = ARCHS["phi3-medium-14b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (40, 5120, 40, 10, 17920, 100352)

    c = ARCHS["whisper-base"]
    assert (c.n_layers, c.enc_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (6, 6, 512, 8, 2048, 51865)

    c = ARCHS["llava-next-34b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (60, 7168, 56, 8, 20480, 64000)

    c = ARCHS["xlstm-125m"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (12, 768, 4, 50304)
    assert c.d_ff == 0

    c = ARCHS["zamba2-7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (81, 3584, 32, 14336, 32000)
    assert c.ssm.state_dim == 64 and c.shared_attn_every == 6


def test_shape_suites_exact():
    s = SHAPES_BY_NAME
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_aliases():
    assert get_arch("llama4").name == "llama4-maverick-400b-a17b"
    with pytest.raises(KeyError):
        get_arch("nope")


@pytest.fixture
def mesh22():
    # AbstractMesh: sharding-rule tests need only axis names/sizes, not devices
    return shr.abstract_mesh((2, 2), ("data", "model"))


def test_logical_to_spec_basic(mesh22):
    spec = shr.logical_to_spec(("batch", "heads"), (8, 8), mesh22)
    assert spec == P("data", "model")
    # divisibility fallback: 7 not divisible by 2 → replicated dim
    spec = shr.logical_to_spec(("batch", "heads"), (7, 8), mesh22)
    assert spec == P(None, "model")


def test_sp_mode_switch(mesh22):
    shr.set_sp_mode(True)
    try:
        spec = shr.logical_to_spec(("batch", "seq"), (1, 64), mesh22)
        assert spec == P(None, "data")
    finally:
        shr.set_sp_mode(False)
    spec = shr.logical_to_spec(("batch", "seq"), (4, 64), mesh22)
    assert spec == P("data", None)


def test_param_pspecs_rules(mesh22):
    params = {
        "layers": {
            "attn": {"wq": jnp.zeros((4, 8, 8)), "wo": jnp.zeros((4, 8, 8))},
            "mlp": {"w_gate": jnp.zeros((4, 8, 16)), "w_down": jnp.zeros((4, 16, 8))},
        },
        "embed": jnp.zeros((100, 8)),
    }
    specs = shr.param_pspecs(params, mesh22)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)


def test_zero1_adds_data_axis(mesh22):
    params = {"w_gate": jnp.zeros((8, 16))}
    z = shr.zero1_pspecs(params, mesh22)
    assert z["w_gate"] == P("data", "model")  # ff→model, zero1 puts data on dim0


def test_no_mesh_shard_is_noop():
    x = jnp.zeros((4, 4))
    assert shr.shard(x, ("batch", None)) is x
