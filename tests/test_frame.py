"""SeriesFrame / FrameSession: the lazy placement-aware session front door.

Pins the `repro.core.frame` layer (PR 4 acceptance):
  * for every placement (array / chunks / sharded) and backend, N deferred
    requests ``.collect()`` in exactly ONE series-sized traversal (counting
    backend) and match the independent eager estimator calls;
  * results are memoized — a repeated ``.collect()`` with no ingest makes
    ZERO new primitive calls (the StatPlan per-member result cache);
  * ``.append`` + re-collect equals recomputing on the concatenated series
    and never re-reads history (no traversal of the old samples);
  * ``FrameSession`` multi-tenant queries equal dedicated per-user
    ``SeriesFrame``s, across ingest lanes;
  * the sliding-window eviction mode serves statistics equal to a recompute
    from only the retained window, across jnp/pallas backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import Deferred, FrameSession, SeriesFrame
from repro.core.backend import get_backend
from repro.core.estimators.arma import fit_arma
from repro.core.estimators.spectral import welch_psd
from repro.core.estimators.stats import (
    autocovariance,
    moment_engine,
    streaming_window_moments,
)
from repro.core.estimators.yule_walker import yule_walker
from repro.core.mapreduce import serial_window_map_reduce
from repro.timeseries import TimeSeriesStore

N, D = 3000, 2
BLOCK = 512  # sharded-placement core size
BIG = 256    # calls walking ≥ this many rows count as series traversals


def _series(n=N, d=D, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def _make_frame(placement, x, backend=None):
    if placement == "array":
        return SeriesFrame.from_array(x, backend=backend)
    if placement == "chunks":
        cuts = [0, 500, 1000, 1500, 1501, x.shape[0]]
        chunks = [x[a:b] for a, b in zip(cuts, cuts[1:])]
        return SeriesFrame.from_chunks(chunks, backend=backend)
    if placement == "sharded":
        return SeriesFrame.from_sharded(x, block_size=BLOCK, backend=backend)
    if placement == "sharded_mesh":
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        return SeriesFrame.from_sharded(
            x, mesh=mesh, block_size=BLOCK, backend=backend
        )
    raise ValueError(placement)


def _defer_all(frame):
    """The acceptance request set: lag family + two moment windows + Welch."""
    return {
        "autocovariance": frame.autocovariance(8),
        "yule_walker": frame.yule_walker(4),
        "moments": frame.moments(32),
        "moments_2": frame.moments(16),
        "welch": frame.welch(nperseg=64, overlap=32),
    }


def _eager(x):
    """The same five statistics by independent estimator calls (jnp)."""
    me32 = moment_engine(32, x.shape[1], backend="jnp")
    me16 = moment_engine(16, x.shape[1], backend="jnp")
    return {
        "autocovariance": autocovariance(x, 8, backend="jnp"),
        "yule_walker": yule_walker(x, 4, backend="jnp"),
        "moments": streaming_window_moments(me32, me32.from_chunk(x)),
        "moments_2": streaming_window_moments(me16, me16.from_chunk(x)),
        "welch": welch_psd(x, nperseg=64, overlap=32, backend="jnp"),
    }


def _assert_matches(got, want):
    np.testing.assert_allclose(
        got["autocovariance"], want["autocovariance"], rtol=1e-5, atol=1e-4
    )
    for g, w in zip(got["yule_walker"], want["yule_walker"]):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)
    for key in ("moments", "moments_2"):
        for stat in ("mean", "var", "count"):
            np.testing.assert_allclose(
                got[key][stat], want[key][stat], rtol=1e-5, atol=1e-6
            )
    np.testing.assert_allclose(got["welch"][0], want["welch"][0], rtol=1e-6)
    np.testing.assert_allclose(got["welch"][1], want["welch"][1], rtol=1e-4, atol=1e-5)


class CountingBackend:
    """Delegating backend recording (primitive, rows walked) per invocation
    (mirrors tests/test_plan.py; fused moments may take a window tuple)."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.calls = []

    def lagged_sums(self, x, max_lag):
        self.calls.append(("lagged_sums", int(x.shape[0])))
        return self._inner.lagged_sums(x, max_lag)

    def masked_lagged_sums(self, y, mask, max_lag):
        self.calls.append(("masked_lagged_sums", int(mask.shape[0])))
        return self._inner.masked_lagged_sums(y, mask, max_lag)

    def windowed_moments(self, x, window):
        self.calls.append(("windowed_moments", int(x.shape[0])))
        return self._inner.windowed_moments(x, window)

    def segment_fft_power(self, segments, taper, detrend=True):
        self.calls.append(
            ("segment_fft_power", int(segments.shape[0] * segments.shape[1]))
        )
        return self._inner.segment_fft_power(segments, taper, detrend)

    def banded_matvec(self, diags, x):
        self.calls.append(("banded_matvec", int(diags.shape[0])))
        return self._inner.banded_matvec(diags, x)

    def fused_lagged_moments(self, y, mask, max_lag, window):
        self.calls.append(("fused_lagged_moments", int(mask.shape[0])))
        return self._inner.fused_lagged_moments(y, mask, max_lag, window)

    def big_walks(self, threshold=BIG):
        """Traced primitive calls that walked ≥ threshold series rows
        (segment FFTs excluded: they consume windows a traversal already
        gathered)."""
        return [
            c
            for c in self.calls
            if c[1] >= threshold and c[0] != "segment_fft_power"
        ]


PLACEMENTS = ["array", "chunks", "sharded", "sharded_mesh"]


# ------------------------------------------------- collect ≡ eager, 1 traversal


@pytest.mark.backend
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("placement", PLACEMENTS)
def test_collect_equals_eager(placement, backend):
    x = _series()
    frame = _make_frame(placement, x, backend=backend)
    handles = _defer_all(frame)
    got = frame.collect()
    assert set(got) == set(handles)
    _assert_matches(got, _eager(x))
    # deferred handles read the same (memoized) results
    np.testing.assert_allclose(
        handles["autocovariance"].result(), got["autocovariance"]
    )


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_collect_is_one_traversal(placement):
    """Five deferred requests (two distinct moment windows!) collect in ONE
    fused traversal: the only primitive that walks series-scale data is
    ``fused_lagged_moments``, exactly once per ingest program — never the
    per-statistic ``lagged_sums`` / ``windowed_moments`` walks."""
    x = _series()
    counting = CountingBackend(get_backend("jnp"))
    frame = _make_frame(placement, x, backend=counting)
    _defer_all(frame)
    got = frame.collect()
    _assert_matches(got, _eager(x))

    assert all(p != "lagged_sums" for p, _ in counting.calls)
    assert all(p != "windowed_moments" for p, _ in counting.calls)
    walks = counting.big_walks()
    # the ONLY series-scale primitive is the fused one, and the traced
    # ingest programs together read each sample once (≤ n rows total)
    assert {p for p, _ in walks} == {"fused_lagged_moments"}
    assert sum(r for _, r in walks) <= N
    if placement == "array":
        assert walks == [("fused_lagged_moments", N)]
    # everything else is halo-sized (merge straddles, finalize corrections)
    small = [
        r for p, r in counting.calls
        if p == "masked_lagged_sums"
    ]
    assert all(r < 64 for r in small)


def test_eager_baseline_is_n_traversals():
    """The baseline the frame removes: independent estimator calls walk the
    series once each."""
    x = _series()
    counting = CountingBackend(get_backend("jnp"))
    autocovariance(x, 8, backend=counting)
    yule_walker(x, 4, backend=counting)
    me = moment_engine(32, x.shape[1], backend=counting)
    streaming_window_moments(me, me.from_chunk(x))
    assert len(counting.big_walks(N)) >= 3


# ------------------------------------------------------------- memoization


@pytest.mark.parametrize("placement", ["array", "chunks", "sharded"])
def test_repeated_collect_makes_zero_calls(placement):
    """Per-member results are cached between queries when no ingest
    happened: a repeated .collect() (or Deferred.result()) is free."""
    x = _series()
    counting = CountingBackend(get_backend("jnp"))
    frame = _make_frame(placement, x, backend=counting)
    handles = _defer_all(frame)
    first = frame.collect()
    counting.calls.clear()
    again = frame.collect()
    assert counting.calls == []
    np.testing.assert_allclose(
        again["autocovariance"], first["autocovariance"]
    )
    handles["welch"].result()
    assert counting.calls == []


def test_statplan_finalize_cache_direct():
    """StatPlan.finalize itself memoizes per states-tuple identity; ingest
    produces fresh states and invalidates."""
    from repro.core.plan import StatPlan, autocovariance_request

    x = _series(n=800)
    counting = CountingBackend(get_backend("jnp"))
    plan = StatPlan([autocovariance_request(4)], d=D, backend=counting)
    states = plan.from_chunk(x)
    out1 = plan.finalize(states)
    counting.calls.clear()
    out2 = plan.finalize(states)
    assert counting.calls == []  # cache hit: no finalize corrections re-run
    np.testing.assert_allclose(out1["autocovariance"], out2["autocovariance"])
    states2 = plan.update(states, _series(n=64, seed=3))
    plan.finalize(states2)
    assert counting.calls != []  # fresh states → recompute


# ------------------------------------------------------- append / incremental


@pytest.mark.parametrize("placement", ["array", "chunks", "sharded"])
def test_append_recollect_equals_concat(placement):
    """.append folds into the carried fused PartialState: re-collect equals
    recomputing on the concatenated series, WITHOUT re-reading history."""
    x = _series()
    extra = [_series(n=97, seed=5), _series(n=300, seed=6)]
    counting = CountingBackend(get_backend("jnp"))
    frame = _make_frame(placement, x, backend=counting)
    _defer_all(frame)
    frame.collect()

    counting.calls.clear()
    for chunk in extra:
        frame.append(chunk)
    got = frame.collect()
    # incremental: every primitive call walked at most one appended chunk,
    # never the n = 3000 sample history (segment FFTs consume windows the
    # chunk walk already gathered — overlap double-counts their rows)
    assert all(
        rows <= 300
        for p, rows in counting.calls
        if p != "segment_fft_power"
    )
    assert all(rows < N for p, rows in counting.calls)
    _assert_matches(got, _eager(jnp.concatenate([x] + extra)))


def test_append_before_first_collect():
    x, y = _series(n=1000, seed=1), _series(n=123, seed=2)
    frame = SeriesFrame.from_array(x)
    frame.autocovariance(6)
    frame.append(y)
    got = frame.collect()
    np.testing.assert_allclose(
        got["autocovariance"],
        autocovariance(jnp.concatenate([x, y]), 6),
        rtol=1e-5,
        atol=1e-4,
    )


def test_new_requests_replan_on_array_and_raise_on_chunks():
    x = _series(n=900, seed=4)
    frame = SeriesFrame.from_array(x)
    frame.autocovariance(4)
    frame.collect()
    frame.moments(16)  # new request after a collect: array replans
    got = frame.collect()
    me = moment_engine(16, D, backend="jnp")
    want = streaming_window_moments(me, me.from_chunk(x))
    np.testing.assert_allclose(got["moments"]["mean"], want["mean"], rtol=1e-5)

    stream = SeriesFrame.from_chunks([x[:500], x[500:]])
    stream.autocovariance(4)
    stream.collect()
    stream.moments(16)
    with pytest.raises(ValueError, match="weak memory"):
        stream.collect()


# -------------------------------------------------- donated append hot path


@pytest.mark.parametrize("placement", ["array", "sharded"])
def test_append_donates_carried_state(placement):
    """.append folds through the engines' DONATED jitted updates: the old
    carried PartialState's buffers are consumed in place (steady-state
    ingest allocates nothing per chunk) — and the results still match."""
    x = _series(n=1200, seed=8)
    frame = _make_frame(placement, x)
    _defer_all(frame)
    frame.collect()
    old_leaves = jax.tree_util.tree_leaves(frame._states)
    frame.append(_series(n=128, seed=9))
    assert all(leaf.is_deleted() for leaf in old_leaves)
    _assert_matches(
        frame.collect(), _eager(jnp.concatenate([x, _series(n=128, seed=9)]))
    )


@pytest.mark.parametrize("placement", ["array", "sharded"])
def test_append_makes_no_device_to_host_copy(placement):
    """The append ingest path is sync-free: no device→host transfer happens
    while folding a chunk (the transfer guard raises on any) — including the
    sharded placement's scatter into the device store."""
    x = _series(n=1200, seed=10)
    frame = _make_frame(placement, x)
    _defer_all(frame)
    frame.collect()
    chunk = _series(n=128, seed=11)  # device-resident arrival
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(3):
            frame.append(chunk)
    _assert_matches(
        frame.collect(), _eager(jnp.concatenate([x, chunk, chunk, chunk]))
    )


def test_session_ingest_makes_no_device_to_host_copy():
    """Multi-tenant ingest (FrameSession → RollingStatsService) stays
    sync-free for host-side user ids in both growing and eviction mode —
    the id validation and the eviction cursor live on the host."""
    ids = np.asarray([0, 2], np.int32)
    chunks = jax.random.normal(jax.random.PRNGKey(12), (2, 16, D))
    for kwargs in ({}, {"window": 64, "num_buckets": 4}):
        sess = FrameSession(d=D, num_users=3, **kwargs)
        sess.autocovariance(4)
        sess.ingest(ids, chunks)  # first ingest compiles the plan
        with jax.transfer_guard_device_to_host("disallow"):
            for _ in range(3):
                sess.ingest(ids, chunks)
        got = sess.query(0)["autocovariance"]
        assert np.all(np.isfinite(np.asarray(got)))
        # float-typed ids keep working (the old jnp validation coerced them)
        sess.ingest(np.asarray([1.0]), chunks[:1])


def test_collect_results_survive_donated_append():
    """Regression: a generic member's finalize must hand out copies, never
    the carried stat's own buffers — the donated append would delete a
    result the caller is still holding ('Array has been deleted')."""
    x = _series(n=800, seed=20)
    w = 4

    def ck(y, mask):
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y, s, w, axis=0)
        )(jnp.arange(mask.shape[0]))
        per = jnp.sum(wins[:, 0] * wins[:, -1], axis=-1)
        return jnp.sum(jnp.where(mask, per, 0.0))

    frame = SeriesFrame.from_array(x)
    frame.map_reduce(ck, h_right=w - 1, name="g")
    res = frame.collect()
    before = float(res["g"])
    frame.append(_series(n=64, seed=21))
    assert float(np.asarray(res["g"])) == before  # still readable, unchanged
    assert float(frame.collect()["g"]) != before


def test_multi_group_sharded_append_after_donation():
    """Regression: multi-group sharded plans build per-group states whose
    leaves must be INDEPENDENT buffers — the donated append consumes group
    states one by one, so a leaf shared across groups would be
    read-after-delete (crashed with 'Array has been deleted')."""
    x = _series(n=1500, seed=18)
    w = 9

    def ck(y, mask):  # non-offset-aware strided kernel → its own group
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y, s, w, axis=0)
        )(jnp.arange(mask.shape[0]))
        per = jnp.sum(wins[:, 0] * wins[:, -1], axis=-1)
        return jnp.sum(jnp.where(mask, per, 0.0))

    frame = SeriesFrame.from_sharded(x, block_size=BLOCK)
    frame.autocovariance(4)
    frame.map_reduce(ck, h_right=w - 1, stride=3, name="g")
    assert frame.num_traversals == 2
    frame.collect()
    extra = _series(n=64, seed=19)
    frame.append(extra)
    frame.append(extra)
    got = frame.collect()
    full = np.asarray(jnp.concatenate([x, extra, extra]))
    np.testing.assert_allclose(
        got["autocovariance"],
        autocovariance(jnp.asarray(full), 4, backend="jnp"),
        rtol=1e-4, atol=1e-4,
    )
    want = sum(
        float(np.dot(full[s], full[s + w - 1]))
        for s in range(0, full.shape[0] - w + 1, 3)
    )
    np.testing.assert_allclose(float(got["g"]), want, rtol=1e-4)


def test_sharded_append_scatters_into_store():
    """Sharded-placement appends land IN the device store (no host-side
    replay list), so a replan after appends re-reads a complete series."""
    x = _series(n=1500, seed=13)
    extra = [_series(n=97, seed=14), _series(n=256, seed=15)]
    frame = SeriesFrame.from_sharded(x, block_size=BLOCK)
    frame.autocovariance(8)
    frame.collect()
    for chunk in extra:
        frame.append(chunk)
    full = jnp.concatenate([x] + extra)
    assert frame._pending == []
    assert frame._store.spec.n == full.shape[0]
    np.testing.assert_allclose(
        frame.collect()["autocovariance"],
        autocovariance(full, 8, backend="jnp"),
        rtol=1e-5, atol=1e-4,
    )
    # store contents ≡ a fresh placement of the concatenated series
    np.testing.assert_allclose(
        np.asarray(frame._store.to_series()), np.asarray(full), atol=1e-6
    )
    # a replan (new request after appends) reads the scattered store
    frame.moments(16)
    got = frame.collect()
    me = moment_engine(16, D, backend="jnp")
    want = streaming_window_moments(me, me.from_chunk(full))
    np.testing.assert_allclose(got["moments"]["mean"], want["mean"], rtol=1e-5)
    np.testing.assert_allclose(got["moments"]["var"], want["var"], rtol=1e-4)


def test_store_append_rows_equals_replacement():
    """TimeSeriesStore.append_rows ≡ from_series on the concatenated data,
    across halo widths (incl. h_right > block_size) and growth boundaries."""
    x = _series(n=333, seed=16)
    extra = _series(n=415, seed=17)
    for B, hr in [(64, 7), (32, 50), (128, 0)]:
        st = TimeSeriesStore.from_series(x, block_size=B, h_left=0, h_right=hr)
        for lo in range(0, extra.shape[0], 111):
            st.append_rows(extra[lo : lo + 111])
        ref = TimeSeriesStore.from_series(
            jnp.concatenate([x, extra]), block_size=B, h_left=0, h_right=hr
        )
        assert st.spec == ref.spec
        # capacity may be over-allocated (geometric growth); the live view
        # must match a fresh placement exactly
        np.testing.assert_array_equal(
            np.asarray(st.padded_blocks_single_host()), np.asarray(ref.blocks)
        )
        assert st.blocks.shape[0] >= ref.blocks.shape[0]


# ------------------------------------------------------------ generic members


def test_map_reduce_deferred_member():
    x = _series(n=500, seed=5)
    w = 4

    def ck(y, mask):
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y, s, w, axis=0)
        )(jnp.arange(mask.shape[0]))
        per = jnp.sum(wins[:, 0] * wins[:, -1], axis=-1)
        return jnp.sum(jnp.where(mask, per, 0.0))

    frame = SeriesFrame.from_array(x)
    handle = frame.map_reduce(ck, h_right=w - 1, name="fl")
    assert frame.num_traversals == 1
    want = serial_window_map_reduce(lambda win: jnp.sum(win[0] * win[-1]), x, 0, w - 1)
    np.testing.assert_allclose(handle.result(), want, rtol=1e-5, atol=1e-5)


def test_arma_deferred_and_duplicate_names():
    x = _series(seed=3)
    frame = SeriesFrame.from_array(x)
    a1 = frame.arma(1, 1)
    m1 = frame.moments(8)
    m2 = frame.moments(24)
    assert isinstance(a1, Deferred) and (m1.name, m2.name) == ("moments", "moments_2")
    A, B, sig = a1.result()
    A_r, B_r, sig_r = fit_arma(x, 1, 1)
    np.testing.assert_allclose(A, A_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(B, B_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sig, sig_r, rtol=1e-4, atol=1e-5)


def test_from_chunks_store_source():
    x = _series(n=2000, seed=7)
    store = TimeSeriesStore.from_series(x, block_size=256, h_left=0, h_right=8)
    frame = SeriesFrame.from_chunks(store, chunk_size=333)
    frame.autocovariance(8)
    got = frame.collect()
    np.testing.assert_allclose(
        got["autocovariance"], autocovariance(x, 8), rtol=1e-5, atol=1e-4
    )


def test_from_sharded_accepts_prebuilt_store_and_validates_halo():
    x = _series(n=2000, seed=8)
    store = TimeSeriesStore.from_series(x, block_size=256, h_left=0, h_right=40)
    frame = SeriesFrame.from_sharded(store)
    frame.autocovariance(8)
    frame.moments(32)
    got = frame.collect()
    np.testing.assert_allclose(
        got["autocovariance"], autocovariance(x, 8), rtol=1e-5, atol=1e-4
    )

    narrow = TimeSeriesStore.from_series(x, block_size=256, h_left=0, h_right=2)
    bad = SeriesFrame.from_sharded(narrow)
    bad.moments(32)  # needs h_right ≥ 31
    with pytest.raises(ValueError, match="halo"):
        bad.collect()


# ------------------------------------------------------------- FrameSession


@pytest.mark.parametrize("num_shards", [1, 2])
def test_frame_session_equals_per_user_frames(num_shards):
    """Multi-tenant queries ≡ dedicated per-user SeriesFrames, including
    streams split across ingest lanes in contiguous segments."""
    streams = [_series(n=600, seed=10 + u) for u in range(3)]
    sess = FrameSession(d=D, num_users=3, num_shards=num_shards, backend="jnp")
    sess.autocovariance(4)
    sess.yule_walker(2)
    sess.moments(8)
    ids = jnp.arange(3)
    for lo in range(0, 600, 100):
        shard = 0 if (lo < 300 or num_shards == 1) else 1
        t0 = None if shard == 0 else jnp.full((3,), lo, jnp.int32)
        sess.ingest(ids, jnp.stack([s[lo : lo + 100] for s in streams]),
                    shard=shard, t0=t0)

    batched = sess.query_batch(ids)
    for u, stream in enumerate(streams):
        ref = SeriesFrame.from_array(stream, backend="jnp")
        ref.autocovariance(4)
        ref.yule_walker(2)
        ref.moments(8)
        want = ref.collect()
        got = sess.query(u)
        np.testing.assert_allclose(
            got["autocovariance"], want["autocovariance"], rtol=1e-4, atol=1e-4
        )
        for g, w in zip(got["yule_walker"], want["yule_walker"]):
            np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-4)
        for k in ("mean", "var", "count"):
            np.testing.assert_allclose(
                got["moments"][k], want["moments"][k], rtol=1e-5, atol=1e-5
            )
            np.testing.assert_allclose(
                batched["moments"][k][u], want["moments"][k], rtol=1e-5, atol=1e-5
            )
        np.testing.assert_allclose(
            batched["autocovariance"][u], want["autocovariance"],
            rtol=1e-4, atol=1e-4,
        )
    np.testing.assert_allclose(sess.lengths(), jnp.full((3,), 600))


@pytest.mark.backend
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_session_eviction_equals_retained_recompute(backend):
    """Sliding-window mode: served statistics ≡ recomputing from ONLY the
    retained window (same plan, same global offsets), per user, after the
    ring has wrapped several times — and before it wraps at all."""
    sess = FrameSession(
        d=D, num_users=2, window=400, num_buckets=4, backend=backend
    )
    sess.autocovariance(4)
    sess.moments(8)
    sess.welch(nperseg=32, overlap=16)
    s0 = _series(n=1000, seed=20)
    s1 = _series(n=450, seed=21)
    for lo in range(0, 1000, 50):
        if lo < 450:
            sess.ingest(jnp.asarray([0, 1]),
                        jnp.stack([s0[lo : lo + 50], s1[lo : lo + 50]]))
        else:
            sess.ingest(jnp.asarray([0]), s0[None, lo : lo + 50])

    plan = sess.plan
    retained = np.asarray(sess.retained_lengths())
    assert retained.tolist() == [400, 350]
    for u, (stream, cnt) in enumerate([(s0, 1000), (s1, 450)]):
        got = sess.query(u)
        start = cnt - int(retained[u])
        want = plan.finalize(
            plan.from_chunk(stream[start:], t0=start), cache=False
        )
        np.testing.assert_allclose(
            got["autocovariance"], want["autocovariance"], rtol=1e-4, atol=1e-4
        )
        for k in ("mean", "var", "count"):
            np.testing.assert_allclose(
                got["moments"][k], want["moments"][k], rtol=1e-5, atol=1e-5
            )
        np.testing.assert_allclose(
            got["welch"][1], want["welch"][1], rtol=1e-4, atol=1e-4
        )


def test_eviction_zero_length_chunk_is_a_noop():
    """An empty arrival at a bucket boundary must NOT fire the boundary
    reset (it would silently wipe a still-retained bucket while the cursor
    — and retained_lengths — stand still)."""
    from repro.core.estimators.stats import lag_sum_engine, streaming_mean
    from repro.serving.rolling import RollingStatsService

    svc = RollingStatsService(lag_sum_engine(0, 1), 1, window=16, num_buckets=4)
    x = jnp.arange(20.0)[:, None]
    for lo in range(0, 20, 4):
        svc.ingest(jnp.asarray([0]), x[None, lo : lo + 4])
    before = float(svc.query(0, lambda eng, s: streaming_mean(s))[0])
    svc.ingest(jnp.asarray([0]), jnp.zeros((1, 0, 1)))  # cursor on boundary
    after = float(svc.query(0, lambda eng, s: streaming_mean(s))[0])
    assert before == after == np.mean(np.arange(4, 20))
    assert int(svc.retained_lengths()[0]) == 16


def test_eviction_mode_validation():
    sess = FrameSession(d=1, num_users=1, window=40, num_buckets=4)
    sess.moments(4)
    sess.ingest(jnp.asarray([0]), jnp.ones((1, 5, 1)))
    with pytest.raises(ValueError, match="straddle"):
        # cursor at 5; a 10-sample chunk would cross the bucket-10 boundary
        sess.ingest(jnp.asarray([0]), jnp.ones((1, 10, 1)))
    with pytest.raises(ValueError, match="bucket span"):
        sess.ingest(jnp.asarray([0]), jnp.ones((1, 11, 1)))
    with pytest.raises(ValueError, match="cursor"):
        sess.ingest(jnp.asarray([0]), jnp.ones((1, 5, 1)), t0=jnp.asarray([7]))
    from repro.serving.rolling import RollingStatsService
    from repro.core.estimators.stats import lag_sum_engine

    with pytest.raises(ValueError, match="single ingest lane"):
        RollingStatsService(lag_sum_engine(2, 1), 4, num_shards=2, window=40)
    with pytest.raises(ValueError, match="multiple"):
        RollingStatsService(lag_sum_engine(2, 1), 4, window=41, num_buckets=4)


# ------------------------------------------------------------ shim coherence


def test_streaming_estimator_is_frame_shim():
    """The StreamingEstimator chunk driver now rides the frame's engine
    mode — same state, same programs."""
    from repro.core.estimators.stats import lag_sum_engine, streaming_autocovariance
    from repro.timeseries import StreamingEstimator

    x = _series(n=1200, seed=30)
    est = StreamingEstimator(lag_sum_engine(4, D))
    est.ingest(x[:700]).ingest(x[700:])
    assert isinstance(est._frame, SeriesFrame)
    np.testing.assert_allclose(
        est.finalize(streaming_autocovariance),
        autocovariance(x, 4),
        rtol=1e-5,
        atol=1e-4,
    )


def test_analyze_is_frame_shim():
    from repro.core.plan import analyze, autocovariance_request, moments_request

    x = _series(n=1100, seed=31)
    out = analyze(x, [autocovariance_request(5), moments_request(16)],
                  chunk_size=271)
    np.testing.assert_allclose(
        out["autocovariance"], autocovariance(x, 5), rtol=1e-5, atol=1e-4
    )
