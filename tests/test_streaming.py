"""Streaming sufficient statistics: cross-strategy equivalence + monoid laws.

The paper's algebra says serial ≡ blocked ≡ sharded ≡ streamed for every
weak-memory estimator; this suite pins all four execution strategies to the
serial oracle and checks the PartialState monoid laws (associativity,
commutativity, identity, chunk-size invariance) plus vmapped multi-series
batching against a per-series Python loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators.arma import fit_arma, fit_arma_streaming
from repro.core.estimators.spectral import streaming_welch, welch_engine, welch_psd
from repro.core.estimators.stats import (
    autocovariance,
    autocovariance_blocked,
    autocovariance_sharded,
    lag_sum_engine,
    streaming_autocovariance,
    streaming_mean,
)
from repro.core.estimators.yule_walker import streaming_yule_walker, yule_walker
from repro.core.mapreduce import serial_window_map_reduce
from repro.core.overlap import OverlapSpec, make_overlapping_blocks
from repro.core.streaming import StreamingEngine
from repro.serving import RollingStatsService
from repro.timeseries import StreamingEstimator, TimeSeriesStore

UNEVEN = [1, 7, 229, 13, 501, 64, 185]  # sums to 1000; includes size-1


def _stream(engine, x, splits):
    assert sum(splits) == x.shape[0]
    st = engine.init()
    off = 0
    for c in splits:
        st = engine.update(st, x[off : off + c])
        off += c
    return st


def _series(n=1000, d=2, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


# ---------------------------------------------------------------- equivalence


@pytest.mark.parametrize("normalization", ["paper", "standard"])
def test_autocovariance_four_strategies_agree(normalization):
    """serial ≡ blocked ≡ sharded ≡ streaming (chunked) to 1e-5."""
    x = _series()
    H = 5
    serial = autocovariance(x, H, normalization=normalization)
    blocked = autocovariance_blocked(x, H, block_size=128, normalization=normalization)

    spec = OverlapSpec(n=x.shape[0], block_size=125, h_left=0, h_right=H)
    blocks, _ = make_overlapping_blocks(x, spec)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sharded = autocovariance_sharded(
        blocks, spec, H, mesh, normalization=normalization
    )

    engine = lag_sum_engine(H, x.shape[1])
    streamed = streaming_autocovariance(
        engine, _stream(engine, x, UNEVEN), normalization
    )

    np.testing.assert_allclose(blocked, serial, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sharded, serial, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(streamed, serial, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "splits",
    [[1000], [500, 500], [999, 1], [1, 999], UNEVEN],
    ids=["mono", "halves", "tail1", "head1", "uneven"],
)
def test_streaming_autocov_chunking_invariant(splits):
    x = _series(seed=1)
    engine = lag_sum_engine(6, 2)
    g = streaming_autocovariance(engine, _stream(engine, x, splits))
    np.testing.assert_allclose(g, autocovariance(x, 6), rtol=1e-5, atol=1e-5)


def test_streaming_yule_walker_equals_dense():
    x = _series(seed=2, d=3)
    engine = lag_sum_engine(4, 3)
    st = _stream(engine, x, UNEVEN)
    A_s, sig_s = streaming_yule_walker(engine, st, 3)
    A_d, sig_d = yule_walker(autocovariance(x, 4, normalization="standard"), 3)
    np.testing.assert_allclose(A_s, A_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sig_s, sig_d, rtol=1e-4, atol=1e-5)


def test_streaming_arma_equals_batch():
    x = _series(seed=3, d=2)
    engine = lag_sum_engine(8, 2)
    st = _stream(engine, x, UNEVEN)
    A_s, B_s, sig_s = fit_arma_streaming(engine, st, 1, 1, m=8)
    g = autocovariance(x, 8, normalization="standard")
    A_b, B_b, sig_b = fit_arma(g, 1, 1, m=8)
    np.testing.assert_allclose(A_s, A_b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(B_s, B_b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sig_s, sig_b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nperseg,overlap", [(32, None), (32, 24), (16, 0)])
def test_streaming_welch_equals_welch_psd(nperseg, overlap):
    """Strided (Welch) windows survive chunk boundaries and merges."""
    x = _series(seed=4, d=2)
    engine = welch_engine(nperseg=nperseg, overlap=overlap, d=2)
    st = _stream(engine, x, UNEVEN)
    f_s, p_s = streaming_welch(engine, st)
    f_b, p_b = welch_psd(x, nperseg=nperseg, overlap=overlap)
    np.testing.assert_allclose(f_s, f_b, rtol=0, atol=0)
    np.testing.assert_allclose(p_s, p_b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("hl,hr", [(0, 0), (3, 0), (0, 4), (2, 5)])
def test_generic_kernel_any_halo_matches_serial(hl, hr):
    """Arbitrary pytree kernels at every h_left/h_right combination."""
    x = _series(n=311, seed=5, d=2)
    kern = lambda w: {"sq": jnp.sum(w * w), "edge": jnp.outer(w[0], w[-1])}
    engine = StreamingEngine(d=2, h_left=hl, h_right=hr, kernel=kern)
    st = _stream(engine, x, [1, 17, 130, 7, 156])
    oracle = serial_window_map_reduce(kern, x, hl, hr)
    np.testing.assert_allclose(st.stat["sq"], oracle["sq"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(st.stat["edge"], oracle["edge"], rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------- monoid laws


def _assert_states_close(a, b, rtol=1e-5, atol=1e-5):
    jax.tree.map(
        lambda u, v: np.testing.assert_allclose(u, v, rtol=rtol, atol=atol), a, b
    )


@pytest.mark.parametrize("make_engine", [
    lambda: lag_sum_engine(4, 2),
    lambda: welch_engine(nperseg=16, overlap=8, d=2),
], ids=["lag_sums", "welch"])
def test_merge_associative(make_engine):
    engine = make_engine()
    x = _series(seed=6)
    cuts = [0, 230, 237, 1000]  # middle segment narrower than the halo carry
    a, b, c = (
        engine.update(engine.init(t0=cuts[i]), x[cuts[i] : cuts[i + 1]], t0=cuts[i])
        for i in range(3)
    )
    _assert_states_close(
        engine.merge(engine.merge(a, b), c), engine.merge(a, engine.merge(b, c))
    )


@pytest.mark.parametrize("make_engine", [
    lambda: lag_sum_engine(4, 2),
    lambda: welch_engine(nperseg=16, overlap=8, d=2),
], ids=["lag_sums", "welch"])
def test_merge_commutative(make_engine):
    """Operands are ordered by global start index — ⊕ is commutative."""
    engine = make_engine()
    x = _series(seed=7)
    a = engine.update(engine.init(), x[:400])
    b = engine.update(engine.init(t0=400), x[400:], t0=400)
    _assert_states_close(engine.merge(a, b), engine.merge(b, a), rtol=0, atol=0)


def test_identity_neutral():
    """init() is the neutral element on either side, regardless of its t0."""
    engine = lag_sum_engine(3, 2)
    a = engine.update(engine.init(t0=50), _series(n=200, seed=8), t0=50)
    for e in (engine.init(), engine.init(t0=123)):
        _assert_states_close(engine.merge(e, a), a, rtol=0, atol=0)
        _assert_states_close(engine.merge(a, e), a, rtol=0, atol=0)


def test_chunk_size_invariance_one_prime_n():
    """Same answer streaming by 1, by a prime, and all-at-once."""
    n = 221
    x = _series(n=n, seed=9)
    engine = lag_sum_engine(4, 2)
    outs = []
    for size in (1, 13, n):
        splits = [size] * (n // size) + ([n % size] if n % size else [])
        outs.append(streaming_autocovariance(engine, _stream(engine, x, splits)))
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-5, atol=1e-5)


def test_batched_vmap_matches_per_series_loop():
    """The leading multi-series axis is a plain vmap: one device pass equals
    the per-series Python loop, state-for-state and estimate-for-estimate."""
    B, n, d = 6, 300, 2
    xb = jax.random.normal(jax.random.PRNGKey(10), (B, n, d))
    engine = lag_sum_engine(3, d)

    batched = engine.init_batch(B)
    for off in range(0, n, 100):
        batched = engine.update_batch(batched, xb[:, off : off + 100])
    g_batched = jax.vmap(lambda s: streaming_autocovariance(engine, s))(batched)
    mu_batched = jax.vmap(streaming_mean)(batched)

    for i in range(B):
        st = _stream(engine, xb[i], [100, 100, 100])
        _assert_states_close(jax.tree.map(lambda l: l[i], batched), st)
        np.testing.assert_allclose(
            g_batched[i], streaming_autocovariance(engine, st), rtol=1e-6, atol=1e-6
        )
        np.testing.assert_allclose(mu_batched[i], xb[i].mean(0), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------- drivers and endpoints


def test_streaming_estimator_from_store():
    x = _series(seed=11)
    engine = lag_sum_engine(4, 2)
    store = TimeSeriesStore.from_series(x, block_size=128, h_left=0, h_right=4)
    est = StreamingEstimator.from_store(engine, store, chunk_size=333)
    assert int(est.length) == x.shape[0]
    np.testing.assert_allclose(
        est.finalize(streaming_autocovariance),
        autocovariance(x, 4),
        rtol=1e-5,
        atol=1e-5,
    )


def test_rolling_service_cross_lane_merge():
    """Per-user partials split across ingest lanes merge correctly on query."""
    U, n, d, H = 4, 600, 2, 3
    xu = jax.random.normal(jax.random.PRNGKey(12), (U, n, d))
    engine = lag_sum_engine(H, d)
    svc = RollingStatsService(engine, num_users=U, num_shards=2)
    ids = jnp.arange(U)
    for off in range(0, 300, 150):  # first half → lane 0
        svc.ingest(ids, xu[:, off : off + 150], shard=0)
    for off in range(300, n, 100):  # second half → lane 1, mid-stream t0
        svc.ingest(ids, xu[:, off : off + 100], shard=1, t0=jnp.full((U,), 300))
    assert np.asarray(svc.lengths()).tolist() == [n] * U

    got = svc.query_batch(ids, streaming_autocovariance)
    want = jnp.stack([autocovariance(xu[i], H) for i in range(U)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    A_one, _ = svc.query(2, streaming_yule_walker, 2)
    A_ref, _ = yule_walker(autocovariance(xu[2], H, normalization="standard"), 2)
    np.testing.assert_allclose(A_one, A_ref, rtol=1e-4, atol=1e-5)
