"""Async serving gateway: per-tick coalescing, durability, backpressure.

Pins the three serving contracts:
  * N concurrent clients in one tick cost exactly ONE ingest scatter and
    ONE batched finalize device program (counting-backend + jit-cache
    assertions — nothing re-traces under steady load);
  * kill-and-restart resumes from the snapshot and serves queries
    identical to pre-crash values with zero re-ingest;
  * backpressure rejects over-rate tenants / full queues immediately,
    without stalling other tenants.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import get_backend
from repro.core.frame import FrameSession, SeriesFrame
from repro.serving.gateway import (
    GatewayConfig,
    QueueFull,
    RateClass,
    RateLimited,
    StatsGateway,
)

D = 2


class CountingBackend:
    """Delegating backend recording every traced primitive invocation
    (mirrors tests/test_frame.py) — a cached jit program records nothing."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.calls = []

    def __getattr__(self, prim):
        fn = getattr(self._inner, prim)

        def wrapped(*args, **kwargs):
            self.calls.append(prim)
            return fn(*args, **kwargs)

        return wrapped


def _session(num_users, backend="jnp", **kwargs):
    sess = FrameSession(d=D, num_users=num_users, backend=backend, **kwargs)
    sess.autocovariance(3)
    sess.moments(8)
    return sess


def _chunks(num_users, c=32, seed=0):
    rng = np.random.RandomState(seed)
    return {u: rng.randn(c, D).astype(np.float32) for u in range(num_users)}


def run(coro):
    return asyncio.run(coro)


# ------------------------------------------------- (a) one program per tick


def test_tick_coalesces_to_one_ingest_and_one_finalize_program():
    N = 6
    counting = CountingBackend(get_backend("jnp"))
    gw = StatsGateway(_session(N, backend=counting))
    chunks = _chunks(N)

    async def scenario():
        # warm-up tick traces the programs once
        futs = [gw.submit_ingest(u, chunks[u]) for u in range(N)]
        qfuts = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        await asyncio.gather(*futs, *qfuts)

        counting.calls.clear()
        before = dict(gw.counters)
        futs = [gw.submit_ingest(u, chunks[u]) for u in range(N)]
        qfuts = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        await asyncio.gather(*futs)
        results = await asyncio.gather(*qfuts)
        return before, results

    before, results = run(scenario())
    # N concurrent clients, one tick: ONE scatter-ingest dispatch, ONE
    # batched finalize dispatch ...
    assert gw.counters["programs_ingest"] - before["programs_ingest"] == 1
    assert gw.counters["programs_finalize"] - before["programs_finalize"] == 1
    # ... and zero primitive traces — the whole tick ran cached compiled
    # programs (the counting backend only ever fires during tracing)
    assert counting.calls == []
    # the jit caches held exactly one entry per program despite N clients
    for svc in gw.session._services:
        assert svc._scatter_update._cache_size() == 1
    assert all(sorted(r) == ["autocovariance", "moments"] for r in results)
    m = gw.metrics()
    assert m["batch_occupancy"]["ingest_mean"] == N
    assert m["batch_occupancy"]["query_mean"] == N


def test_gateway_results_match_direct_session():
    N = 3
    gw = StatsGateway(_session(N))
    chunks = _chunks(N, c=40, seed=3)

    async def scenario():
        for _ in range(2):
            futs = [gw.submit_ingest(u, chunks[u]) for u in range(N)]
            await gw.tick()
            await asyncio.gather(*futs)
        q = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        return await asyncio.gather(*q)

    results = run(scenario())
    for u in range(N):
        ref = SeriesFrame.from_array(
            np.concatenate([chunks[u], chunks[u]]), backend="jnp"
        )
        ref.autocovariance(3)
        ref.moments(8)
        want = ref.collect()
        np.testing.assert_allclose(
            results[u]["autocovariance"], want["autocovariance"],
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_allclose(
            results[u]["moments"]["mean"], want["moments"]["mean"],
            rtol=1e-5, atol=1e-6,
        )


def test_same_tenant_twice_in_a_tick_carries_over_in_order():
    gw = StatsGateway(_session(2))
    chunks = _chunks(1, c=16, seed=5)
    second = np.ones((16, D), np.float32)

    async def scenario():
        f1 = gw.submit_ingest(0, chunks[0])
        f2 = gw.submit_ingest(0, second)  # same tenant: deferred one tick
        await gw.tick()
        assert f1.done() and not f2.done()
        assert gw.metrics()["queue_depth"]["ingest"] == 1
        await gw.tick()
        await asyncio.gather(f1, f2)
        q = gw.submit_query(0)
        await gw.tick()
        return await q

    got = run(scenario())
    ref = SeriesFrame.from_array(
        np.concatenate([chunks[0], second]), backend="jnp"
    )
    ref.autocovariance(3)
    ref.moments(8)
    np.testing.assert_allclose(
        got["autocovariance"], ref.collect()["autocovariance"],
        rtol=1e-4, atol=1e-5,
    )


# ------------------------------------------------ (b) kill-and-restart


def test_kill_and_restart_serves_identical_answers(tmp_path):
    N = 4
    cfg = GatewayConfig(checkpoint_dir=str(tmp_path), snapshot_every=1)
    gw = StatsGateway(_session(N), cfg)
    chunks = _chunks(N, c=24, seed=7)

    async def before_crash():
        for seed in (0, 1):
            futs = [
                gw.submit_ingest(u, chunks[u] + seed) for u in range(N)
            ]
            await gw.tick()
            await asyncio.gather(*futs)
        q = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        return await asyncio.gather(*q)

    pre = run(before_crash())
    # the snapshot reached the worker queue; let it hit disk, then "crash"
    # (abandon the gateway object — no graceful stop, no final snapshot)
    gw._loop_rt.manager.flush()

    gw2 = StatsGateway(_session(N), cfg)
    assert gw2.counters["restored_from_snapshot"] == 1
    # tick numbering resumes after the last DURABLE tick (tick 1 — the
    # query-only tick 2 was clean and rightly never snapshotted)
    assert gw2._tick == 2

    async def after_restart():
        q = [gw2.submit_query(u) for u in range(N)]
        await gw2.tick()
        return await asyncio.gather(*q)

    post = run(after_restart())
    # identical answers, with zero re-ingest of history
    assert gw2.counters["programs_ingest"] == 0
    np.testing.assert_array_equal(
        np.asarray(gw2.session.lengths()), np.full(N, 48)
    )
    for u in range(N):
        np.testing.assert_array_equal(
            np.asarray(pre[u]["autocovariance"]),
            np.asarray(post[u]["autocovariance"]),
        )
        for k in ("mean", "var", "count"):
            np.testing.assert_array_equal(
                np.asarray(pre[u]["moments"][k]),
                np.asarray(post[u]["moments"][k]),
            )
    run(gw2.stop())


def test_snapshot_only_when_dirty(tmp_path):
    cfg = GatewayConfig(checkpoint_dir=str(tmp_path), snapshot_every=1)
    gw = StatsGateway(_session(2), cfg)

    async def scenario():
        for _ in range(3):
            await gw.tick()  # idle ticks: nothing to snapshot
        f = gw.submit_ingest(0, np.ones((8, D), np.float32))
        await gw.tick()
        await f
        await gw.stop()

    run(scenario())
    assert gw.counters["snapshots"] == 1


def test_import_state_rejects_mismatched_session(tmp_path):
    sess = _session(3)
    other = FrameSession(d=D, num_users=3, backend="jnp")
    other.autocovariance(3)  # different request set → different plan
    sess.ingest(np.asarray([0]), np.ones((1, 8, D), np.float32))
    snap = sess.export_state()
    with pytest.raises(ValueError, match="does not match"):
        other.import_state(snap)
    smaller = _session(2)
    with pytest.raises(ValueError, match="num_users"):
        smaller.import_state(snap)


# ------------------------------------------------ (c) backpressure


def test_over_rate_tenant_rejected_without_stalling_others():
    cfg = GatewayConfig(
        rate_classes={
            "default": RateClass(),
            "limited": RateClass(ingest_per_tick=1, query_per_tick=1,
                                 burst=1),
        },
    )
    gw = StatsGateway(_session(4), cfg)
    gw.set_tenant_class(0, "limited")
    chunk = np.ones((8, D), np.float32)

    async def scenario():
        ok = gw.submit_ingest(0, chunk)  # consumes tenant 0's only token
        with pytest.raises(RateLimited):
            gw.submit_ingest(0, chunk)
        # other tenants sail through in the same tick
        others = [gw.submit_ingest(u, chunk) for u in (1, 2, 3)]
        await gw.tick()
        await asyncio.gather(ok, *others)
        # the bucket refills per tick: tenant 0 is admitted again
        f = gw.submit_ingest(0, chunk)
        await gw.tick()
        await f

    run(scenario())
    assert gw.counters["rejected_ingest_rate"] == 1
    assert gw.counters["programs_ingest"] == 2
    m = gw.metrics()
    assert m["ingest"]["count"] == 5  # 4 + 1 admitted requests resolved


def test_queue_full_rejects_and_recovers():
    cfg = GatewayConfig(max_pending_ingest=2, max_pending_query=1)
    gw = StatsGateway(_session(8), cfg)
    chunk = np.ones((8, D), np.float32)

    async def scenario():
        a = gw.submit_ingest(0, chunk)
        b = gw.submit_ingest(1, chunk)
        with pytest.raises(QueueFull):
            gw.submit_ingest(2, chunk)
        q = gw.submit_query(0)
        with pytest.raises(QueueFull):
            gw.submit_query(1)
        await gw.tick()
        await asyncio.gather(a, b, q)
        # drained: admission works again
        c = gw.submit_ingest(2, chunk)
        await gw.tick()
        await c

    run(scenario())
    assert gw.counters["rejected_ingest_queue_full"] == 1
    assert gw.counters["rejected_query_queue_full"] == 1


def test_tenant_validation_and_closed_gateway():
    gw = StatsGateway(_session(2))
    with pytest.raises(ValueError, match="tenant"):
        gw.submit_ingest(5, np.ones((4, D), np.float32))
    with pytest.raises(ValueError, match="chunk"):
        gw.submit_ingest(0, np.ones((4, D + 1), np.float32))
    run(gw.stop())
    with pytest.raises(RuntimeError, match="closed"):
        gw.submit_query(0)


def test_serve_forever_background_loop():
    gw = StatsGateway(_session(2), GatewayConfig(tick_interval=0.001))
    chunk = np.ones((8, D), np.float32)

    async def scenario():
        gw.start()
        got = await asyncio.wait_for(
            asyncio.gather(gw.ingest(0, chunk), gw.query(0)), timeout=10.0
        )
        await gw.stop()
        return got

    _, res = run(scenario())
    assert sorted(res) == ["autocovariance", "moments"]
    assert gw.metrics()["ticks"] >= 1


def test_kill_and_restart_serves_identical_forecasts(tmp_path):
    """Forecast determinism under serving: the restarted gateway's
    forecasts and anomaly scores are bit-identical to pre-crash — the
    snapshot's retained tail IS the recurrence seed."""
    N = 3

    def forecast_session():
        sess = FrameSession(d=D, num_users=N)
        sess.autocovariance(3)
        sess.forecast(5, model="arma", p=2, q=1)
        sess.anomaly_scores(model="ar", p=2)
        return sess

    cfg = GatewayConfig(checkpoint_dir=str(tmp_path), snapshot_every=1)
    gw = StatsGateway(forecast_session(), cfg)
    chunks = _chunks(N, c=48, seed=11)

    async def before_crash():
        for seed in (0, 1):
            futs = [gw.submit_ingest(u, chunks[u] + seed) for u in range(N)]
            await gw.tick()
            await asyncio.gather(*futs)
        q = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        return await asyncio.gather(*q)

    pre = run(before_crash())
    gw._loop_rt.manager.flush()

    gw2 = StatsGateway(forecast_session(), cfg)
    assert gw2.counters["restored_from_snapshot"] == 1
    assert gw2._tick == 2

    async def after_restart():
        q = [gw2.submit_query(u) for u in range(N)]
        await gw2.tick()
        return await asyncio.gather(*q)

    post = run(after_restart())
    assert gw2.counters["programs_ingest"] == 0
    for u in range(N):
        for key in ("pred", "sigma"):
            np.testing.assert_array_equal(
                np.asarray(pre[u]["forecast"][key]),
                np.asarray(post[u]["forecast"][key]),
            )
        for key in ("z", "score", "valid"):
            np.testing.assert_array_equal(
                np.asarray(pre[u]["anomaly"][key]),
                np.asarray(post[u]["anomaly"][key]),
            )
    run(gw2.stop())


# -------------------------------------------------- (e) query-kind filter


def test_query_only_filters_kinds_without_extra_programs():
    N = 3
    sess = _session(N)
    sess.forecast(4, model="ar", p=2)
    gw = StatsGateway(sess)
    chunks = _chunks(N, c=32, seed=13)

    async def scenario():
        futs = [gw.submit_ingest(u, chunks[u]) for u in range(N)]
        await gw.tick()
        await asyncio.gather(*futs)
        full = gw.submit_query(0)
        narrow = gw.submit_query(1, only="forecast")
        pair = gw.submit_query(2, only=("moments", "forecast"))
        before = dict(gw.counters)
        await gw.tick()
        res = await asyncio.gather(full, narrow, pair)
        return before, res

    before, (full, narrow, pair) = run(scenario())
    # narrowing is host-side: still ONE batched finalize for the tick
    assert (
        gw.counters["programs_finalize"] - before.get("programs_finalize", 0)
        == 1
    )
    assert sorted(full) == ["autocovariance", "forecast", "moments"]
    assert sorted(narrow) == ["forecast"]
    assert sorted(pair) == ["forecast", "moments"]
    np.testing.assert_array_equal(
        np.asarray(narrow["forecast"]["pred"]).shape, (4, D)
    )


def test_query_only_unknown_kind_rejected_at_submit():
    gw = StatsGateway(_session(2))
    with pytest.raises(ValueError, match="spectrum"):
        gw.submit_query(0, only="spectrum")
    with pytest.raises(ValueError, match="autocovariance"):
        gw.submit_query(0, only=("moments", "nope"))
