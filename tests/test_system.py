"""End-to-end behaviour tests for the whole system."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators.stats import autocovariance
from repro.core.estimators.yule_walker import yule_walker
from repro.timeseries import TimeSeriesStore, random_stable_var, simulate_var

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def test_paper_pipeline_end_to_end():
    """The paper's full workflow: simulate → overlapping store → map-reduce
    sufficient statistics → Yule-Walker fit — without ever touching the raw
    series after ingestion."""
    A = random_stable_var(jax.random.PRNGKey(0), 2, 4, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(1), A, 60_000)
    store = TimeSeriesStore.from_series(xs, block_size=4096, h_left=0, h_right=3)

    max_lag = 3

    def lag_kernel(w):
        return jnp.stack([jnp.outer(w[0], w[h]) for h in range(max_lag + 1)])

    sums = store.map_reduce(lag_kernel)
    n = xs.shape[0]
    gamma = sums / n
    Ahat, sigma = yule_walker(gamma, 2)
    assert float(jnp.max(jnp.abs(Ahat - A))) < 0.03
    # consistency with the direct estimator
    g_direct = autocovariance(xs, max_lag, normalization="standard")
    np.testing.assert_allclose(gamma, g_direct, rtol=1e-3, atol=1e-4)


def test_train_driver_end_to_end(tmp_path):
    """launch.train main(): loss descends, checkpoints written, resume works."""
    from repro.launch.train import main

    ckpt = str(tmp_path / "ck")
    loss = main([
        "--arch", "qwen3", "--reduced", "--steps", "30", "--batch", "4",
        "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "10", "--f32",
        "--lr", "3e-3",
    ])
    assert np.isfinite(loss)
    steps = [n for n in os.listdir(ckpt) if n.startswith("step_")]
    assert steps, "no checkpoints written"
    # resume for a few more steps from the checkpoint
    loss2 = main([
        "--arch", "qwen3", "--reduced", "--steps", "35", "--batch", "4",
        "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "10", "--f32",
        "--lr", "3e-3",
    ])
    assert np.isfinite(loss2)


def test_irregular_regularize():
    from repro.timeseries.irregular import regularize

    t = jnp.asarray([0.0, 1.0, 3.0, 7.0])
    x = jnp.asarray([[0.0], [10.0], [30.0], [70.0]])
    grid = jnp.asarray([0.0, 2.0, 5.0, 7.0])
    locf = regularize(t, x, grid, method="locf")
    np.testing.assert_allclose(locf[:, 0], [0.0, 10.0, 30.0, 70.0])
    lin = regularize(t, x, grid, method="linear")
    np.testing.assert_allclose(lin[:, 0], [0.0, 20.0, 50.0, 70.0])


def test_fractional_differencing_long_memory():
    """Paper §10.3: a truncated (1−L)^d kernel reduces a long-memory series
    to weak memory; d=1 recovers ordinary differencing exactly."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.differencing import (
        difference,
        fractional_diff_weights,
        fractional_difference,
    )

    # d = 1 → weights (1, -1, 0, 0, …): matches Δ
    x = jnp.cumsum(jax.random.normal(jax.random.PRNGKey(0), (500, 2)), axis=0)
    fd = fractional_difference(x, d=1.0, truncation=8)
    dx = difference(x, 1)
    # fd[t] corresponds to Δ at aligned offsets (note Δ convention x_{t+1}-x_t)
    np.testing.assert_allclose(fd, dx[7:], rtol=1e-4, atol=1e-4)

    # weights telescope: Σ w_k → 0 for d > 0 as K grows (kernel is localized)
    w = fractional_diff_weights(0.4, 512)
    assert abs(float(jnp.sum(w))) < 0.1
    # d = 0.4 fractional noise: fractional differencing kills the long tail
    key = jax.random.PRNGKey(1)
    eps = jax.random.normal(key, (20000, 1))
    # synthesize ARFIMA(0,d,0) by inverse filter (truncated MA(∞) of (1-L)^{-d})
    w_inv = fractional_diff_weights(-0.4, 128)
    xs = jnp.stack(
        [jnp.einsum("j,jd->d", w_inv[::-1], jax.lax.dynamic_slice_in_dim(eps, t, 129, 0))
         for t in range(0, 8000)]
    )
    recovered = fractional_difference(xs, d=0.4, truncation=128)
    from repro.core.estimators.stats import autocorrelation, autocovariance

    # the ARFIMA input has a slowly-decaying (long-memory) correlogram …
    rho_x = autocorrelation(autocovariance(xs - xs.mean(), 8))
    assert float(rho_x[8, 0, 0]) > 0.3
    # … while the fractionally differenced series is white again
    rho = autocorrelation(autocovariance(recovered, 8))
    assert float(jnp.max(jnp.abs(rho[1:]))) < 0.05
