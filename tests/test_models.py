"""Per-arch reduced-config smoke tests (brief deliverable f) + exact
prefill/decode/forward consistency across all families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME, cell_is_runnable
from repro.models import cache_spec, decode_step, forward, init_params, prefill
from repro.models.layers import cross_entropy_loss
from repro.models.vlm_stub import fake_frame_embeds, fake_patch_embeds

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow


B, S = 2, 64
ALL_ARCHS = sorted(ARCHS)


def _batch(r, key):
    toks = jax.random.randint(key, (B, S), 0, r.vocab)
    batch = {"tokens": toks, "labels": toks}
    if r.family == "vlm":
        batch["tokens"] = toks[:, : S - r.n_patches]
        batch["patch_embeds"] = fake_patch_embeds(key, B, r.n_patches, r.d_model, jnp.float32)
    if r.family == "encdec":
        batch["frames"] = fake_frame_embeds(key, B, S, r.d_model, jnp.float32)
    return batch


@pytest.fixture(scope="module")
def fitted():
    """init params once per arch (module scoped for speed)."""
    out = {}
    for name in ALL_ARCHS:
        r = ARCHS[name].reduced()
        out[name] = (r, init_params(jax.random.PRNGKey(3), r, dtype=jnp.float32))
    return out


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name, fitted):
    r, params = fitted[name]
    batch = _batch(r, jax.random.PRNGKey(4))
    logits, aux = forward(params, batch, r)
    assert logits.shape == (B, S, r.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux["lb_loss"])) and np.isfinite(float(aux["z_loss"]))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step_no_nans(name, fitted):
    from repro.training.optimizer import adamw_init
    from repro.training.train_step import make_train_step

    r, params = fitted[name]
    batch = _batch(r, jax.random.PRNGKey(5))
    step = make_train_step(r, lr_fn=1e-3)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    finite = jax.tree.map(lambda a: bool(jnp.all(jnp.isfinite(a))), new_params)
    assert all(jax.tree.leaves(finite))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name, fitted):
    """prefill(S−1) + decode(token S−1) == forward(S) at the last position."""
    r, params = fitted[name]
    batch = _batch(r, jax.random.PRNGKey(6))
    logits_full, _ = forward(params, batch, r)
    batch_p = dict(batch)
    batch_p["tokens"] = batch["tokens"][:, :-1]
    lg_p, cache = prefill(params, batch_p, r)
    spec = cache_spec(r, B, S, dtype=jnp.float32)

    def fit(a, s):
        pads = [(0, sd - ad) for ad, sd in zip(a.shape, s.shape)]
        if any(p[1] for p in pads):
            cv = -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0
            a = jnp.pad(a, pads, constant_values=cv)
        return a.astype(s.dtype)

    cache = jax.tree.map(fit, cache, spec)
    pos = jnp.asarray(S - 1, jnp.int32)  # absolute position (incl. patches)
    db = {"tokens": batch["tokens"][:, -1], "pos": pos}
    lg_d, _ = decode_step(params, cache, db, r)
    np.testing.assert_allclose(lg_d, logits_full[:, -1], rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_spec_matches_decode_output(name, fitted):
    """decode_step must return a cache structurally identical to cache_spec."""
    r, params = fitted[name]
    spec = cache_spec(r, B, S, dtype=jnp.float32)
    zero_cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    db = {"tokens": jnp.zeros((B,), jnp.int32), "pos": jnp.asarray(3, jnp.int32)}
    _, new_cache = decode_step(params, zero_cache, db, r)
    spec_shapes = jax.tree.map(lambda s: (s.shape, s.dtype), spec)
    got_shapes = jax.tree.map(lambda a: (a.shape, a.dtype), new_cache)
    assert jax.tree.structure(spec_shapes) == jax.tree.structure(got_shapes)
    assert jax.tree.leaves(spec_shapes) == jax.tree.leaves(got_shapes)


def test_cell_runnability_rules():
    long = SHAPES_BY_NAME["long_500k"]
    assert not cell_is_runnable(ARCHS["glm4-9b"], long)[0]
    assert not cell_is_runnable(ARCHS["llama4-maverick-400b-a17b"], long)[0]
    assert cell_is_runnable(ARCHS["h2o-danube-1.8b"], long)[0]  # SWA
    assert cell_is_runnable(ARCHS["xlstm-125m"], long)[0]
    assert cell_is_runnable(ARCHS["zamba2-7b"], long)[0]
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for a in ARCHS.values():
            assert cell_is_runnable(a, SHAPES_BY_NAME[s])[0]


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10))
    labels = jnp.asarray([[1, 2, -1, 3]])
    loss = cross_entropy_loss(logits, labels)
    assert float(loss) == pytest.approx(np.log(10), rel=1e-5)


def test_moe_dispatch_variants_equivalent():
    """gather dispatch (default) == einsum dispatch (§Perf iteration-0 ref)."""
    import dataclasses

    from repro.models.moe import moe_apply, moe_init

    base = ARCHS["deepseek-v2-236b"].reduced()
    p = moe_init(jax.random.PRNGKey(11), base, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 16, base.d_model)) * 0.5
    cfg_g = dataclasses.replace(base, moe=dataclasses.replace(base.moe, dispatch="gather"))
    cfg_e = dataclasses.replace(base, moe=dataclasses.replace(base.moe, dispatch="einsum"))
    y_g, aux_g = moe_apply(p, x, cfg_g)
    y_e, aux_e = moe_apply(p, x, cfg_e)
    np.testing.assert_allclose(y_g, y_e, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_g["lb_loss"]), float(aux_e["lb_loss"]), rtol=1e-5)


def test_seq_parallel_residual_flag_preserves_math():
    """B5 residual sharding is a layout hint: identical logits on 1 device."""
    import dataclasses

    r = ARCHS["qwen3-0.6b"].reduced()
    p = init_params(jax.random.PRNGKey(13), r, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(14), (2, 32), 0, r.vocab)
    l1, _ = forward(p, {"tokens": toks}, r)
    r2 = dataclasses.replace(r, seq_parallel_residual=True)
    l2, _ = forward(p, {"tokens": toks}, r2)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)
