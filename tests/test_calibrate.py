"""Calibration-subsystem suite (`repro.core.calibrate`).

Pins the PR 5 policy contract: the "auto" backend dispatches every
primitive through per-primitive *measured* crossovers — default table when
nothing is cached (off-accelerator: always jnp), cache round-trip, measured
tables actually steering dispatch, and platform hygiene (a cache from
another platform is never misapplied).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core.backend import (
    AutoBackend,
    JnpBackend,
    PallasBackend,
    get_backend,
)

pytestmark = pytest.mark.backend


def _table(thresholds, platform=None, source="test"):
    return cal.CalibrationTable(
        platform or jax.default_backend(), dict(thresholds), source
    )


class _Recording(PallasBackend):
    """Pallas backend that counts which primitives were dispatched to it."""

    def __init__(self):
        super().__init__()
        self.calls = []

    def __getattribute__(self, name):
        attr = object.__getattribute__(self, name)
        if name in cal.PRIMITIVES:
            calls = object.__getattribute__(self, "calls")

            def wrapped(*args, **kwargs):
                calls.append(name)
                return attr(*args, **kwargs)

            return wrapped
        return attr


def _drive_all_primitives(be):
    """One small call per registered primitive through ``be``."""
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 2))
    y = jax.random.normal(jax.random.PRNGKey(1), (52, 2))
    mask = jnp.ones((48,), jnp.bool_)
    segs = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 2))
    taper = jnp.hanning(16)
    diags = jax.random.normal(jax.random.PRNGKey(3), (48, 5))
    be.lagged_sums(x, 4)
    be.masked_lagged_sums(y, mask, 4)
    be.windowed_moments(x, 8)
    be.segment_fft_power(segs, taper)
    be.banded_matvec(diags, x[:, 0])
    be.fused_lagged_moments(y, mask, 4, 8)


def test_default_table_off_accelerator_never_picks_pallas():
    table = cal.default_table("cpu")
    assert set(table.thresholds) == set(cal.PRIMITIVES)
    assert all(math.isinf(v) for v in table.thresholds.values())
    # ...and a TPU default exists for every primitive (finite sane values)
    tpu = cal.default_table("tpu")
    assert set(tpu.thresholds) == set(cal.PRIMITIVES)
    assert all(np.isfinite(v) and v > 0 for v in tpu.thresholds.values())


def test_auto_dispatch_follows_injected_table():
    rec = _Recording()
    # threshold 0: everything crosses over → every primitive hits pallas
    auto = AutoBackend(
        pallas_backend=rec, table=_table({p: 0.0 for p in cal.PRIMITIVES})
    )
    _drive_all_primitives(auto)
    assert sorted(set(rec.calls)) == sorted(cal.PRIMITIVES)
    # threshold inf: nothing does
    rec2 = _Recording()
    auto2 = AutoBackend(
        pallas_backend=rec2,
        table=_table({p: math.inf for p in cal.PRIMITIVES}),
    )
    _drive_all_primitives(auto2)
    assert rec2.calls == []


def test_auto_per_primitive_thresholds_are_independent():
    rec = _Recording()
    thresholds = {p: math.inf for p in cal.PRIMITIVES}
    thresholds["lagged_sums"] = 10.0  # only this one crosses over
    auto = AutoBackend(pallas_backend=rec, table=_table(thresholds))
    _drive_all_primitives(auto)
    assert set(rec.calls) == {"lagged_sums"}
    # parity while doing so
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 2))
    np.testing.assert_allclose(
        auto.lagged_sums(x, 3), JnpBackend().lagged_sums(x, 3), atol=1e-4
    )


def test_cache_roundtrip_and_platform_hygiene(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    table = _table(
        {p: (512.0 if i % 2 else math.inf) for i, p in enumerate(cal.PRIMITIVES)},
        source="measured",
    )
    cal.save_table(table)
    loaded = cal.load_table()
    assert loaded is not None and loaded.source == "cache"
    assert loaded.thresholds == table.thresholds  # inf survives JSON (null)
    # resolve_table prefers the cache over defaults and auto-measurement
    resolved = cal.resolve_table()
    assert resolved.thresholds == table.thresholds
    # a cache written on another platform is ignored, never misapplied
    alien = _table({p: 1.0 for p in cal.PRIMITIVES}, platform="tpu")
    cal.save_table(alien)
    assert cal.load_table() is None
    assert cal.resolve_table(autocalibrate=False).source == "default"


def test_calibrate_measures_all_primitives_and_persists(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    table = cal.calibrate(sizes=(32, 64), d=2, iters=1, warmup=0, save=True)
    assert table.source == "measured"
    assert set(table.thresholds) == set(cal.PRIMITIVES)
    for v in table.thresholds.values():
        assert math.isinf(v) or v in (32.0, 64.0)
    assert path.exists()
    # a fresh resolve (e.g. a new process's first "auto" dispatch) reads it
    assert cal.resolve_table().thresholds == table.thresholds


def test_registry_auto_has_no_hardcoded_row_constant():
    """The acceptance pin: the registered "auto" policy carries a
    calibration table (resolved lazily), not a min_rows constant."""
    auto = get_backend("auto")
    assert not hasattr(auto, "min_rows")
    assert set(auto.table.thresholds) == set(cal.PRIMITIVES)
