"""Calibration-subsystem suite (`repro.core.calibrate`).

Pins the PR 5 policy contract: the "auto" backend dispatches every
primitive through per-primitive *measured* crossovers — default table when
nothing is cached (off-accelerator: always jnp), cache round-trip, measured
tables actually steering dispatch, and platform hygiene (a cache from
another platform is never misapplied).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core.backend import (
    AutoBackend,
    JnpBackend,
    PallasBackend,
    get_backend,
)

pytestmark = pytest.mark.backend


@pytest.fixture(autouse=True)
def _isolate_active_table():
    """Tests install tables process-wide (calibrate / tune_blocks /
    set_active_table); reset to lazy read-through afterwards."""
    yield
    cal.set_active_table(None)


def _table(thresholds, platform=None, source="test"):
    return cal.CalibrationTable(
        platform or jax.default_backend(), dict(thresholds), source
    )


class _Recording(PallasBackend):
    """Pallas backend that counts which primitives were dispatched to it."""

    def __init__(self):
        super().__init__()
        self.calls = []

    def __getattribute__(self, name):
        attr = object.__getattribute__(self, name)
        if name in cal.PRIMITIVES:
            calls = object.__getattribute__(self, "calls")

            def wrapped(*args, **kwargs):
                calls.append(name)
                return attr(*args, **kwargs)

            return wrapped
        return attr


def _drive_all_primitives(be):
    """One small call per registered primitive through ``be``."""
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 2))
    y = jax.random.normal(jax.random.PRNGKey(1), (52, 2))
    mask = jnp.ones((48,), jnp.bool_)
    segs = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 2))
    taper = jnp.hanning(16)
    diags = jax.random.normal(jax.random.PRNGKey(3), (48, 5))
    be.lagged_sums(x, 4)
    be.masked_lagged_sums(y, mask, 4)
    be.windowed_moments(x, 8)
    be.segment_fft_power(segs, taper)
    be.segment_csd(segs, taper)
    be.banded_matvec(diags, x[:, 0])
    be.fused_lagged_moments(y, mask, 4, 8)
    be.fused_plan_update(y, mask, 0, 4, (8,), (16,), (8,), (taper,))


def test_default_table_off_accelerator_never_picks_pallas():
    table = cal.default_table("cpu")
    assert set(table.thresholds) == set(cal.PRIMITIVES)
    assert all(math.isinf(v) for v in table.thresholds.values())
    # ...and a TPU default exists for every primitive (finite sane values)
    tpu = cal.default_table("tpu")
    assert set(tpu.thresholds) == set(cal.PRIMITIVES)
    assert all(np.isfinite(v) and v > 0 for v in tpu.thresholds.values())


def test_auto_dispatch_follows_injected_table():
    rec = _Recording()
    # threshold 0: everything crosses over → every primitive hits pallas
    auto = AutoBackend(
        pallas_backend=rec, table=_table({p: 0.0 for p in cal.PRIMITIVES})
    )
    _drive_all_primitives(auto)
    assert sorted(set(rec.calls)) == sorted(cal.PRIMITIVES)
    # threshold inf: nothing does
    rec2 = _Recording()
    auto2 = AutoBackend(
        pallas_backend=rec2,
        table=_table({p: math.inf for p in cal.PRIMITIVES}),
    )
    _drive_all_primitives(auto2)
    assert rec2.calls == []


def test_auto_per_primitive_thresholds_are_independent():
    rec = _Recording()
    thresholds = {p: math.inf for p in cal.PRIMITIVES}
    thresholds["lagged_sums"] = 10.0  # only this one crosses over
    auto = AutoBackend(pallas_backend=rec, table=_table(thresholds))
    _drive_all_primitives(auto)
    assert set(rec.calls) == {"lagged_sums"}
    # parity while doing so
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 2))
    np.testing.assert_allclose(
        auto.lagged_sums(x, 3), JnpBackend().lagged_sums(x, 3), atol=1e-4
    )


def test_cache_roundtrip_and_platform_hygiene(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    table = _table(
        {p: (512.0 if i % 2 else math.inf) for i, p in enumerate(cal.PRIMITIVES)},
        source="measured",
    )
    cal.save_table(table)
    loaded = cal.load_table()
    assert loaded is not None and loaded.source == "cache"
    assert loaded.thresholds == table.thresholds  # inf survives JSON (null)
    # resolve_table prefers the cache over defaults and auto-measurement
    resolved = cal.resolve_table()
    assert resolved.thresholds == table.thresholds
    # a cache written on another platform is ignored, never misapplied
    alien = _table({p: 1.0 for p in cal.PRIMITIVES}, platform="tpu")
    cal.save_table(alien)
    assert cal.load_table() is None
    assert cal.resolve_table(autocalibrate=False).source == "default"


def test_calibrate_measures_all_primitives_and_persists(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    table = cal.calibrate(sizes=(32, 64), d=2, iters=1, warmup=0, save=True)
    assert table.source == "measured"
    assert set(table.thresholds) == set(cal.PRIMITIVES)
    for v in table.thresholds.values():
        assert math.isinf(v) or v in (32.0, 64.0)
    assert path.exists()
    # a fresh resolve (e.g. a new process's first "auto" dispatch) reads it
    assert cal.resolve_table().thresholds == table.thresholds


def test_registry_auto_has_no_hardcoded_row_constant():
    """The acceptance pin: the registered "auto" policy carries a
    calibration table (resolved lazily), not a min_rows constant."""
    auto = get_backend("auto")
    assert not hasattr(auto, "min_rows")
    assert set(auto.table.thresholds) == set(cal.PRIMITIVES)

# ------------------------------------------------- PR 7: blocks + stale cache


def test_stale_cache_missing_primitive_falls_back_to_builtin():
    """Satellite-6 pin: a cached table that predates ``fused_plan_update``
    (or any newly registered primitive) must degrade to the BUILT-IN
    default for the table's platform — never a KeyError, never a blanket
    "always pallas"."""
    old = {p: 0.0 for p in cal.PRIMITIVES if p != "fused_plan_update"}
    stale_cpu = _table(old, platform="cpu", source="cache")
    assert math.isinf(stale_cpu.crossover("fused_plan_update"))
    stale_tpu = _table(old, platform="tpu", source="cache")
    assert stale_tpu.crossover("fused_plan_update") == 4096.0
    # dispatch through the auto policy: the missing primitive quietly runs
    # on jnp (cpu built-in = inf), everything present still crosses over
    rec = _Recording()
    auto = AutoBackend(pallas_backend=rec, table=_table(old, platform="cpu"))
    _drive_all_primitives(auto)
    assert "fused_plan_update" not in rec.calls
    assert "lagged_sums" in rec.calls


def test_blocks_json_roundtrip_and_resolution(tmp_path, monkeypatch):
    """Tuned tile configs survive the cache round-trip and steer
    `repro.kernels.tiling.resolve_block` (override > table > default)."""
    from repro.kernels.tiling import DEFAULT_BLOCKS, resolve_block

    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    table = _table({p: math.inf for p in cal.PRIMITIVES}, source="measured")
    table.blocks = {
        "lagged_sums": {"block_t": 256},
        "segment_fft_power": {"block_s": 2},
    }
    cal.save_table(table)
    loaded = cal.load_table()
    assert loaded.blocks == table.blocks
    assert loaded.block_config("lagged_sums") == {"block_t": 256}
    assert loaded.block_config("banded_matvec") == {}  # never tuned

    cal.set_active_table(loaded)
    assert cal.active_blocks("lagged_sums") == {"block_t": 256}
    assert resolve_block("lagged_sums", "block_t", None) == 256
    assert resolve_block("segment_fft_power", "block_s", None) == 2
    # explicit override beats the table; untuned primitive gets the default
    assert resolve_block("lagged_sums", "block_t", 64) == 64
    assert (
        resolve_block("banded_matvec", "block_rows", None)
        == DEFAULT_BLOCKS["banded_matvec"]["block_rows"]
    )
    # reset → lazy read-through finds the same persisted blocks
    cal.set_active_table(None)
    assert cal.active_blocks("lagged_sums") == {"block_t": 256}


def test_tune_blocks_records_all_tunable_primitives(tmp_path, monkeypatch):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    monkeypatch.setattr(
        cal, "BLOCK_CANDIDATES",
        {"block_t": (32, 64), "block_s": (2, 4), "block_rows": (32,)},
    )
    table = cal.tune_blocks(n=48, iters=1, warmup=0, save=True)
    assert set(table.blocks) == set(cal.TUNABLE_BLOCKS)
    for prim, params in cal.TUNABLE_BLOCKS.items():
        for param in params:
            assert table.blocks[prim][param] in cal.BLOCK_CANDIDATES[param]
    # persisted AND installed as the active table
    assert cal.load_table().blocks == table.blocks
    assert cal.active_table() is table


def test_calibrate_tune_blocks_one_artifact(tmp_path, monkeypatch):
    """``calibrate(tune_blocks=True)`` yields ONE table carrying both the
    dispatch thresholds and the kernel geometry."""
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    monkeypatch.setattr(
        cal, "BLOCK_CANDIDATES",
        {"block_t": (32,), "block_s": (2,), "block_rows": (32,)},
    )
    table = cal.calibrate(
        sizes=(32,), d=2, iters=1, warmup=0, save=True, tune_blocks=True
    )
    assert set(table.thresholds) == set(cal.PRIMITIVES)
    assert set(table.blocks) == set(cal.TUNABLE_BLOCKS)
    reloaded = cal.load_table()
    assert reloaded.blocks == table.blocks


def test_cli_show_and_bless(tmp_path, monkeypatch, capsys):
    import json

    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    assert cal.main(["--show"]) == 0
    out = capsys.readouterr().out
    assert "crossover thresholds" in out and "tuned tile configs" in out

    def _payload(platform):
        t = _table(
            {p: 128.0 for p in cal.PRIMITIVES},
            platform=platform,
            source="measured",
        )
        t.blocks = {"lagged_sums": {"block_t": 128}}
        return t.to_json()

    # bless: wrong platform refused, right platform installed as the cache
    alien = tmp_path / "alien.json"
    alien.write_text(json.dumps(_payload("definitely-not-this-platform")))
    assert cal.main(["--bless", str(alien)]) == 1
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_payload(jax.default_backend())))
    assert cal.main(["--bless", str(good)]) == 0
    assert path.exists()
    assert cal.load_table().blocks == {"lagged_sums": {"block_t": 128}}


# --------------------------------------------- PR 8: corrupt-cache hygiene


@pytest.mark.parametrize(
    "body",
    [
        "{not json",                        # truncated / invalid JSON
        '{"thresholds": 42}',               # valid JSON, wrong structure
        '["a", "list"]',                    # valid JSON, wrong top type
        '{"platform": null, "thresholds": {"lagged_sums": "NaNish"}}',
    ],
)
def test_corrupt_cache_degrades_to_defaults_with_warning(
    tmp_path, monkeypatch, body
):
    """A torn or hand-mangled cache file must never crash the "auto"
    policy's first dispatch: load_table warns and returns None, and
    resolve_table falls through to the built-in defaults."""
    path = tmp_path / "calib.json"
    path.write_text(body)
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    with pytest.warns(RuntimeWarning, match="corrupt calibration cache"):
        assert cal.load_table() is None
    with pytest.warns(RuntimeWarning):
        resolved = cal.resolve_table(autocalibrate=False)
    assert resolved.source == "default"
    assert set(resolved.thresholds) == set(cal.PRIMITIVES)


def test_cli_bless_rejects_corrupt_table(tmp_path, monkeypatch, capsys):
    path = tmp_path / "calib.json"
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(path))
    bad = tmp_path / "bad.json"
    bad.write_text('{"thresholds": 42}')
    assert cal.main(["--bless", str(bad)]) == 1
    assert "refusing to bless" in capsys.readouterr().out
    assert cal.main(["--bless", str(tmp_path / "missing.json")]) == 1
    assert "cannot read" in capsys.readouterr().out
    assert not path.exists()                 # nothing was installed
