"""Checkpointing (atomic, async, retention, elastic restore) + fault runtime."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
    sweep_tmp_dirs,
)
from repro.runtime.fault import FaultTolerantLoop, StragglerMonitor, plan_remesh

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path), 3)
    assert latest_step(str(tmp_path)) == 3
    back = restore_pytree(jax.tree.map(jnp.zeros_like, t), str(tmp_path))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, back)


def test_atomic_no_tmp_left(tmp_path):
    save_pytree(_tree(), str(tmp_path), 1)
    assert not any(n.startswith("tmp.") for n in os.listdir(tmp_path))


def test_async_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(_tree(s), s)
    mgr.flush()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    mgr.close()


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-lays arrays onto a (different) mesh via device_put."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_pytree(t, str(tmp_path), 0)
    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    back = restore_pytree(t, str(tmp_path), shardings=sh)
    assert back["layers"]["w"].sharding == NamedSharding(mesh, P())


def test_fault_loop_resume(tmp_path):
    loop = FaultTolerantLoop(str(tmp_path), every=2)
    state = {"x": jnp.zeros(3)}
    for step in range(5):
        state = {"x": state["x"] + 1}
        loop.after_step(step, state)
    loop.checkpoint_now()
    loop.close()

    loop2 = FaultTolerantLoop(str(tmp_path), every=2)
    restored, start = loop2.restore_or({"x": jnp.zeros(3)})
    assert start == 5
    np.testing.assert_array_equal(restored["x"], np.full(3, 5.0))
    loop2.close()


def test_straggler_monitor():
    flagged = []
    mon = StragglerMonitor(threshold=2.0, on_straggle=lambda s, t, m: flagged.append(s))
    for i in range(20):
        mon.record(i, 0.1)
    mon.record(20, 0.5)  # 5× median
    assert flagged == [20]
    assert mon.record(21, 0.1) is False


def test_plan_remesh():
    p = plan_remesh(512)
    assert (p.data, p.model, p.dropped_devices) == (32, 16, 0)
    p = plan_remesh(500)  # lost 12 devices
    assert p.model == 16 and p.data == 31 and p.dropped_devices == 4
    p = plan_remesh(7, model_divisors=(16, 8, 4, 2, 1))
    assert p.world <= 7 and p.model in (4, 2, 1)
    with pytest.raises(ValueError):
        plan_remesh(0)


def test_manager_sweeps_stale_tmp_dirs_on_start(tmp_path):
    """A crash mid-write used to leak its tmp dir forever; manager start
    sweeps the debris (incomplete tmp + trash dirs)."""
    save_pytree(_tree(), str(tmp_path), 1)
    for name in ("tmp.7.abcd1234", "trash.1.deadbeef"):
        d = tmp_path / name
        d.mkdir()
        (d / "arrays.npz").write_bytes(b"partial garbage")
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.close()
    left = sorted(n for n in os.listdir(tmp_path))
    assert left == ["step_0000000001"]
    # the surviving checkpoint still restores
    restore_pytree(_tree(), str(tmp_path))


def test_crash_mid_write_preserves_previous_checkpoint(tmp_path, monkeypatch):
    """A crash while serializing a re-save must leave the existing
    checkpoint for that step intact (the old rmtree-then-rename pair
    deleted it before the new one was in place)."""
    t_old = _tree(0)
    save_pytree(t_old, str(tmp_path), 5)

    def boom(*a, **k):
        raise OSError("disk died mid-save")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        save_pytree(_tree(1), str(tmp_path), 5)
    monkeypatch.undo()
    back = restore_pytree(jax.tree.map(jnp.zeros_like, t_old), str(tmp_path), 5)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(a, b), t_old, back
    )


def test_crash_between_renames_is_recovered_on_sweep(tmp_path, monkeypatch):
    """The narrowest crash window: the old final was moved aside but the
    finished new save was not yet renamed into place.  The start-up sweep
    recognizes the complete orphan and recovers it — the step is never
    lost."""
    save_pytree(_tree(0), str(tmp_path), 2)
    t_new = _tree(1)
    real_rename = os.rename
    calls = {"n": 0}

    def flaky_rename(src, dst):
        # 1st rename: final -> trash; 2nd: tmp -> final (the crash point)
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("killed between the renames")
        return real_rename(src, dst)

    monkeypatch.setattr(os, "rename", flaky_rename)
    with pytest.raises(OSError):
        save_pytree(t_new, str(tmp_path), 2)
    monkeypatch.undo()
    assert latest_step(str(tmp_path)) is None  # the step is invisible...
    recovered = sweep_tmp_dirs(str(tmp_path))  # ...until the sweep
    assert len(recovered) == 1 and recovered[0].endswith("step_0000000002")
    back = restore_pytree(jax.tree.map(jnp.zeros_like, t_new), str(tmp_path))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(a, b), t_new, back
    )
    assert not any(
        n.startswith(("tmp.", "trash.")) for n in os.listdir(tmp_path)
    )


def test_close_does_not_leak_worker_after_save_error(tmp_path, monkeypatch):
    """close() must enqueue the shutdown sentinel even when flush() raises
    a deferred save error — the daemon worker used to leak."""
    import repro.checkpoint.manager as mgr_mod

    mgr = CheckpointManager(str(tmp_path))

    def boom(tree, directory, step):
        raise RuntimeError("save exploded")

    monkeypatch.setattr(mgr_mod, "save_pytree", boom)
    mgr.save(_tree(), 0)
    with pytest.raises(RuntimeError, match="save exploded"):
        mgr.close()
    mgr._worker.join(timeout=5.0)
    assert not mgr._worker.is_alive()


def test_restore_shape_mismatch_names_key_and_shapes(tmp_path):
    """An elastic restore onto a template with a different leaf shape must
    fail loudly at restore time, naming the key and both shapes — not
    surface as an opaque error at first use."""
    save_pytree(_tree(), str(tmp_path), 0)
    template = _tree()
    template["layers"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError) as ei:
        restore_pytree(template, str(tmp_path))
    msg = str(ei.value)
    assert "layers/w" in msg and "(8, 4)" in msg and "(4, 4)" in msg


def test_first_step_time_excludes_construction_and_restore(tmp_path):
    """The straggler median must not be poisoned by billing construction /
    restore wall time to the first step."""
    loop = FaultTolerantLoop(str(tmp_path), every=0)
    time.sleep(0.25)  # "restore / compile" happening before step 0
    state = {"x": jnp.zeros(2)}
    loop.after_step(0, state)
    assert loop.monitor.times == []  # no inter-step interval exists yet
    loop.after_step(1, state)
    assert len(loop.monitor.times) == 1 and loop.monitor.times[0] < 0.2
    loop.close()


def test_checkpoint_now_skips_step_already_saved(tmp_path):
    """A preemption landing on a periodic-checkpoint boundary used to
    serialize the same step twice."""
    loop = FaultTolerantLoop(str(tmp_path), every=2)
    state = {"x": jnp.zeros(2)}
    loop.after_step(0, state)
    loop.after_step(1, state)  # periodic save of step 1
    loop.checkpoint_now()      # must NOT re-save step 1
    loop.manager.flush()
    assert loop.manager.saved_steps == [1]
    loop.after_step(2, state)  # not on the boundary
    loop.checkpoint_now()      # step 2 unsaved -> saves
    loop.manager.flush()
    assert loop.manager.saved_steps == [1, 2]
    loop.checkpoint_now()      # idempotent: still nothing new
    loop.manager.flush()
    assert loop.manager.saved_steps == [1, 2]
    loop.close()


def test_restart_determinism_with_pipeline(tmp_path):
    """Crash + resume replays the identical batch sequence (data keyed by step)."""
    from repro.data.tokens import SyntheticTokenPipeline

    pipe = SyntheticTokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=3)
    ref = [pipe.host_batch(s)["tokens"] for s in range(6)]
    # "crash" at step 3; new process, new pipeline object:
    pipe2 = SyntheticTokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=3)
    resumed = [pipe2.host_batch(s)["tokens"] for s in range(3, 6)]
    for a, b in zip(ref[3:], resumed):
        np.testing.assert_array_equal(a, b)
