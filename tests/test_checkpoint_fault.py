"""Checkpointing (atomic, async, retention, elastic restore) + fault runtime."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)
from repro.runtime.fault import FaultTolerantLoop, StragglerMonitor, plan_remesh

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path), 3)
    assert latest_step(str(tmp_path)) == 3
    back = restore_pytree(jax.tree.map(jnp.zeros_like, t), str(tmp_path))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, back)


def test_atomic_no_tmp_left(tmp_path):
    save_pytree(_tree(), str(tmp_path), 1)
    assert not any(n.startswith("tmp.") for n in os.listdir(tmp_path))


def test_async_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(_tree(s), s)
    mgr.flush()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    mgr.close()


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-lays arrays onto a (different) mesh via device_put."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_pytree(t, str(tmp_path), 0)
    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    back = restore_pytree(t, str(tmp_path), shardings=sh)
    assert back["layers"]["w"].sharding == NamedSharding(mesh, P())


def test_fault_loop_resume(tmp_path):
    loop = FaultTolerantLoop(str(tmp_path), every=2)
    state = {"x": jnp.zeros(3)}
    for step in range(5):
        state = {"x": state["x"] + 1}
        loop.after_step(step, state)
    loop.checkpoint_now()
    loop.close()

    loop2 = FaultTolerantLoop(str(tmp_path), every=2)
    restored, start = loop2.restore_or({"x": jnp.zeros(3)})
    assert start == 5
    np.testing.assert_array_equal(restored["x"], np.full(3, 5.0))
    loop2.close()


def test_straggler_monitor():
    flagged = []
    mon = StragglerMonitor(threshold=2.0, on_straggle=lambda s, t, m: flagged.append(s))
    for i in range(20):
        mon.record(i, 0.1)
    mon.record(20, 0.5)  # 5× median
    assert flagged == [20]
    assert mon.record(21, 0.1) is False


def test_plan_remesh():
    p = plan_remesh(512)
    assert (p.data, p.model, p.dropped_devices) == (32, 16, 0)
    p = plan_remesh(500)  # lost 12 devices
    assert p.model == 16 and p.data == 31 and p.dropped_devices == 4
    p = plan_remesh(7, model_divisors=(16, 8, 4, 2, 1))
    assert p.world <= 7 and p.model in (4, 2, 1)
    with pytest.raises(ValueError):
        plan_remesh(0)


def test_restart_determinism_with_pipeline(tmp_path):
    """Crash + resume replays the identical batch sequence (data keyed by step)."""
    from repro.data.tokens import SyntheticTokenPipeline

    pipe = SyntheticTokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=3)
    ref = [pipe.host_batch(s)["tokens"] for s in range(6)]
    # "crash" at step 3; new process, new pipeline object:
    pipe2 = SyntheticTokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=3)
    resumed = [pipe2.host_batch(s)["tokens"] for s in range(3, 6)]
    for a, b in zip(ref[3:], resumed):
        np.testing.assert_array_equal(a, b)
