"""Sequence-mixer correctness: chunkwise-parallel forms vs step recurrences
(the weak-memory chunk-halo equivalence at the mixer level)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.ssm import mamba2_apply, mamba2_init, mamba2_state_spec
from repro.models.xlstm import (
    mlstm_apply,
    mlstm_init,
    mlstm_state_spec,
    slstm_apply,
    slstm_init,
    slstm_state_spec,
)

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow


B = 2


def _zero_state(spec, minus_inf_keys=()):
    return {
        k: (jnp.full(s.shape, -1e30, s.dtype) if k in minus_inf_keys else jnp.zeros(s.shape, s.dtype))
        for k, s in spec.items()
    }


@pytest.mark.parametrize("s", [32, 64, 100])
def test_mamba2_chunk_equals_recurrence(s):
    r = ARCHS["zamba2-7b"].reduced()
    p = mamba2_init(jax.random.PRNGKey(0), r, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, s, r.d_model)) * 0.5
    y_chunk, st_chunk = mamba2_apply(p, x, r, return_state=True)
    st = _zero_state(mamba2_state_spec(r, B, dtype=jnp.float32))
    ys = []
    for t in range(s):
        y_t, st = mamba2_apply(p, x[:, t : t + 1], r, state=st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_chunk["ssd"], st["ssd"], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s,chunk", [(64, 16), (50, 16), (32, 32)])
def test_mlstm_chunk_equals_recurrence(s, chunk):
    r = ARCHS["xlstm-125m"].reduced()
    p = mlstm_init(jax.random.PRNGKey(2), r, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, s, r.d_model)) * 0.5
    y_chunk, _ = mlstm_apply(p, x, r, return_state=True, chunk=chunk)
    st = _zero_state(mlstm_state_spec(r, B), minus_inf_keys=("m",))
    ys = []
    for t in range(s):
        y_t, st = mlstm_apply(p, x[:, t : t + 1], r, state=st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, rtol=2e-4, atol=2e-4)


def test_mlstm_state_carries_across_segments():
    """prefill(x[:k]) state + forward(x[k:]) == forward(x) — the paper's
    halo-carried-state claim for chunk-index weak memory."""
    r = ARCHS["xlstm-125m"].reduced()
    p = mlstm_init(jax.random.PRNGKey(4), r, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 64, r.d_model)) * 0.5
    y_full, _ = mlstm_apply(p, x, r, return_state=True, chunk=16)
    y1, st = mlstm_apply(p, x[:, :32], r, return_state=True, chunk=16)
    y2, _ = mlstm_apply(p, x[:, 32:], r, state=st, chunk=16)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=1), y_full, rtol=2e-4, atol=2e-4
    )


def test_mamba2_state_carries_across_segments():
    r = ARCHS["zamba2-7b"].reduced()
    p = mamba2_init(jax.random.PRNGKey(6), r, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, 64, r.d_model)) * 0.5
    y_full, _ = mamba2_apply(p, x, r, return_state=True)
    y1, st = mamba2_apply(p, x[:, :32], r, return_state=True)
    y2, _ = mamba2_apply(p, x[:, 32:], r, state=st)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=1), y_full, rtol=1e-4, atol=1e-4
    )


def test_slstm_deterministic_recurrence():
    r = ARCHS["xlstm-125m"].reduced()
    p = slstm_init(jax.random.PRNGKey(8), r, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, 40, r.d_model)) * 0.5
    y, st = slstm_apply(p, x, r, return_state=True)
    assert bool(jnp.all(jnp.isfinite(y)))
    # segment-carry equivalence
    y1, st1 = slstm_apply(p, x[:, :20], r, return_state=True)
    y2, _ = slstm_apply(p, x[:, 20:], r, state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y, rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_dense():
    from repro.kernels.swa_attention.ref import swa_attention_ref
    from repro.models.attention import _chunked_attention

    b, s, h, hd = 2, 128, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(10), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(11), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(12), (b, s, h, hd))
    # full causal via window=None
    out = _chunked_attention(
        q.reshape(b, s, h, 1, hd), k, v, hd**-0.5, chunk=32
    ).reshape(b, s, h, hd)
    ref = swa_attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1), window=s
    )
    np.testing.assert_allclose(out, jnp.moveaxis(ref, 1, 2), rtol=1e-4, atol=1e-5)
