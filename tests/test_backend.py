"""Backend-registry parity suite.

Pins the tentpole contract of the compute registry (`repro.core.backend`):
every primitive produces the same numbers on "jnp" and "pallas" (interpret
mode on CPU) — across dtypes (f32/bf16), 1-D vs (n, d) inputs, tiny series,
and through every layer that routes through the registry (serial, blocked,
sharded, streaming update/merge, serving, map-reduce chunk kernels).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import (
    JnpBackend,
    PallasBackend,
    get_backend,
    list_backends,
    register_backend,
)
from repro.core.estimators.stats import (
    autocovariance,
    autocovariance_blocked,
    gamma_normalizer,
    lag_sum_engine,
    raw_lag_sums,
    streaming_autocovariance,
    windowed_moments,
)
from repro.core.estimators.spectral import streaming_welch, welch_engine, welch_psd
from repro.core.estimators.yule_walker import yule_walker
from repro.core.estimators.spatial import banded_predict, banded_to_dense

pytestmark = pytest.mark.backend

JNP = get_backend("jnp")
PALLAS = get_backend("pallas")


def _series(n, d, dtype=jnp.float32, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d) if d else (n,))
    return x.astype(dtype)


# ------------------------------------------------------------ registry --
def test_registry_contents_and_resolution():
    assert {"jnp", "pallas", "auto"} <= set(list_backends())
    assert get_backend(None).name == "auto"
    assert get_backend("jnp") is JNP
    assert get_backend(JNP) is JNP  # instances pass through
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_register_new_backend_reaches_estimators():
    class Recording(JnpBackend):
        name = "recording"
        calls = 0

        def lagged_sums(self, x, max_lag):
            Recording.calls += 1
            return super().lagged_sums(x, max_lag)

    register_backend("recording", Recording())
    x = _series(200, 2)
    g = autocovariance(x, 3, backend="recording")
    assert Recording.calls == 1
    np.testing.assert_allclose(g, autocovariance(x, 3, backend="jnp"), rtol=1e-6)


def test_auto_backend_is_jnp_off_tpu():
    # On CPU the "auto" policy must never route to (slow) interpret Pallas.
    x = _series(5000, 2)
    np.testing.assert_array_equal(
        get_backend("auto").lagged_sums(x, 4), JNP.lagged_sums(x, 4)
    )


# ---------------------------------------------------- primitive parity --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [0, 1, 3])  # 0 → 1-D series
@pytest.mark.parametrize("n,max_lag", [(257, 7), (64, 0), (33, 32)])
def test_lagged_sums_parity(n, max_lag, d, dtype):
    x = _series(n, d, dtype)
    ref = JNP.lagged_sums(x, max_lag)
    out = PALLAS.lagged_sums(x, max_lag)
    assert out.dtype == jnp.float32
    tol = 1e-5 * n if dtype == jnp.float32 else 1e-2 * n
    np.testing.assert_allclose(out, ref, atol=tol)


@pytest.mark.parametrize("n,max_lag", [(3, 8), (1, 4), (2, 0), (8, 8)])
def test_lagged_sums_tiny_series(n, max_lag):
    """Tiny series (n < max_lag): positive grid, exact vs the serial oracle
    (regression for the window_stats block_t clamping)."""
    x = _series(n, 2, seed=5)
    ref = JNP.lagged_sums(x, max_lag)
    np.testing.assert_allclose(PALLAS.lagged_sums(x, max_lag), ref, atol=1e-5)
    # explicit oracle: brute-force the ragged sum
    xs = np.asarray(x)
    for h in range(max_lag + 1):
        brute = sum(
            np.outer(xs[k], xs[k + h]) for k in range(max(n - h, 0))
        ) if n - h > 0 else np.zeros((2, 2))
        np.testing.assert_allclose(ref[h], brute, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_lagged_sums_parity(dtype):
    H, L = 6, 48
    y = _series(L + H, 3, dtype, seed=1)
    mask = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (L,))
    ref = JNP.masked_lagged_sums(y, mask, H)
    out = PALLAS.masked_lagged_sums(y, mask, H)
    np.testing.assert_allclose(out, ref, atol=1e-3)
    # serial oracle over unmasked starts
    ys, ms = np.asarray(y, np.float32), np.asarray(mask)
    for h in range(H + 1):
        brute = sum(np.outer(ys[s], ys[s + h]) for s in range(L) if ms[s])
        np.testing.assert_allclose(np.asarray(ref)[h], brute, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nrhs", [0, 4])  # 0 → 1-D vector
def test_banded_matvec_parity(dtype, nrhs):
    d, b = 70, 3
    diags = _series(d, 2 * b + 1, dtype, seed=3)
    x = _series(d, 0, dtype, seed=4) if nrhs == 0 else _series(nrhs, d, dtype, seed=4)
    ref = JNP.banded_matvec(diags, x)
    out = PALLAS.banded_matvec(diags, x)
    assert out.shape == ref.shape == x.shape
    np.testing.assert_allclose(out, ref, atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    # dense oracle (f32 path)
    if dtype == jnp.float32 and nrhs == 0:
        dense = np.asarray(banded_to_dense(diags)) @ np.asarray(x)
        np.testing.assert_allclose(out, dense, atol=1e-4)


@pytest.mark.parametrize("n,window", [(200, 16), (17, 17), (40, 1)])
def test_windowed_moments_parity(n, window):
    x = _series(n, 3, seed=6)
    ref = JNP.windowed_moments(x, window)
    out = PALLAS.windowed_moments(x, window)
    assert out.shape == (n - window + 1, 2, 3)
    np.testing.assert_allclose(out, ref, atol=1e-4)
    with pytest.raises(ValueError):
        PALLAS.windowed_moments(x, n + 1)


@pytest.mark.parametrize(
    "max_lag,windows",
    [(6, (10, 3, 24)), (0, (1, 2)), (8, (16,)), (0, (33, 1, 7, 16))],
)
def test_fused_lagged_moments_multi_window_parity(max_lag, windows):
    """The fused primitive accepts a tuple of distinct moment windows: one
    traversal emits every window's sums, matching both the per-window
    single calls and the naive reference, on jnp AND the Pallas VMEM
    kernel (interpret mode on CPU) — including unsorted window order."""
    from repro.kernels.window_stats.ref import fused_lag_moments_ref

    y = _series(300, 3, seed=11)
    mask = jax.random.bernoulli(jax.random.PRNGKey(12), 0.7, (280,))
    lag_r, mom_r = fused_lag_moments_ref(y, mask, max_lag, windows)
    assert mom_r.shape == (len(windows), 2, 3)
    for be in (JNP, PALLAS):
        lag, mom = be.fused_lagged_moments(y, mask, max_lag, windows)
        np.testing.assert_allclose(lag, lag_r, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(mom, mom_r, rtol=1e-5, atol=1e-4)
        for k, w in enumerate(windows):
            _, mom_one = be.fused_lagged_moments(y, mask, max_lag, w)
            np.testing.assert_allclose(mom[k], mom_one, rtol=1e-5, atol=1e-4)


def test_fused_lagged_moments_window_validation():
    y = _series(64, 2, seed=13)
    mask = jnp.ones((60,), jnp.bool_)
    for be in (JNP, PALLAS):
        with pytest.raises(ValueError, match="distinct"):
            be.fused_lagged_moments(y, mask, 2, (8, 8))
        with pytest.raises(ValueError, match="positive"):
            be.fused_lagged_moments(y, mask, 2, (8, 0))
        with pytest.raises(ValueError, match="window"):
            be.fused_lagged_moments(y, mask, 2, ())


@pytest.mark.parametrize("detrend", [True, False])
@pytest.mark.parametrize(
    "S,L,d", [(5, 64, 2), (3, 33, 1), (9, 16, 5), (1, 256, 3), (17, 8, 2)]
)
def test_segment_fft_power_parity(S, L, d, detrend):
    """The Pallas twiddle-matmul DFT ≡ the jnp rfft oracle across segment
    counts (incl. non-block_s multiples), segment lengths (incl. odd L —
    the F = L//2+1 one-sided grid), and channel counts."""
    segs = jax.random.normal(jax.random.PRNGKey(7), (S, L, d))
    taper = jnp.hanning(L)
    ref = JNP.segment_fft_power(segs, taper, detrend)
    out = PALLAS.segment_fft_power(segs, taper, detrend)
    assert out.shape == ref.shape == (S, L // 2 + 1, d)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-4 * L)
    # and against the standalone matmul oracle (tiling check, tighter tol)
    from repro.kernels.segment_dft import segment_fft_power_reference

    np.testing.assert_allclose(
        out, segment_fft_power_reference(segs, taper, detrend),
        rtol=1e-5, atol=1e-5 * L,
    )


@pytest.mark.parametrize("detrend", [True, False])
@pytest.mark.parametrize("S,L,d", [(5, 64, 2), (3, 17, 1), (9, 16, 3), (1, 32, 2)])
def test_segment_csd_parity(S, L, d, detrend):
    """Complex cross-spectra from four real contractions: the Pallas
    ``segment_csd`` (re/im twiddle matmuls + channel outer products,
    recombined off-kernel) ≡ the jnp rfft oracle, Hermitian per (i, j),
    with the diagonal equal to ``segment_fft_power``."""
    segs = jax.random.normal(jax.random.PRNGKey(11), (S, L, d))
    taper = jnp.hanning(L)
    ref = JNP.segment_csd(segs, taper, detrend)
    out = PALLAS.segment_csd(segs, taper, detrend)
    assert out.shape == ref.shape == (S, L // 2 + 1, d, d)
    assert jnp.iscomplexobj(out)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-4 * L)
    # Hermitian in the channel pair, diagonal == the PSD primitive
    np.testing.assert_allclose(
        np.asarray(out), np.conj(np.swapaxes(np.asarray(out), 2, 3)),
        atol=1e-5 * L,
    )
    power = PALLAS.segment_fft_power(segs, taper, detrend)
    diag = np.real(np.asarray(out)[:, :, np.arange(d), np.arange(d)])
    np.testing.assert_allclose(diag, power, rtol=1e-3, atol=1e-4 * L)


def test_welch_csd_cross_backend():
    from repro.core.estimators.spectral import welch_csd

    x = _series(2048, 3, seed=21)
    fj, cj = welch_csd(x, nperseg=64, backend="jnp")
    fp, cp = welch_csd(x, nperseg=64, backend="pallas")
    np.testing.assert_allclose(fj, fp)
    np.testing.assert_allclose(cj, cp, rtol=2e-3, atol=1e-5)


def test_segment_fft_power_large_L_twiddle_precision():
    """The twiddle phase index t·f overflows f32 past L ≈ 4k; the exact
    mod-L integer reduction keeps the matmul DFT tight at the sizes the
    calibrated auto policy routes to it."""
    L = 4096
    segs = jax.random.normal(jax.random.PRNGKey(30), (2, L, 1))
    taper = jnp.hanning(L)
    ref = JNP.segment_fft_power(segs, taper)
    out = PALLAS.segment_fft_power(segs, taper)
    err = float(jnp.max(jnp.abs(out - ref))) / float(jnp.max(ref))
    assert err < 5e-5, f"relative-to-peak error {err:.2e}"


def test_segment_fft_power_bf16_and_validation():
    segs = jax.random.normal(jax.random.PRNGKey(7), (4, 32, 2), jnp.bfloat16)
    taper = jnp.hanning(32)
    out = PALLAS.segment_fft_power(segs, taper)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, JNP.segment_fft_power(segs, taper), rtol=5e-2, atol=1e-1 * 32
    )
    from repro.kernels.segment_dft import segment_fft_power

    with pytest.raises(ValueError, match="taper"):
        segment_fft_power(segs.astype(jnp.float32), jnp.hanning(16))


# ------------------------------------------------- estimator-level parity --
def test_autocovariance_cross_backend():
    x = _series(2000, 3, seed=8)
    gj = autocovariance(x, 8, backend="jnp")
    gp = autocovariance(x, 8, backend="pallas")
    np.testing.assert_allclose(gp, gj, atol=1e-4)
    gb = autocovariance_blocked(x, 8, 128, backend="pallas")
    np.testing.assert_allclose(gb, gj, atol=1e-4)


def test_yule_walker_cross_backend_and_series_input():
    x = _series(3000, 2, seed=9)
    Aj, sj = yule_walker(x, 3, backend="jnp")
    Ap, sp = yule_walker(x, 3, backend="pallas")
    np.testing.assert_allclose(Ap, Aj, atol=1e-4)
    np.testing.assert_allclose(sp, sj, atol=1e-4)
    # series input ≡ explicit gamma input
    g = autocovariance(x, 3, normalization="standard")
    Ag, _ = yule_walker(g, 3)
    np.testing.assert_allclose(Aj, Ag, atol=1e-5)


def test_welch_cross_backend():
    x = _series(2048, 2, seed=10)
    fj, pj = welch_psd(x, 128, backend="jnp")
    fp, pp = welch_psd(x, 128, backend="pallas")
    np.testing.assert_allclose(pp, pj, atol=1e-4)
    np.testing.assert_array_equal(fj, fp)


@pytest.mark.parametrize("d", [1, 3])
@pytest.mark.parametrize(
    "nperseg,overlap", [(64, 32), (64, 0), (32, 24), (50, 25)]
)
def test_welch_parity_across_segment_geometry(nperseg, overlap, d):
    """Welch through the Pallas DFT kernel ≡ jnp rfft across segment
    lengths L, steps (L − overlap), and channel counts — the estimator-level
    pin of the new spectral primitive."""
    x = _series(1200, d, seed=20)
    fj, pj = welch_psd(x, nperseg, overlap=overlap, backend="jnp")
    fp, pp = welch_psd(x, nperseg, overlap=overlap, backend="pallas")
    np.testing.assert_array_equal(fj, fp)
    np.testing.assert_allclose(pp, pj, rtol=1e-3, atol=1e-4)


def test_fused_plan_welch_rides_pallas_spectral():
    """A fused plan containing a Welch member stays backend-uniform: the
    pallas-compiled plan (spectral member included) matches the jnp plan —
    previously the spectral member silently ejected to jnp."""
    from repro.core.plan import (
        analyze,
        autocovariance_request,
        moments_request,
        welch_request,
    )

    x = _series(900, 2, seed=21)
    reqs = lambda: [
        welch_request(64),
        autocovariance_request(4),
        moments_request(16),
    ]
    rj = analyze(x, reqs(), backend="jnp")
    rp = analyze(x, reqs(), backend="pallas")
    np.testing.assert_allclose(rp["welch"][1], rj["welch"][1], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(rp["autocovariance"], rj["autocovariance"], atol=1e-4)
    np.testing.assert_allclose(rp["moments"]["var"], rj["moments"]["var"], atol=1e-4)


# ------------------------------------------------- streaming path parity --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streaming_update_merge_parity(dtype):
    """Pallas-chunk-kernel streaming ≡ jnp streaming ≡ serial, through
    uneven update chunks AND a two-segment merge."""
    H, d = 5, 2
    x = _series(901, d, dtype, seed=11)
    serial = autocovariance(x.astype(jnp.float32), H, backend="jnp")

    for be in ["jnp", "pallas"]:
        eng = lag_sum_engine(H, d, backend=be)
        left, right = eng.init(), eng.init(t0=400)
        for c in jnp.split(x[:400], [3, 139]):
            left = eng.update(left, c)
        for c in jnp.split(x[400:], [256]):
            right = eng.update(right, c)
        merged = eng.merge(right, left)  # commutative: reversed order
        got = streaming_autocovariance(eng, merged)
        tol = 1e-4 * x.shape[0] if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(got, serial, atol=tol)


def test_streaming_welch_backend_threading():
    x = _series(1500, 2, seed=12)
    f_ref, p_ref = welch_psd(x, 128)
    eng = welch_engine(128, d=2, backend="pallas")
    assert eng.backend is PALLAS
    st = eng.init()
    for c in jnp.split(x, [333, 900]):
        st = eng.update(st, c)
    f, p = streaming_welch(eng, st)
    np.testing.assert_allclose(p, p_ref, atol=1e-4)


def test_mapreduce_chunk_kernel_path():
    """block_partials' fused chunk-kernel path ≡ the per-window vmap path."""
    from repro.core.mapreduce import block_window_map_reduce, serial_window_map_reduce
    from repro.core.overlap import OverlapSpec

    H, d = 4, 2
    x = _series(513, d, seed=13)
    kernel = lambda w: jnp.einsum("i,tj->tij", w[0], w)  # lag sums, per window

    serial = serial_window_map_reduce(kernel, x, 0, H)
    spec = OverlapSpec(n=x.shape[0], block_size=64, h_left=0, h_right=H)
    for be in ["jnp", "pallas"]:
        ck = lambda y, m: get_backend(be).masked_lagged_sums(y, m, H)
        got = block_window_map_reduce(None, x, spec, chunk_kernel=ck)
        np.testing.assert_allclose(got, serial, atol=1e-4)


def test_banded_predict_backend():
    diags = _series(64, 7, seed=14)
    x = _series(5, 64, seed=15)
    np.testing.assert_allclose(
        banded_predict(diags, x, backend="pallas"),
        banded_predict(diags, x, backend="jnp"),
        atol=1e-5,
    )


def test_band_transpose_is_matrix_transpose():
    from repro.kernels.banded_matvec.ops import band_transpose

    from repro.core.estimators.spatial import dense_to_banded

    # canonical storage: off-matrix slots zeroed (transpose zeroes them too)
    diags = dense_to_banded(banded_to_dense(_series(37, 5, seed=22)), 2)
    np.testing.assert_allclose(
        banded_to_dense(band_transpose(diags)),
        banded_to_dense(diags).T,
        atol=1e-6,
    )
    # involution on canonical storage
    np.testing.assert_allclose(
        band_transpose(band_transpose(diags)), diags, atol=1e-6
    )


def test_banded_matvec_custom_vjp_matches_jnp_grad():
    """The Pallas banded matvec is differentiable: both cotangents (w.r.t.
    the diagonals and the vector) match jax.grad through the jnp gather
    oracle — the satellite unblocking `fit_banded_ar` from the jnp pin."""
    d, b, T = 48, 2, 6
    diags = 0.1 * _series(d, 2 * b + 1, seed=23)
    X = _series(T, d, seed=24)

    def loss(be):
        return lambda dg, xx: jnp.sum(jnp.sin(banded_predict(dg, xx, backend=be)) ** 2)

    gj_d, gj_x = jax.grad(loss("jnp"), argnums=(0, 1))(diags, X)
    gp_d, gp_x = jax.grad(loss("pallas"), argnums=(0, 1))(diags, X)
    np.testing.assert_allclose(gp_d, gj_d, atol=1e-4)
    np.testing.assert_allclose(gp_x, gj_x, atol=1e-4)


def test_fit_banded_ar_runs_on_pallas_backend():
    from repro.core.estimators.spatial import fit_banded_ar

    xs = _series(200, 16, seed=25)
    fj = fit_banded_ar(xs, 2, n_steps=5, backend="jnp")
    fp = fit_banded_ar(xs, 2, n_steps=5, backend="pallas")
    np.testing.assert_allclose(fp.diags, fj.diags, atol=1e-4)
    np.testing.assert_allclose(fp.nll_trace, fj.nll_trace, rtol=1e-5)


# ----------------------------------------------------------- regressions --
def test_gamma_normalizer_clamped_near_series_end():
    """paper-normalization divisor n-h-1 ≤ 0 when max_lag ≥ n-1: clamped to
    1, never ±inf (regression)."""
    norm = np.asarray(gamma_normalizer(5, 5, "paper"))
    assert np.all(np.isfinite(norm)) and np.all(norm > 0)
    x = _series(5, 2, seed=16)
    for be in ["jnp", "pallas"]:
        g = autocovariance(x, 4, normalization="paper", backend=be)
        assert np.all(np.isfinite(np.asarray(g)))
    # kernel-wrapper normalizer agrees
    from repro.kernels.window_stats import ops as ws

    gk = ws.autocovariance(x, 4, interpret=True, normalization="paper")
    np.testing.assert_allclose(
        gk, autocovariance(x, 4, normalization="paper", backend="jnp"), atol=1e-5
    )


def test_windowed_moments_high_mean_variance():
    """Var via E[x²]−E[x]² cancels in f32 for high-mean series; the estimator
    centers globally first and clamps at 0 (regression)."""
    # offset 100 / signal 1e-2: far beyond naive E[x²]−E[x]² f32 cancellation
    # (ulp(1e4) ≈ 1e-3 ≫ var ≈ 1e-4) yet cleanly representable in the input.
    noise = 1e-2 * jax.random.normal(jax.random.PRNGKey(18), (512, 1))
    x = 100.0 + noise
    for be in ["jnp", "pallas"]:
        wm = windowed_moments(x, 64, backend=be)
        assert np.all(np.asarray(wm["var"]) >= 0)
        ref_var = np.var(np.asarray(x)[:64].astype(np.float64))
        np.testing.assert_allclose(np.asarray(wm["var"])[0, 0], ref_var, rtol=0.05)
        np.testing.assert_allclose(np.asarray(wm["mean"])[0, 0], np.mean(np.asarray(x)[:64]), rtol=1e-6)
    # extreme offset: clamping keeps the degenerate regime non-negative
    wm = windowed_moments(1e4 + noise, 64, backend="jnp")
    assert np.all(np.asarray(wm["var"]) >= 0)


def test_raw_lag_sums_tiny_series_no_crash():
    # seed behaviour: negative dynamic_slice size when n ≤ max_lag
    s = raw_lag_sums(_series(3, 2, seed=17), 8)
    assert s.shape == (9, 2, 2) and np.all(np.isfinite(np.asarray(s)))
