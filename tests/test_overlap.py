"""Overlapping block data structure (paper §10) — construction invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.overlap import (
    OverlapSpec,
    block_core,
    core_mask,
    make_overlapping_blocks,
    reconstruct,
    replication_overhead,
)


@pytest.mark.parametrize("n,bs,hl,hr", [(100, 10, 3, 5), (97, 16, 0, 7), (64, 64, 2, 2), (10, 3, 4, 4)])
def test_roundtrip(n, bs, hl, hr):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    spec = OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    blocks, mask = make_overlapping_blocks(x, spec)
    assert blocks.shape == (spec.num_blocks, spec.padded_width, 4)
    np.testing.assert_allclose(reconstruct(blocks, spec), x, rtol=0, atol=0)


def test_halo_slots_are_replicas():
    n, bs, h = 64, 16, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    spec = OverlapSpec(n=n, block_size=bs, h_left=h, h_right=h)
    blocks, mask = make_overlapping_blocks(x, spec)
    # block i's left halo == block i-1's core tail
    for i in range(1, spec.num_blocks):
        np.testing.assert_array_equal(
            blocks[i, :h], blocks[i - 1, h + bs - h : h + bs]
        )


def test_boundary_zero_fill():
    x = jnp.ones((20, 1))
    spec = OverlapSpec(n=20, block_size=5, h_left=2, h_right=3)
    blocks, mask = make_overlapping_blocks(x, spec)
    assert float(blocks[0, :2].sum()) == 0.0  # before series start
    assert float(blocks[-1, -3:].sum()) == 0.0  # past series end
    assert not bool(mask[0, 0]) and bool(mask[0, 2])


def test_replication_overhead_formula():
    spec = OverlapSpec(n=1000, block_size=100, h_left=5, h_right=5)
    ov = replication_overhead(spec)
    assert ov == pytest.approx(10 * 110 / 1000 - 1.0)


def test_core_mask_tail_padding():
    spec = OverlapSpec(n=10, block_size=4, h_left=1, h_right=1)
    m = core_mask(spec)
    assert m.shape == (3, 4)
    assert m[:2].all() and list(m[2]) == [True, True, False, False]


@pytest.mark.parametrize(
    "n,bs,hl,hr",
    [
        (5, 8, 0, 2),   # block_size > n: single partially-filled block
        (5, 64, 3, 3),  # block_size ≫ n
        (40, 4, 6, 9),  # halo ≥ block_size on both sides
        (40, 4, 4, 4),  # halo == block_size
        (7, 11, 13, 17),  # block_size > n AND halo > block_size
    ],
)
def test_edge_geometry_roundtrip(n, bs, hl, hr):
    """Streaming relies on degenerate geometries (tiny chunks, wide halos):
    reconstruct ∘ make_overlapping_blocks must stay exact there."""
    x = jax.random.normal(jax.random.PRNGKey(n * 31 + bs), (n, 3))
    spec = OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    blocks, mask = make_overlapping_blocks(x, spec)
    assert blocks.shape == (spec.num_blocks, spec.padded_width, 3)
    # every invalid slot is zero-filled, every valid slot is real data
    np.testing.assert_array_equal(np.asarray(blocks)[~np.asarray(mask)], 0.0)
    np.testing.assert_array_equal(np.asarray(reconstruct(blocks, spec)), np.asarray(x))


def test_block_size_exceeding_n_single_block():
    spec = OverlapSpec(n=5, block_size=8, h_left=0, h_right=2)
    assert spec.num_blocks == 1
    x = jnp.arange(5.0)[:, None]
    blocks, mask = make_overlapping_blocks(x, spec)
    # core holds the 5 real samples then tail padding; halo is all padding
    np.testing.assert_array_equal(np.asarray(blocks[0, :5, 0]), np.arange(5.0))
    assert float(jnp.abs(blocks[0, 5:]).sum()) == 0.0
    assert not bool(mask[0, 5])


def test_halo_wider_than_block_replicas():
    """halo ≥ block_size: halos span several neighbouring cores, and interior
    blocks still replicate exactly the global slice around their core."""
    n, bs, h = 24, 3, 7
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 2))
    spec = OverlapSpec(n=n, block_size=bs, h_left=h, h_right=h)
    blocks, _ = make_overlapping_blocks(x, spec)
    i = 3  # interior block: [i*bs - h, (i+1)*bs + h) is fully in range
    np.testing.assert_array_equal(
        np.asarray(blocks[i]), np.asarray(x[i * bs - h : (i + 1) * bs + h])
    )


def test_replication_overhead_monotonicity():
    """Overhead grows with halo width and shrinks with block size (the
    paper's parallelism-vs-replication trade, §10)."""
    n = 4096
    ovs = [
        replication_overhead(OverlapSpec(n=n, block_size=64, h_left=h, h_right=h))
        for h in range(0, 33, 4)
    ]
    assert all(b > a for a, b in zip(ovs, ovs[1:]))
    ovs_bs = [
        replication_overhead(OverlapSpec(n=n, block_size=bs, h_left=8, h_right=8))
        for bs in (16, 32, 64, 128, 256)
    ]
    assert all(b < a for a, b in zip(ovs_bs, ovs_bs[1:]))
    # and with no halo + exact tiling there is no overhead at all
    assert replication_overhead(
        OverlapSpec(n=n, block_size=64, h_left=0, h_right=0)
    ) == pytest.approx(0.0)


def test_invalid_specs_raise():
    with pytest.raises(ValueError):
        OverlapSpec(n=0, block_size=4, h_left=0, h_right=0)
    with pytest.raises(ValueError):
        OverlapSpec(n=10, block_size=0, h_left=0, h_right=0)
    with pytest.raises(ValueError):
        OverlapSpec(n=10, block_size=4, h_left=-1, h_right=0)
