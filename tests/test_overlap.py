"""Overlapping block data structure (paper §10) — construction invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.overlap import (
    OverlapSpec,
    block_core,
    core_mask,
    make_overlapping_blocks,
    reconstruct,
    replication_overhead,
)


@pytest.mark.parametrize("n,bs,hl,hr", [(100, 10, 3, 5), (97, 16, 0, 7), (64, 64, 2, 2), (10, 3, 4, 4)])
def test_roundtrip(n, bs, hl, hr):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    spec = OverlapSpec(n=n, block_size=bs, h_left=hl, h_right=hr)
    blocks, mask = make_overlapping_blocks(x, spec)
    assert blocks.shape == (spec.num_blocks, spec.padded_width, 4)
    np.testing.assert_allclose(reconstruct(blocks, spec), x, rtol=0, atol=0)


def test_halo_slots_are_replicas():
    n, bs, h = 64, 16, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    spec = OverlapSpec(n=n, block_size=bs, h_left=h, h_right=h)
    blocks, mask = make_overlapping_blocks(x, spec)
    # block i's left halo == block i-1's core tail
    for i in range(1, spec.num_blocks):
        np.testing.assert_array_equal(
            blocks[i, :h], blocks[i - 1, h + bs - h : h + bs]
        )


def test_boundary_zero_fill():
    x = jnp.ones((20, 1))
    spec = OverlapSpec(n=20, block_size=5, h_left=2, h_right=3)
    blocks, mask = make_overlapping_blocks(x, spec)
    assert float(blocks[0, :2].sum()) == 0.0  # before series start
    assert float(blocks[-1, -3:].sum()) == 0.0  # past series end
    assert not bool(mask[0, 0]) and bool(mask[0, 2])


def test_replication_overhead_formula():
    spec = OverlapSpec(n=1000, block_size=100, h_left=5, h_right=5)
    ov = replication_overhead(spec)
    assert ov == pytest.approx(10 * 110 / 1000 - 1.0)


def test_core_mask_tail_padding():
    spec = OverlapSpec(n=10, block_size=4, h_left=1, h_right=1)
    m = core_mask(spec)
    assert m.shape == (3, 4)
    assert m[:2].all() and list(m[2]) == [True, True, False, False]


def test_invalid_specs_raise():
    with pytest.raises(ValueError):
        OverlapSpec(n=0, block_size=4, h_left=0, h_right=0)
    with pytest.raises(ValueError):
        OverlapSpec(n=10, block_size=0, h_left=0, h_right=0)
    with pytest.raises(ValueError):
        OverlapSpec(n=10, block_size=4, h_left=-1, h_right=0)
