"""Welch spectral estimation (overlap structure in the frequency domain)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators.spectral import (
    ar1_theoretical_psd,
    hann_window,
    welch_csd,
    welch_psd,
)
from repro.timeseries import simulate_var


def test_white_noise_flat_psd_and_parseval():
    x = jax.random.normal(jax.random.PRNGKey(0), (200_000, 2)) * 2.0
    freqs, psd = welch_psd(x, nperseg=512)
    # Parseval: ∫psd df = var (one-sided, fs=1 → df = 1/nperseg)
    power = jnp.sum(psd, axis=0) / 512
    np.testing.assert_allclose(power, jnp.var(x, axis=0), rtol=0.05)
    # flatness: mid-band variation small
    mid = psd[5:-5, 0]
    assert float(mid.std() / mid.mean()) < 0.15


def test_ar1_matches_theoretical_spectrum():
    phi = 0.7
    A = jnp.asarray([[[phi]]])
    xs = simulate_var(jax.random.PRNGKey(1), A, 400_000)
    freqs, psd = welch_psd(xs, nperseg=256)
    theo = ar1_theoretical_psd(phi, 1.0, freqs)
    # compare away from DC (window bias largest there)
    ratio = psd[3:, 0] / theo[3:]
    assert float(jnp.abs(ratio - 1.0).mean()) < 0.1


def test_csd_hermitian_and_diagonal_consistency():
    xs = jax.random.normal(jax.random.PRNGKey(2), (50_000, 3))
    freqs, csd = welch_csd(xs, nperseg=128)
    np.testing.assert_allclose(
        np.asarray(csd), np.conj(np.swapaxes(np.asarray(csd), 1, 2)), atol=1e-6
    )
    _, psd = welch_psd(xs, nperseg=128)
    # diagonal of (two-sided) csd ×(one-sided multiplier) == psd
    mult = np.ones(len(freqs)); mult[1:] = 2.0; mult[-1] = 1.0
    diag = np.real(np.asarray(csd)[:, np.arange(3), np.arange(3)]) * mult[:, None]
    np.testing.assert_allclose(diag, np.asarray(psd), rtol=1e-4, atol=1e-6)


def test_hann_window_normalization():
    w = hann_window(64)
    assert abs(float(jnp.mean(w)) - 0.5) < 1e-6
