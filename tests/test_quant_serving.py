"""Weight-only int8 serving quantization (§Perf C3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import forward, init_params
from repro.serving.quant import (
    QuantTensor,
    dequantize_tree,
    quantize_leaf,
    quantize_tree,
    tree_param_bytes,
)

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow


def test_quantize_roundtrip_error():
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    q = quantize_leaf(w)
    back = (q.codes.astype(jnp.float32) * q.scale)
    err = jnp.abs(back - w)
    step = jnp.broadcast_to(q.scale, w.shape)
    assert bool(jnp.all(err <= step * 0.5 + 1e-6))


def test_tree_quantization_selective_and_smaller():
    r = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(jax.random.PRNGKey(1), r, dtype=jnp.float32)
    qp = quantize_tree(params)
    # embedding (512×64=32768 < threshold) stays fp in reduced config; check
    # at least SOME leaves quantized for a wider model
    big = init_params(jax.random.PRNGKey(1), ARCHS["qwen3-0.6b"], dtype=jnp.bfloat16)
    # use eval_shape-scale? full init is heavy; use a 2-layer variant
    import dataclasses

    cfg2 = dataclasses.replace(ARCHS["qwen3-0.6b"], n_layers=2)
    big = init_params(jax.random.PRNGKey(1), cfg2, dtype=jnp.bfloat16)
    qbig = quantize_tree(big)
    n_q = sum(
        isinstance(l, QuantTensor)
        for l in jax.tree.leaves(qbig, is_leaf=lambda l: isinstance(l, QuantTensor))
    )
    assert n_q >= 5
    assert tree_param_bytes(qbig) < 0.6 * tree_param_bytes(big)


def test_quantized_generation_close_to_fp():
    """Greedy generation with int8 weights matches fp argmax on most steps
    (random init is unusually quant-sensitive; trained nets do better)."""
    r = ARCHS["h2o-danube-1.8b"].reduced()
    params = init_params(jax.random.PRNGKey(2), r, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, r.vocab)
    logits_fp, _ = forward(params, {"tokens": toks}, r)
    qp = dequantize_tree(quantize_tree(params), dtype=jnp.float32)
    logits_q, _ = forward(qp, {"tokens": toks}, r)
    # logits close in the metric that matters for sampling
    top_fp = jnp.argmax(logits_fp, -1)
    top_q = jnp.argmax(logits_q, -1)
    agree = float(jnp.mean(top_fp == top_q))
    assert agree > 0.9, agree


def test_dequantize_preserves_structure():
    r = ARCHS["xlstm-125m"].reduced()
    params = init_params(jax.random.PRNGKey(4), r, dtype=jnp.float32)
    qp = quantize_tree(params)
    back = dequantize_tree(qp, dtype=jnp.float32)
    assert jax.tree.structure(back) == jax.tree.structure(params)
