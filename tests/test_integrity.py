"""Data-plane integrity (PR 10): sentinel, self-healing tenants, drift.

The headline contract: a tenant streaming NaN-poisoned chunks through a
LIVE gateway — seeded chaos at the ``ingest.payload`` site — costs nothing
but its own lane.  Every other tenant's served answers stay bit-identical
to a fault-free run, the poisoned tenant is quarantined, and
``rebuild_tenant`` surgically restores it from the newest intact
checkpoint generation without touching anyone else's live state.

Plus the units underneath: the fused all-finite sentinel verdict, the
three per-tenant poisoning policies, on-device audit + rebuild when the
sentinel is OFF (poison in state, not at the boundary), per-tenant
checkpoint extraction with generation walk-back, dtype-validated state
import, the compensated-accumulation drift pin, and the regression gate's
warn-and-skip path for never-blessed benches.
"""
import asyncio
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointCorrupt,
    restore_tenant_latest_intact,
    restore_tenant_pytree,
    save_pytree,
)
from repro.core.frame import FrameSession
from repro.core.integrity import sentinel_scan
from repro.runtime import chaos
from repro.runtime.chaos import FaultInjector
from repro.serving.gateway import GatewayConfig, PoisonedChunk, StatsGateway

pytestmark = pytest.mark.integrity

D = 2
N_TENANTS = 4
CHUNK = 32


def _session():
    """≥2 statistic families + a forecast: the fused megakernel-eligible
    plan shape the gateway serves in production."""
    sess = FrameSession(d=D, num_users=N_TENANTS, backend="jnp")
    sess.autocovariance(3)
    sess.moments(8)
    sess.forecast(4, model="ar", p=2)
    return sess


def _chunks(tick, seed=0):
    rng = np.random.RandomState(seed + tick)
    return {u: rng.randn(CHUNK, D).astype(np.float32) for u in range(N_TENANTS)}


def _flat(result):
    leaves, treedef = jax.tree_util.tree_flatten(result)
    return [np.asarray(l) for l in leaves], treedef


def run(coro):
    return asyncio.run(coro)


# ------------------------------------------------------------ sentinel unit


def test_sentinel_scan_verdict_and_clean():
    batch = np.ones((3, 8, D), np.float32)
    batch[1, 2, 0] = np.nan
    batch[1, 5, 1] = np.inf
    verdict, clean = sentinel_scan(batch)
    np.testing.assert_array_equal(verdict, [True, False, True])
    clean = np.asarray(clean)
    # clean rows of a clean chunk are bit-identical pass-through ...
    np.testing.assert_array_equal(clean[0], batch[0])
    np.testing.assert_array_equal(clean[2], batch[2])
    # ... and the poisoned chunk is masked finite (sanitize policy's input)
    assert np.isfinite(clean[1]).all()
    assert clean[1][2, 0] == 0.0 and clean[1][5, 1] == 0.0

    all_good, same = sentinel_scan(np.ones((2, 4, D), np.float32))
    assert np.asarray(all_good).all()
    np.testing.assert_array_equal(np.asarray(same), np.ones((2, 4, D)))


# ------------------------------------------------------- per-tenant policies


@pytest.mark.parametrize("policy", ["reject", "sanitize"])
def test_sentinel_policy_reject_and_sanitize(policy):
    gw = StatsGateway(_session(), GatewayConfig(sentinel=True,
                                                sentinel_policy=policy))
    chunks = _chunks(0)
    bad = chunks[1].copy()
    bad[3, 0] = np.nan

    async def scenario():
        futs = {u: gw.submit_ingest(u, chunks[u]) for u in (0, 2, 3)}
        futs[1] = gw.submit_ingest(1, bad)
        await gw.tick()
        outcomes = {}
        for u, f in futs.items():
            try:
                outcomes[u] = await f
            except PoisonedChunk:
                outcomes[u] = "poisoned"
        qfuts = {u: gw.submit_query(u) for u in range(N_TENANTS)}
        await gw.tick()
        res = {u: await f for u, f in qfuts.items()}
        await gw.stop(final_snapshot=False)
        return outcomes, res

    outcomes, res = run(scenario())
    # healthy tenants land regardless of the poisoned co-tenant in-batch
    assert all(outcomes[u] != "poisoned" for u in (0, 2, 3))
    health = gw.health()["integrity"]
    if policy == "reject":
        assert outcomes[1] == "poisoned"
        assert health["poisoned_rejected"] == 1
        assert health["quarantined"] == []        # reject is per-chunk only
    else:
        assert outcomes[1] != "poisoned"          # masked, then ingested
        assert health["sanitized_chunks"] == 1
    # every tenant that ingested serves finite answers (a rejected chunk
    # leaves tenant 1 EMPTY under "reject" — empty-state moments are NaN
    # by documented contract, which is precisely not poisoning)
    served = (0, 2, 3) if policy == "reject" else range(N_TENANTS)
    for u in served:
        leaves, _ = _flat(res[u])
        assert all(np.isfinite(l).all() for l in leaves
                   if l.dtype.kind in "fc")
    # counters ride the observability window automatically
    window = gw.metrics()["window"]
    assert window["sentinel_scans"] >= 1


def test_quarantine_policy_blocks_ingest_and_query():
    gw = StatsGateway(_session(), GatewayConfig(sentinel=True))
    gw.set_tenant_policy(2, "quarantine")
    chunks = _chunks(1)
    bad = chunks[2].copy()
    bad[0, 0] = np.inf

    async def scenario():
        futs = [gw.submit_ingest(u, chunks[u]) for u in (0, 1, 3)]
        pf = gw.submit_ingest(2, bad)
        await gw.tick()
        await asyncio.gather(*futs)
        with pytest.raises(PoisonedChunk):
            await pf
        # the tenant is now fenced at the front door, both planes
        with pytest.raises(PoisonedChunk):
            gw.submit_ingest(2, chunks[2])
        with pytest.raises(PoisonedChunk):
            gw.submit_query(2)
        # co-tenants are not
        ok = gw.submit_query(0)
        await gw.tick()
        await ok
        await gw.stop(final_snapshot=False)

    run(scenario())
    health = gw.health()["integrity"]
    assert health["quarantined"] == [2]
    assert health["tenants_quarantined"] == 1
    assert gw.counters["rejected_ingest_quarantined"] >= 1
    assert gw.counters["rejected_query_quarantined"] >= 1


# ------------------------------------------------------------- headline e2e


def test_e2e_poisoned_tenant_quarantined_others_bit_identical_then_rebuilt(
    tmp_path,
):
    """Seeded chaos NaN-poisons tenant 2 mid-stream through a LIVE gateway.
    Non-poisoned tenants' answers are bit-identical to a fault-free run;
    tenant 2 is quarantined at the boundary, then surgically rebuilt from
    the newest intact snapshot and serves exactly the state that snapshot
    held."""
    TICKS = 8
    REBUILD_AT = 5

    async def drive(gw, inj):
        answers = {u: [] for u in range(N_TENANTS)}
        rebuilt = None
        ctx = chaos.scoped(inj) if inj is not None else None
        if ctx is not None:
            ctx.__enter__()
        try:
            for t in range(TICKS):
                if t == REBUILD_AT and inj is not None:
                    ctx.__exit__(None, None, None)
                    ctx = None
                    rebuilt = gw.rebuild_tenant(2)
                    # quarantine released: query BEFORE any new ingest so
                    # the served answer is exactly the snapshot state
                    qf = gw.submit_query(2)
                    await gw.tick()
                    answers[2].append(("rebuilt", await qf))
                chunks = _chunks(t)
                futs = []
                for u in range(N_TENANTS):
                    try:
                        futs.append(gw.submit_ingest(u, chunks[u]))
                    except PoisonedChunk:
                        pass
                qu = t % N_TENANTS
                try:
                    qfut = gw.submit_query(qu)
                except PoisonedChunk:
                    qfut = None
                await gw.tick()
                for f in futs:
                    try:
                        await f
                    except PoisonedChunk:
                        pass
                if qfut is not None:
                    try:
                        answers[qu].append((t, await qfut))
                    except PoisonedChunk:
                        pass
        finally:
            if ctx is not None:
                ctx.__exit__(None, None, None)
        return answers, rebuilt

    # chaos poisons the (4*2+2)rd admitted submission: tick 2, tenant 2 —
    # deterministic because call order == submission order
    inj = FaultInjector(seed=7)
    inj.corrupt("ingest.payload", calls={N_TENANTS * 2 + 2})

    async def faulty():
        gw = StatsGateway(
            _session(),
            GatewayConfig(sentinel=True, snapshot_every=2,
                          checkpoint_dir=str(tmp_path / "ckpt")),
        )
        gw.set_tenant_policy(2, "quarantine")
        answers, rebuilt = await drive(gw, inj)
        health = gw.health()["integrity"]
        await gw.stop(final_snapshot=False)
        return answers, rebuilt, health, inj.log

    async def clean():
        gw = StatsGateway(_session(), GatewayConfig(sentinel=True))
        answers, _ = await drive(gw, None)
        await gw.stop(final_snapshot=False)
        return answers

    ans_f, rebuilt, health, log = run(faulty())
    ans_c = run(clean())

    # the chaos rule fired, and fired where the schedule says
    assert ("ingest.payload", N_TENANTS * 2 + 2, "corrupt") in log
    # tenant 2 was quarantined, then rebuilt and released
    assert rebuilt["released"] and rebuilt["tenant"] == 2
    assert health["tenants_quarantined"] == 1
    assert health["tenants_rebuilt"] == 1
    assert health["quarantined"] == []

    # every non-poisoned tenant: answers BIT-IDENTICAL to the clean run
    for u in (0, 1, 3):
        assert len(ans_f[u]) == len(ans_c[u]) > 0
        for (tf, rf), (tc, rc) in zip(ans_f[u], ans_c[u]):
            assert tf == tc
            lf, df = _flat(rf)
            lc, dc = _flat(rc)
            assert df == dc
            for a, b in zip(lf, lc):
                np.testing.assert_array_equal(a, b)

    # the rebuilt tenant serves the snapshot state: bit-identical to a
    # fresh gateway that ingested only what the snapshot had absorbed
    # (tenant 2's last successful ingests were ticks 0 and 1)
    async def reference():
        gw = StatsGateway(_session(), GatewayConfig(sentinel=True))
        for t in range(2):
            chunks = _chunks(t)
            futs = [gw.submit_ingest(u, chunks[u]) for u in range(N_TENANTS)]
            await gw.tick()
            await asyncio.gather(*futs)
        qf = gw.submit_query(2)
        await gw.tick()
        res = await qf
        await gw.stop(final_snapshot=False)
        return res

    want = run(reference())
    tag, got = ans_f[2][0]
    assert tag == "rebuilt"
    lw, dw = _flat(want)
    lg, dg = _flat(got)
    assert dw == dg
    for a, b in zip(lg, lw):
        np.testing.assert_array_equal(a, b)
    # and it kept serving (finite) after release
    post = [t for (t, _r) in ans_f[2][1:] if isinstance(t, int)]
    assert any(t >= REBUILD_AT for t in post)


# -------------------------------------------- audit + rebuild, sentinel OFF


def test_audit_detects_in_state_poison_and_rebuild_restores(tmp_path):
    """With the sentinel OFF the NaN reaches the lane state itself.  The
    on-device audit sweep finds it, quarantines the tenant, and rebuild
    walks PAST the post-poisoning snapshot (byte-intact but poisoned) to
    the newest healthy generation."""

    async def scenario():
        gw = StatsGateway(
            _session(),
            GatewayConfig(sentinel=False, snapshot_every=1,
                          checkpoint_dir=str(tmp_path / "ckpt")),
        )
        # two clean ticks → clean snapshots
        for t in range(2):
            chunks = _chunks(t)
            futs = [gw.submit_ingest(u, chunks[u]) for u in range(N_TENANTS)]
            await gw.tick()
            await asyncio.gather(*futs)
        qf = gw.submit_query(1)
        await gw.tick()
        want = await qf

        # poisoned tick: NaN sails past the disabled sentinel INTO state,
        # and the per-tick snapshot then persists the poisoned lane
        chunks = _chunks(2)
        bad = chunks[1].copy()
        bad[4, 1] = np.nan
        futs = [gw.submit_ingest(u, chunks[u]) for u in (0, 2, 3)]
        futs.append(gw.submit_ingest(1, bad))
        await gw.tick()
        await asyncio.gather(*futs)

        verdict = gw.audit()
        assert verdict["unhealthy"] == [1]
        assert verdict["quarantined"] == [1]
        with pytest.raises(PoisonedChunk):
            gw.submit_query(1)

        rebuilt = gw.rebuild_tenant(1)
        # the newest generation holds the poisoned lane — walked past
        assert rebuilt["skipped"], "poisoned snapshot should be skipped"
        qf = gw.submit_query(1)
        await gw.tick()
        got = await qf
        await gw.stop(final_snapshot=False)
        return want, got, rebuilt, gw.session.audit()

    want, got, rebuilt, healthy = run(scenario())
    lw, dw = _flat(want)
    lg, dg = _flat(got)
    assert dw == dg
    for a, b in zip(lg, lw):
        np.testing.assert_array_equal(a, b)
    assert healthy.all()                      # post-rebuild audit is clean


# ------------------------------------------- per-tenant checkpoint extraction


def _toy_state(scale):
    return {
        "lanes": {"stat": np.arange(24, dtype=np.float32).reshape(2, 4, 3)
                  * scale},
        "counts": np.arange(4, dtype=np.int64) * int(scale),
    }


_TOY_AXES = {"lanes/stat": 1, "counts": 0}


def test_restore_tenant_pytree_extracts_one_tenant(tmp_path):
    d = str(tmp_path)
    save_pytree(_toy_state(1.0), d, 1, meta={"tenant_axes": _TOY_AXES})
    save_pytree(_toy_state(2.0), d, 2, meta={"tenant_axes": _TOY_AXES})
    got = restore_tenant_pytree(_toy_state(0.0), d, tenant=3)
    np.testing.assert_array_equal(
        got["lanes"]["stat"], _toy_state(2.0)["lanes"]["stat"][:, 3]
    )
    assert got["counts"] == 6
    # explicit older generation
    got1 = restore_tenant_pytree(_toy_state(0.0), d, tenant=3, step=1)
    assert got1["counts"] == 3
    with pytest.raises(ValueError):
        restore_tenant_pytree(_toy_state(0.0), d, tenant=99)


def test_restore_tenant_latest_intact_walks_back(tmp_path):
    d = str(tmp_path)
    save_pytree(_toy_state(1.0), d, 1, meta={"tenant_axes": _TOY_AXES})
    save_pytree(_toy_state(2.0), d, 2, meta={"tenant_axes": _TOY_AXES})
    # tear the newest payload on disk
    arrs = os.path.join(d, "step_0000000002", "arrays.npz")
    with open(arrs, "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad\xbe\xef")
    state, step, skipped = restore_tenant_latest_intact(
        _toy_state(0.0), d, tenant=0
    )
    assert step == 1 and skipped == [2]
    assert state["counts"] == 0

    # a POISONED (byte-intact) newest generation is walked past too
    poisoned = _toy_state(3.0)
    poisoned["lanes"]["stat"][0, 2, 1] = np.nan
    save_pytree(poisoned, d, 3, meta={"tenant_axes": _TOY_AXES})
    _, step, skipped = restore_tenant_latest_intact(
        _toy_state(0.0), d, tenant=2
    )
    assert step == 1 and 3 in skipped
    # ... but only for the tenant whose slice holds the NaN
    _, step, _ = restore_tenant_latest_intact(_toy_state(0.0), d, tenant=1)
    assert step == 3


def test_restore_tenant_requires_extraction_metadata(tmp_path):
    d = str(tmp_path)
    save_pytree(_toy_state(1.0), d, 1)          # pre-PR-10 manifest: no meta
    with pytest.raises(CheckpointCorrupt):
        restore_tenant_pytree(_toy_state(0.0), d, tenant=0)


# ---------------------------------------------------- dtype-validated import


def test_import_state_dtype_cast_or_raise():
    sess = _session()
    sess2 = _session()
    chunks = _chunks(0)
    ids = np.arange(N_TENANTS)
    batch = np.stack([chunks[u] for u in range(N_TENANTS)])
    sess.ingest(ids, batch)
    exported = sess.export_state()

    # same-kind widening round-trips exactly (f32 values survive f64)
    widened = jax.tree.map(
        lambda l: np.asarray(l, np.float64)
        if np.asarray(l).dtype.kind == "f" else np.asarray(l),
        exported,
    )
    sess2.import_state(widened)
    want, got = sess.query(1), sess2.query(1)
    for a, b in zip(_flat(want)[0], _flat(got)[0]):
        np.testing.assert_array_equal(a, b)

    # kind changes refuse loudly instead of silently reinterpreting —
    # the PR 6 int32-t0 bug class
    broken = jax.tree.map(
        lambda l: np.asarray(l).astype(np.int32)
        if np.asarray(l).dtype.kind == "f" else np.asarray(l),
        exported,
    )
    with pytest.raises(ValueError, match="kind"):
        _session().import_state(broken)


# ----------------------------------------------------------- drift pin


@pytest.mark.slow
def test_compensated_drift_ratio_pin():
    """The reason compensated mode exists: ≥10× less drift than plain f32
    on the bench's own hostile seeded workload (the exact configuration
    `benchmarks.bench_integrity` gates in BENCH_integrity.json)."""
    from benchmarks.bench_integrity import _drift_phase

    drift = _drift_phase([])
    assert drift["ratio"] >= 10.0, drift


# -------------------------------------------- regression-gate warn-and-skip


def test_check_regression_warn_skips_unblessed_bench(tmp_path, monkeypatch,
                                                     capsys):
    from benchmarks import check_regression as cr

    monkeypatch.setattr(cr, "REPO_ROOT", str(tmp_path))
    monkeypatch.setattr(cr, "BASELINE_DIR", str(tmp_path / "baselines"))

    # a brand-new bench with no baseline anywhere: warn-and-skip, exit 0
    payload = ('{"platform": "cpu", "results": '
               '[{"name": "x", "us_per_call": 5000.0}]}')
    (tmp_path / "BENCH_new.json").write_text(payload)
    assert cr.main(["--files", "BENCH_new.json"]) == 0
    out = capsys.readouterr().out
    assert "no blessed or committed baseline" in out

    # listed-but-never-run (fresh BENCH_FILES entry): also not a failure
    assert cr.main(["--files", "BENCH_ghost.json"]) == 0
    assert "no working-tree run and no baseline" in capsys.readouterr().out

    # discovery picks the new file up and --update-baselines blesses it
    assert "BENCH_new.json" in cr.discover_files()
    assert cr.main(["--update-baselines", "--files", "BENCH_new.json"]) == 0
    assert (tmp_path / "baselines" / "BENCH_new.json").read_text() == payload
    # ... after which it gates like any tracked trajectory
    assert cr.main(["--files", "BENCH_new.json"]) == 0
    slow = payload.replace("5000.0", "50000.0")
    (tmp_path / "BENCH_new.json").write_text(slow)
    assert cr.main(["--files", "BENCH_new.json"]) == 1
