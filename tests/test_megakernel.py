"""Fused-plan megakernel suite (`repro.kernels.fused_plan`, PR 7).

Pins the seventh backend primitive three ways:

  * parity of the Pallas megakernel (interpret mode) AND the backend-level
    jnp composition against the naive oracle (`fused_plan_update_ref`)
    across the edge grid — chunks shorter than a tile, d = 1, odd segment
    lengths, ``max_lag`` longer than the chunk, multi-window moment tuples;
  * the launch-count acceptance pin: a 3-family plan's chunk update stages
    the chunk through exactly ONE ``pallas_call`` — each tile enters VMEM
    once and feeds lagged sums, every moment window, and the Welch member —
    on both the interpret and the compiled trace;
  * the measured-precision mode: ``stage_dtype="bfloat16"`` narrows the
    HBM↔VMEM stream identically on both backends (bit-compatible rounding
    of the staged series) while accumulating in f32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import core

from repro.core.backend import JnpBackend, PallasBackend
from repro.core.plan import (
    StatPlan,
    autocovariance_request,
    moments_request,
    welch_request,
)
from repro.kernels.fused_plan import fused_plan_update, fused_plan_update_ref

pytestmark = pytest.mark.backend


def _args(n=96, d=2, max_lag=6, windows=(8,), seg_lens=(16,), seg_steps=(8,),
          z0=0, seed=0, mask_holes=False):
    reach = max([max_lag] + [w - 1 for w in windows] + [s - 1 for s in seg_lens])
    y = jax.random.normal(jax.random.PRNGKey(seed), (n + reach, d))
    mask = jnp.ones((n,), jnp.bool_)
    if mask_holes:
        mask = mask.at[n // 3 :: 5].set(False)
    tapers = tuple(jnp.hanning(L) for L in seg_lens)
    return (y, mask, z0, max_lag, windows, seg_lens, seg_steps, tapers)


def _assert_tuple_close(got, want, rtol, atol=1e-4):
    lag_g, mom_g, psds_g, nseg_g = got
    lag_w, mom_w, psds_w, nseg_w = want
    np.testing.assert_allclose(lag_g, lag_w, rtol=rtol, atol=atol)
    assert (mom_g is None) == (mom_w is None)
    if mom_w is not None:
        np.testing.assert_allclose(mom_g, mom_w, rtol=rtol, atol=atol)
    assert len(psds_g) == len(psds_w)
    for pg, pw in zip(psds_g, psds_w):
        np.testing.assert_allclose(pg, pw, rtol=10 * rtol, atol=10 * atol)
    for ng, nw in zip(nseg_g, nseg_w):
        np.testing.assert_allclose(ng, nw)


EDGE_GRID = {
    # n < block_t: the whole chunk fits in one (clamped) tile
    "short_chunk": dict(n=40, block_t=512),
    "d_one": dict(n=80, d=1, windows=(4, 12)),
    "odd_seg_len": dict(n=90, seg_lens=(13,), seg_steps=(5,)),
    "lag_exceeds_chunk": dict(n=24, max_lag=40, seg_lens=(), seg_steps=(),
                              windows=(6,)),
    "multi_window": dict(n=100, windows=(3, 8, 17), mask_holes=True),
    "multi_welch": dict(n=128, seg_lens=(16, 24), seg_steps=(8, 12),
                        z0=7, mask_holes=True),
    "tiled_offset": dict(n=96, block_t=32, z0=11, mask_holes=True),
    "no_moments": dict(n=64, windows=()),
}


@pytest.mark.parametrize("case", sorted(EDGE_GRID))
def test_megakernel_edge_grid_parity(case):
    kw = dict(EDGE_GRID[case])
    block_t = kw.pop("block_t", 64)
    args = _args(**kw)
    want = fused_plan_update_ref(*args)
    got_pallas = fused_plan_update(*args, block_t=block_t, interpret=True)
    _assert_tuple_close(got_pallas, want, rtol=2e-3)
    got_jnp = JnpBackend().fused_plan_update(*args)
    _assert_tuple_close(got_jnp, want, rtol=2e-3)


def test_backend_primitive_parity_jnp_vs_pallas():
    args = _args(n=192, d=3, max_lag=9, windows=(5, 16), seg_lens=(32,),
                 seg_steps=(16,), z0=13, mask_holes=True, seed=3)
    got = PallasBackend(block_t=64, interpret=True).fused_plan_update(*args)
    want = JnpBackend().fused_plan_update(*args)
    _assert_tuple_close(got, want, rtol=2e-3)


# ------------------------------------------------------- launch counting


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in core.jaxprs_in_params(eqn.params):
            n += _count_pallas_calls(sub)
    return n


@pytest.mark.parametrize("interpret", [True, False], ids=["interpret", "compiled"])
def test_three_family_plan_is_one_kernel_launch(interpret):
    """The acceptance pin: with lagged + moments + welch members live, the
    plan's chunk update traces to exactly ONE ``pallas_call`` — one VMEM
    staging of each tile feeds all three families.  The compiled variant
    pins the same program geometry on the non-interpret lowering path."""
    be = PallasBackend(block_t=64, interpret=interpret)
    plan = StatPlan(
        [
            autocovariance_request(8),
            moments_request(32),
            welch_request(nperseg=64, overlap=32),
        ],
        d=2,
        backend=be,
    )
    (group,) = plan.groups
    assert group._use_megakernel

    y = jax.random.normal(jax.random.PRNGKey(5), (256 + group.window - 1, 2))
    mask = jnp.ones((256,), jnp.bool_)
    jaxpr = jax.make_jaxpr(
        lambda y, mask, z0: group._fused_chunk_kernel(y, mask, z0)
    )(y, mask, jnp.asarray(0, jnp.int32))
    assert _count_pallas_calls(jaxpr.jaxpr) == 1

    if interpret:  # execute the interpret path: parity with the jnp plan
        got = group._fused_chunk_kernel(y, mask, jnp.asarray(0, jnp.int32))
        jnp_plan = StatPlan(
            [
                autocovariance_request(8),
                moments_request(32),
                welch_request(nperseg=64, overlap=32),
            ],
            d=2,
            backend=JnpBackend(),
        )
        want = jnp_plan.groups[0]._fused_chunk_kernel(
            y, mask, jnp.asarray(0, jnp.int32)
        )
        np.testing.assert_allclose(got["lagged"], want["lagged"], rtol=2e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(got["welch"]["psd"], want["welch"]["psd"],
                                   rtol=2e-2, atol=1e-3)
        np.testing.assert_allclose(got["welch"]["n_seg"], want["welch"]["n_seg"])


def test_single_family_plan_keeps_legacy_path():
    """<2 families (or a backend without the primitive): no megakernel."""
    be = PallasBackend(interpret=True)
    plan = StatPlan([autocovariance_request(8)], d=2, backend=be)
    assert not plan.groups[0]._use_megakernel

    class _NoFused:
        name = "nofused"

        def __getattr__(self, item):
            if item == "fused_plan_update":
                raise AttributeError(item)
            return getattr(JnpBackend(), item)

    plan2 = StatPlan(
        [autocovariance_request(8), moments_request(32)],
        d=2,
        backend=_NoFused(),
    )
    assert not plan2.groups[0]._use_megakernel
    x = jax.random.normal(jax.random.PRNGKey(1), (400, 2))
    out = plan2.finalize(plan2.from_chunk(x))
    want = StatPlan(
        [autocovariance_request(8), moments_request(32)], d=2, backend="jnp"
    )
    want_out = want.finalize(want.from_chunk(x))
    np.testing.assert_allclose(
        out["autocovariance"], want_out["autocovariance"], rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------------- bf16 staging mode


def test_bf16_staging_parity_and_accuracy():
    args = _args(n=128, d=2, max_lag=5, windows=(8,), seg_lens=(16,),
                 seg_steps=(8,), seed=7)
    got = fused_plan_update(
        *args, block_t=64, interpret=True, stage_dtype="bfloat16"
    )
    want = JnpBackend().fused_plan_update(*args, stage_dtype="bfloat16")
    # both paths round the staged series through bf16 → tight agreement
    _assert_tuple_close(got, want, rtol=2e-3)
    # and the narrowed stream stays close to the f32 result
    full = fused_plan_update(*args, block_t=64, interpret=True)
    _assert_tuple_close(got, full, rtol=3e-2, atol=3e-2)
