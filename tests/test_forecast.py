"""Forecast subsystem: plan forecasts ≡ eager oracles, one traversal,
one vmapped program, anomaly scoring, periodicity-seeded auto models.

Pins the ISSUE-9 acceptance contracts:
  * a plan-served forecast equals the eager `ar_forecast` / `arma_forecast`
    oracle (same fit, same tail window) across jnp and pallas backends;
  * a 3-statistic plan WITH a forecast member still reads the series once
    (counting backend);
  * `FrameSession` forecasts for N tenants compile to ONE vmapped
    recurrence program (jit-cache pin) and match per-tenant frames;
  * anomaly scores flag an injected spike and match the direct
    standardized-innovations computation;
  * ``model="auto"`` detects the seasonal period from the plan's Welch
    member and its restricted-lag fit reduces to dense Yule-Walker on
    contiguous lags.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.backend import get_backend
from repro.core.forecast import (
    anomaly_request,
    detect_period,
    fit_seasonal_ar,
    forecast_request,
    lagged_forecast,
    standardized_innovations,
)
from repro.core.frame import FrameSession, SeriesFrame
from repro.core.estimators.arma import fit_arma
from repro.core.estimators.prediction import (
    ar_forecast,
    arma_forecast,
    arma_innovations_filter,
)
from repro.core.estimators.stats import autocovariance
from repro.core.estimators.yule_walker import yule_walker

D = 2


def _ar_series(n=512, d=D, seed=0, noise=0.3):
    rng = np.random.RandomState(seed)
    A1 = 0.5 * np.eye(d, dtype=np.float32) + 0.1 * np.triu(np.ones((d, d)), 1)
    x = np.zeros((n, d), np.float32)
    for t in range(1, n):
        x[t] = x[t - 1] @ A1.T + noise * rng.randn(d)
    return jnp.asarray(x)


def _seasonal_series(n=512, d=D, period=8, seed=1, noise=0.1):
    rng = np.random.RandomState(seed)
    t = np.arange(n)
    base = np.sin(2 * np.pi * t / period)[:, None] * np.ones((1, d))
    return jnp.asarray((base + noise * rng.randn(n, d)).astype(np.float32))


# ---------------------------------------------------------------- oracles


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_forecast_matches_ar_oracle(backend):
    """Plan-served AR forecast == eager ar_forecast on the same YW fit,
    bit-for-bit (same γ̂, same recurrence)."""
    x = _ar_series()
    f = SeriesFrame.from_array(x, backend=backend)
    f.yule_walker(3, normalization="standard")
    f.forecast(6, model="ar", p=3)
    res = f.collect()
    A, sigma = res["yule_walker"]
    want = ar_forecast(A, x, 6)
    np.testing.assert_array_equal(
        np.asarray(res["forecast"]["pred"]), np.asarray(want)
    )
    np.testing.assert_array_equal(
        np.asarray(res["forecast"]["sigma"]), np.asarray(sigma)
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_forecast_matches_arma_oracle(backend):
    """Plan-served ARMA forecast == eager arma_forecast fed the SAME fit
    and the SAME weak-memory history window (the carried tail)."""
    x = _ar_series(seed=2)
    f = SeriesFrame.from_array(x, backend=backend)
    f.forecast(5, model="arma", p=1, q=1)
    res = f.collect()
    carry = f._plan.groups[0].engine.carry
    gamma = autocovariance(x, 2, normalization="standard")
    A, B, sigma = fit_arma(gamma, 1, 1, 2, ridge=1e-8)
    want = arma_forecast(A, B, x[-carry:], 5)
    np.testing.assert_allclose(
        np.asarray(res["forecast"]["pred"]), np.asarray(want),
        rtol=1e-5, atol=1e-6,
    )


def test_forecast_seeds_from_tail_not_just_lags():
    """Two series with identical γ̂-shape but different endings forecast
    differently — the recurrence must read the carried tail, not only the
    lag sums."""
    x = _ar_series(seed=3)
    flipped = jnp.concatenate([x[:-8], -x[-8:]])
    preds = []
    for series in (x, flipped):
        f = SeriesFrame.from_array(series)
        f.forecast(3, model="ar", p=2)
        preds.append(np.asarray(f.collect()["forecast"]["pred"]))
    assert np.max(np.abs(preds[0] - preds[1])) > 1e-4


# ------------------------------------------------------------ one traversal


class CountingBackend:
    """Delegating backend recording (primitive, rows) per invocation
    (mirrors tests/test_plan.py)."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.calls = []

    def _rec(self, prim, rows):
        self.calls.append((prim, int(rows)))

    def masked_lagged_sums(self, y, mask, max_lag):
        self._rec("masked_lagged_sums", mask.shape[0])
        return self._inner.masked_lagged_sums(y, mask, max_lag)

    def fused_lagged_moments(self, y, mask, max_lag, window):
        self._rec("fused_lagged_moments", mask.shape[0])
        return self._inner.fused_lagged_moments(y, mask, max_lag, window)

    def segment_fft_power(self, segments, taper, detrend=True):
        self._rec("segment_fft_power", segments.shape[0] * segments.shape[1])
        return self._inner.segment_fft_power(segments, taper, detrend)

    def series_traversals(self, n):
        return [
            c for c in self.calls if c[1] >= n and c[0] != "segment_fft_power"
        ]


def test_three_statistic_plan_with_forecast_is_one_traversal():
    """[autocovariance, moments, forecast] — the forecast member joins the
    shared lagged entry: exactly ONE series-sized primitive call, every
    other call a halo-sized finalize correction."""
    n = 2000
    x = _ar_series(n=n)
    counting = CountingBackend(get_backend("jnp"))
    f = SeriesFrame.from_array(x, backend=counting)
    f.autocovariance(3)
    f.moments(8)
    f.forecast(4, model="ar", p=3)
    res = f.collect()
    assert sorted(res) == ["autocovariance", "forecast", "moments"]
    assert f.num_traversals == 1
    walks = counting.series_traversals(n)
    assert walks == [("fused_lagged_moments", n)]
    others = [r for p, r in counting.calls if r < n]
    assert all(r < 64 for r in others)  # tail-correction contractions only


# ----------------------------------------------------- session / one program


def test_session_forecasts_compile_one_vmapped_recurrence_program():
    """N tenants' forecasts ride ONE jit-cached vmapped finalize — and each
    tenant's answer equals a dedicated per-tenant SeriesFrame."""
    N, c = 6, 96
    sess = FrameSession(d=D, num_users=N)
    sess.forecast(5, model="ar", p=3)
    sess.anomaly_scores(model="ar", p=3)
    chunks = np.stack(
        [np.asarray(_ar_series(n=c, seed=10 + u)) for u in range(N)]
    )
    sess.ingest(np.arange(N, dtype=np.int32), chunks)

    out = sess.query_batch(np.arange(N, dtype=np.int32))
    assert out["forecast"]["pred"].shape == (N, 5, D)
    # different id subsets of the same batch size: still one trace
    sess.query_batch(np.asarray([3, 1, 0, 2, 5, 4], np.int32))
    assert sess._finalize_batch._cache_size() == 1

    for u in range(N):
        ref = SeriesFrame.from_array(chunks[u])
        ref.forecast(5, model="ar", p=3)
        ref.anomaly_scores(model="ar", p=3)
        want = ref.collect()
        np.testing.assert_allclose(
            np.asarray(out["forecast"]["pred"][u]),
            np.asarray(want["forecast"]["pred"]),
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_allclose(
            np.asarray(out["anomaly"]["score"][u]),
            np.asarray(want["anomaly"]["score"]),
            rtol=1e-4, atol=1e-5,
        )


# ------------------------------------------------------------------ anomaly


def test_anomaly_scores_flag_injected_spike():
    """A spike inside the scored tail window stands far above the baseline
    Mahalanobis scores of clean AR data."""
    x = np.asarray(_ar_series(seed=4, noise=0.2)).copy()
    f_probe = SeriesFrame.from_array(x)
    f_probe.anomaly_scores(model="ar", p=4)
    carry = len(np.asarray(f_probe.collect()["anomaly"]["score"]))
    spike_at = len(x) - carry // 2  # inside the scored window
    x[spike_at] += 8.0

    f = SeriesFrame.from_array(x)
    f.anomaly_scores(model="ar", p=4)
    res = f.collect()["anomaly"]
    scores = np.asarray(res["score"])
    assert np.asarray(res["valid"]).all()
    spike_pos = spike_at - (len(x) - carry)
    assert scores[spike_pos] == scores.max()
    clean = np.delete(scores, [spike_pos, spike_pos + 1])
    assert scores[spike_pos] > 4 * np.median(clean)


def test_anomaly_matches_direct_standardization():
    """Plan anomaly == standardized_innovations of the fitted model run
    over the tail window directly."""
    x = _ar_series(seed=5)
    f = SeriesFrame.from_array(x)
    f.yule_walker(3, normalization="standard")
    f.anomaly_scores(model="ar", p=3)
    res = f.collect()
    A, sigma = res["yule_walker"]
    carry = f._plan.groups[0].engine.carry
    z, score = standardized_innovations(
        A, jnp.zeros((0, D)), x[-carry:], sigma
    )
    np.testing.assert_allclose(
        np.asarray(res["anomaly"]["z"]), np.asarray(z), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(res["anomaly"]["score"]), np.asarray(score),
        rtol=1e-5, atol=1e-6,
    )


def test_anomaly_valid_mask_covers_only_ingested_rows():
    """A series shorter than the tail window marks the zero-filled prefix
    invalid and scores it zero."""
    sess = FrameSession(d=D, num_users=1)
    sess.anomaly_scores(model="ar", p=4)
    sess.ingest(np.asarray([0], np.int32),
                np.asarray(_ar_series(n=3))[None, :3])
    res = sess.query(0)["anomaly"]
    valid = np.asarray(res["valid"])
    assert valid.sum() == 3 and not valid[:-3].any()
    assert (np.asarray(res["score"])[~valid] == 0).all()


# ------------------------------------------------------------------- auto


def test_auto_detects_period_and_tracks_seasonal_series():
    period = 8
    x = _seasonal_series(period=period)
    f = SeriesFrame.from_array(x)
    f.welch(64)
    f.forecast(2 * period, model="auto", p=2, max_period=16)
    res = f.collect()["forecast"]
    assert int(res["period"]) == period
    t_next = len(x) + np.arange(2 * period)
    truth = np.sin(2 * np.pi * t_next / period)
    pred = np.asarray(res["pred"])[:, 0]
    assert np.mean(np.abs(pred - truth)) < 0.25
    # the seasonal lag is what carries the forecast: a short-lag AR of the
    # same order p decays toward the mean and does measurably worse
    f_ar = SeriesFrame.from_array(x)
    f_ar.forecast(2 * period, model="ar", p=2)
    pred_ar = np.asarray(f_ar.collect()["forecast"]["pred"])[:, 0]
    assert np.mean(np.abs(pred - truth)) < np.mean(np.abs(pred_ar - truth))


def test_auto_periods_vary_per_tenant_in_one_batch():
    """Two tenants with different seasonal periods get different detected
    periods from the SAME vmapped finalize program."""
    N = 2
    periods = [6, 12]
    sess = FrameSession(d=D, num_users=N)
    sess.welch(48, overlap=24)
    sess.forecast(4, model="auto", p=2, max_period=24)
    chunks = np.stack([
        np.asarray(_seasonal_series(n=192, period=pp, seed=20 + i))
        for i, pp in enumerate(periods)
    ])
    sess.ingest(np.arange(N, dtype=np.int32), chunks)
    out = sess.query_batch(np.arange(N, dtype=np.int32))
    assert sess._finalize_batch._cache_size() == 1
    assert list(np.asarray(out["forecast"]["period"])) == periods


def test_fit_seasonal_ar_reduces_to_dense_yule_walker():
    """On contiguous lags 1..p the restricted-lag solve IS Yule-Walker."""
    x = _ar_series(seed=6)
    gamma = autocovariance(x, 4, normalization="standard")
    A_yw, sig_yw = yule_walker(gamma, 4)
    A_sl, sig_sl = fit_seasonal_ar(gamma, jnp.arange(1, 5, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(A_sl), np.asarray(A_yw),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sig_sl), np.asarray(sig_yw),
                               rtol=1e-4, atol=1e-5)


def test_detect_period_picks_dominant_bin():
    nperseg = 64
    psd = np.zeros((nperseg // 2 + 1, D), np.float32)
    psd[8] = 3.0   # bin 8 ↔ period 64/8 = 8
    psd[0] = 99.0  # DC must be ignored
    assert int(detect_period(jnp.asarray(psd), nperseg, 3, 16)) == 8
    # clipping: a too-long period clamps into the trackable range
    psd2 = np.zeros_like(psd)
    psd2[1] = 1.0  # period 64 > max_period
    assert int(detect_period(jnp.asarray(psd2), nperseg, 3, 16)) == 16


# -------------------------------------------------------------- validation


def test_request_validation():
    with pytest.raises(ValueError, match="horizon"):
        forecast_request(0)
    with pytest.raises(ValueError, match="model"):
        forecast_request(4, model="lstm")
    with pytest.raises(ValueError, match="p >= 1"):
        forecast_request(4, model="ar", p=0)
    with pytest.raises(ValueError, match="max_period"):
        forecast_request(4, model="auto", p=8, max_period=8)
    with pytest.raises(ValueError, match="model"):
        anomaly_request(model="nope")


def test_auto_without_welch_member_raises():
    f = SeriesFrame.from_array(_seasonal_series())
    f.forecast(4, model="auto", p=2, max_period=16)
    with pytest.raises(ValueError, match="[Ww]elch"):
        f.collect()


# ------------------------------------------------------- recurrence direct


def test_lagged_forecast_equals_oracles_on_padded_layouts():
    """Dense zero-padded Φ rows change nothing: the fused-plan layout stays
    on ar_forecast/arma_forecast's numbers."""
    x = _ar_series(seed=7)
    gamma = autocovariance(x, 3, normalization="standard")
    A, _ = yule_walker(gamma, 2)
    L = 5
    Phi = jnp.zeros((L, D, D)).at[:2].set(A)
    xlag = x[-1 : -L - 1 : -1]
    got = lagged_forecast(Phi, jnp.zeros((0, D)), xlag, jnp.zeros((0, D)), 4)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ar_forecast(A, x, 4))
    )
    # and with an MA part: padded filter == unpadded filter
    A2, B2, _ = fit_arma(gamma, 1, 1, 2)
    Phi2 = jnp.zeros((L, D, D)).at[:1].set(A2)
    _, innov_pad = arma_innovations_filter(Phi2, B2, x)
    _, innov = arma_innovations_filter(A2, B2, x)
    np.testing.assert_array_equal(np.asarray(innov_pad), np.asarray(innov))
