import os
import sys

# Tests see ONE device (brief: only dryrun.py forces 512).  Distributed
# tests spawn subprocesses that set XLA_FLAGS themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="include tests marked slow (jit-heavy model/system suites)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: jit-heavy model/system test, deselected by default; "
        "include with --runslow (or select directly with -m slow)",
    )


def pytest_collection_modifyitems(config, items):
    """Fast default run: deselect ``slow`` unless --runslow or an explicit
    -m expression is given, so ``python -m pytest -x -q`` stays quick and
    deterministic (the estimator/streaming equivalence tier).  Naming a
    test file or node id directly also opts in — ``pytest
    tests/test_models.py`` should run it, not report 'no tests ran'."""
    explicit = any(
        a.endswith(".py") or "::" in a for a in config.invocation_params.args
    )
    if config.getoption("--runslow") or config.getoption("-m") or explicit:
        return
    selected = [i for i in items if "slow" not in i.keywords]
    deselected = [i for i in items if "slow" in i.keywords]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = selected
