import os
import sys

# Tests see ONE device (brief: only dryrun.py forces 512).  Distributed
# tests spawn subprocesses that set XLA_FLAGS themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="include tests marked slow (jit-heavy model/system suites)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: jit-heavy model/system test, deselected by default; "
        "include with --runslow (or select directly with -m slow)",
    )
    config.addinivalue_line(
        "markers",
        "backend: compute-backend registry parity test (jnp vs "
        "pallas-interpret); always part of the fast default tier — "
        "select alone with -m backend",
    )
    config.addinivalue_line(
        "markers",
        "integrity: data-plane integrity test (ingest sentinel, tenant "
        "rebuild, compensated accumulation); always part of the fast "
        "default tier — select alone with -m integrity",
    )


def pytest_collection_modifyitems(config, items):
    """Fast default run: deselect ``slow`` unless --runslow or an explicit
    -m expression is given, so ``python -m pytest -x -q`` stays quick and
    deterministic (the estimator/streaming equivalence tier).  Naming a
    test file or node id directly also opts in — ``pytest
    tests/test_models.py`` should run it, not report 'no tests ran'."""
    explicit = any(
        a.endswith(".py") or "::" in a for a in config.invocation_params.args
    )
    if config.getoption("--runslow") or config.getoption("-m") or explicit:
        return
    # backend-parity and integrity tests are pinned into the fast tier even
    # if a future module marks them slow: cross-backend equivalence and the
    # data-plane integrity contracts are tier-1.
    keep = lambda i: (
        "slow" not in i.keywords
        or "backend" in i.keywords
        or "integrity" in i.keywords
    )
    selected = [i for i in items if keep(i)]
    deselected = [i for i in items if not keep(i)]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = selected
