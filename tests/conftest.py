import os
import sys

# Tests see ONE device (brief: only dryrun.py forces 512).  Distributed
# tests spawn subprocesses that set XLA_FLAGS themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
