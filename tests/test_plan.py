"""Fused statistics plans: fused ≡ independent, one traversal, monoid laws.

Pins the `repro.core.plan` layer:
  * every member of a fused plan matches its independent estimator call to
    float round-off — across jnp/pallas-interpret backends and across the
    monolithic / chunked / merged / scan-ingested execution strategies;
  * a plan evaluation traverses the series exactly ONCE (counted by a
    wrapper backend on the primitives), where independent calls traverse
    once per statistic;
  * the shared-halo construction is exact when members need very different
    window widths (the widest member donates the halo, narrower members
    recover their tail windows at finalize);
  * non-offset-aware generic kernels with stride > 1 fall back to grouped
    sub-plans (extra traversal), everything else fuses into one group.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import get_backend
from repro.core.estimators.arma import fit_arma
from repro.core.estimators.spectral import welch_psd
from repro.core.estimators.stats import (
    autocovariance,
    lag_sum_engine,
    moment_engine,
    streaming_autocovariance,
    streaming_window_moments,
)
from repro.core.estimators.yule_walker import yule_walker
from repro.core.mapreduce import (
    block_window_map_reduce,
    scan_window_map_reduce,
    serial_window_map_reduce,
)
from repro.core.overlap import OverlapSpec
from repro.core.plan import (
    StatPlan,
    analyze,
    arma_request,
    autocovariance_request,
    fused_engine,
    kernel_request,
    moments_request,
    welch_request,
    yule_walker_request,
)
from repro.timeseries import StreamingEstimator

REQUESTS = [
    autocovariance_request(8),
    yule_walker_request(4),
    moments_request(32),
    welch_request(nperseg=64, overlap=32),
]


def _series(n=3000, d=2, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def _independent(x, backend=None):
    """The four REQUESTS statistics computed by separate estimator calls."""
    me = moment_engine(32, x.shape[1], backend=backend)
    return {
        "autocovariance": autocovariance(x, 8, backend=backend),
        "yule_walker": yule_walker(x, 4, backend=backend),
        "moments": streaming_window_moments(me, me.from_chunk(x)),
        "welch": welch_psd(x, nperseg=64, overlap=32, backend=backend),
    }


def _assert_matches(got, want):
    np.testing.assert_allclose(
        got["autocovariance"], want["autocovariance"], rtol=1e-5, atol=1e-4
    )
    for g, w in zip(got["yule_walker"], want["yule_walker"]):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)
    for key in ("mean", "var", "count"):
        np.testing.assert_allclose(
            got["moments"][key], want["moments"][key], rtol=1e-5, atol=1e-6
        )
    np.testing.assert_allclose(got["welch"][0], want["welch"][0], rtol=1e-6)
    np.testing.assert_allclose(got["welch"][1], want["welch"][1], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------- fused ≡ independent


@pytest.mark.backend
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_equals_independent(backend):
    x = _series()
    got = analyze(x, REQUESTS, backend=backend)
    _assert_matches(got, _independent(x, backend="jnp"))


@pytest.mark.backend
@pytest.mark.parametrize("max_lag,window", [(6, 10), (0, 1), (8, 1), (0, 16)])
def test_fused_primitive_cross_backend_parity(max_lag, window):
    """The sixth primitive agrees between jnp and the fused Pallas VMEM
    kernel (interpret mode on CPU), and with its naive reference."""
    from repro.kernels.window_stats.ref import fused_lag_moments_ref

    y = jax.random.normal(jax.random.PRNGKey(11), (300, 3))
    mask = jax.random.bernoulli(jax.random.PRNGKey(12), 0.7, (280,))
    lag_j, mom_j = get_backend("jnp").fused_lagged_moments(y, mask, max_lag, window)
    lag_p, mom_p = get_backend("pallas").fused_lagged_moments(y, mask, max_lag, window)
    lag_r, mom_r = fused_lag_moments_ref(y, mask, max_lag, window)
    np.testing.assert_allclose(lag_j, lag_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(mom_j, mom_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(lag_p, lag_r, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(mom_p, mom_r, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize(
    "strategy", ["monolithic", "chunked", "scan", "merged"]
)
def test_fused_strategies_agree(strategy):
    """One traversal, chunked updates, scan ingest, and a merge tree all
    produce the same member results."""
    x = _series(seed=1)
    plan = fused_engine(REQUESTS, d=x.shape[1])
    if strategy == "monolithic":
        states = plan.from_chunk(x)
    elif strategy == "chunked":
        states = plan.init()
        for lo, hi in [(0, 1), (1, 700), (700, 1413), (1413, 3000)]:
            states = plan.update(states, x[lo:hi])
    elif strategy == "scan":
        states = plan.consume(plan.init(), x.reshape(10, 300, x.shape[1]))
    else:  # merged: adjacent segments joined with commuted operands
        a = plan.from_chunk(x[:1100], 0)
        b = plan.from_chunk(x[1100:1101], 1100)
        c = plan.from_chunk(x[1101:], 1101)
        states = plan.merge(c, plan.merge(b, a))
    _assert_matches(plan.finalize(states), _independent(x))


def test_shared_halo_mixed_windows():
    """Members with very different h_right share the widest member's halo;
    the narrow members' tail windows are recovered exactly at finalize."""
    x = _series(n=700, d=3, seed=2)
    got = analyze(
        x,
        [
            autocovariance_request(2),
            moments_request(5),
            welch_request(nperseg=128, overlap=0),
        ],
    )
    np.testing.assert_allclose(
        got["autocovariance"], autocovariance(x, 2), rtol=1e-5, atol=1e-4
    )
    me = moment_engine(5, 3)
    want_m = streaming_window_moments(me, me.from_chunk(x))
    for key in ("mean", "var", "count"):
        np.testing.assert_allclose(
            got["moments"][key], want_m[key], rtol=1e-5, atol=1e-6
        )
    f, p = welch_psd(x, nperseg=128, overlap=0)
    np.testing.assert_allclose(got["welch"][1], p, rtol=1e-4, atol=1e-5)


def test_arma_member_shares_lagged_entry():
    x = _series(seed=3)
    got = analyze(x, [arma_request(1, 1), autocovariance_request(8)])
    A, B, sig = got["arma"]
    A_r, B_r, sig_r = fit_arma(x, 1, 1)
    np.testing.assert_allclose(A, A_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(B, B_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sig, sig_r, rtol=1e-4, atol=1e-5)


def test_chunk_size_analyze_path():
    x = _series(seed=4)
    got = analyze(x, REQUESTS, chunk_size=271)  # ragged remainder exercised
    _assert_matches(got, _independent(x))


# ---------------------------------------------------------------- one traversal


class CountingBackend:
    """Delegating backend that records (primitive, rows) per invocation."""

    name = "counting"

    def __init__(self, inner):
        self._inner = inner
        self.calls = []

    def _rec(self, prim, rows):
        self.calls.append((prim, int(rows)))

    def lagged_sums(self, x, max_lag):
        self._rec("lagged_sums", x.shape[0])
        return self._inner.lagged_sums(x, max_lag)

    def masked_lagged_sums(self, y, mask, max_lag):
        self._rec("masked_lagged_sums", mask.shape[0])
        return self._inner.masked_lagged_sums(y, mask, max_lag)

    def windowed_moments(self, x, window):
        self._rec("windowed_moments", x.shape[0])
        return self._inner.windowed_moments(x, window)

    def segment_fft_power(self, segments, taper, detrend=True):
        self._rec("segment_fft_power", segments.shape[0] * segments.shape[1])
        return self._inner.segment_fft_power(segments, taper, detrend)

    def banded_matvec(self, diags, x):
        self._rec("banded_matvec", diags.shape[0])
        return self._inner.banded_matvec(diags, x)

    def fused_lagged_moments(self, y, mask, max_lag, window):
        self._rec("fused_lagged_moments", mask.shape[0])
        return self._inner.fused_lagged_moments(y, mask, max_lag, window)

    def series_traversals(self, n):
        """Primitive invocations that walked ≥ n rows of *series-layout*
        input.  ``segment_fft_power`` is excluded: it consumes segment
        windows already gathered inside a traversal (overlap duplicates
        rows), so its row count measures segment math, not series reads."""
        return [
            c for c in self.calls if c[1] >= n and c[0] != "segment_fft_power"
        ]


def test_analyze_is_one_traversal():
    """analyze([autocov, yw, moments, welch]) reads the series ONCE: exactly
    one series-sized primitive call (the fused one); every other primitive
    call is a halo-sized finalize correction."""
    n = 2000
    x = _series(n=n)
    counting = CountingBackend(get_backend("jnp"))
    got = analyze(x, REQUESTS, backend=counting)
    _assert_matches(got, _independent(x))

    walks = counting.series_traversals(n)
    assert walks == [("fused_lagged_moments", n)]
    # no un-fused series-sized contraction ever ran
    assert all(prim != "lagged_sums" for prim, _ in counting.calls)
    assert all(prim != "windowed_moments" for prim, _ in counting.calls)
    # the welch member FFTs segments exactly once during the traversal (plus
    # at most one halo-sized finalize correction)
    ffts = [r for p, r in counting.calls if p == "segment_fft_power"]
    assert len(ffts) <= 2 and max(ffts) <= 2 * n + 64
    # every remaining call is a halo-sized finalize correction
    others = [
        r
        for p, r in counting.calls
        if p not in ("fused_lagged_moments", "segment_fft_power") or (
            p == "fused_lagged_moments" and r < n
        )
    ]
    assert all(r < 64 for r in others)


def test_independent_calls_are_n_traversals():
    """The baseline the plan removes: each independent estimator call makes
    its own series-sized traversal."""
    n = 2000
    x = _series(n=n)
    counting = CountingBackend(get_backend("jnp"))
    autocovariance(x, 8, backend=counting)
    yule_walker(x, 4, backend=counting)
    me = moment_engine(32, x.shape[1], backend=counting)
    streaming_window_moments(me, me.from_chunk(x))
    assert len(counting.series_traversals(n)) >= 3


# ------------------------------------------------------------ generic members


def test_kernel_request_custom_member():
    """A generic ChunkKernel member rides the shared traversal; its raw stat
    equals the serial window map-reduce over the same kernel."""
    x = _series(n=500, d=2, seed=5)
    w = 4  # window width h_left=0, h_right=3

    def ck(y, mask):
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y, s, w, axis=0)
        )(jnp.arange(mask.shape[0]))
        per = jnp.sum(wins[:, 0] * wins[:, -1], axis=-1)  # first·last product
        return jnp.sum(jnp.where(mask, per, 0.0))

    plan = StatPlan(
        [kernel_request("fl", ck, h_right=w - 1)], d=2
    )
    assert plan.num_traversals == 1
    raw = plan.finalize(plan.from_chunk(x))["fl"]
    want = serial_window_map_reduce(
        lambda win: jnp.sum(win[0] * win[-1]), x, 0, w - 1
    )
    # member covers starts with a full fused window (= its own window here)
    np.testing.assert_allclose(raw, want, rtol=1e-5, atol=1e-5)


def test_mixed_stride_generic_kernel_groups():
    """A non-offset-aware strided kernel cannot fuse — it gets its own
    traversal group; built-ins stay fused in group 0."""
    ck = lambda y, mask: jnp.sum(jnp.where(mask[:, None], y[: mask.shape[0]], 0.0))
    plan = StatPlan(
        [
            autocovariance_request(4),
            welch_request(nperseg=32, overlap=16),  # strided but offset-aware
            kernel_request("coarse", ck, h_right=0, stride=7),
        ],
        d=1,
    )
    assert plan.num_traversals == 2
    x = _series(n=400, d=1, seed=6)
    out = plan.finalize(plan.from_chunk(x))
    np.testing.assert_allclose(
        out["autocovariance"], autocovariance(x, 4), rtol=1e-5, atol=1e-4
    )
    # stride-7 member summed every 7th sample (window 1)
    np.testing.assert_allclose(out["coarse"], jnp.sum(x[::7]), rtol=1e-5)


def test_duplicate_request_names_dedup():
    x = _series(n=300)
    out = analyze(x, [moments_request(8), moments_request(16)])
    assert set(out) == {"moments", "moments_2"}
    assert float(out["moments"]["count"]) == 300 - 8 + 1
    assert float(out["moments_2"]["count"]) == 300 - 16 + 1


# ------------------------------------------------------------------ monoid laws


def test_plan_monoid_laws():
    x = _series(n=900, d=2, seed=7)
    plan = fused_engine(
        [autocovariance_request(3), welch_request(nperseg=32, overlap=16)], d=2
    )
    a = plan.from_chunk(x[:301], 0)
    b = plan.from_chunk(x[301:600], 301)
    c = plan.from_chunk(x[600:], 600)

    left = plan.merge(plan.merge(a, b), c)
    right = plan.merge(a, plan.merge(b, c))
    ref = plan.finalize(plan.from_chunk(x))
    for tree_a, tree_b in [(plan.finalize(left), ref), (plan.finalize(right), ref)]:
        jax.tree.map(
            lambda u, v: np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-5),
            tree_a,
            tree_b,
        )
    # identity
    with_id = plan.merge(plan.init(), plan.from_chunk(x))
    jax.tree.map(
        lambda u, v: np.testing.assert_allclose(u, v, rtol=1e-5, atol=1e-6),
        plan.finalize(with_id),
        ref,
    )


# --------------------------------------------------------- scan-driven ingest


def test_streaming_estimator_consume_equals_ingest_iter():
    x = _series(n=2048, d=2, seed=8)
    engine = lag_sum_engine(6, 2)
    stack = x.reshape(16, 128, 2)

    loop = StreamingEstimator(engine).ingest_iter(list(stack))
    scan = StreamingEstimator(engine).consume(stack)
    np.testing.assert_allclose(
        scan.finalize(streaming_autocovariance),
        loop.finalize(streaming_autocovariance),
        rtol=1e-6,
        atol=1e-6,
    )
    np.testing.assert_allclose(
        scan.finalize(streaming_autocovariance),
        autocovariance(x, 6),
        rtol=1e-5,
        atol=1e-4,
    )


def test_streaming_estimator_consume_batched():
    xb = jax.random.normal(jax.random.PRNGKey(9), (3, 1200, 2))
    engine = lag_sum_engine(4, 2)
    stack = jnp.stack([xb[:, i * 300 : (i + 1) * 300] for i in range(4)])
    est = StreamingEstimator(engine, batch=3).consume(stack)
    got = est.finalize(streaming_autocovariance)
    want = jnp.stack([autocovariance(xb[i], 4) for i in range(3)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_scan_window_map_reduce_equals_block():
    x = _series(n=1000, d=2, seed=10)
    spec = OverlapSpec(n=1000, block_size=128, h_left=1, h_right=2)
    kernel = lambda w: jnp.outer(w[0], w[-1])
    want = block_window_map_reduce(kernel, x, spec)
    got = scan_window_map_reduce(kernel, x, spec)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        got, serial_window_map_reduce(kernel, x, 1, 2), rtol=1e-5, atol=1e-5
    )
