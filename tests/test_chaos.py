"""Chaos engineering: injected faults must degrade the stack, not kill it.

Pins the PR's four robustness contracts end-to-end, all deterministic
(seeded schedules, call-counted cooldowns — a failure here replays):

  * FaultInjector — named-site schedules (explicit call indices + seeded
    Bernoulli rates) replay bit-for-bit; per-site RNG substreams are
    independent;
  * CircuitBreakerBackend — a raising primitive trips to the jnp oracle,
    probes after a call-counted cooldown, recovers on success, re-opens on
    a failed probe;
  * verified checkpoints — a torn payload fails crc32 verification with
    CheckpointCorrupt, restore walks back past corrupt generations to the
    newest intact one, transient write failures are retried with backoff;
  * degraded-mode gateway — a blown tick deadline flips health to
    degraded, sheds lowest-priority queries with Degraded (never
    RateLimited), defers snapshots, and recovers after clean ticks;

plus the acceptance scenario: one seeded schedule combining a kernel
failure, a torn checkpoint, and a stalled tick, with kill-and-restart and
walk-back, serving answers identical to a no-fault run for every
non-rejected query.
"""
import asyncio
import os
import time

import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointCorrupt,
    CheckpointManager,
    list_steps,
    restore_latest_intact,
    restore_pytree,
    save_pytree,
)
from repro.core.backend import (
    CircuitBreakerBackend,
    JnpBackend,
    PRIMITIVE_NAMES,
    get_backend,
)
from repro.core.frame import FrameSession
from repro.runtime import chaos
from repro.runtime.chaos import FaultInjector, InjectedFault
from repro.runtime.fault import FaultTolerantLoop, StragglerMonitor
from repro.serving.gateway import (
    Degraded,
    GatewayConfig,
    RateClass,
    StatsGateway,
    _Pending,
)

D = 2


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    yield
    chaos.clear()


def _session(num_users, backend="jnp"):
    sess = FrameSession(d=D, num_users=num_users, backend=backend)
    sess.autocovariance(3)
    sess.moments(8)
    return sess


def run(coro):
    return asyncio.run(coro)


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "w": rng.randn(4, 3).astype(np.float32),
        "b": rng.randn(3).astype(np.float32),
    }


def _tear(path):
    """Overwrite bytes in the middle of a file (simulated torn write)."""
    with open(path, "r+b") as f:
        f.seek(max(os.path.getsize(path) // 2, 0))
        f.write(b"\x00TORN\x00")


# ------------------------------------------------------ (1) FaultInjector


def test_injector_explicit_call_schedule():
    inj = FaultInjector(seed=0)
    inj.fail("backend.fused_plan_update", calls={2, 3})
    raised = []
    for i in range(6):
        try:
            inj.fire("backend.fused_plan_update")
        except InjectedFault:
            raised.append(i)
    assert raised == [2, 3]
    assert inj.count("backend.fused_plan_update") == 6
    assert inj.log == [
        ("backend.fused_plan_update", 2, "fail"),
        ("backend.fused_plan_update", 3, "fail"),
    ]


def test_injector_rate_schedule_replays_bit_for_bit():
    def firings(seed):
        inj = FaultInjector(seed=seed).fail("site.x", rate=0.3)
        out = []
        for i in range(200):
            try:
                inj.fire("site.x")
            except InjectedFault:
                out.append(i)
        return out

    a, b = firings(7), firings(7)
    assert a == b                      # same seed: identical schedule
    assert 20 < len(a) < 100           # the rate actually fires
    assert firings(8) != a             # different seed: different draws


def test_injector_sites_are_independent_substreams():
    # adding a rule (and draws) on one site must not shift another's
    solo = FaultInjector(seed=3).fail("b", rate=0.5)
    both = FaultInjector(seed=3).fail("a", rate=0.5).fail("b", rate=0.5)

    def fires_b(inj):
        out = []
        for i in range(64):
            if inj is both:
                try:
                    inj.fire("a")
                except InjectedFault:
                    pass
            try:
                inj.fire("b")
            except InjectedFault:
                out.append(i)
        return out

    assert fires_b(solo) == fires_b(both)


def test_injector_stall_then_fail_composes():
    inj = FaultInjector()
    inj.stall("s", calls={1}, seconds=0.05).fail("s", calls={1})
    inj.fire("s")                      # call 0: clean
    t0 = time.perf_counter()
    with pytest.raises(InjectedFault, match="call 1"):
        inj.fire("s")
    assert time.perf_counter() - t0 >= 0.04
    assert [a for (_, _, a) in inj.log] == ["stall", "fail"]


def test_injector_corrupt_rule_and_scoped_install():
    inj = FaultInjector().corrupt("checkpoint.payload", calls={1})
    assert chaos.installed() is None
    with chaos.scoped(inj) as got:
        assert got is inj and chaos.installed() is inj
        assert chaos.should_corrupt("checkpoint.payload") is False
        assert chaos.should_corrupt("checkpoint.payload") is True
        assert chaos.should_corrupt("checkpoint.payload") is False
    assert chaos.installed() is None
    # module-level hooks are no-ops with nothing installed
    chaos.fire("anything")
    assert chaos.should_corrupt("anything") is False


# ------------------------------------------------- (2) circuit breaker


def _x(seed=0, n=32):
    import jax.numpy as jnp

    return jnp.asarray(
        np.random.RandomState(seed).randn(n, D).astype(np.float32)
    )


def test_breaker_trips_to_fallback_and_recovers_after_cooldown():
    br = CircuitBreakerBackend(
        primary=JnpBackend(), fallback=JnpBackend(),
        trip_after=2, cooldown_calls=3,
    )
    want = np.asarray(JnpBackend().lagged_sums(_x(), 3))
    inj = FaultInjector().fail("backend.lagged_sums", calls={0, 1})
    with chaos.scoped(inj):
        outs = [np.asarray(br.lagged_sums(_x(), 3)) for _ in range(5)]
    # every call served the oracle value, through primary or fallback
    for got in outs:
        np.testing.assert_array_equal(got, want)
    st = br.breaker_metrics()["primitives"]["lagged_sums"]
    # calls 0,1 fail → trip; 2,3 ride the open cooldown; 4 probes and heals
    assert st["trips"] == 1
    assert st["probes"] == 1
    assert st["recoveries"] == 1
    assert st["state"] == "closed"
    assert st["fallback_calls"] == 4
    assert st["primary_calls"] == 1
    assert "InjectedFault" in st["last_error"]
    m = br.breaker_metrics()
    assert m["trips"] == 1 and m["open"] == []


def test_breaker_failed_probe_reopens():
    br = CircuitBreakerBackend(
        primary=JnpBackend(), fallback=JnpBackend(),
        trip_after=1, cooldown_calls=2,
    )
    inj = FaultInjector().fail("backend.lagged_sums", calls={0, 1, 2})
    with chaos.scoped(inj):
        for _ in range(7):
            br.lagged_sums(_x(), 3)
    st = br.breaker_metrics()["primitives"]["lagged_sums"]
    # d0 trips; probes at d2/d4 fail and re-open (not new trips); d6 heals
    assert st["trips"] == 1
    assert st["probes"] == 3
    assert st["recoveries"] == 1
    assert st["state"] == "closed"


def test_breaker_open_state_skips_primary_entirely():
    class Wedged:
        name = "wedged"

        def __getattr__(self, prim):
            if prim in PRIMITIVE_NAMES:
                def boom(*a, **k):
                    raise RuntimeError("kernel build wedged")
                return boom
            raise AttributeError(prim)

    br = CircuitBreakerBackend(
        primary=Wedged(), fallback=JnpBackend(),
        trip_after=1, cooldown_calls=4,
    )
    want = np.asarray(JnpBackend().lagged_sums(_x(), 3))
    for _ in range(4):
        np.testing.assert_array_equal(
            np.asarray(br.lagged_sums(_x(), 3)), want
        )
    st = br.breaker_metrics()["primitives"]["lagged_sums"]
    assert st["state"] == "open"
    # only the tripping call touched the primary; the cooldown never did
    assert st["consecutive_failures"] == 1
    assert br.breaker_metrics()["open"] == ["lagged_sums"]
    br.reset("lagged_sums")
    assert br.breaker_metrics()["open"] == []


def test_breaker_default_pallas_primary_matches_oracle():
    br = CircuitBreakerBackend()       # pallas primary, jnp fallback
    x = _x(seed=5, n=48)
    np.testing.assert_allclose(
        np.asarray(br.lagged_sums(x, 4)),
        np.asarray(JnpBackend().lagged_sums(x, 4)),
        rtol=1e-4, atol=1e-4,
    )
    st = br.breaker_metrics()["primitives"]["lagged_sums"]
    assert st["state"] == "closed" and st["primary_calls"] == 1


def test_breaker_validates_config_and_rejects_unknown_attr():
    with pytest.raises(ValueError):
        CircuitBreakerBackend(trip_after=0)
    br = CircuitBreakerBackend(primary=JnpBackend(), fallback=JnpBackend())
    with pytest.raises(AttributeError):
        br.not_a_primitive


# ------------------------------------------- (3) verified checkpoints


def test_manifest_carries_checksums_and_restore_verifies(tmp_path):
    tree = _tree(1)
    path = save_pytree(tree, str(tmp_path), 0)
    import json

    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert sorted(manifest["checksums"]) == sorted(manifest["keys"])
    got = restore_pytree(_tree(9), str(tmp_path), 0)
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_torn_payload_raises_checkpoint_corrupt(tmp_path):
    save_pytree(_tree(1), str(tmp_path), 0)
    _tear(str(tmp_path / "step_0000000000" / "arrays.npz"))
    with pytest.raises(CheckpointCorrupt):
        restore_pytree(_tree(1), str(tmp_path), 0)
    # verify=False skips checksum checks but a torn zip still can't load
    with pytest.raises(CheckpointCorrupt):
        restore_pytree(_tree(1), str(tmp_path), 0, verify=False)


def test_injected_corruption_is_caught_by_verification(tmp_path):
    inj = FaultInjector().corrupt("checkpoint.payload", calls={1})
    with chaos.scoped(inj):
        save_pytree(_tree(1), str(tmp_path), 0)   # call 0: intact
        save_pytree(_tree(2), str(tmp_path), 1)   # call 1: torn on disk
    restore_pytree(_tree(0), str(tmp_path), 0)
    with pytest.raises(CheckpointCorrupt, match="verification|unreadable"):
        restore_pytree(_tree(0), str(tmp_path), 1)


def test_walk_back_to_newest_intact_generation(tmp_path):
    for step in range(3):
        save_pytree(_tree(step), str(tmp_path), step)
    _tear(str(tmp_path / "step_0000000002" / "arrays.npz"))
    state, step, skipped = restore_latest_intact(_tree(0), str(tmp_path))
    assert step == 1 and skipped == [2]
    np.testing.assert_array_equal(state["w"], _tree(1)["w"])
    assert list_steps(str(tmp_path)) == [0, 1, 2]


def test_pre_checksum_checkpoint_restores_unverified(tmp_path):
    import json

    save_pytree(_tree(1), str(tmp_path), 0)
    man = str(tmp_path / "step_0000000000" / "manifest.json")
    with open(man) as f:
        payload = json.load(f)
    del payload["checksums"]           # a checkpoint from before this PR
    with open(man, "w") as f:
        json.dump(payload, f)
    got = restore_pytree(_tree(0), str(tmp_path), 0)
    np.testing.assert_array_equal(got["b"], _tree(1)["b"])


def test_all_generations_corrupt_cold_starts_loop(tmp_path):
    for step in range(2):
        save_pytree(_tree(step), str(tmp_path), step)
        _tear(str(tmp_path / f"step_{step:010d}" / "arrays.npz"))
    with pytest.raises(CheckpointCorrupt, match="every retained"):
        restore_latest_intact(_tree(0), str(tmp_path))
    loop = FaultTolerantLoop(str(tmp_path), every=1)
    with pytest.warns(RuntimeWarning, match="starting fresh"):
        state, start = loop.restore_or(_tree(5))
    assert start == 0
    assert loop.last_restore_skipped == [1, 0]
    np.testing.assert_array_equal(state["w"], _tree(5)["w"])
    loop.close()


def test_manager_retries_transient_write_failure(tmp_path):
    inj = FaultInjector().fail("checkpoint.write", calls={0})
    mgr = CheckpointManager(str(tmp_path), retries=2, backoff=0.01)
    with chaos.scoped(inj):
        mgr.save(_tree(3), 0)
        mgr.flush()                    # inside scope: the worker retries
    assert mgr.retried_saves == 1
    assert mgr.saved_steps == [0]
    got = restore_pytree(_tree(0), str(tmp_path), 0)
    np.testing.assert_array_equal(got["w"], _tree(3)["w"])
    mgr.close()


def test_manager_surfaces_exhausted_retries(tmp_path):
    inj = FaultInjector().fail("checkpoint.write", calls={0, 1, 2})
    mgr = CheckpointManager(str(tmp_path), retries=2, backoff=0.01)
    with chaos.scoped(inj):
        mgr.save(_tree(3), 0)
        with pytest.raises(InjectedFault):
            mgr.flush()
    assert mgr.retried_saves == 2
    assert mgr.latest_step() is None
    with pytest.raises(InjectedFault):
        mgr.close()                    # re-raises, but still reaps the worker
    assert not mgr._worker.is_alive()


# --------------------------------------------- (4) degraded-mode gateway


def test_blown_deadline_degrades_sheds_and_recovers():
    cfg = GatewayConfig(tick_deadline=0.05, degraded_recovery=2)
    gw = StatsGateway(_session(3), cfg)
    inj = FaultInjector().stall("gateway.tick", calls={1}, seconds=0.2)

    async def scenario():
        with chaos.scoped(inj):
            await gw.tick()                        # tick 0: in budget
            assert gw.health()["state"] == "ok"
            await gw.tick()                        # tick 1: stalled → blown
        assert gw.health()["state"] == "degraded"
        # lowest-priority pending queries are shed at the next tick start
        # with Degraded — a distinct signal from RateLimited
        fut = asyncio.get_running_loop().create_future()
        gw._query_q.append(_Pending(0, fut, time.perf_counter()))
        await gw.tick()                            # tick 2: sheds, in budget
        with pytest.raises(Degraded, match="shed"):
            await fut
        assert gw.health()["state"] == "degraded"  # streak 1 of 2
        await gw.tick()                            # tick 3: recovery
        assert gw.health()["state"] == "ok"
        # disarm before the query tick: its first-use jit trace would blow
        # the 50ms budget on its own and re-degrade the gateway
        gw.config.tick_deadline = 0.0
        q = gw.submit_query(0)                     # healthy again: served
        await gw.tick()
        return await q

    res = run(scenario())
    assert sorted(res) == ["autocovariance", "moments"]
    h = gw.health()
    assert h["deadline"]["blown"] == 1
    assert h["deadline"]["shed"] == 1
    assert gw.counters["degraded_entries"] == 1
    assert gw.counters["degraded_recoveries"] == 1
    m = gw.metrics()
    assert m["deadline_blown"] == 1 and m["query"]["rejected_degraded"] == 1


def test_shedding_respects_rate_class_priority():
    cfg = GatewayConfig(
        tick_deadline=0.05,
        degraded_recovery=8,           # stay degraded across the tick
        rate_classes={
            "default": RateClass(priority=0),
            "gold": RateClass(name="gold", priority=1),
        },
    )
    gw = StatsGateway(_session(2), cfg)
    gw.set_tenant_class(1, "gold")
    inj = FaultInjector().stall("gateway.tick", calls={0}, seconds=0.2)

    async def scenario():
        with chaos.scoped(inj):
            await gw.tick()                        # blown → degraded
        loop = asyncio.get_running_loop()
        cheap = _Pending(0, loop.create_future(), time.perf_counter())
        gold = gw.submit_query(1)                  # priority 1: kept
        gw._query_q.appendleft(cheap)
        await gw.tick()
        with pytest.raises(Degraded):
            await cheap.future
        return await gold

    res = run(scenario())
    assert sorted(res) == ["autocovariance", "moments"]
    assert gw.counters["shed_query_degraded"] == 1


def test_snapshot_deferred_while_degraded_taken_on_recovery(tmp_path):
    cfg = GatewayConfig(
        checkpoint_dir=str(tmp_path), snapshot_every=1,
        tick_deadline=0.005, degraded_recovery=1,
    )
    gw = StatsGateway(_session(2), cfg)

    async def scenario():
        f = gw.submit_ingest(0, np.ones((8, D), np.float32))
        await gw.tick()    # ingest + trace: certainly over 5ms → degraded
        await f
        assert gw.health()["state"] == "degraded"
        assert gw.health()["deadline"]["snapshot_deferred"] is True
        assert gw.counters["snapshots"] == 0
        await gw.tick()    # empty tick: in budget → recovery + snapshot
        assert gw.health()["state"] == "ok"

    run(scenario())
    gw._loop_rt.manager.flush()
    assert gw.counters["snapshots_deferred"] == 1
    assert gw.counters["snapshots"] == 1
    assert gw._loop_rt.manager.latest_step() == 1  # saved at the recovery tick
    run(gw.stop())


def test_injected_tick_fault_is_survivable():
    gw = StatsGateway(_session(2))
    inj = FaultInjector().fail("gateway.tick", calls={0})

    async def scenario():
        with chaos.scoped(inj):
            q = gw.submit_query(0)
            await gw.tick()            # the injected raise doesn't kill it
            return await q

    res = run(scenario())
    assert sorted(res) == ["autocovariance", "moments"]
    assert gw.counters["tick_faults"] == 1


def test_idle_token_buckets_are_evicted():
    cfg = GatewayConfig(
        bucket_idle_ticks=4,
        rate_classes={"default": RateClass(ingest_per_tick=100,
                                           query_per_tick=100)},
    )
    gw = StatsGateway(_session(4), cfg)
    chunk = np.ones((8, D), np.float32)

    async def scenario():
        futs = [gw.submit_ingest(0, chunk), gw.submit_ingest(1, chunk)]
        await gw.tick()                # tick 0
        await asyncio.gather(*futs)
        assert gw.metrics()["bucket_tenants"] == 2
        await gw.tick()                # 1
        await gw.tick()                # 2
        f = gw.submit_ingest(1, chunk)  # tenant 1 active at tick 3
        await gw.tick()                # 3
        await f
        await gw.tick()                # tick 4: sweep evicts tenant 0

    run(scenario())
    assert gw.counters["buckets_evicted"] == 1
    assert gw.metrics()["bucket_tenants"] == 1  # tenant 1 survived


def test_reset_metrics_windows_while_totals_stay_monotonic():
    cfg = GatewayConfig(max_pending_query=1)
    gw = StatsGateway(_session(2), cfg)

    async def scenario():
        from repro.serving.gateway import QueueFull

        q = gw.submit_query(0)
        with pytest.raises(QueueFull):
            gw.submit_query(1)
        await gw.tick()
        await q
        m1 = gw.metrics()
        gw.reset_metrics()
        m2 = gw.metrics()
        q = gw.submit_query(0)
        with pytest.raises(QueueFull):
            gw.submit_query(1)
        await gw.tick()
        await q
        return m1, m2, gw.metrics()

    m1, m2, m3 = run(scenario())
    assert m1["query"]["rejected_queue_full"] == 1
    assert m1["window"]["rejected_query_queue_full"] == 1
    # reset: window re-bases and samples clear, totals never move backwards
    assert m2["query"]["rejected_queue_full"] == 1
    assert m2["window"]["rejected_query_queue_full"] == 0
    assert m2["query"]["count"] == 0
    assert m3["query"]["rejected_queue_full"] == 2
    assert m3["window"]["rejected_query_queue_full"] == 1
    assert m3["query"]["count"] == 1


def test_health_surfaces_breaker_and_draining():
    plain = StatsGateway(_session(2))
    assert "breaker" not in plain.health()
    br = CircuitBreakerBackend(primary=JnpBackend(), fallback=JnpBackend())
    gw = StatsGateway(_session(2, backend=br))
    h = gw.health()
    assert h["state"] == "ok" and h["breaker"]["trips"] == 0
    run(gw.stop())
    assert gw.health()["state"] == "draining"
    assert gw.metrics()["health"] == "draining"
    run(plain.stop())


# ------------------------------------------ (5) StragglerMonitor edges


def test_straggler_window_shorter_than_warmup_still_flags():
    mon = StragglerMonitor(threshold=2.0, window=4)
    for step in range(3):
        assert mon.record(step, 0.01) is False
    assert mon.record(3, 0.1) is True  # flat warm-up of 8 never got here
    assert mon.flagged == [3]
    with pytest.raises(ValueError):
        StragglerMonitor(window=0)


def test_straggler_threshold_exactly_met_is_not_flagged():
    mon = StragglerMonitor(threshold=2.0, window=16)
    for step in range(8):
        mon.record(step, 1.0)
    assert mon.record(8, 2.0) is False   # exactly 2× median: not a straggler
    assert mon.record(9, 2.0 + 1e-6) is True


def test_straggler_recovery_after_straggle_run():
    seen = []
    mon = StragglerMonitor(threshold=2.0, window=64,
                           on_straggle=lambda s, dt, med: seen.append(s))
    for step in range(8):
        mon.record(step, 1.0)
    for step in range(8, 11):
        assert mon.record(step, 5.0) is True
    for step in range(11, 20):          # back to normal: median holds at 1.0
        assert mon.record(step, 1.0) is False
    assert mon.flagged == [8, 9, 10]
    assert seen == [8, 9, 10]


# --------------------------------------------- (6) acceptance scenario


def test_chaos_schedule_end_to_end_matches_fault_free_run(tmp_path):
    """One seeded schedule — kernel failure + torn checkpoint + stalled
    tick — driven through the gateway with kill-and-restart: every
    non-rejected query answers identically to a fault-free run, and a
    second restart walks back past corrupted generations."""
    N = 3
    lengths = (16, 24, 32)
    rng = np.random.RandomState(11)
    rounds = [
        {u: rng.randn(c, D).astype(np.float32) for u in range(N)}
        for c in lengths
    ]

    async def drive(gw, do_rounds):
        answers = []
        for chunks in do_rounds:
            futs = [gw.submit_ingest(u, chunks[u]) for u in range(N)]
            qfuts = [gw.submit_query(u) for u in range(N)]
            await gw.tick()
            await asyncio.gather(*futs)
            answers.append(await asyncio.gather(*qfuts))
        return answers

    async def query_all(gw):
        qfuts = [gw.submit_query(u) for u in range(N)]
        await gw.tick()
        return await asyncio.gather(*qfuts)

    # fault-free reference: plain jnp, no durability, no injector
    ref_gw = StatsGateway(_session(N))
    ref = run(drive(ref_gw, rounds))          # ref[k] = answers after k+1 rounds
    run(ref_gw.stop())

    def check(got, want):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(
                np.asarray(g["autocovariance"]), np.asarray(w["autocovariance"])
            )
            for k in ("mean", "var", "count"):
                np.testing.assert_array_equal(
                    np.asarray(g["moments"][k]), np.asarray(w["moments"][k])
                )

    # chaos run: breaker over (jnp, jnp) so fallback math is bit-identical
    def chaos_gateway():
        br = CircuitBreakerBackend(
            primary=JnpBackend(), fallback=JnpBackend(),
            trip_after=1, cooldown_calls=2,
        )
        return StatsGateway(_session(N, backend=br), cfg)

    cfg = GatewayConfig(
        checkpoint_dir=str(tmp_path), snapshot_every=1, keep_checkpoints=3,
        tick_deadline=0.0,             # armed mid-run, past the trace ticks
        degraded_recovery=1,
    )
    inj = FaultInjector(seed=42)
    inj.fail("backend.fused_plan_update", calls=range(1000))  # kernel down
    inj.corrupt("checkpoint.payload", calls={1})              # tear gen 1
    inj.stall("gateway.tick", calls={2}, seconds=0.25)        # straggle tick 2

    gw = chaos_gateway()
    with chaos.scoped(inj):
        got = run(drive(gw, rounds[:2]))      # ticks 0-1 (snapshots 0, 1)
        check(got[0], ref[0])
        check(got[1], ref[1])
        gw.config.tick_deadline = 0.05        # arm the watchdog
        got2 = run(drive(gw, rounds[2:]))     # tick 2: stalled but serves
        check(got2[0], ref[2])
        assert gw.health()["state"] == "degraded"
        assert gw.counters["snapshots_deferred"] == 1
        with pytest.raises(Degraded):         # shed while degraded: rejected,
            gw.submit_query(0)                # excluded from the comparison

        async def recover():
            await gw.tick()                   # tick 3: clean → ok + snapshot
            assert gw.health()["state"] == "ok"
            return await query_all(gw)        # tick 4

        check(run(recover()), ref[2])
        # the kernel fault tripped the breaker exactly once and every
        # dispatch was served by the oracle
        bm = gw.health()["breaker"]
        assert bm["trips"] == 1 and bm["fallback_calls"] > 0
        assert ("backend.fused_plan_update", 0, "fail") in inj.log
        gw._loop_rt.manager.flush()           # snapshots durable, then "crash"

        # kill-and-restart: the newest generation (recovery tick 3) is
        # intact, so the restart serves identical answers, zero re-ingest
        gw.config.tick_deadline = 0.0
        gw2 = chaos_gateway()
        assert gw2.counters["restored_from_snapshot"] == 1
        assert gw2._loop_rt.last_restore_skipped == []
        check(run(query_all(gw2)), ref[2])
        assert gw2.counters["programs_ingest"] == 0
        run(gw2.stop())

        # tear the newest generation too: restore must walk back past BOTH
        # corrupted generations (3 torn now, 1 torn by the injector) to the
        # intact generation 0 — answers equal the reference after round 1
        assert list_steps(str(tmp_path)) == [0, 1, 3]
        _tear(str(tmp_path / "step_0000000003" / "arrays.npz"))
        gw3 = chaos_gateway()
        assert gw3.counters["restored_from_snapshot"] == 1
        assert gw3._loop_rt.last_restore_skipped == [3, 1]
        assert gw3._tick == 1
        check(run(query_all(gw3)), ref[0])
        run(gw3.stop(final_snapshot=False))
