"""Serving engine: batched greedy generation == step-by-step full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import forward, init_params
from repro.models.vlm_stub import fake_frame_embeds
from repro.serving.engine import ServeEngine

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def _greedy_by_full_forward(params, cfg, prompts, max_new, extra=None):
    toks = prompts
    out = []
    for _ in range(max_new):
        batch = {"tokens": toks, **(extra or {})}
        logits, _ = forward(params, batch, cfg)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(nxt)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return np.stack([np.asarray(t) for t in out], axis=1)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "h2o-danube-1.8b", "zamba2-7b", "xlstm-125m"])
def test_generate_matches_full_forward(arch):
    r = ARCHS[arch].reduced()
    params = init_params(jax.random.PRNGKey(0), r, dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, r.vocab)
    eng = ServeEngine(r, params, max_len=32)
    got = eng.generate(prompts, max_new=6).tokens
    ref = _greedy_by_full_forward(params, r, prompts, 6)
    np.testing.assert_array_equal(got, ref)


def test_generate_encdec():
    r = ARCHS["whisper-base"].reduced()
    params = init_params(jax.random.PRNGKey(2), r, dtype=jnp.float32)
    frames = fake_frame_embeds(jax.random.PRNGKey(3), 2, 16, r.d_model, jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, r.vocab)
    eng = ServeEngine(r, params, max_len=24)
    got = eng.generate(prompts, max_new=4, extra={"frames": frames}).tokens
    ref = _greedy_by_full_forward(params, r, prompts, 4, extra={"frames": frames})
    np.testing.assert_array_equal(got, ref)


def test_generate_rejects_overflow():
    r = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(jax.random.PRNGKey(5), r, dtype=jnp.float32)
    eng = ServeEngine(r, params, max_len=16)
    prompts = jnp.zeros((1, 14), jnp.int32)
    with pytest.raises(ValueError):
        eng.generate(prompts, max_new=8)


def test_rolling_ingest_mixed_dtype_t0_compiles_one_program():
    """Mixed int32/int64 ``t0`` arrivals must share ONE donated scatter
    program: the old bare ``jnp.asarray(t0)`` left the dtype
    caller-dependent, so every dtype mix compiled (and cached) a duplicate
    of the same-shaped hot-loop program."""
    from repro.core.estimators.stats import lag_sum_engine
    from repro.serving.rolling import RollingStatsService

    svc = RollingStatsService(lag_sum_engine(2, 1), 4, num_shards=2)
    ids = jnp.asarray([0, 1])
    chunks = jnp.ones((2, 8, 1))
    svc.ingest(ids, chunks, shard=0)  # t0=None default path
    svc.ingest(ids, chunks, shard=1, t0=np.asarray([8, 8], np.int64))
    svc.ingest(ids, chunks, shard=1, t0=np.asarray([16, 16], np.int32))
    svc.ingest(ids, chunks, shard=1, t0=[24, 24])  # python ints
    assert svc._scatter_update._cache_size() == 1


def test_rolling_shard_range_error_reports_real_range():
    """The shard-range error used to check ``_num_lanes`` (the eviction
    ring size) while reporting ``[0, num_shards)`` — the caller-facing
    lane count is what is enforced, in both modes."""
    from repro.core.estimators.stats import lag_sum_engine
    from repro.serving.rolling import RollingStatsService

    svc = RollingStatsService(lag_sum_engine(2, 1), 4, num_shards=2)
    with pytest.raises(ValueError, match=r"\[0, 2\)"):
        svc.ingest(jnp.asarray([0]), jnp.ones((1, 4, 1)), shard=2)
    ring = RollingStatsService(
        lag_sum_engine(0, 1), 4, window=16, num_buckets=4
    )
    # the ring's 4 internal buckets are NOT addressable ingest lanes
    with pytest.raises(ValueError, match=r"\[0, 1\)"):
        ring.ingest(jnp.asarray([0]), jnp.ones((1, 4, 1)), shard=2)


def test_generate_quantized_engine():
    """int8 ServeEngine produces valid generations (structure + finiteness)."""
    r = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(jax.random.PRNGKey(7), r, dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(8), (2, 10), 0, r.vocab)
    eng_q = ServeEngine(r, params, max_len=24, quantize=True)
    out = eng_q.generate(prompts, max_new=5)
    assert out.tokens.shape == (2, 5)
    assert (out.tokens >= 0).all() and (out.tokens < r.vocab).all()
