"""Banded spatial AR (paper §6) and graph weak memory (paper §9, §11)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators.spatial import (
    SpatialPartition,
    banded_nll,
    banded_predict,
    banded_predict_partitioned,
    banded_to_dense,
    dense_to_banded,
    fit_banded_ar,
)
from repro.core.graphs import (
    grid_graph,
    graph_window_map_reduce,
    k_hop_neighbors,
    line_graph,
    make_graph_partition,
    simulate_traffic_dbn,
    traffic_dbn_step,
)
from repro.timeseries import simulate_var

pytestmark = pytest.mark.slow  # jit-heavy: deselected by default, use --runslow



def _valid_band_mask(d, b):
    rows = np.arange(d)[:, None]
    cols = rows + np.arange(-b, b + 1)[None, :]
    return (cols >= 0) & (cols < d)


def test_banded_predict_matches_dense():
    d, b = 96, 3
    diags = jax.random.normal(jax.random.PRNGKey(0), (d, 2 * b + 1)) * 0.2
    diags = diags * _valid_band_mask(d, b)
    x = jax.random.normal(jax.random.PRNGKey(1), (d,))
    dense = banded_to_dense(diags)
    np.testing.assert_allclose(banded_predict(diags, x), dense @ x, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dense_to_banded(dense, b), diags, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("parts", [2, 4, 8])
def test_partitioned_predictor_exact(parts):
    """§6.1: row-partitioned predictor with P_i⁺ halos == full matvec."""
    d, b = 64, 2
    diags = jax.random.normal(jax.random.PRNGKey(2), (d, 2 * b + 1)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(3), (d,))
    part = SpatialPartition(d=d, num_parts=parts, bandwidth=b)
    y1 = banded_predict(diags, x)
    y2 = banded_predict_partitioned(diags, x, part)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_blockdiag_precision_separates():
    """§6.2: block-diagonal Π makes the likelihood separable per partition."""
    d, b = 32, 1
    diags = jax.random.normal(jax.random.PRNGKey(4), (d, 2 * b + 1)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(5), (100, d))
    part = SpatialPartition(d=d, num_parts=4, bandwidth=b)
    blocks = jnp.stack([jnp.eye(part.part_size)] * 4)
    full = banded_nll(diags, x, blocks, part)
    ident = banded_nll(diags, x, None, part)
    np.testing.assert_allclose(full, ident, rtol=1e-5, atol=1e-6)


def test_fit_banded_ar_recovers():
    d, b = 24, 2
    key = jax.random.PRNGKey(6)
    diags_true = (jax.random.normal(key, (d, 2 * b + 1)) * 0.15) * _valid_band_mask(d, b)
    A = banded_to_dense(diags_true)
    xs = simulate_var(jax.random.PRNGKey(7), A[None], 30_000)
    res = fit_banded_ar(xs, bandwidth=b, n_steps=250, num_parts=4)
    err = np.abs(np.asarray(res.diags - diags_true))[_valid_band_mask(d, b)]
    assert err.max() < 0.03


def test_k_hop():
    g = line_graph(10)
    m = k_hop_neighbors(g, np.array([5]), 2)
    assert sorted(np.where(m)[0]) == [3, 4, 5, 6, 7]


def test_graph_map_reduce_equals_serial():
    g = grid_graph(4, 6)
    x = jax.random.normal(jax.random.PRNGKey(8), (24, 2))
    part = make_graph_partition(g, 4, k=1)
    kern = lambda xc, nb, m: jnp.sum(xc**2) + jnp.sum(jnp.where(m[:, None], nb, 0.0) * xc)
    par = graph_window_map_reduce(kern, x, g, part)
    serial = 0.0
    for v in range(24):
        nb_ids = g.nbrs[v]
        nb = jnp.stack([x[n] if n >= 0 else jnp.zeros(2) for n in nb_ids])
        mask = jnp.asarray(nb_ids >= 0)
        serial += kern(x[v], nb, mask)
    np.testing.assert_allclose(par, serial, rtol=1e-5, atol=1e-4)


def test_traffic_dbn_conserves_and_bounds():
    g = line_graph(30)
    x0 = jnp.ones(30) * 0.5
    traj = simulate_traffic_dbn(g, x0, 100, jax.random.PRNGKey(9), inflow_scale=0.0)
    assert traj.shape == (101, 30)
    assert bool(jnp.all((traj >= 0) & (traj <= 1.0)))
    # without inflow, total mass is non-increasing (vehicles exit downstream)
    mass = np.asarray(jnp.sum(traj, axis=1))
    assert (np.diff(mass) <= 1e-5).all()


def test_traffic_step_is_local():
    """(1,1) weak memory: changing a far vertex does not affect a local update."""
    g = line_graph(20)
    nbrs = jnp.asarray(g.nbrs)
    x = jnp.ones(20) * 0.4
    y1 = traffic_dbn_step(x, nbrs, jnp.zeros(20))
    x2 = x.at[15].set(0.9)
    y2 = traffic_dbn_step(x2, nbrs, jnp.zeros(20))
    np.testing.assert_allclose(y1[:14], y2[:14], rtol=0, atol=1e-7)
