"""Statistical estimators vs oracles and synthetic ground truth (paper §2-§5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimators.arma import arma_psi_weights, fit_arma, solve_arma_from_psi
from repro.core.estimators.innovation import fit_ma, innovation_algorithm
from repro.core.estimators.mle import (
    ar_conditional_nll,
    fit_ar_mle,
    fit_ar_sgd,
    optimal_step_size,
)
from repro.core.estimators.prediction import (
    ar_forecast,
    ar_one_step,
    arma_forecast,
    arma_innovations_filter,
)
from repro.core.estimators.stats import (
    autocorrelation,
    autocovariance,
    autocovariance_blocked,
    mean,
    partial_autocorrelation,
)
from repro.core.estimators.yule_walker import block_levinson, levinson_durbin, yule_walker
from repro.timeseries import (
    random_invertible_ma,
    random_stable_var,
    simulate_var,
    simulate_varma,
    simulate_vma,
    spectral_radius,
)


@pytest.fixture(scope="module")
def var2_data():
    A = random_stable_var(jax.random.PRNGKey(1), 2, 3, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(2), A, 120_000)
    return A, xs


def test_autocovariance_blocked_equals_serial():
    x = jax.random.normal(jax.random.PRNGKey(0), (5000, 4))
    g1 = autocovariance(x, 8)
    g2 = autocovariance_blocked(x, 8, block_size=512)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_autocovariance_numpy_oracle():
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (2000, 2)))
    g = np.asarray(autocovariance(jnp.asarray(x), 3, normalization="paper"))
    n = x.shape[0]
    for h in range(4):
        ref = sum(np.outer(x[k], x[k + h]) for k in range(n - h)) / (n - h - 1)
        np.testing.assert_allclose(g[h], ref, rtol=1e-4, atol=1e-5)


def test_white_noise_acf_vanishes():
    x = jax.random.normal(jax.random.PRNGKey(4), (100_000, 2))
    rho = autocorrelation(autocovariance(x, 5))
    assert np.allclose(rho[0], np.eye(2), atol=0.02)
    assert np.max(np.abs(np.asarray(rho[1:]))) < 0.02


def test_yule_walker_recovers_var(var2_data):
    A, xs = var2_data
    g = autocovariance(xs, 3, normalization="standard")
    Ahat, sigma = yule_walker(g, 2)
    assert float(jnp.max(jnp.abs(Ahat - A))) < 0.02
    assert np.allclose(np.asarray(sigma), np.eye(3), atol=0.05)


def test_block_levinson_matches_dense(var2_data):
    _, xs = var2_data
    g = autocovariance(xs, 5, normalization="standard")
    A_dense, s_dense = yule_walker(g, 4)
    A_lev, s_lev, pacf = block_levinson(g, 4)
    np.testing.assert_allclose(A_dense, A_lev, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(s_dense, s_lev, rtol=1e-3, atol=1e-5)


def test_pacf_cutoff_for_ar_p(var2_data):
    """PACF of an AR(2) vanishes for lags > 2 (paper §3.1 order selection)."""
    _, xs = var2_data
    g = autocovariance(xs, 6, normalization="standard")
    pacf = partial_autocorrelation(g, 5)
    assert float(jnp.max(jnp.abs(pacf[2:]))) < 0.02  # lags 3..5 ≈ 0
    assert float(jnp.max(jnp.abs(pacf[1]))) > 0.05  # lag 2 present


def test_levinson_durbin_univariate():
    phi_true = np.array([0.5, -0.3])
    A = jnp.asarray(phi_true).reshape(2, 1, 1)
    xs = simulate_var(jax.random.PRNGKey(5), A, 200_000)
    g = autocovariance(xs, 3, normalization="standard")[:, 0, 0]
    phi, v, pacf = levinson_durbin(g, 2)
    np.testing.assert_allclose(phi, phi_true, atol=0.02)
    assert abs(float(v) - 1.0) < 0.05


def test_ma_innovation_recovery():
    B = jnp.asarray([[[0.5]]])
    xs = simulate_vma(jax.random.PRNGKey(6), B, 200_000)
    g = autocovariance(xs, 20, normalization="standard")
    Bh, sigma = fit_ma(g, 1, m=20)
    assert abs(float(Bh[0, 0, 0]) - 0.5) < 0.03
    assert abs(float(sigma[0, 0]) - 1.0) < 0.05


def test_arma_exact_from_true_psi():
    A = random_stable_var(jax.random.PRNGKey(7), 2, 2, radius=0.5)
    B = random_invertible_ma(jax.random.PRNGKey(8), 1, 2, radius=0.4)
    psi = arma_psi_weights(A, B, 12)
    Ah, Bh = solve_arma_from_psi(psi, 2, 1)
    np.testing.assert_allclose(Ah, A, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(Bh, B, rtol=1e-4, atol=1e-5)


def test_arma_statistical_fit():
    A = random_stable_var(jax.random.PRNGKey(9), 1, 2, radius=0.5)
    B = random_invertible_ma(jax.random.PRNGKey(10), 1, 2, radius=0.4)
    xs = simulate_varma(jax.random.PRNGKey(11), A, B, 300_000)
    g = autocovariance(xs, 30, normalization="standard")
    Ah, Bh, sig = fit_arma(g, 1, 1, m=25)
    assert float(jnp.max(jnp.abs(Ah - A))) < 0.05
    assert float(jnp.max(jnp.abs(Bh - B))) < 0.05


def test_mle_gd_matches_least_squares():
    A = random_stable_var(jax.random.PRNGKey(12), 1, 3, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(13), A, 30_000)
    res = fit_ar_mle(xs, 1, n_steps=150, block_size=4096)
    assert float(jnp.max(jnp.abs(res.A - A))) < 0.03
    # NLL trace is monotone decreasing (convex objective + 2/(m+L) step)
    t = np.asarray(res.nll_trace)
    assert (np.diff(t) < 1e-6).mean() > 0.95


def test_sgd_converges():
    A = random_stable_var(jax.random.PRNGKey(14), 1, 2, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(15), A, 30_000)
    res = fit_ar_sgd(xs, 1, n_steps=1200, batch=256)
    assert float(jnp.max(jnp.abs(res.A - A))) < 0.05


def test_optimal_step_size_bounds():
    x = jax.random.normal(jax.random.PRNGKey(16), (5000, 3)) * jnp.asarray([1.0, 2.0, 0.5])
    lr = float(optimal_step_size(x))
    c = np.cov(np.asarray(x), rowvar=False)
    ev = np.linalg.eigvalsh(c)
    assert lr == pytest.approx(2.0 / (ev[0] + ev[-1]), rel=1e-3)


def test_prediction_ar_consistency(var2_data):
    A, xs = var2_data
    hist = xs[:100]
    one = ar_one_step(A, hist)
    multi = ar_forecast(A, hist, 3)
    np.testing.assert_allclose(one, multi[0], rtol=1e-5, atol=1e-5)


def test_innovations_filter_whitens():
    """Innovations of the true ARMA model ≈ the driving white noise."""
    A = random_stable_var(jax.random.PRNGKey(17), 1, 2, radius=0.5)
    B = random_invertible_ma(jax.random.PRNGKey(18), 1, 2, radius=0.3)
    xs = simulate_varma(jax.random.PRNGKey(19), A, B, 50_000)
    _, innov = arma_innovations_filter(A, B, xs)
    g = autocovariance(innov[500:], 3, normalization="standard")
    rho = autocorrelation(g)
    assert float(jnp.max(jnp.abs(rho[1:]))) < 0.03  # serially uncorrelated


def test_generator_stability():
    A = random_stable_var(jax.random.PRNGKey(20), 3, 4, radius=0.8)
    assert spectral_radius(np.asarray(A)) == pytest.approx(0.8, rel=1e-5)


# ------------------------------------------------- prediction edge cases


def test_forecast_steps_one_is_one_step(var2_data):
    A, xs = var2_data
    hist = xs[:257]
    np.testing.assert_array_equal(
        np.asarray(ar_forecast(A, hist, 1)[0]),
        np.asarray(ar_one_step(A, hist)),
    )


def test_pure_ma_forecast_p_zero():
    """p=0 must use an EMPTY AR buffer — not history[-0:], which is the
    whole series. Beyond q steps a pure-MA forecast is exactly zero."""
    d = 2
    B = random_invertible_ma(jax.random.PRNGKey(21), 2, d, radius=0.4)
    xs = simulate_vma(jax.random.PRNGKey(22), B, 500)
    A0 = jnp.zeros((0, d, d))
    preds = arma_forecast(A0, B, xs, 5)
    assert preds.shape == (5, d)
    # the first q=2 steps are driven purely by retained innovations
    _, innov = arma_innovations_filter(A0, B, xs)
    want1 = B[0] @ innov[-1] + B[1] @ innov[-2]
    np.testing.assert_allclose(np.asarray(preds[0]), np.asarray(want1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(preds[2:]),
                                  np.zeros((3, d), np.float32))


def test_pure_ar_arma_forecast_matches_ar_forecast(var2_data):
    """q=0 collapses arma_forecast onto the plain AR recurrence."""
    A, xs = var2_data
    hist = xs[:300]
    got = arma_forecast(A, jnp.zeros((0, 3, 3)), hist, 6)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ar_forecast(A, hist, 6)))


def test_prediction_univariate_d1():
    """d=1: matrix recurrences reduce to the scalar AR(1)/MA(1) formulas."""
    phi, theta = 0.6, 0.4
    rng = np.random.RandomState(0)
    x = np.zeros((400, 1), np.float32)
    e = rng.randn(400).astype(np.float32)
    for t in range(1, 400):
        x[t] = phi * x[t - 1] + e[t] + theta * e[t - 1]
    A = jnp.full((1, 1, 1), phi)
    B = jnp.full((1, 1, 1), theta)
    xs = jnp.asarray(x)
    preds = arma_forecast(A, B, xs, 3)
    _, innov = arma_innovations_filter(A, B, xs)
    p1 = phi * x[-1, 0] + theta * float(innov[-1, 0])
    assert float(preds[0, 0]) == pytest.approx(p1, rel=1e-5)
    assert float(preds[1, 0]) == pytest.approx(phi * p1, rel=1e-5)
    assert float(preds[2, 0]) == pytest.approx(phi * phi * p1, rel=1e-5)


def test_innovations_filter_matches_python_recursion():
    """Pin arma_innovations_filter against a direct loop: pred_t =
    sum_i A_i x_{t-i} + sum_j B_j e_{t-j}, e_t = x_t - pred_t, zero init."""
    d, p, q, n = 2, 2, 1, 64
    A = random_stable_var(jax.random.PRNGKey(23), p, d, radius=0.5)
    B = random_invertible_ma(jax.random.PRNGKey(24), q, d, radius=0.3)
    xs = simulate_varma(jax.random.PRNGKey(25), A, B, n)
    preds, innov = arma_innovations_filter(A, B, xs)

    An, Bn, x = np.asarray(A), np.asarray(B), np.asarray(xs)
    e = np.zeros_like(x)
    pr = np.zeros_like(x)
    for t in range(n):
        acc = np.zeros(d, x.dtype)
        for i in range(p):
            if t - 1 - i >= 0:
                acc += An[i] @ x[t - 1 - i]
        for j in range(q):
            if t - 1 - j >= 0:
                acc += Bn[j] @ e[t - 1 - j]
        pr[t] = acc
        e[t] = x[t] - acc
    np.testing.assert_allclose(np.asarray(preds), pr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(innov), e, rtol=1e-4, atol=1e-5)
