"""End-to-end LM training driver (brief deliverable b).

Trains an xlstm-125m-family model on the synthetic Markov-bigram pipeline
with the full substrate: sharded params, AdamW, async fault-tolerant
checkpointing, deterministic restart.  Defaults are CPU-budgeted (a ~1.6M
param width-reduced stack, 120 steps, loss visibly descends below the
unigram entropy); pass --full for the real 125M config (TPU-scale).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --arch qwen3 --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true", help="full config (TPU-scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--f32",
    ]
    if not args.full:
        argv.append("--reduced")
    final_loss = train_main(argv)
    print(f"[example] final loss {final_loss:.4f}")


if __name__ == "__main__":
    main()
