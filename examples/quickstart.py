"""Quickstart — the paper's workflow end to end on one machine.

Simulate a causal VAR(2), ingest it into the overlapping distributed store,
compute sufficient statistics by embarrassingly-parallel map-reduce, fit
AR / MA / ARMA models, and forecast.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.estimators.prediction import ar_forecast
from repro.core.estimators.stats import autocorrelation, partial_autocorrelation
from repro.core.estimators.yule_walker import block_levinson, yule_walker
from repro.timeseries import TimeSeriesStore, random_stable_var, simulate_var


def main():
    # 1. A "large" multivariate series with known dynamics.
    d, p, n = 6, 2, 200_000
    A_true = random_stable_var(jax.random.PRNGKey(0), p, d, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(1), A_true, n)
    print(f"simulated VAR({p}) with d={d}, N={n}")

    # 2. Overlapping distributed store (paper §10): partitioned along TIME,
    #    halo h_right = max lag we will ever need.
    max_lag = 6
    store = TimeSeriesStore.from_series(xs, block_size=8192, h_left=0, h_right=max_lag)
    print(f"store: {store.spec.num_blocks} blocks, "
          f"replication overhead {store.replication_overhead:.4%}")

    # 3. Sufficient statistics by weak-memory map-reduce — the data is never
    #    shuffled; only the (max_lag+1, d, d) statistic is reduced.
    kern = lambda w: jnp.stack([jnp.outer(w[0], w[h]) for h in range(max_lag + 1)])
    gamma = store.map_reduce(kern) / n

    # 4. Model identification (paper §3.1): ACF / PACF.
    rho = autocorrelation(gamma)
    pacf = partial_autocorrelation(gamma, 4)
    pacf_norm = [float(jnp.max(jnp.abs(pacf[m]))) for m in range(4)]
    print("PACF magnitude by order:", [f"{v:.3f}" for v in pacf_norm],
          "→ first insignificant order", 1 + int(jnp.argmax(jnp.asarray(pacf_norm) < 0.02)),
          "⇒ choose p =", int(jnp.argmax(jnp.asarray(pacf_norm) < 0.02)))

    # 5. Fit by Yule-Walker (dense + Whittle recursion agree).
    A_hat, sigma = yule_walker(gamma, p)
    A_lev, _, _ = block_levinson(gamma, p)
    print(f"YW error: {float(jnp.max(jnp.abs(A_hat - A_true))):.4f} "
          f"(dense vs levinson: {float(jnp.max(jnp.abs(A_hat - A_lev))):.2e})")

    # 6. Forecast.
    preds = ar_forecast(A_hat, xs[-10:], steps=5)
    print("5-step forecast (first dim):", [f"{float(v):.3f}" for v in preds[:, 0]])


if __name__ == "__main__":
    main()
