"""Quickstart — the paper's workflow through the deferred session API.

Everything goes through ONE front door now: build a `SeriesFrame` over your
data placement, defer the statistics you want, and ``collect()`` them in a
single fused traversal.

    from repro import SeriesFrame

    frame = SeriesFrame.from_array(xs)          # or .from_chunks(stream)
    gamma = frame.autocovariance(6)             # deferred — reads nothing
    fit   = frame.yule_walker(2)                # rides the same traversal
    roll  = frame.moments(window=256)           # ... and so does this
    psd   = frame.welch(nperseg=512)
    frame.collect()                             # ONE pass serves all four
    A_hat, sigma = fit.result()                 # memoized — free
    frame.append(new_chunk)                     # folds into the carried ⊕
    fit.result()                                # re-read: walks ONLY new data

The demo below simulates a causal VAR(2), places it three ways (monolithic
array / chunked stream / overlapping shards — the paper's §10 structure,
halo sized lazily from the widest deferred window), collects identical
statistics from each, identifies and fits the model, and forecasts.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import SeriesFrame
from repro.core.estimators.prediction import ar_forecast
from repro.core.estimators.stats import autocorrelation, partial_autocorrelation
from repro.timeseries import random_stable_var, simulate_var


def main():
    # 1. A "large" multivariate series with known dynamics.
    d, p, n = 6, 2, 200_000
    A_true = random_stable_var(jax.random.PRNGKey(0), p, d, radius=0.6)
    xs = simulate_var(jax.random.PRNGKey(1), A_true, n)
    print(f"simulated VAR({p}) with d={d}, N={n}")

    # 2. One frame, four deferred statistics, ONE traversal at collect().
    max_lag = 6
    frame = SeriesFrame.from_array(xs)
    gamma_h = frame.autocovariance(max_lag, normalization="paper")
    fit_h = frame.yule_walker(p)
    roll_h = frame.moments(window=4096)
    frame.welch(nperseg=1024)
    frame.collect()
    print(f"collected {len(frame.collect())} statistics in "
          f"{frame.num_traversals} fused traversal(s)")

    # 3. The same session over the paper's placements: a chunked stream
    #    (scan-driven ingest) and mesh-ready overlapping shards (per-shard
    #    partials + one psum; the halo is sized lazily at collect, when the
    #    fused plan knows its widest window).
    stream = SeriesFrame.from_chunks(
        [xs[lo : lo + 8192] for lo in range(0, n, 8192)]
    )
    stream.autocovariance(max_lag)
    sharded = SeriesFrame.from_sharded(xs, block_size=8192)
    sharded.autocovariance(max_lag)
    agree = jnp.max(jnp.abs(
        stream.collect()["autocovariance"] - sharded.collect()["autocovariance"]
    ))
    print(f"chunked ≡ sharded placement to {float(agree):.2e}")

    # 4. Model identification (paper §3.1): ACF / PACF from the collected γ̂.
    gamma = gamma_h.result()  # memoized — no second traversal
    rho = autocorrelation(gamma)
    pacf = partial_autocorrelation(gamma, 4)
    pacf_norm = [float(jnp.max(jnp.abs(pacf[m]))) for m in range(4)]
    print("PACF magnitude by order:", [f"{v:.3f}" for v in pacf_norm],
          "⇒ choose p =", int(jnp.argmax(jnp.asarray(pacf_norm) < 0.02)))

    # 5. The Yule-Walker fit rode the same traversal as γ̂.
    A_hat, sigma = fit_h.result()
    print(f"YW error: {float(jnp.max(jnp.abs(A_hat - A_true))):.4f}; "
          f"rolling var (last 4096-window avg): "
          f"{float(jnp.mean(roll_h.result()['var'])):.3f}")

    # 6. New data folds into the carried state — history is never re-read.
    tail = simulate_var(jax.random.PRNGKey(2), A_true, 5_000)
    frame.append(tail)
    A_hat2, _ = fit_h.result()
    print(f"after append(+5k): YW drift "
          f"{float(jnp.max(jnp.abs(A_hat2 - A_hat))):.2e} "
          f"(incremental — only the new chunk was walked)")

    # 7. Forecast.
    preds = ar_forecast(A_hat2, tail[-10:], steps=5)
    print("5-step forecast (first dim):", [f"{float(v):.3f}" for v in preds[:, 0]])

    # 8. Where the math ran: the default "auto" backend dispatches each of
    #    the eight primitives through MEASURED per-primitive crossovers
    #    (repro.core.calibrate), not a hard-coded size constant.  On TPU the
    #    first dispatch microbenchmarks and caches the thresholds; anywhere
    #    you can also calibrate explicitly — one pass, persisted, picked up
    #    by every later process on this machine:
    #
    #        from repro.core.calibrate import calibrate
    #        get_backend("auto").set_table(calibrate())   # measures + caches
    #
    from repro.core.backend import get_backend
    from repro.core.calibrate import cache_path

    table = get_backend("auto").table
    shown = {k: ("never" if v == float("inf") else int(v))
             for k, v in sorted(table.thresholds.items())}
    print(f"auto-backend crossovers ({table.platform}, {table.source}; "
          f"cache: {cache_path()}):")
    for prim, thr in shown.items():
        print(f"  {prim:<22s} -> pallas at {thr} rows")

    # 9. Serving: the same math behind a concurrency front door.  A
    #    `FrameSession` holds per-tenant partials as ONE stacked pytree;
    #    `repro.serving.gateway.StatsGateway` serves it to concurrent
    #    asyncio clients — each tick coalesces every admitted ingest into
    #    one donated scatter and every query into one vmapped fused
    #    finalize, with token-bucket backpressure, p50/p99 metrics, and
    #    periodic snapshots (a killed gateway restarts from the last
    #    snapshot serving identical answers, zero re-ingest):
    #
    #        from repro.serving import GatewayConfig, StatsGateway
    #        session = FrameSession(d=d, num_users=10_000)
    #        session.autocovariance(6); session.moments(4096)
    #        gw = StatsGateway(session, GatewayConfig(checkpoint_dir=...))
    #        gw.start()                         # background coalescing ticks
    #        await gw.ingest(tenant, chunk)
    #        stats = await gw.query(tenant)
    #
    print("serving front door: PYTHONPATH=src python examples/gateway_demo.py")

    # 10. The megakernel and the tuned tile table.  When a plan carries ≥2
    #     primitive families (lagged sums / rolling moments / Welch members),
    #     its chunk update collapses into ONE ``fused_plan_update`` backend
    #     call — on the Pallas backend a single persistent kernel launch
    #     that stages each (block_t, d) tile into VMEM once and feeds ALL
    #     families from the resident block (the frame above did this at
    #     collect()).  Tile sizes are not hard-coded: every kernel entry
    #     point resolves its block_t / block_s / block_rows through the
    #     calibrated table, and
    #
    #         from repro.core.calibrate import calibrate
    #         calibrate(tune_blocks=True)     # crossovers AND tile search,
    #                                         # persisted to the same cache
    #
    #     searches the candidate grid per primitive on THIS machine and
    #     persists the winners next to the dispatch thresholds — one
    #     calibration artifact, picked up by every later process.  Inspect /
    #     re-measure / install from the shell:
    #
    #         PYTHONPATH=src python -m repro.core.calibrate --show
    #         PYTHONPATH=src python -m repro.core.calibrate --tune
    #         PYTHONPATH=src python -m repro.core.calibrate --bless table.json
    #
    #     Memory-bound deployments can additionally narrow the HBM↔VMEM
    #     stream with ``fused_engine(..., stage_dtype="bfloat16")`` — the
    #     series is staged in bf16, every accumulation stays f32 (measured
    #     mode: validate against the default on your data first).
    tuned = table.blocks or "(none tuned — kernels use built-in defaults)"
    print(f"megakernel engaged for ≥2-family plans; tuned tile configs: {tuned}")

    # 11. Operating under failure.  The serving stack assumes things break
    #     and degrades instead of dying — every piece is deterministic and
    #     rehearsable with the seedable fault injector
    #     (`repro.runtime.chaos`):
    #
    #       * circuit breaker: wrap the compute in
    #         ``CircuitBreakerBackend(primary=PallasBackend(),
    #         fallback=JnpBackend())`` and a raising kernel is quarantined —
    #         calls are served by the jnp oracle, the primary is probed
    #         again after a call-counted cooldown, and every trip/recovery
    #         shows up in ``breaker_metrics()`` and ``gw.health()``;
    #       * verified checkpoints: every snapshot manifest carries per-leaf
    #         crc32 checksums; restore verifies them and walks back past a
    #         torn generation to the newest intact one (freshness is lost,
    #         availability never); transient write failures retry with
    #         backoff;
    #       * tick deadline + degraded mode: set
    #         ``GatewayConfig(tick_deadline=0.05)`` and a blown tick flips
    #         ``gw.health()`` to "degraded" — lowest-priority queries are
    #         shed with `Degraded` (distinct from `RateLimited`), snapshots
    #         defer, and clean ticks recover to "ok";
    #       * rehearse it before production does it to you:
    #
    #             from repro.runtime.chaos import FaultInjector, scoped
    #             inj = FaultInjector(seed=0)
    #             inj.fail("backend.fused_plan_update", calls=range(3, 6))
    #             inj.corrupt("checkpoint.payload", calls={1})
    #             inj.stall("gateway.tick", calls={4}, seconds=0.2)
    #             with scoped(inj):
    #                 ...   # drive the gateway; answers must not change
    #
    #     (tests/test_chaos.py drives exactly this schedule end-to-end and
    #     pins that every non-rejected answer matches a fault-free run.)
    print("chaos drill: PYTHONPATH=src python -m pytest tests/test_chaos.py -q")

    # 12. Forecasting as a query, not a pipeline.  ``.forecast(h)`` and
    #     ``.anomaly_scores()`` are deferred statistics like any other:
    #     they join the fused plan's lag family (still ONE traversal), fit
    #     their model from the SAME corrected lagged sums the estimators
    #     use, and seed a jitted companion-matrix recurrence from the
    #     plan's carried tail window — predictions and standardized
    #     innovation scores serve from weak memory (O(W) retained
    #     samples), never a second pass over the series.
    f12 = SeriesFrame.from_array(xs[-32_768:])
    fit12 = f12.yule_walker(p)
    fc12 = f12.forecast(8, model="ar", p=p)
    an12 = f12.anomaly_scores(model="ar", p=p)
    f12.collect()
    A12, _ = fit12.result()
    drift = jnp.max(jnp.abs(
        fc12.result()["pred"] - ar_forecast(A12, xs[-32_768:], 8)
    ))
    print(f"plan forecast ≡ eager ar_forecast oracle to {float(drift):.1e}; "
          f"max anomaly score on the retained window: "
          f"{float(jnp.max(an12.result()['score'])):.2f} "
          f"({f12.num_traversals} traversal)")
    #     ``model="auto"`` additionally wants a deferred ``.welch(...)``
    #     member: the dominant period is detected from the plan's own
    #     spectrum (per tenant, under vmap) and seeds a seasonal-lag fit.
    #     The serving side — per-tenant forecasts + anomaly flags
    #     coalesced through the gateway, breaker tripping mid-serve —
    #     is examples/forecast_service.py.
    print("forecast service: "
          "PYTHONPATH=src python examples/forecast_service.py")

    # 13. Data-plane integrity.  Weak memory cuts both ways: state is only
    #     ever ⊕-folded, never recomputed, so one NaN ingested is a NaN
    #     FOREVER and f32 rounding per merge is drift forever.  PR 10 adds
    #     the three defenses:
    #
    #       * ingest sentinel: ``GatewayConfig(sentinel=True)`` runs ONE
    #         fused all-finite verdict per coalesced ingest batch (no extra
    #         host syncs), with a per-tenant policy —
    #         ``gw.set_tenant_policy(t, "reject" | "sanitize" |
    #         "quarantine")``; a rejected chunk raises `PoisonedChunk`, a
    #         quarantined tenant is fenced off both planes until repaired;
    #       * self-healing tenants: ``gw.audit()`` sweeps every lane
    #         on-device for non-finite state (poison that predates the
    #         sentinel, or arrived with it off) and quarantines the
    #         unhealthy; ``gw.rebuild_tenant(t)`` restores ONE tenant from
    #         the newest checkpoint generation whose slice verifies AND is
    #         finite — no other tenant's live state moves, nothing
    #         re-traces, and the chaos site ``ingest.payload`` rehearses
    #         the whole story seedably (tests/test_integrity.py);
    #       * compensated accumulation: ``fused_engine(...,
    #         compensated=True)`` / ``FrameSession(compensated=True)``
    #         carries Neumaier error companions through every chunk update
    #         and ⊕-merge, recovering the rounding a plain f32 fold
    #         discards (benchmarks/bench_integrity.py pins ≥10× less
    #         drift on hostile offset data).
    from repro.core.plan import autocovariance_request, fused_engine

    comp = fused_engine([autocovariance_request(max_lag)], d=d,
                        compensated=True)
    cs = comp.init()
    for lo in range(0, n, 8192):
        cs = comp.update_jit(cs, xs[lo : lo + 8192])
    g_comp = comp.finalize(cs)["autocovariance"]
    g_plain = stream.collect()["autocovariance"]
    print(f"compensated streaming γ̂ matches plain to "
          f"{float(jnp.max(jnp.abs(g_comp - g_plain))):.1e} "
          f"(error companions reabsorbed at readout); integrity drill: "
          f"PYTHONPATH=src python -m pytest tests/test_integrity.py -q")


if __name__ == "__main__":
    main()
