"""Time-series graphs (paper §9, §11): arterial-traffic DBN on a corridor.

Simulates the order-(1,1) traffic Bayesian network, partitions the graph
with 1-hop halos, and estimates per-link AR dynamics by graph map-reduce —
each partition touching only its own vertices plus replicated halo
neighbours (paper Fig. 5-8).

  PYTHONPATH=src python examples/traffic_graph.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.estimators.stats import autocovariance
from repro.core.estimators.yule_walker import levinson_durbin
from repro.core.graphs import (
    graph_window_map_reduce,
    line_graph,
    make_graph_partition,
    simulate_traffic_dbn,
)


def main():
    v, steps = 512, 2000
    g = line_graph(v)
    x0 = jnp.full((v,), 0.4)
    traj = simulate_traffic_dbn(g, x0, steps, jax.random.PRNGKey(0), inflow_scale=0.08)
    print(f"traffic DBN: {v} links, {steps} steps, "
          f"occupancy ∈ [{float(traj.min()):.3f}, {float(traj.max()):.3f}]")

    # per-link temporal dynamics: univariate AR(1) via Durbin-Levinson
    x_mid = traj[:, v // 2] - traj[:, v // 2].mean()
    gam = autocovariance(x_mid[:, None], 3, normalization="standard")[:, 0, 0]
    phi, var, pacf = levinson_durbin(gam, 2)
    print(f"link {v//2}: AR(2) fit φ = {[f'{float(p):.3f}' for p in phi]}, "
          f"PACF = {[f'{float(p):.3f}' for p in pacf]}")

    # graph map-reduce with 1-hop halos: Σ_v Σ_t x_v(t)·mean_nb x(t) — the
    # spatial weak-memory cross statistic, partition-parallel (Fig. 5)
    part = make_graph_partition(g, num_parts=8, k=1)

    def kern(xc, nb, mask):
        # xc: (T,) own series; nb: (max_deg, T) neighbour series
        nbm = jnp.sum(jnp.where(mask[:, None], nb, 0.0), axis=0) / jnp.maximum(
            jnp.sum(mask), 1
        )
        return jnp.sum(xc * nbm)

    stat = graph_window_map_reduce(kern, jnp.moveaxis(traj, 0, 1), g, part)
    # serial check
    serial = 0.0
    for vtx in range(v):
        nb_ids = [n for n in g.nbrs[vtx] if n >= 0]
        nbm = jnp.mean(traj[:, jnp.asarray(nb_ids)], axis=1)
        serial += float(jnp.sum(traj[:, vtx] * nbm))
    print(f"graph-parallel neighbour statistic: {float(stat):.3f} "
          f"(serial {serial:.3f}; {part.padded.shape[1] * 8 - v} replicated halo vertices)")


if __name__ == "__main__":
    main()
