"""Forecast service demo — served predictions + anomaly flags (PR 9).

Forecasting rides the SAME weak-memory state every other statistic uses:
the fused plan's lagged sums fit the model (Yule-Walker / innovations
ARMA / periodicity-seeded seasonal AR) and the carried tail window seeds
a jitted companion-matrix recurrence.  Under the gateway, N tenants'
forecasts coalesce into ONE vmapped finalize per tick — prediction is a
query kind, not a separate pipeline.

Two acts:

  1. 32 tenants stream seasonal traffic (random phase each, one tenant
     with an injected spike); every tenant asks the gateway for
     ``model="auto"`` forecasts and anomaly scores, narrowed with the
     ``only=`` query filter.  The period is detected per tenant from the
     plan's Welch member; the spiked tenant is the one flagged.
  2. The same workload on a `CircuitBreakerBackend`, with a seeded
     `FaultInjector` killing the primary's tail-correction primitive
     mid-serve: the breaker trips to the jnp oracle, the served forecasts
     are IDENTICAL to act 1, and the breaker metrics show the trip.

  PYTHONPATH=src python examples/forecast_service.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import asyncio

import numpy as np

from repro.core.backend import CircuitBreakerBackend, JnpBackend
from repro.core.frame import FrameSession
from repro.runtime import chaos
from repro.runtime.chaos import FaultInjector
from repro.serving.gateway import StatsGateway

TENANTS, D, CHUNK = 32, 2, 160
PERIOD, HORIZON = 8, 12
SPIKED_TENANT = 7


def make_session(backend) -> FrameSession:
    sess = FrameSession(d=D, num_users=TENANTS, backend=backend)
    sess.welch(64)
    sess.forecast(HORIZON, model="auto", p=2, max_period=16)
    sess.anomaly_scores(model="ar", p=2)
    return sess


def make_traffic() -> np.ndarray:
    """Seasonal sine per tenant (random phase) + noise; tenant 7 takes a
    spike near the end of its stream — inside the scored tail window."""
    rng = np.random.RandomState(0)
    t = np.arange(CHUNK)
    phases = rng.uniform(0, 2 * np.pi, size=TENANTS)
    base = np.sin(2 * np.pi * t[None, :] / PERIOD + phases[:, None])
    chunks = (
        base[:, :, None] + 0.15 * rng.randn(TENANTS, CHUNK, D)
    ).astype(np.float32)
    chunks[SPIKED_TENANT, -9] += 12.0
    return chunks


async def serve(backend) -> list:
    """Ingest every tenant's stream, then query forecast + anomaly through
    the ticking gateway (the ``only=`` filter narrows each answer)."""
    gw = StatsGateway(make_session(backend))
    gw.start()
    chunks = make_traffic()

    async def tenant_task(u: int) -> dict:
        await gw.ingest(u, chunks[u])
        fc = await gw.query(u, only="forecast")
        an = await gw.query(u, only=("anomaly",))
        return {**fc, **an}

    answers = await asyncio.gather(*(tenant_task(u) for u in range(TENANTS)))
    metrics = gw.metrics()
    health = gw.health()
    await gw.stop()
    occupancy = metrics["batch_occupancy"]
    print(
        f"  served {TENANTS} tenants: health={health!r}, "
        f"mean query batch occupancy={occupancy['query_mean']:.1f}"
    )
    return answers


def report(answers: list) -> None:
    periods = [int(a["forecast"]["period"]) for a in answers]
    hit = sum(p == PERIOD for p in periods)
    print(f"  period detection: {hit}/{TENANTS} tenants -> {PERIOD}")
    # flag relative to the fleet: the AR(2) anomaly model leaves some
    # seasonal structure in everyone's residuals (so an absolute cutoff
    # would be workload-dependent), and a large spike partially masks
    # itself by inflating the fitted innovation variance — 2x the fleet
    # median is the robust line the spike still clears decisively
    maxima = np.asarray(
        [float(np.max(a["anomaly"]["score"])) for a in answers]
    )
    flagged = [u for u in range(TENANTS) if maxima[u] > 2 * np.median(maxima)]
    print(
        f"  anomaly flags (max score > 2x fleet median): tenants {flagged}"
        f" (score {maxima[SPIKED_TENANT]:.1f} vs median {np.median(maxima):.1f})"
    )
    assert flagged == [SPIKED_TENANT]
    pred = np.asarray(answers[0]["forecast"]["pred"])
    print(
        "  tenant 0 forecast (dim 0, first 6 steps): "
        + " ".join(f"{v:+.2f}" for v in pred[:6, 0])
    )


def main() -> None:
    print("== act 1: forecasts + anomaly scoring through the gateway ==")
    clean = asyncio.run(serve("jnp"))
    report(clean)

    print("== act 2: breaker trips mid-serve, forecasts unchanged ==")
    # the injector kills the primary's first two tail-correction calls —
    # they fire while the finalize program traces, i.e. mid-first-serve
    br = CircuitBreakerBackend(
        primary=JnpBackend(), fallback=JnpBackend(),
        trip_after=1, cooldown_calls=8,
    )
    inj = FaultInjector(seed=0).fail(
        "backend.masked_lagged_sums", calls={0, 1}
    )
    with chaos.scoped(inj):
        faulted = asyncio.run(serve(br))
    report(faulted)
    st = br.breaker_metrics()["primitives"]["masked_lagged_sums"]
    print(
        f"  breaker: trips={st['trips']} state={st['state']!r} "
        f"fallback_calls={st['fallback_calls']}"
    )
    assert st["trips"] >= 1
    for u in range(TENANTS):
        np.testing.assert_array_equal(
            np.asarray(clean[u]["forecast"]["pred"]),
            np.asarray(faulted[u]["forecast"]["pred"]),
        )
        np.testing.assert_array_equal(
            np.asarray(clean[u]["anomaly"]["score"]),
            np.asarray(faulted[u]["anomaly"]["score"]),
        )
    print("  forecasts and anomaly scores bit-identical to the clean run")


if __name__ == "__main__":
    main()
