"""Serving demo — the async gateway over a multi-tenant FrameSession.

The paper's mergeable partials make per-tenant statistics *servable*:
state is a fixed-size stacked pytree, ingest is a scatter-⊕, queries are
a gather-⊕-finalize.  `repro.serving.gateway.StatsGateway` is the
concurrency front door over that math:

    gw = StatsGateway(session, GatewayConfig(checkpoint_dir=...))
    gw.start()                            # background coalescing ticks
    await gw.ingest(tenant, chunk)        # any number of asyncio clients
    stats = await gw.query(tenant)

Every tick, all admitted ingests coalesce into ONE donated scatter
program and all queries into ONE vmapped fused finalize — device cost
per tick is flat in the number of connected clients.  The demo below
runs three acts:

  1. 64 concurrent tenant tasks ingest + query through a ticking
     gateway; the metrics show the coalescing ratio.
  2. An over-rate tenant is rejected (RateLimited backpressure) while
     everyone else keeps flowing.
  3. The process "crashes" (the gateway is abandoned), a new gateway
     restores from the periodic snapshot, and serves answers identical
     to pre-crash — zero re-ingest of history.

  PYTHONPATH=src python examples/gateway_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import asyncio
import tempfile

import numpy as np

from repro.core.frame import FrameSession
from repro.serving.gateway import (
    GatewayConfig,
    RateClass,
    RateLimited,
    StatsGateway,
)

TENANTS, D, CHUNK = 64, 3, 128


def make_session() -> FrameSession:
    sess = FrameSession(d=D, num_users=TENANTS, backend="jnp")
    sess.autocovariance(4)
    sess.moments(32)
    return sess


async def tenant_task(gw: StatsGateway, tenant: int, rounds: int) -> dict:
    """One simulated client: stream chunks, then read statistics."""
    rng = np.random.RandomState(tenant)
    for _ in range(rounds):
        await gw.ingest(tenant, rng.randn(CHUNK, D).astype(np.float32))
    return await gw.query(tenant)


async def main() -> None:
    ckpt = tempfile.mkdtemp(prefix="gateway_demo_")
    cfg = GatewayConfig(
        tick_interval=0.002,
        snapshot_every=5,
        checkpoint_dir=ckpt,
        rate_classes={
            "default": RateClass(),
            "free-tier": RateClass(ingest_per_tick=1, burst=1),
        },
    )

    # -- act 1: concurrent tenants through the background tick loop -------
    gw = StatsGateway(make_session(), cfg)
    gw.start()
    answers = await asyncio.gather(
        *(tenant_task(gw, t, rounds=4) for t in range(TENANTS))
    )
    m = gw.metrics()
    served = m["ingest"]["count"] + m["query"]["count"]
    programs = m["ingest"]["programs"] + m["query"]["programs"]
    print(f"served {served} requests from {TENANTS} tenants in "
          f"{m['ticks']} ticks using {programs} device programs "
          f"({served / programs:.0f} requests/program)")
    print(f"latency p50/p99: ingest {m['ingest']['p50_us']:.0f}/"
          f"{m['ingest']['p99_us']:.0f}us, query {m['query']['p50_us']:.0f}/"
          f"{m['query']['p99_us']:.0f}us")
    mean0 = np.asarray(answers[0]["moments"]["mean"])
    print(f"tenant 0 rolling mean (first dim): {mean0[0]:.4f}")

    # -- act 2: backpressure — over-rate tenant, unharmed neighbours ------
    gw.set_tenant_class(0, "free-tier")
    chunk = np.zeros((CHUNK, D), np.float32)
    rejected = 0
    admitted = gw.submit_ingest(0, chunk)   # consumes the only token
    try:
        gw.submit_ingest(0, chunk)          # same tick: over rate
    except RateLimited:
        rejected += 1
    neighbour = gw.submit_ingest(1, chunk)  # sails through, same tick
    await asyncio.gather(admitted, neighbour)
    print(f"free-tier tenant rejected {rejected} over-rate request(s); "
          f"others unaffected (rejections total: "
          f"{gw.counters['rejected_ingest_rate']})")

    # -- act 3: crash, restart, identical answers -------------------------
    pre = await gw.query(7)
    gw._loop_rt.manager.flush()             # let the async snapshot land
    del gw                                  # the "crash": no graceful stop

    gw2 = StatsGateway(make_session(), cfg)  # same ckpt dir → restores
    gw2.start()
    post = await gw2.query(7)
    same = np.array_equal(
        np.asarray(pre["autocovariance"]), np.asarray(post["autocovariance"])
    )
    print(f"restarted from snapshot (restored="
          f"{gw2.counters['restored_from_snapshot']}, resume tick "
          f"{gw2.metrics()['tick']}); tenant 7 answers identical: {same} "
          f"with {gw2.counters['programs_ingest']} re-ingest programs")
    await gw2.stop()


if __name__ == "__main__":
    asyncio.run(main())
