"""Very-high-d banded spatial AR (paper §6) — the d ≫ p regime where
Yule-Walker's O(d³) inversion is intractable and the paper's partitioned
first-order method is the only scalable option.

Simulates a d=16384 banded system (a numerical-differentiation-style
stencil), fits it with the partitioned conditional-MLE gradient, and checks
the one-step predictor via the Pallas banded_matvec kernel.

  PYTHONPATH=src python examples/spatial_ar.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro.core.estimators.spatial import (
    SpatialPartition,
    banded_predict,
    banded_predict_partitioned,
    banded_to_dense,
    fit_banded_ar,
)
from repro.kernels.banded_matvec import ops as bmv


def main():
    d, b, n = 1024, 2, 8_000  # (paper regime is d~1e5+; CPU-budgeted here)
    key = jax.random.PRNGKey(0)
    rows = jnp.arange(d)[:, None]
    cols = rows + jnp.arange(-b, b + 1)[None, :]
    valid = (cols >= 0) & (cols < d)
    diags_true = (jax.random.normal(key, (d, 2 * b + 1)) * 0.15) * valid
    print(f"banded AR(1): d={d}, bandwidth={b} "
          f"(dense would be {d*d} params; banded is {d*(2*b+1)})")

    # simulate with the O(d·(2b+1)) predictor — never materialize dense A
    def sim(key, steps):
        def body(x, k):
            nxt = banded_predict(diags_true, x) + jax.random.normal(k, (d,))
            return nxt, nxt
        _, xs = jax.lax.scan(body, jnp.zeros(d), jax.random.split(key, steps))
        return xs

    xs = sim(jax.random.PRNGKey(1), n)

    # partitioned fit (paper §6.2): gradient separates across row partitions
    t0 = time.time()
    res = fit_banded_ar(xs, bandwidth=b, n_steps=100, num_parts=16)
    err = float(jnp.max(jnp.abs((res.diags - diags_true) * valid)))
    print(f"fit: {time.time()-t0:.1f}s, max coefficient error {err:.4f}, "
          f"final nll {float(res.nll_trace[-1]):.4f}")

    # partitioned predictor == full predictor (embarrassingly parallel, §6.1)
    part = SpatialPartition(d=d, num_parts=16, bandwidth=b)
    x = xs[-1]
    y_part = banded_predict_partitioned(res.diags, x, part)
    y_full = banded_predict(res.diags, x)
    print(f"partitioned vs full predictor: {float(jnp.max(jnp.abs(y_part-y_full))):.2e}")

    # Pallas kernel path (VMEM row tiles with spatial halos)
    y_kernel = bmv.banded_matvec(res.diags, x, block_rows=256, interpret=True)
    print(f"pallas banded_matvec vs ref:   {float(jnp.max(jnp.abs(y_kernel-y_full))):.2e}")


if __name__ == "__main__":
    main()
