"""Batched serving example: prefill + jit'd decode loop with KV caches,
with per-user rolling telemetry through the multi-tenant session API.

Serves a reduced qwen3 (GQA + qk_norm) and a reduced zamba2 (hybrid SSM —
constant-memory recurrent state) on batched requests, cross-checks the
engine against full re-forward greedy decoding, and — the PR 4 session
layer — treats every request slot as a tenant of a
`repro.FrameSession`: each decode step's per-token log-probability stream
is scatter-ingested into one stacked fused-plan state (a sliding window of
the last 16 tokens), and every tenant's rolling mean/variance +
lag-1 autocovariance of decode confidence is served from ONE fused
finalize — the weak-memory monoid doing LM serving observability.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro import FrameSession
from repro.configs import ARCHS
from repro.models import init_params
from repro.serving.engine import ServeEngine


def main():
    for arch in ("qwen3-0.6b", "zamba2-7b"):
        cfg = ARCHS[arch].reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        eng = ServeEngine(cfg, params, max_len=96)

        batch, prompt_len, max_new = 8, 32, 24
        prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
        t0 = time.time()
        out = eng.generate(prompts, max_new=max_new)
        dt = time.time() - t0
        print(f"[{arch}] generated {batch}×{max_new} tokens in {dt:.2f}s "
              f"({batch*max_new/dt:.0f} tok/s incl. compile)")
        t0 = time.time()
        out = eng.generate(prompts, max_new=max_new)  # warm
        dt = time.time() - t0
        print(f"[{arch}] warm: {batch*max_new/dt:.0f} tok/s; "
              f"first row: {out.tokens[0][:8].tolist()}…")

        # -- per-tenant rolling decode telemetry (FrameSession) ------------
        # One session serves every request slot: a sliding 16-token window
        # of per-step greedy log-probabilities, ingested 4 tokens at a time
        # by ONE donated scatter program, queried as fused statistics.
        session = FrameSession(
            d=1, num_users=batch, window=16, num_buckets=4
        )
        session.moments(window=4, name="confidence")
        session.autocovariance(1, normalization="standard", name="conf_acv")

        # the engine returns greedy tokens only — use token-id drift as the
        # per-step confidence surrogate (any per-step scalar stream works)
        tokens = jnp.asarray(out.tokens)
        series = -jnp.abs(jnp.diff(tokens, axis=1)).astype(jnp.float32) / cfg.vocab
        ids = jnp.arange(batch)
        for lo in range(0, series.shape[1] - series.shape[1] % 4, 4):
            session.ingest(ids, series[:, lo : lo + 4, None])

        stats = session.query_batch(ids)
        mean = stats["confidence"]["mean"][:, 0]
        var = stats["confidence"]["var"][:, 0]
        print(f"[{arch}] rolling decode confidence (last ≤16 tok): "
              f"mean {float(jnp.mean(mean)):.3f}, "
              f"var {float(jnp.mean(var)):.4f}, "
              f"lag-1 acv {float(jnp.mean(stats['conf_acv'][:, 1, 0, 0])):.4f}")


if __name__ == "__main__":
    main()
