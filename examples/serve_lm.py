"""Batched serving example: prefill + jit'd decode loop with KV caches.

Serves a reduced qwen3 (GQA + qk_norm) and a reduced zamba2 (hybrid SSM —
constant-memory recurrent state) on batched requests, and cross-checks the
engine against full re-forward greedy decoding.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving.engine import ServeEngine


def main():
    for arch in ("qwen3-0.6b", "zamba2-7b"):
        cfg = ARCHS[arch].reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        eng = ServeEngine(cfg, params, max_len=96)

        batch, prompt_len, max_new = 8, 32, 24
        prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)
        t0 = time.time()
        out = eng.generate(prompts, max_new=max_new)
        dt = time.time() - t0
        print(f"[{arch}] generated {batch}×{max_new} tokens in {dt:.2f}s "
              f"({batch*max_new/dt:.0f} tok/s incl. compile)")
        t0 = time.time()
        out = eng.generate(prompts, max_new=max_new)  # warm
        dt = time.time() - t0
        print(f"[{arch}] warm: {batch*max_new/dt:.0f} tok/s; "
              f"first row: {out.tokens[0][:8].tolist()}…")


if __name__ == "__main__":
    main()
