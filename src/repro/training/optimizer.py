"""AdamW in pure JAX (no optax in this environment).

State is a pytree mirroring params: {m, v, step}.  Supports decoupled weight
decay, global-norm clipping, cosine LR schedule with linear warmup, and
optional f32 master copies for bf16 params (the large-config default).

ZeRO-style optimizer-state sharding: the m/v trees inherit the param
PartitionSpecs by structure; `repro.launch.steps` may additionally shard
them over "data" (ZeRO-1) — a §Perf lever.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(
    base_lr: float, warmup: int, total: int
) -> "callable":
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        update = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(m=m, v=v, step=step)
