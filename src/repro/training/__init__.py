"""Training substrate: optimizer, train step, gradient compression, trainer."""
from .optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule, global_norm
from .train_step import make_train_step, loss_fn
from .compression import compress_int8, decompress_int8, error_feedback_allreduce
