"""Train step factory: loss → grad → AdamW, with optional microbatching.

The returned function is pjit-ready: pure, (params, opt_state, batch) →
(params, opt_state, metrics).  Sharding is injected from outside
(in_shardings/out_shardings at jit time + with_sharding_constraint inside
the models); remat is inside the models' layer scans.

Microbatch accumulation splits the per-device batch into ``accum`` slices
scanned sequentially — activation memory drops ×accum at the cost of accum
backward sweeps (a §Perf lever for the memory-bound cells).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import forward
from ..models.model_zoo import forward_hidden
from ..models.layers import chunked_cross_entropy, cross_entropy_loss
from .optimizer import AdamWState, adamw_update

Metrics = Dict[str, jax.Array]


def loss_fn(
    params,
    batch: Dict[str, jax.Array],
    cfg,
    *,
    lb_coef=0.01,
    z_coef=1e-3,
    fused: bool = False,
    loss_chunk: int = 256,
):
    labels = batch["labels"]
    if fused:
        # §Perf B1: never materialize (B, S, V) logits — chunked fused CE.
        hidden, head, aux = forward_hidden(params, batch, cfg)
        # next-token objective: hidden at t predicts labels at t+1
        shifted = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1
        )
        ce = chunked_cross_entropy(hidden, head, shifted, chunk=loss_chunk)
    else:
        logits, aux = forward(params, batch, cfg)
        ce = cross_entropy_loss(logits[:, :-1], labels[:, 1:])
    loss = ce + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
    return loss, {"ce": ce, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}


def make_train_step(
    cfg,
    *,
    lr_fn: Callable[[jax.Array], jax.Array] | float = 3e-4,
    accum: int = 1,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
    fused_loss: bool = False,
) -> Callable[[Any, AdamWState, Dict[str, jax.Array]], Tuple[Any, AdamWState, Metrics]]:
    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(loss_fn, fused=fused_loss), has_aux=True
        )(params, batch, cfg)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, metrics, grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_grads, acc_loss = carry
                loss, _, grads = grad_fn(params, mb)
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_grads, acc_loss + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {"ce": loss, "lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(())}

        lr = lr_fn(opt_state.step) if callable(lr_fn) else lr_fn
        params, opt_state = adamw_update(
            grads,
            opt_state,
            params,
            lr=lr,
            weight_decay=weight_decay,
            clip_norm=clip_norm,
        )
        metrics = dict(metrics, loss=loss, lr=jnp.asarray(lr, jnp.float32))
        return params, opt_state, metrics

    return train_step
