"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized all-reduce: each leaf is quantized to int8 with a
per-block f32 scale before the cross-replica reduction, and the
quantization residual is fed back into the next step's gradient (error
feedback keeps SGD/Adam convergence — Karimireddy et al. 2019).  Wire bytes
drop 4× for f32 / 2× for bf16 gradients at the cost of two cheap VPU passes.

Used through `error_feedback_allreduce` inside a shard_map'd data-parallel
step (see tests/test_compression.py and DESIGN.md §6); under plain pjit the
all-reduce is implicit and this module is bypassed.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array, block: int = BLOCK) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) → (int8 codes, per-block f32 scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def decompress_int8(codes: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape).astype(dtype)


def error_feedback_allreduce(
    grads: Any, residual: Any, axis_name: str
) -> Tuple[Any, Any]:
    """psum of int8-compressed (grad + residual); returns (mean grad, new residual).

    Must run inside shard_map/pmap with ``axis_name`` bound.  The psum is
    performed on the int32-accumulated codes (exact), scales are psum'd
    per-block; decompression uses the mean scale — a standard low-error
    approximation whose residual is, by construction, re-injected next step.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        target = g.astype(jnp.float32) + r
        codes, scale = compress_int8(target)
        codes_sum = jax.lax.psum(codes.astype(jnp.int32), axis_name)
        scale_mean = jax.lax.psum(scale, axis_name) / n
        reduced = decompress_int8(codes_sum.astype(jnp.float32) / n, scale_mean, g.shape)
        local_decoded = decompress_int8(codes, scale, g.shape)
        new_residual = target - local_decoded
        return reduced, new_residual

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree.unflatten(tree, [o[0] for o in out])
    new_res = jax.tree.unflatten(tree, [o[1] for o in out])
    return reduced, new_res
