from .manager import (
    CheckpointCorrupt,
    CheckpointManager,
    latest_step,
    list_steps,
    restore_latest_intact,
    restore_pytree,
    save_pytree,
    sweep_tmp_dirs,
)
