from .manager import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
    sweep_tmp_dirs,
)
