from .manager import (
    CheckpointCorrupt,
    CheckpointManager,
    latest_step,
    list_steps,
    load_manifest,
    path_key,
    restore_latest_intact,
    restore_pytree,
    restore_tenant_latest_intact,
    restore_tenant_pytree,
    save_pytree,
    sweep_tmp_dirs,
)
