"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
  * atomicity — write to a unique ``<dir>/tmp.<step>.*`` dir, then swap it
    into place with renames only (never delete-then-rename): at every
    instant a complete copy of the step exists on disk, and a crash
    mid-save never corrupts — or loses — an existing checkpoint.  Manager
    start sweeps crash debris (`sweep_tmp_dirs`), recovering any finished
    save that died between the renames;
  * async — saves run on a daemon thread off the training critical path
    (the step only pays for the host transfer of its arrays);
  * retention — keep the newest K checkpoints;
  * elasticity — :func:`restore_pytree` takes a target sharding tree, so a
    checkpoint written on one mesh restores onto ANY other mesh (shrunk /
    grown world after a failure): arrays land host-side then device_put
    against the new NamedShardings.

Format: one .npz per checkpoint (flattened pytree paths as keys) + a JSON
manifest with step and tree structure.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree: Any, directory: str, step: int) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    # Unique tmp name: two writers of the same step never collide, and a
    # crash mid-write leaves an identifiable orphan for sweep_tmp_dirs.
    tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=directory)
    final = os.path.join(directory, f"step_{step:010d}")
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree.structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef), "keys": sorted(flat)}, f)
    # Swap, never delete-then-rename: the old `shutil.rmtree(final)` +
    # `os.rename` pair lost the existing checkpoint for this step if the
    # process died between the two calls.  Move the old dir aside under a
    # unique trash name first — at every instant there is a complete copy
    # of the step on disk (the new tmp dir is fully written by now, and
    # sweep_tmp_dirs recovers a complete orphan whose final is missing).
    trash = None
    if os.path.exists(final):
        trash = tempfile.mkdtemp(prefix=f"trash.{step}.", dir=directory)
        os.rmdir(trash)
        os.rename(final, trash)
    os.rename(tmp, final)
    if trash is not None:
        shutil.rmtree(trash, ignore_errors=True)
    return final


def sweep_tmp_dirs(directory: str) -> list:
    """Clean the crash window's debris: ``tmp.*`` / ``trash.*`` dirs.

    A save that died mid-write leaks its unique tmp dir forever (they used
    to accumulate and eat disk across restarts).  A complete tmp dir whose
    ``step_*`` target is missing is a finished save that crashed between
    the two renames — recover it into place instead of discarding the only
    surviving copy of that step.  Returns the recovered checkpoint paths.
    """
    if not os.path.isdir(directory):
        return []
    recovered = []
    for name in sorted(os.listdir(directory)):
        if not (name.startswith("tmp.") or name.startswith("trash.")):
            continue
        path = os.path.join(directory, name)
        if not os.path.isdir(path):
            continue
        step = None
        if name.startswith("tmp."):
            try:
                with open(os.path.join(path, "manifest.json")) as f:
                    step = int(json.load(f)["step"])
            except (OSError, ValueError, KeyError, json.JSONDecodeError):
                step = None  # incomplete write: plain debris
        if step is not None:
            final = os.path.join(directory, f"step_{step:010d}")
            if not os.path.exists(final):
                os.rename(path, final)
                recovered.append(final)
                continue
        shutil.rmtree(path, ignore_errors=True)
    return recovered


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(n.split("_")[1]) for n in os.listdir(directory) if n.startswith("step_")
    ]
    return max(steps) if steps else None


def restore_pytree(
    template: Any, directory: str, step: Optional[int] = None, shardings: Any = None
) -> Any:
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding) re-lays the arrays onto
    the *current* mesh — elastic restore across different world sizes.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for p, leaf in flat_paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            # dtype is coerced below, but a silent shape change would only
            # blow up (or worse, broadcast) at first use, far from here
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore template expects {tuple(jnp.shape(leaf))} "
                f"(step {step} under {directory})"
            )
        if isinstance(leaf, np.ndarray):
            # host-side template leaf (e.g. a serving cursor): restore
            # host-side — device_put'ing it would both x64-truncate and
            # force a pointless transfer
            leaves.append(np.asarray(arr, dtype=leaf.dtype))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    restored = jax.tree.unflatten(jax.tree.structure(template), leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    return restored


class CheckpointManager:
    """Async checkpointing with retention + preemption flush.

    save() enqueues a host-side snapshot and returns immediately; a daemon
    thread serializes.  ``flush()`` (called by the preemption handler in
    `repro.runtime.fault`) blocks until the queue drains.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        # a previous process that crashed mid-save left tmp/trash debris
        # (and possibly a complete-but-unrenamed checkpoint) behind
        self.recovered = sweep_tmp_dirs(directory)
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.saved_steps: list[int] = []
        self._errors: list[Exception] = []

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self.saved_steps.append(step)
                self._gc()
            except Exception as e:  # pragma: no cover - surfaced via .errors
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    def save(self, tree: Any, step: int) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device now
        self._q.put((host_tree, step))

    def flush(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        # the sentinel must reach the worker even when flush() raises a
        # deferred save error — otherwise the daemon thread leaks
        try:
            self.flush()
        finally:
            self._q.put(None)
            self._q.join()
            self._worker.join(timeout=5.0)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
