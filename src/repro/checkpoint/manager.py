"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
  * atomicity — write to a unique ``<dir>/tmp.<step>.*`` dir, then swap it
    into place with renames only (never delete-then-rename): at every
    instant a complete copy of the step exists on disk, and a crash
    mid-save never corrupts — or loses — an existing checkpoint.  Manager
    start sweeps crash debris (`sweep_tmp_dirs`), recovering any finished
    save that died between the renames;
  * verification — the manifest carries a content checksum per leaf;
    :func:`restore_pytree` verifies them on restore and raises the named
    :class:`CheckpointCorrupt` on a torn or bit-flipped payload instead of
    silently deserializing garbage into serving state;
  * walk-back — retention keeps the newest K *generations*, and
    :func:`restore_latest_intact` walks back from the newest generation
    past any torn/corrupt one to the newest that verifies — a fault at the
    worst possible moment costs freshness, never availability;
  * async + retry — saves run on a daemon thread off the critical path
    (the step only pays for the host transfer of its arrays), and a
    transient write failure is retried with bounded backoff before it is
    surfaced;
  * elasticity — :func:`restore_pytree` takes a target sharding tree, so a
    checkpoint written on one mesh restores onto ANY other mesh (shrunk /
    grown world after a failure): arrays land host-side then device_put
    against the new NamedShardings.

Chaos hooks (`repro.runtime.chaos`): ``checkpoint.write`` fires at the top
of every :func:`save_pytree` (a ``fail`` rule models a transient IO
error); ``checkpoint.payload`` is checked after the arrays payload lands
(a ``corrupt`` rule tears the on-disk bytes, exactly what the checksum
verification and walk-back exist to survive).

Format: one .npz per checkpoint (flattened pytree paths as keys) + a JSON
manifest with step, tree structure, per-key crc32 checksums, and optional
caller metadata (``meta``).  Sessions record ``meta["tenant_axes"]`` — a
flat-key → axis map (`FrameSession.tenant_axes`) — which lets
:func:`restore_tenant_pytree` slice ONE tenant's state out of a full
checkpoint (verified leaf-by-leaf first) without the caller materializing
or re-ingesting anything else: the self-healing path behind
`StatsGateway.rebuild_tenant`.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed content verification (torn write, bit rot)."""


def _chaos():
    # function-scope import: runtime/__init__ pulls fault.py, which imports
    # THIS module — a top-level back-edge would deadlock that cycle
    from ..runtime import chaos

    return chaos


def path_key(path) -> str:
    """The canonical flat key for one pytree path — the .npz entry name and
    the key every manifest table (checksums, meta["tenant_axes"]) uses."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_key(path)] = np.asarray(leaf)
    return flat


def _checksum(arr: np.ndarray) -> int:
    """Content crc32 over the raw leaf bytes (shape/dtype changes are caught
    separately by the restore template check)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_pytree(
    tree: Any, directory: str, step: int, meta: Optional[dict] = None
) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path.

    ``meta`` (optional, JSON-serializable) is recorded verbatim in the
    manifest — sessions store their ``tenant_axes`` map here so per-tenant
    extraction works from the checkpoint alone.
    """
    chaos = _chaos()
    chaos.fire("checkpoint.write")  # injected transient IO failure point
    os.makedirs(directory, exist_ok=True)
    # Unique tmp name: two writers of the same step never collide, and a
    # crash mid-write leaves an identifiable orphan for sweep_tmp_dirs.
    tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=directory)
    final = os.path.join(directory, f"step_{step:010d}")
    flat = _flatten(tree)
    payload = os.path.join(tmp, "arrays.npz")
    np.savez(payload, **flat)
    if chaos.should_corrupt("checkpoint.payload"):
        # tear the written payload in place: the manifest checksums below
        # are computed from the INTACT arrays, so verification must refuse
        # this generation and walk-back must skip it
        with open(payload, "r+b") as f:
            f.seek(max(os.path.getsize(payload) // 2, 0))
            f.write(b"\x00CHAOS-TORN\x00")
    treedef = jax.tree.structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(
            {
                "step": step,
                "treedef": str(treedef),
                "keys": sorted(flat),
                "checksums": {k: _checksum(v) for k, v in flat.items()},
                "meta": dict(meta or {}),
            },
            f,
        )
    # Swap, never delete-then-rename: the old `shutil.rmtree(final)` +
    # `os.rename` pair lost the existing checkpoint for this step if the
    # process died between the two calls.  Move the old dir aside under a
    # unique trash name first — at every instant there is a complete copy
    # of the step on disk (the new tmp dir is fully written by now, and
    # sweep_tmp_dirs recovers a complete orphan whose final is missing).
    trash = None
    if os.path.exists(final):
        trash = tempfile.mkdtemp(prefix=f"trash.{step}.", dir=directory)
        os.rmdir(trash)
        os.rename(final, trash)
    os.rename(tmp, final)
    if trash is not None:
        shutil.rmtree(trash, ignore_errors=True)
    return final


def sweep_tmp_dirs(directory: str) -> list:
    """Clean the crash window's debris: ``tmp.*`` / ``trash.*`` dirs.

    A save that died mid-write leaks its unique tmp dir forever (they used
    to accumulate and eat disk across restarts).  A complete tmp dir whose
    ``step_*`` target is missing is a finished save that crashed between
    the two renames — recover it into place instead of discarding the only
    surviving copy of that step.  Returns the recovered checkpoint paths.
    """
    if not os.path.isdir(directory):
        return []
    recovered = []
    for name in sorted(os.listdir(directory)):
        if not (name.startswith("tmp.") or name.startswith("trash.")):
            continue
        path = os.path.join(directory, name)
        if not os.path.isdir(path):
            continue
        step = None
        if name.startswith("tmp."):
            try:
                with open(os.path.join(path, "manifest.json")) as f:
                    step = int(json.load(f)["step"])
            except (OSError, ValueError, KeyError, json.JSONDecodeError):
                step = None  # incomplete write: plain debris
        if step is not None:
            final = os.path.join(directory, f"step_{step:010d}")
            if not os.path.exists(final):
                os.rename(path, final)
                recovered.append(final)
                continue
        shutil.rmtree(path, ignore_errors=True)
    return recovered


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(n.split("_")[1]) for n in os.listdir(directory) if n.startswith("step_")
    ]
    return max(steps) if steps else None


def _load_checksums(step_dir: str) -> Optional[Dict[str, int]]:
    """The manifest's per-key checksums, or None for a pre-verification
    checkpoint (older format: restores unverified rather than refusing)."""
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return None
    sums = manifest.get("checksums")
    if not isinstance(sums, dict):
        return None
    return {k: int(v) for k, v in sums.items()}


def restore_pytree(
    template: Any,
    directory: str,
    step: Optional[int] = None,
    shardings: Any = None,
    verify: bool = True,
) -> Any:
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding) re-lays the arrays onto
    the *current* mesh — elastic restore across different world sizes.
    ``verify`` (default on) checks each leaf against the manifest's content
    checksum and raises :class:`CheckpointCorrupt` on a mismatch — a torn
    or bit-flipped generation is refused loudly here, never deserialized
    into serving state (checkpoints written before checksums existed carry
    none and restore unverified).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    step_dir = os.path.join(directory, f"step_{step:010d}")
    path = os.path.join(step_dir, "arrays.npz")
    checksums = _load_checksums(step_dir) if verify else None
    try:
        data = np.load(path)
    except Exception as e:  # truncated zip, missing file, ...
        raise CheckpointCorrupt(
            f"checkpoint step {step} under {directory} is unreadable: {e!r}"
        ) from e
    flat_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for p, leaf in flat_paths:
        key = path_key(p)
        try:
            arr = data[key]
        except KeyError:
            raise CheckpointCorrupt(
                f"checkpoint step {step} under {directory} is missing leaf "
                f"{key!r}"
            ) from None
        except Exception as e:  # zipfile.BadZipFile on a torn entry, ...
            raise CheckpointCorrupt(
                f"checkpoint leaf {key!r} of step {step} under {directory} "
                f"is unreadable: {e!r}"
            ) from e
        if checksums is not None:
            want = checksums.get(key)
            got = _checksum(arr)
            if want is not None and got != want:
                raise CheckpointCorrupt(
                    f"checkpoint leaf {key!r} of step {step} under "
                    f"{directory} fails verification (crc32 {got} != "
                    f"manifest {want}) — torn write or bit rot"
                )
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            # dtype is coerced below, but a silent shape change would only
            # blow up (or worse, broadcast) at first use, far from here
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore template expects {tuple(jnp.shape(leaf))} "
                f"(step {step} under {directory})"
            )
        if isinstance(leaf, np.ndarray):
            # host-side template leaf (e.g. a serving cursor): restore
            # host-side — device_put'ing it would both x64-truncate and
            # force a pointless transfer
            leaves.append(np.asarray(arr, dtype=leaf.dtype))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    restored = jax.tree.unflatten(jax.tree.structure(template), leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    return restored


def list_steps(directory: str) -> list:
    """All on-disk generations under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_")
    )


def restore_latest_intact(
    template: Any, directory: str, shardings: Any = None
) -> Tuple[Any, int, list]:
    """Restore the newest generation that passes verification.

    Walks the retained generations newest→oldest, skipping any that fail
    content verification or are torn/unreadable (:class:`CheckpointCorrupt`)
    — the corrupt-at-the-worst-moment failure mode costs freshness, never
    availability.  Returns ``(state, step, skipped)`` where ``skipped``
    lists the corrupt generations walked past (newest first).  Raises
    ``FileNotFoundError`` when no generation exists at all, and
    :class:`CheckpointCorrupt` when every retained generation is corrupt
    (the caller decides whether a cold start is acceptable).
    """
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    skipped: list = []
    for step in reversed(steps):
        try:
            state = restore_pytree(template, directory, step, shardings)
            return state, step, skipped
        except CheckpointCorrupt:
            skipped.append(step)
    raise CheckpointCorrupt(
        f"every retained checkpoint generation under {directory} is corrupt "
        f"(steps {skipped})"
    )


def load_manifest(directory: str, step: int) -> dict:
    """One generation's manifest dict; :class:`CheckpointCorrupt` when the
    manifest is missing or unparseable (torn write)."""
    path = os.path.join(directory, f"step_{step:010d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorrupt(
            f"manifest of checkpoint step {step} under {directory} is "
            f"unreadable: {e!r}"
        ) from e


def restore_tenant_pytree(
    template: Any,
    directory: str,
    tenant: int,
    step: Optional[int] = None,
    verify: bool = True,
) -> Any:
    """Extract ONE tenant's slice from a full-session checkpoint.

    ``template`` is the FULL session state template (shapes with every
    tenant); the manifest's ``meta["tenant_axes"]`` names the axis each
    leaf carries tenants on, and the returned tree holds that axis sliced
    down to ``tenant`` — exactly the `FrameSession.import_tenant` payload.
    Each leaf is checksum-verified in full before slicing (``verify=True``),
    so a torn generation raises :class:`CheckpointCorrupt` here and the
    walk-back of :func:`restore_tenant_latest_intact` can skip it.
    """
    tenant = int(tenant)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    manifest = load_manifest(directory, step)
    axes = manifest.get("meta", {}).get("tenant_axes")
    if not isinstance(axes, dict):
        raise CheckpointCorrupt(
            f"checkpoint step {step} under {directory} carries no "
            "tenant_axes metadata — written before per-tenant extraction "
            "existed, or by a saver that is not a session gateway"
        )
    step_dir = os.path.join(directory, f"step_{step:010d}")
    checksums = _load_checksums(step_dir) if verify else None
    try:
        data = np.load(os.path.join(step_dir, "arrays.npz"))
    except Exception as e:
        raise CheckpointCorrupt(
            f"checkpoint step {step} under {directory} is unreadable: {e!r}"
        ) from e
    flat_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for p, leaf in flat_paths:
        key = path_key(p)
        try:
            arr = data[key]
        except KeyError:
            raise CheckpointCorrupt(
                f"checkpoint step {step} under {directory} is missing leaf "
                f"{key!r}"
            ) from None
        except Exception as e:
            raise CheckpointCorrupt(
                f"checkpoint leaf {key!r} of step {step} under {directory} "
                f"is unreadable: {e!r}"
            ) from e
        if checksums is not None:
            want = checksums.get(key)
            got = _checksum(arr)
            if want is not None and got != want:
                raise CheckpointCorrupt(
                    f"checkpoint leaf {key!r} of step {step} under "
                    f"{directory} fails verification (crc32 {got} != "
                    f"manifest {want}) — torn write or bit rot"
                )
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore template expects {tuple(jnp.shape(leaf))} "
                f"(step {step} under {directory})"
            )
        ax = axes.get(key)
        if ax is None:
            raise CheckpointCorrupt(
                f"checkpoint step {step} under {directory} has no tenant "
                f"axis recorded for leaf {key!r}"
            )
        ax = int(ax)
        if not 0 <= tenant < arr.shape[ax]:
            raise ValueError(
                f"tenant {tenant} out of range [0, {arr.shape[ax]}) on leaf "
                f"{key!r} (axis {ax})"
            )
        sliced = np.take(arr, tenant, axis=ax)
        if isinstance(leaf, np.ndarray):
            leaves.append(np.asarray(sliced, dtype=leaf.dtype))
        else:
            leaves.append(jnp.asarray(sliced, dtype=leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(template), leaves)


def restore_tenant_latest_intact(
    template: Any, directory: str, tenant: int, verify: bool = True
) -> Tuple[Any, int, list]:
    """Per-tenant :func:`restore_latest_intact`: the newest generation from
    which ``tenant``'s slice extracts, verifies, AND is all-finite.

    The finiteness requirement is what makes this a *repair* primitive: a
    poisoned lane that survived long enough to be snapshotted (sentinel
    off, or an in-state corruption) is byte-perfect on disk — checksums
    pass — yet restoring it would re-plant exactly the damage
    ``rebuild_tenant`` is trying to excise, so such generations are walked
    past the same way torn ones are.  Returns ``(tenant_state, step,
    skipped)``; raises ``FileNotFoundError`` with no generations and
    :class:`CheckpointCorrupt` when every one is corrupt, poisoned, or
    lacks tenant metadata."""
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    skipped: list = []
    for step in reversed(steps):
        try:
            state = restore_tenant_pytree(
                template, directory, tenant, step, verify=verify
            )
            for leaf in jax.tree_util.tree_leaves(state):
                arr = np.asarray(leaf)
                if arr.dtype.kind in "fc" and not np.isfinite(arr).all():
                    raise CheckpointCorrupt(
                        f"step {step}: tenant {tenant}'s slice holds "
                        "non-finite values — poisoned before snapshot"
                    )
            return state, step, skipped
        except CheckpointCorrupt:
            skipped.append(step)
    raise CheckpointCorrupt(
        f"no retained checkpoint generation under {directory} yields an "
        f"intact slice for tenant {tenant} (skipped {skipped})"
    )


class CheckpointManager:
    """Async checkpointing with retention, write retry + preemption flush.

    save() enqueues a host-side snapshot and returns immediately; a daemon
    thread serializes.  A failed write is retried ``retries`` times with
    exponentially growing backoff (``backoff * 2**attempt`` seconds) before
    the error is recorded — transient IO hiccups (full page cache, a
    remounting network volume, an injected ``checkpoint.write`` fault)
    don't silently cost the generation.  ``flush()`` (called by the
    preemption handler in `repro.runtime.fault`) blocks until the queue
    drains.
    """

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        retries: int = 2,
        backoff: float = 0.05,
    ):
        self.directory = directory
        self.keep = keep
        self.retries = retries
        self.backoff = backoff
        # a previous process that crashed mid-save left tmp/trash debris
        # (and possibly a complete-but-unrenamed checkpoint) behind
        self.recovered = sweep_tmp_dirs(directory)
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.saved_steps: list[int] = []
        self.retried_saves: int = 0
        self._errors: list[Exception] = []

    def _save_with_retry(self, tree, step, meta=None) -> None:
        for attempt in range(self.retries + 1):
            try:
                # positional-only without meta: metadata-free callers keep
                # working against simpler save_pytree substitutes
                if meta is None:
                    save_pytree(tree, self.directory, step)
                else:
                    save_pytree(tree, self.directory, step, meta=meta)
                return
            except Exception:
                # a half-written unique tmp dir is left behind; the next
                # attempt writes its own and sweep_tmp_dirs clears debris
                if attempt == self.retries:
                    raise
                self.retried_saves += 1
                time.sleep(self.backoff * (2 ** attempt))

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            tree, step, meta = item
            try:
                self._save_with_retry(tree, step, meta)
                self.saved_steps.append(step)
                self._gc()
            except Exception as e:  # pragma: no cover - surfaced via .errors
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    def save(self, tree: Any, step: int, meta: Optional[dict] = None) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device now
        self._q.put((host_tree, step, meta))

    def flush(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        # the sentinel must reach the worker even when flush() raises a
        # deferred save error — otherwise the daemon thread leaks
        try:
            self.flush()
        finally:
            self._q.put(None)
            self._q.join()
            self._worker.join(timeout=5.0)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
