"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
  * atomicity — write to ``<dir>/tmp.<step>`` then os.rename (POSIX-atomic);
    a crash mid-save never corrupts the latest checkpoint;
  * async — saves run on a daemon thread off the training critical path
    (the step only pays for the host transfer of its arrays);
  * retention — keep the newest K checkpoints;
  * elasticity — :func:`restore_pytree` takes a target sharding tree, so a
    checkpoint written on one mesh restores onto ANY other mesh (shrunk /
    grown world after a failure): arrays land host-side then device_put
    against the new NamedShardings.

Format: one .npz per checkpoint (flattened pytree paths as keys) + a JSON
manifest with step and tree structure.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree: Any, directory: str, step: int) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree.structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef), "keys": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(n.split("_")[1]) for n in os.listdir(directory) if n.startswith("step_")
    ]
    return max(steps) if steps else None


def restore_pytree(
    template: Any, directory: str, step: Optional[int] = None, shardings: Any = None
) -> Any:
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding) re-lays the arrays onto
    the *current* mesh — elastic restore across different world sizes.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for p, leaf in flat_paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    restored = jax.tree.unflatten(jax.tree.structure(template), leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    return restored


class CheckpointManager:
    """Async checkpointing with retention + preemption flush.

    save() enqueues a host-side snapshot and returns immediately; a daemon
    thread serializes.  ``flush()`` (called by the preemption handler in
    `repro.runtime.fault`) blocks until the queue drains.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.saved_steps: list[int] = []
        self._errors: list[Exception] = []

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self.saved_steps.append(step)
                self._gc()
            except Exception as e:  # pragma: no cover - surfaced via .errors
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    def save(self, tree: Any, step: int) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device now
        self._q.put((host_tree, step))

    def flush(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.flush()
        self._q.put(None)
        self._q.join()

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
