"""Logical-axis sharding rules → PartitionSpecs (GSPMD/pjit integration).

Activations and parameters are annotated with *logical* axis names; this
module resolves them against whatever mesh is active
(``jax.sharding.set_mesh``), with automatic divisibility fallback: a logical
axis whose dimension does not divide over its mesh axes is replicated
instead of erroring — so the same model code lowers on the 16×16 single-pod
mesh, the 2×16×16 multi-pod mesh, an 8-device test mesh, and a single CPU
device.

Rules (DESIGN.md §6):
  batch   → ("pod", "data")   data parallelism (pod = outer pure-DP axis)
  heads   → "model"           tensor parallelism over (kv-grouped) heads
  ff      → "model"           tensor parallelism over MLP hidden
  experts → "model"           expert parallelism
  vocab   → "model"           embedding / logits sharding
  seq     → "data" in SP mode sequence/context parallelism (long_500k)

SP mode is a module-level switch flipped by the launchers for cells where
the batch axis is too small to fill "data" (global_batch=1 long-context):
batch then stays replicated and the sequence axis takes over "data".
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

LogicalAxis = Union[str, None, Tuple[str, ...]]

_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": (),
    "seq": (),  # overridden in SP mode
    "seq_sp": ("data",),
    "seq_tp": ("model",),  # Megatron-SP residual sharding (§Perf B5)
}

_SP_MODE = False


def set_sp_mode(enabled: bool) -> None:
    """Sequence-parallel mode: 'seq' → data axis, 'batch' → replicated."""
    global _SP_MODE
    _SP_MODE = enabled


def sp_mode_enabled() -> bool:
    return _SP_MODE


def abstract_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """Version-portable ``AbstractMesh`` (axis names/sizes only, no devices).

    Newer JAX takes ``(shape, axis_names, axis_types=...)``; 0.4.x takes a
    single ``((name, size), ...)`` tuple.  Sharding-rule resolution only
    reads ``mesh.shape``/``mesh.axis_names``, which both spell the same.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape),
            tuple(axis_names),
            axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
        )
    return jax.sharding.AbstractMesh(tuple(zip(axis_names, shape)))


def _active_mesh():
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        m = get_am()
        return None if m is None or m.empty else m
    # JAX 0.4.x: the mesh installed by `with mesh:` lives in thread resources.
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def mesh_axis_size(mesh, names: Sequence[str]) -> int:
    return math.prod(dict(mesh.shape).get(n, 1) for n in names)


def _resolve(logical: LogicalAxis, mesh) -> Tuple[str, ...]:
    if logical is None:
        return ()
    if isinstance(logical, tuple):
        names: Tuple[str, ...] = logical
    else:
        if logical == "batch" and _SP_MODE:
            return ()
        if logical == "seq" and _SP_MODE:
            names = _RULES["seq_sp"]
        else:
            names = _RULES.get(logical, (logical,))
    return tuple(n for n in names if n in mesh.axis_names)


def logical_to_spec(axes: Sequence[LogicalAxis], shape: Sequence[int], mesh) -> P:
    """Resolve logical names per-dimension with divisibility fallback."""
    entries = []
    used: set = set()
    for dim, logical in zip(shape, axes):
        names = tuple(n for n in _resolve(logical, mesh) if n not in used)
        if names and dim % mesh_axis_size(mesh, names) == 0:
            used.update(names)
            entries.append(names if len(names) > 1 else names[0])
        else:
            entries.append(None)
    return P(*entries)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` without replication checking, across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Every
    sharded estimator path goes through here so the paper's cluster scheme
    lowers on either.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def psum_tree(tree: Any, axis: str) -> Any:
    """Single-collective reduction of a pytree of per-device partials.

    This is the cluster-level merge of the weak-memory monoid
    (`repro.core.streaming`): per-shard partial statistics built from
    halo-complete blocks contain every window the shard owns, so the global
    ⊕ degenerates to one ``psum`` of the (tiny) sufficient statistics —
    never the data.  Used by every sharded estimator path
    (`core.mapreduce.sharded_window_map_reduce`,
    `core.estimators.stats.autocovariance_sharded`,
    `timeseries.TimeSeriesStore.map_reduce`).  The per-shard local
    contraction feeding this collective routes through the compute-backend
    registry (`repro.core.backend`) — shards hit the Pallas tile kernels or
    pure jnp per the caller's ``backend=``, while the collective itself is
    backend-agnostic.
    """
    return jax.tree.map(lambda l: jax.lax.psum(l, axis), tree)


def shard(x: jax.Array, axes: Sequence[LogicalAxis]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------ parameter pspecs ----

# Leaf-name → logical axes (per dimension).  Matched by the *last* path
# component; falls back to replicated.  Divisibility fallback applies per
# dim, so e.g. a 4-head test model simply replicates its head axis.
_PARAM_RULES: Dict[str, Tuple[LogicalAxis, ...]] = {
    # attention
    "wq": (None, "heads"),
    "wk": (None, "kv"),
    "wv": (None, "kv"),
    "wo": ("heads", None),
    # MLA
    "w_dq": (None, None),
    "w_uq": (None, "heads"),
    "w_dkv": (None, None),
    "w_uk": (None, "heads"),
    "w_uv": (None, "heads"),
    "w_kr": (None, None),
    # MLP
    "w_gate": (None, "ff"),
    "w_up": (None, "ff"),
    "w_down": ("ff", None),
    # MoE (leading expert axis)
    "router": (None, None),
    "e_gate": ("experts", None, None),
    "e_up": ("experts", None, None),
    "e_down": ("experts", None, None),
    # embeddings / head
    "embed": ("vocab", "embed"),
    "lm_head": (None, "vocab"),
    "patch_proj": (None, None),
    # mamba2
    "in_proj": (None, "ff"),
    "conv_w": (None, "ff"),
    "conv_b": ("ff",),
    "out_proj": ("ff", None),
    "A_log": ("ff",),
    "D": ("ff",),
    "dt_bias": ("ff",),
    # xlstm
    "w_qkv": (None, "ff"),
    "w_if": (None, "heads"),
    "w_o_gate": (None, "ff"),
    "up_proj": (None, "ff"),
    "down_proj": ("ff", None),
    "w_gates": (None, "heads"),
    "r_gates": (None, "heads"),
}


def _leaf_rule(path: Tuple[Any, ...], leaf) -> Tuple[LogicalAxis, ...]:
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            name = key
            break
    rule = _PARAM_RULES.get(name or "", None)
    if rule is None:
        return (None,) * leaf.ndim
    if len(rule) == leaf.ndim:
        return rule
    if len(rule) + 1 == leaf.ndim:
        # stacked-over-layers variant (leading L axis from scan init)
        return (None,) + rule
    return (None,) * leaf.ndim


def param_pspecs(params: Any, mesh) -> Any:
    """PartitionSpec pytree for a parameter pytree (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: logical_to_spec(_leaf_rule(path, leaf), leaf.shape, mesh),
        params,
    )


def zero1_pspecs(params: Any, mesh) -> Any:
    """ZeRO-1 optimizer-state specs: the param spec PLUS the data(+pod) axes
    on the first still-unsharded divisible dimension.

    Optimizer moments are only touched at the (per-step) update, so paying a
    reduce-scatter/all-gather there buys an N_data× memory reduction — the
    standard ZeRO-1 trade.  Falls back to the plain param spec when no
    dimension divides.
    """
    dp_axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    dp = mesh_axis_size(mesh, dp_axes)

    def one(path, leaf):
        spec = logical_to_spec(_leaf_rule(path, leaf), leaf.shape, mesh)
        if dp <= 1:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
            if e is None and dim % dp == 0:
                entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                return P(*entries)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)
