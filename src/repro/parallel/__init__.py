"""Distribution substrate: logical sharding rules, halo sequence parallelism."""
from .sharding import (
    shard,
    logical_to_spec,
    param_pspecs,
    psum_tree,
    set_sp_mode,
    sp_mode_enabled,
    mesh_axis_size,
)
