"""Overlapping distributed blocks — the paper's core data structure (§10).

A length-N, d-dimensional regularly-sampled series is partitioned **along
time** into P blocks of core width ``block_size``; each block additionally
carries a replicated halo of ``h_left`` past samples and ``h_right`` future
samples.  Any order-(h_left, h_right) weak-memory estimator (paper §8) then
reduces per-block kernel computations with **zero communication** between
blocks — the embarrassingly-parallel scheme of paper Fig. 4.

Representation: ``(P, h_left + block_size + h_right, d)`` array plus a
validity mask.  Out-of-range halo slots (at the global series boundary) are
zero-filled and masked.  The core region of block ``i`` covers global indices
``[i*block_size, (i+1)*block_size)``; the last block may contain padding,
also masked.

The same structure is used at every level of the memory hierarchy:
  * cluster level — the leading P axis is sharded over a mesh axis
    (`repro.parallel.halo` exchanges halos with collective-permute instead of
    materializing them when memory is tighter than ICI bandwidth);
  * intra-device — `repro.kernels.window_stats` re-creates the same overlap
    pattern between VMEM tiles via its BlockSpec index map (paper Fig. 9,
    shared-memory scheme, adapted to the TPU memory hierarchy).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "OverlapSpec",
    "make_overlapping_blocks",
    "block_core",
    "core_mask",
    "center_global_index",
    "reconstruct",
    "num_blocks",
    "replication_overhead",
]


@dataclasses.dataclass(frozen=True)
class OverlapSpec:
    """Static description of an overlapping block partitioning.

    Attributes:
      n: global number of time steps in the series.
      block_size: number of *core* (owned, non-replicated) steps per block.
      h_left: halo width into the past (# steps replicated from the previous
        block).  For a causal order-p estimator (AR(p) gradient) this is p.
      h_right: halo width into the future.  For a symmetric ±H kernel
        (autocovariance at lags 0..H needs X_{k+h}) this is H.
    """

    n: int
    block_size: int
    h_left: int
    h_right: int

    def __post_init__(self):
        if self.block_size <= 0:
            raise ValueError(f"block_size must be positive, got {self.block_size}")
        if self.n <= 0:
            raise ValueError(f"series length must be positive, got {self.n}")
        if self.h_left < 0 or self.h_right < 0:
            raise ValueError("halo widths must be non-negative")

    @property
    def num_blocks(self) -> int:
        return -(-self.n // self.block_size)  # ceil div

    @property
    def padded_width(self) -> int:
        return self.h_left + self.block_size + self.h_right

    @property
    def window(self) -> int:
        """Width of the widest kernel window this spec supports."""
        return self.h_left + 1 + self.h_right

    def global_indices(self) -> np.ndarray:
        """(P, padded_width) global time index of every padded slot.

        Out-of-range slots (before 0 / at-or-after n) are clamped but flagged
        by :func:`slot_mask`; the data there is zero-filled.
        """
        p = self.num_blocks
        starts = np.arange(p) * self.block_size - self.h_left
        idx = starts[:, None] + np.arange(self.padded_width)[None, :]
        return idx

    def slot_mask(self) -> np.ndarray:
        """(P, padded_width) bool — True where the padded slot holds real data."""
        idx = self.global_indices()
        return (idx >= 0) & (idx < self.n)


def num_blocks(n: int, block_size: int) -> int:
    return -(-n // block_size)


def replication_overhead(spec: OverlapSpec) -> float:
    """Fraction of extra storage paid for the halos ((P·padded)/N - 1).

    The paper's cost of embarrassing parallelism: ``(P-1)·(h_l+h_r)``
    duplicated samples plus tail padding.
    """
    return spec.num_blocks * spec.padded_width / spec.n - 1.0


def make_overlapping_blocks(x: jax.Array, spec: OverlapSpec) -> Tuple[jax.Array, jax.Array]:
    """Build the overlapping block array from a contiguous series.

    Args:
      x: (n, d) series (or (n,) — promoted to (n, 1)).
      spec: partitioning description; ``spec.n`` must equal ``x.shape[0]``.

    Returns:
      blocks: (P, padded_width, d) — zero-filled outside the valid range.
      mask:   (P, padded_width) bool validity mask for every padded slot.
    """
    if x.ndim == 1:
        x = x[:, None]
    if x.shape[0] != spec.n:
        raise ValueError(f"series length {x.shape[0]} != spec.n {spec.n}")
    idx = jnp.asarray(spec.global_indices())
    mask = jnp.asarray(spec.slot_mask())
    gathered = jnp.take(x, jnp.clip(idx, 0, spec.n - 1), axis=0)
    blocks = jnp.where(mask[..., None], gathered, 0.0)
    return blocks, mask


def block_core(blocks: jax.Array, spec: OverlapSpec) -> jax.Array:
    """Extract the owned (core) region of every block: (P, block_size, d)."""
    return blocks[:, spec.h_left : spec.h_left + spec.block_size, :]


def core_mask(spec: OverlapSpec) -> np.ndarray:
    """(P, block_size) bool — True where the core slot maps to a real sample.

    Only the final block can have invalid core slots (tail padding).
    """
    idx = spec.global_indices()[:, spec.h_left : spec.h_left + spec.block_size]
    return (idx >= 0) & (idx < spec.n)


def center_global_index(spec: OverlapSpec) -> np.ndarray:
    """(P, block_size) global time index of each core slot (clamped)."""
    return np.clip(
        spec.global_indices()[:, spec.h_left : spec.h_left + spec.block_size], 0, spec.n - 1
    )


def reconstruct(blocks: jax.Array, spec: OverlapSpec) -> jax.Array:
    """Inverse of :func:`make_overlapping_blocks`: recover the (n, d) series.

    Property-tested: ``reconstruct(make_overlapping_blocks(x, s), s) == x``
    for every admissible spec (the halos are pure replication, so dropping
    them and concatenating cores is exact).
    """
    core = block_core(blocks, spec)
    flat = core.reshape(spec.num_blocks * spec.block_size, core.shape[-1])
    return flat[: spec.n]
