"""The paper's primary contribution: overlapping distributed blocks +
embarrassingly-parallel weak-memory estimation (map-reduce over windowed
kernels), in JAX.

Layering:
  overlap.py      — the data structure (OverlapSpec, block build/reconstruct)
  backend.py      — the compute registry (jnp / Pallas / auto substrates)
  mapreduce.py    — the execution engine (serial / blocked / shard_map paths)
  streaming.py    — the mergeable PartialState monoid + scan-driven ingest
  plan.py         — fused statistics plans (N estimators, one traversal)
  frame.py        — SeriesFrame/FrameSession: the lazy, placement-aware
                    session front door over plans, streaming, and serving
  halo.py         — replication vs collective-permute halo materialization
  estimators/     — M- and Z-estimators of the paper (§2–§6)
  graphs.py       — order-(H,K) graph generalization + traffic DBN (§9, §11)
  differencing.py — integrated-process reduction (§1.4, §10.3)
"""
from .backend import (
    Backend,
    JnpBackend,
    PallasBackend,
    get_backend,
    register_backend,
    list_backends,
    set_default_backend,
)
from .overlap import (
    OverlapSpec,
    make_overlapping_blocks,
    block_core,
    core_mask,
    reconstruct,
    replication_overhead,
)
from .mapreduce import (
    serial_window_map_reduce,
    block_window_map_reduce,
    scan_window_map_reduce,
    sharded_window_map_reduce,
    block_partials,
    tree_sum,
)
from .plan import (
    StatPlan,
    fused_engine,
    analyze,
    autocovariance_request,
    yule_walker_request,
    arma_request,
    moments_request,
    welch_request,
    kernel_request,
)
from .frame import SeriesFrame, FrameSession, Deferred
from .halo import halo_exchange, halo_exchange_grouped
from . import estimators
from .estimators import *  # noqa: F401,F403  (re-export the estimator API)
from .differencing import difference, integrate, difference_blocked
from . import graphs
