"""Streaming sufficient statistics: the weak-memory monoid made explicit.

The paper's central observation (§7–§10) is that every order-(h_left,
h_right) weak-memory estimator is a sum of per-window kernel contributions

    Est(X) = ⊕_s k( X[s : s+W] ),        W = h_left + 1 + h_right,

for a commutative-associative ⊕.  `core.mapreduce` exploits this for one
fully-materialized series per call; this module exploits it for data that
*arrives over time*, in chunks of arbitrary uneven sizes, possibly on
different machines: partial results form a **monoid** and can be merged in
any order, at any granularity, from any source.

The carrier is :class:`PartialState` — the partial ⊕-sum of all windows
fully inside the covered segment, plus the only context a future merge can
ever need: the first and last ``W-1`` samples of the segment (the halo), the
segment length, and its global start index.  The API is the classic
streaming quartet:

  * ``engine.init()``           — the neutral element;
  * ``engine.update(s, chunk)`` — absorb the next chunk of the segment
    (needs only ``h_left + h_right`` carried samples, never the series);
  * ``engine.merge(a, b)``      — combine two adjacent segments, adding the
    boundary-straddling windows from the carried halos.  Commutative: the
    operands are ordered internally by global start index;
  * ``engine.finalize(s)``      — read out the raw statistic (estimator
    front-ends apply normalization / ragged boundary corrections).

``stride`` generalizes the window walk to strided segment estimators
(Welch periodograms: windows start only at global multiples of
``nperseg - overlap``); global start indices keep strided alignment exact
across chunk boundaries and merges.

Every operation is pure jnp on fixed shapes, so a leading **batch axis over
independent series** comes for free via ``jax.vmap`` — one device pass
updates rolling statistics for thousands of series at once
(``init_batch`` / ``update_batch`` / ``merge_batch``).

Relation to the block paths: a per-shard partial built from halo-*padded*
blocks (`core.mapreduce.block_partials`) already contains every window the
shard owns, so the global merge degenerates to a plain pytree sum — on a
mesh, the single ``psum`` of `repro.parallel.sharding.psum_tree`.  The
streaming merge is the general case: it is what that psum is *allowed to
forget*, re-derived from first principles for halo-free ingestion.

Estimator front-ends live next to their batch counterparts:
`estimators.stats.lag_sum_engine` (autocovariance → Yule-Walker → ARMA) and
`estimators.spectral.welch_engine`.  Their ChunkKernels are built from
`repro.core.backend` primitives, so the same engine streams through pure
jnp or the Pallas VMEM tile kernels by passing ``backend=`` — the execution
substrate is a deployment knob, not a property of the estimator.

Because the carried partial is *never recomputed* from raw data, float
rounding in the ⊕-folds accumulates for the lifetime of a session.  The
opt-in **compensated mode** (``StreamingEngine(..., compensated=True)``)
threads a Neumaier error-companion pytree (``PartialState.stat_err``,
mirroring ``stat``) through every ``update`` / ``merge`` / donated-scan
path; ``finalize`` reads out ``stat + stat_err`` via :func:`resolved_stat`.
The carried ``stat`` itself is bit-identical to plain mode — compensation
only tracks what rounding discarded — so compensated and plain states
checkpoint/restore with their own structure and never mix in one fold.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .backend import BackendSpec, get_backend
from .integrity import tree_neumaier_add, tree_neumaier_merge
from .mapreduce import tree_sum

__all__ = ["PartialState", "StreamingEngine", "resolved_stat"]

# (window (W, d)) -> pytree contribution
WindowKernel = Callable[[jax.Array], Any]
# (y_padded (L + W - 1, d), start_mask (L,)) -> pytree: the ⊕-sum of
# k(y_padded[s : s+W]) over starts s with start_mask[s].  Whenever
# start_mask[s] is True, rows [s, s+W) hold real data.
# With ``kernel_takes_offset=True`` the kernel receives a third argument,
# z0 () int32 — the GLOBAL series index of y_padded's row 0 — so it can
# apply its own alignment rules (per-member strides in a fused plan,
# strided segment gathers) without the engine knowing about them.
ChunkKernel = Callable[..., Any]

_FAR = jnp.iinfo(jnp.int32).max


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["stat", "sample_sum", "head", "tail", "length", "t0", "stat_err"],
    meta_fields=[],
)
@dataclasses.dataclass
class PartialState:
    """Mergeable partial result of a weak-memory estimator over one segment.

    Attributes:
      stat: pytree — ⊕-sum of kernel contributions of every window fully
        inside the covered segment (strided windows only, if stride > 1).
      sample_sum: (d,) — plain sum of all covered samples (order-0
        statistic; rolling means come for free).
      head: (W-1, d) — first ``min(length, W-1)`` samples, left-aligned,
        zero elsewhere.  A future merge-from-the-left reads these.
      tail: (W-1, d) — last ``min(length, W-1)`` samples, right-aligned,
        zero elsewhere.  A future merge-from-the-right (or a ragged
        boundary correction at finalize) reads these.
      length: () int32 — number of samples covered.
      t0: () int32 — global index of the segment's first sample.  Orders
        merge operands and anchors strided window alignment.
      stat_err: Neumaier error-companion pytree mirroring ``stat``
        (compensated engines only; ``None`` — an empty pytree subtree — in
        plain mode, so plain states keep their historical structure and
        checkpoints round-trip unchanged).  Read out via
        :func:`resolved_stat`.
    """

    stat: Any
    sample_sum: jax.Array
    head: jax.Array
    tail: jax.Array
    length: jax.Array
    t0: jax.Array
    stat_err: Any = None


def resolved_stat(state: PartialState) -> Any:
    """``state.stat`` with the Neumaier error companion folded in.

    The single readout point for code that inspects a partial's statistic
    directly (engine/plan finalizers, estimator front-ends): plain states
    pass through untouched; compensated states return ``stat + stat_err``
    leaf-wise, recovering the rounding residue the ⊕-folds discarded.
    """
    if state.stat_err is None:
        return state.stat
    return jax.tree.map(lambda s, e: s + e, state.stat, state.stat_err)


class StreamingEngine:
    """init / update / merge / finalize for one weak-memory estimator.

    Args:
      d: series dimension.
      h_left, h_right: kernel window half-widths (W = h_left + 1 + h_right).
      kernel: per-window kernel (vmapped generic path).  Optional when
        ``chunk_kernel`` is given.
      chunk_kernel: fused masked-window reducer (e.g. the lagged-matmul MXU
        form for autocovariance) honouring the :data:`ChunkKernel` contract.
        Estimator front-ends build these from `repro.core.backend`
        primitives (``masked_lagged_sums`` / ``segment_fft_power``), so a
        streaming ``update`` hits the same jnp-or-Pallas tile path as the
        batch estimators.
      stride: windows start only at global indices ≡ 0 (mod stride).
      backend: compute-backend spec (name, Backend instance, or None for the
        registry default).  Recorded on the engine so finalizers
        (``streaming_autocovariance``'s ragged-tail correction) run their own
        contractions through the same substrate the updates used.
      kernel_takes_offset: the chunk kernel accepts a third argument — the
        global index of its first row — enabling per-member alignment rules
        inside one shared traversal (fused plans, strided segment gathers).
      compensated: thread a Neumaier error companion (``stat_err``) through
        every ⊕-fold so long-horizon rounding drift is recovered at
        readout.  The carried ``stat`` stays bit-identical to plain mode;
        only the extra companion leaves are new, so a compensated state has
        a different pytree structure and must not be merged with a plain
        one (the tree-structure mismatch fails loudly).

    Every traced entry point is built **once** here and cached: ``update``
    / ``merge`` stay pure (composable under an outer jit/vmap), while
    ``update_jit`` / ``merge_jit`` / ``update_batch`` / ``merge_batch`` are
    jitted programs — repeated ingest through them never re-traces.
    ``consume`` / ``consume_batch`` fold a stacked (k, c, d) chunk stack
    with one ``lax.scan`` — a single device program for the whole stream,
    no per-chunk Python dispatch, with the carried state's buffers donated.
    """

    def __init__(
        self,
        d: int,
        h_left: int = 0,
        h_right: int = 0,
        kernel: Optional[WindowKernel] = None,
        chunk_kernel: Optional[ChunkKernel] = None,
        stride: int = 1,
        backend: BackendSpec = None,
        kernel_takes_offset: bool = False,
        compensated: bool = False,
    ):
        if kernel is None and chunk_kernel is None:
            raise ValueError("need a per-window kernel or a chunk_kernel")
        if h_left < 0 or h_right < 0:
            raise ValueError("halo widths must be non-negative")
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        self.d = d
        self.h_left = h_left
        self.h_right = h_right
        self.stride = stride
        self.backend = get_backend(backend)
        self.window = h_left + 1 + h_right
        self.carry = self.window - 1  # samples of context an update keeps
        self.kernel_takes_offset = kernel_takes_offset
        self.compensated = compensated

        if chunk_kernel is None:
            if kernel_takes_offset:
                raise ValueError("kernel_takes_offset requires a chunk_kernel")
            chunk_kernel = self._vmapped_chunk_kernel(kernel)
        self.chunk_kernel = chunk_kernel
        struct_args = [
            jax.ShapeDtypeStruct((self.window, d), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.bool_),
        ]
        if kernel_takes_offset:
            struct_args.append(jax.ShapeDtypeStruct((), jnp.int32))
        self._stat_struct = jax.eval_shape(chunk_kernel, *struct_args)

        # Batched (multi-series) entry points: PartialState is a pytree of
        # arrays, so a leading series axis is just vmap.  All cached entry
        # points are traced at most once per ingest shape — drivers that
        # loop over chunks reuse the same compiled program.
        self.update_jit = jax.jit(self.update)
        # The ingest hot path's variant: the carried PartialState's buffers
        # are DONATED — XLA reuses them for the new state, so a long-running
        # append stream allocates nothing per chunk.  Only for callers that
        # own the state exclusively (`SeriesFrame.append`): any other alias
        # of the old state dies with the donation.
        self.update_donated = jax.jit(self.update, donate_argnums=0)
        self.merge_jit = jax.jit(self.merge)
        self.update_batch = jax.jit(jax.vmap(self.update))
        self.merge_batch = jax.jit(jax.vmap(self.merge))
        self.consume = jax.jit(self._consume, donate_argnums=0)
        self.consume_batch = jax.jit(self._consume_batch, donate_argnums=0)

    def _call_kernel(self, y: jax.Array, mask: jax.Array, z0: jax.Array) -> Any:
        """Invoke the chunk kernel, passing the global row-0 index when the
        kernel is offset-aware (fused plans / strided gathers)."""
        if self.kernel_takes_offset:
            return self.chunk_kernel(y, mask, jnp.asarray(z0, jnp.int32))
        return self.chunk_kernel(y, mask)

    # -- internals ---------------------------------------------------------
    def _vmapped_chunk_kernel(self, kernel: WindowKernel) -> ChunkKernel:
        w = self.window

        def ck(y_padded: jax.Array, start_mask: jax.Array) -> Any:
            starts = jnp.arange(start_mask.shape[0])
            wins = jax.vmap(
                lambda s: jax.lax.dynamic_slice_in_dim(y_padded, s, w, axis=0)
            )(starts)
            contribs = jax.vmap(kernel)(wins)

            def reduce(leaf):
                m = start_mask.reshape(start_mask.shape + (1,) * (leaf.ndim - 1))
                return jnp.sum(jnp.where(m, leaf, 0), axis=0)

            return jax.tree.map(reduce, contribs)

        return ck

    def _zeros_stat(self) -> Any:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), self._stat_struct)

    # -- monoid ------------------------------------------------------------
    def init(self, t0: int | jax.Array = 0) -> PartialState:
        """The neutral element (an empty segment starting at ``t0``)."""
        return PartialState(
            stat=self._zeros_stat(),
            sample_sum=jnp.zeros((self.d,)),
            head=jnp.zeros((self.carry, self.d)),
            tail=jnp.zeros((self.carry, self.d)),
            length=jnp.asarray(0, jnp.int32),
            t0=jnp.asarray(t0, jnp.int32),
            stat_err=self._zeros_stat() if self.compensated else None,
        )

    def from_chunk(self, chunk: jax.Array, t0: int | jax.Array = 0) -> PartialState:
        """Lift one contiguous chunk into a PartialState.

        Only windows fully inside the chunk enter ``stat``; boundary
        windows appear later, when a merge supplies the neighbour's halo.
        """
        if chunk.ndim == 1:
            chunk = chunk[:, None]
        c = chunk.shape[0]
        if c == 0:  # an empty chunk is the neutral element
            return self.init(t0)
        w, carry = self.window, self.carry
        t0 = jnp.asarray(t0, jnp.int32)

        y = jnp.concatenate([chunk, jnp.zeros((carry, self.d), chunk.dtype)])
        starts = jnp.arange(c)
        mask = starts <= c - w
        if self.stride > 1:
            mask &= (t0 + starts) % self.stride == 0
        stat = self._call_kernel(y, mask, t0)

        rows = jnp.arange(carry)
        head = jnp.where(
            (rows < c)[:, None], chunk[jnp.clip(rows, 0, c - 1)], 0.0
        )
        tidx = c - carry + rows
        tail = jnp.where(
            (tidx >= 0)[:, None], chunk[jnp.clip(tidx, 0, c - 1)], 0.0
        )
        return PartialState(
            stat=stat,
            sample_sum=jnp.sum(chunk, axis=0),
            head=head,
            tail=tail,
            length=jnp.asarray(c, jnp.int32),
            t0=t0,
            # A single chunk's kernel output has no rounding history yet —
            # its companion starts at zero.
            stat_err=self._zeros_stat() if self.compensated else None,
        )

    def update(
        self,
        state: PartialState,
        chunk: jax.Array,
        t0: Optional[jax.Array] = None,
    ) -> PartialState:
        """Absorb the next chunk of the state's segment.

        ``update(s, c) == merge(s, from_chunk(c, end-of-s))`` — the
        homomorphism property; every update exercises the merge path.
        ``t0`` (optional) seeds the global start index when ``state`` is
        still empty (e.g. a shard that starts mid-stream).
        """
        start = state.t0 + state.length
        if t0 is not None:
            start = jnp.where(state.length == 0, jnp.asarray(t0, jnp.int32), start)
        return self.merge(state, self.from_chunk(chunk, start))

    def merge(self, a: PartialState, b: PartialState) -> PartialState:
        """⊕ of two partial states covering *adjacent* segments.

        Commutative (operands are ordered by ``t0`` internally; empty
        states are neutral regardless of their ``t0``) and associative:
        the boundary-straddling windows are recovered exactly once from
        the carried halos, whatever the merge tree looks like.
        """
        carry, w = self.carry, self.window

        # Order operands by global start; empty states sort last so the
        # neutral element never claims the t0/halo of a real segment.
        key_a = jnp.where(a.length > 0, a.t0, _FAR)
        key_b = jnp.where(b.length > 0, b.t0, _FAR)
        swap = key_b < key_a
        pick = lambda x, y: jax.tree.map(
            lambda u, v: jnp.where(swap, v, u), x, y
        )
        first: PartialState = pick(a, b)
        second: PartialState = pick(b, a)

        if self.compensated:
            stat, err = tree_neumaier_merge(
                first.stat, first.stat_err, second.stat, second.stat_err
            )
        else:
            stat, err = tree_sum(first.stat, second.stat), None
        if carry > 0:
            k_first = jnp.minimum(first.length, carry)
            k_second = jnp.minimum(second.length, carry)
            # z = first's tail halo ++ second's head halo: every complete
            # window in z straddles the boundary (each side is < W wide),
            # and every straddling window lies inside z.
            z = jnp.concatenate([first.tail, second.head])
            starts = jnp.arange(carry)
            mask = (starts >= carry - k_first) & (starts + w <= carry + k_second)
            # z[carry - k_first] is the first valid row and holds global
            # sample first.t0 + first.length - k_first, so row s of z sits
            # at global index first.t0 + first.length - carry + s.
            z0 = first.t0 + first.length - carry
            if self.stride > 1:
                mask &= (z0 + starts) % self.stride == 0
            boundary = self._call_kernel(z, mask, z0)
            if self.compensated:
                stat, err = tree_neumaier_add(stat, err, boundary)
            else:
                stat = tree_sum(stat, boundary)

            rows = jnp.arange(carry)
            head = jnp.where(
                (rows < first.length)[:, None],
                first.head,
                second.head[jnp.clip(rows - first.length, 0, carry - 1)],
            )
            tail = jnp.where(
                (rows >= carry - second.length)[:, None],
                second.tail,
                first.tail[jnp.clip(rows + second.length, 0, carry - 1)],
            )
        else:
            head = first.head
            tail = first.tail

        return PartialState(
            stat=stat,
            sample_sum=first.sample_sum + second.sample_sum,
            head=head,
            tail=tail,
            length=first.length + second.length,
            t0=jnp.where(first.length > 0, first.t0, second.t0),
            stat_err=err,
        )

    def finalize(self, state: PartialState) -> Any:
        """Raw windowed statistic.  Estimator front-ends wrap this with
        normalization and (where the serial estimator is ragged at the
        series end, e.g. lag sums) a boundary correction read from
        ``state.tail``.  Compensated states fold their error companion in
        here (:func:`resolved_stat`)."""
        return resolved_stat(state)

    # -- scan-driven ingest ------------------------------------------------
    def _consume(self, state: PartialState, chunks: jax.Array) -> PartialState:
        """Fold a (k, c, d) stack of equal-length chunks into ``state`` with
        one ``lax.scan`` — a single device program for the whole stream.

        The public jitted entry point is ``self.consume`` (built in
        ``__init__`` with ``donate_argnums=0``: the carried PartialState's
        buffers are reused in place, so a long-running ingest loop allocates
        nothing per chunk).  Equivalent to ``functools.reduce(update, chunks,
        state)`` but without k Python dispatches and k host round-trips.
        """

        def step(st, chunk):
            return self.update(st, chunk), None

        state, _ = jax.lax.scan(step, state, chunks)
        return state

    def _consume_batch(self, state: PartialState, chunks: jax.Array) -> PartialState:
        """Batched scan ingest: ``chunks`` is (k, batch, c, d); the scan runs
        over the chunk axis, each step updating all series in one vmapped
        pass.  Jitted + donated as ``self.consume_batch``."""

        def step(st, chunk):
            return jax.vmap(self.update)(st, chunk), None

        state, _ = jax.lax.scan(step, state, chunks)
        return state

    # -- batching ----------------------------------------------------------
    def init_batch(self, batch: int, t0: int | jax.Array = 0) -> PartialState:
        """Neutral states for ``batch`` independent series (leading axis).

        ``t0`` may be scalar (broadcast) or a (batch,) array of per-series
        global start indices.
        """
        t0 = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (batch,))
        one = self.init()
        tiled = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (batch,) + l.shape), one
        )
        return dataclasses.replace(tiled, t0=t0)
