"""Order-(H, K) weak memory on time-series graphs (paper §9, §11).

A time-series graph is ((X_t^v)_{v∈V})_t.  An estimator has order-(H, K)
weak memory if its kernel at (t, v) reads only vertices ≤K hops away within
±H time steps.  The overlapping structure generalizes:

  * graph partition: vertices split into parts; each part replicates its
    K-hop boundary (the *graph halo*, paper Fig. 5);
  * cross-product partitioning (paper Fig. 8): (time block + H halo) ×
    (vertex part + K halo) — both axes embarrassingly parallel.

Graphs are represented TPU-style: a dense padded neighbour table
``nbrs (V, max_deg)`` with −1 padding — gathers instead of pointer chasing
(the skip-list machinery of paper §12.3 does not transfer; see DESIGN.md).

Includes the paper's running example: the order-(1,1) arterial-traffic
Dynamic Bayesian Network simulator (§11.1.1).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Graph",
    "line_graph",
    "grid_graph",
    "k_hop_neighbors",
    "GraphPartition",
    "make_graph_partition",
    "graph_window_map_reduce",
    "traffic_dbn_step",
    "simulate_traffic_dbn",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded dense adjacency: nbrs[v] lists neighbours of v, −1 = padding."""

    nbrs: np.ndarray  # (V, max_deg) int32

    @property
    def num_vertices(self) -> int:
        return self.nbrs.shape[0]


def line_graph(v: int) -> Graph:
    """A road corridor: v links in a line (the paper's arterial example)."""
    nbrs = np.full((v, 2), -1, dtype=np.int32)
    nbrs[1:, 0] = np.arange(v - 1)  # upstream
    nbrs[:-1, 1] = np.arange(1, v)  # downstream
    return Graph(nbrs)


def grid_graph(rows: int, cols: int) -> Graph:
    """4-connected grid (sensor lattice, paper Fig. 3)."""
    v = rows * cols
    nbrs = np.full((v, 4), -1, dtype=np.int32)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            cand = [(r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1)]
            k = 0
            for rr, cc in cand:
                if 0 <= rr < rows and 0 <= cc < cols:
                    nbrs[i, k] = rr * cols + cc
                    k += 1
    return Graph(nbrs)


def k_hop_neighbors(g: Graph, seeds: np.ndarray, k: int) -> np.ndarray:
    """Boolean (V,) mask of vertices within k hops of any seed (BFS)."""
    mask = np.zeros(g.num_vertices, dtype=bool)
    mask[seeds] = True
    for _ in range(k):
        cur = np.where(mask)[0]
        nb = g.nbrs[cur].reshape(-1)
        nb = nb[nb >= 0]
        mask[nb] = True
    return mask


@dataclasses.dataclass(frozen=True)
class GraphPartition:
    """Overlapping vertex partition: part i owns ``own[i]`` and replicates
    ``halo[i]`` (its K-hop boundary).  ``padded[i] = own ∪ halo`` padded to a
    common length with −1 so the parts stack into a dense array."""

    own: np.ndarray  # (P, own_size) int32
    padded: np.ndarray  # (P, padded_size) int32, −1 padding
    local_nbrs: np.ndarray  # (P, padded_size, max_deg) — neighbour slots
    #   remapped to local padded positions, −1 where the neighbour is absent


def make_graph_partition(g: Graph, num_parts: int, k: int) -> GraphPartition:
    """Contiguous vertex partition with K-hop halos (paper Fig. 5).

    Assumes vertex ids are ordered so contiguous ranges are meaningful
    (true for line/grid graphs; general graphs should be pre-ordered with a
    bandwidth-minimizing permutation — same assumption as the paper's banded
    §6 case).
    """
    v = g.num_vertices
    if v % num_parts != 0:
        raise ValueError(f"V={v} must divide into {num_parts} parts")
    size = v // num_parts
    own = np.arange(v, dtype=np.int32).reshape(num_parts, size)
    padded_sets = []
    for i in range(num_parts):
        mask = k_hop_neighbors(g, own[i], k)
        padded_sets.append(np.where(mask)[0].astype(np.int32))
    width = max(len(s) for s in padded_sets)
    padded = np.full((num_parts, width), -1, dtype=np.int32)
    for i, s in enumerate(padded_sets):
        padded[i, : len(s)] = s

    # Remap each padded vertex's neighbour list into local padded slots.
    local_nbrs = np.full((num_parts, width, g.nbrs.shape[1]), -1, dtype=np.int32)
    for i in range(num_parts):
        g2l = {int(gv): li for li, gv in enumerate(padded[i]) if gv >= 0}
        for li, gv in enumerate(padded[i]):
            if gv < 0:
                continue
            for j, nb in enumerate(g.nbrs[gv]):
                if nb >= 0 and int(nb) in g2l:
                    local_nbrs[i, li, j] = g2l[int(nb)]
    return GraphPartition(own=own, padded=padded, local_nbrs=local_nbrs)


def graph_window_map_reduce(
    kernel: Callable[[jax.Array, jax.Array], jax.Array],
    x: jax.Array,
    g: Graph,
    part: GraphPartition,
) -> jax.Array:
    """Σ_v kernel(x[v], x[neighbours(v)]) computed part-parallel.

    kernel: (d,), (max_deg, d), (max_deg,) mask → pytree contribution.
    Each part evaluates only its OWN vertices, reading halo data locally —
    zero inter-part communication; equality with the serial evaluation is
    property-tested.
    """
    padded_x = jnp.where(
        (part.padded >= 0)[..., None],
        x[jnp.clip(part.padded, 0, g.num_vertices - 1)],
        0.0,
    )  # (P, W, d)

    own_local = []
    for i in range(part.own.shape[0]):
        g2l = {int(gv): li for li, gv in enumerate(part.padded[i]) if gv >= 0}
        own_local.append([g2l[int(v)] for v in part.own[i]])
    own_local = jnp.asarray(np.array(own_local, dtype=np.int32))

    local_nbrs = jnp.asarray(part.local_nbrs)

    def per_part(xp, own_idx, lnbrs):
        def per_vertex(li):
            nb_idx = lnbrs[li]
            nb_mask = nb_idx >= 0
            nb = jnp.where(nb_mask[:, None], xp[jnp.clip(nb_idx, 0, xp.shape[0] - 1)], 0.0)
            return kernel(xp[li], nb, nb_mask)

        contribs = jax.vmap(per_vertex)(own_idx)
        return jax.tree.map(lambda l: jnp.sum(l, axis=0), contribs)

    partials = jax.vmap(per_part)(padded_x, own_local, local_nbrs)
    return jax.tree.map(lambda l: jnp.sum(l, axis=0), partials)


def traffic_dbn_step(
    x: jax.Array,
    nbrs: jax.Array,
    inflow: jax.Array,
    capacity: float = 1.0,
    send_rate: float = 0.3,
) -> jax.Array:
    """One step of the order-(1,1) arterial-traffic DBN (paper §11.1.1).

    Vehicles leave each link at ``send_rate`` (bounded by downstream spare
    capacity) and arrive from upstream; ``inflow`` models boundary demand.
    Pure function of the 1-hop neighbourhood → runs under the cross-product
    overlapping partitioning.
    """
    v = x.shape[0]
    up = nbrs[:, 0]
    down = nbrs[:, 1]
    has_down = down >= 0
    has_up = up >= 0
    down_occ = jnp.where(has_down, x[jnp.clip(down, 0, v - 1)], 0.0)
    spare = jnp.maximum(capacity - down_occ, 0.0)
    out = jnp.minimum(send_rate * x, spare) * has_down
    inn = jnp.where(has_up, out[jnp.clip(up, 0, v - 1)], 0.0)
    return jnp.clip(x - out + inn + inflow, 0.0, capacity)


def simulate_traffic_dbn(
    g: Graph,
    x0: jax.Array,
    steps: int,
    key: jax.Array,
    inflow_scale: float = 0.05,
) -> jax.Array:
    """(steps+1, V) trajectory of the traffic DBN with random boundary demand."""
    nbrs = jnp.asarray(g.nbrs)

    def body(carry, k):
        x = carry
        inflow = inflow_scale * jax.random.uniform(k, x.shape) * (nbrs[:, 0] < 0)
        nxt = traffic_dbn_step(x, nbrs, inflow)
        return nxt, nxt

    keys = jax.random.split(key, steps)
    _, traj = jax.lax.scan(body, x0, keys)
    return jnp.concatenate([x0[None], traj], axis=0)
