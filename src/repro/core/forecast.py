"""Forecast subsystem: served predictions + anomaly scores on the fused-plan
lag state (paper §4; periodicity-seeded models after arXiv 1810.07776).

The paper's §4 point is that *prediction* is itself a weak-memory
computation: AR/ARMA forecasting needs only the last max(p, q)
observations/innovations, so it composes with the same
fragment-and-replicate scheme as estimation.  This module makes that
end-to-end: a :func:`forecast_request` (and :func:`anomaly_request`) joins
the deferred-request surface of `repro.core.plan.StatPlan` as a lag-family
member, and its finalizer reuses the plan's carried state twice over —

  * the **shared lagged-sum entry** (tail-corrected by
    ``_PlanGroup._corrected_gamma_sums``) yields the model fit:
    Yule-Walker for ``model="ar"``, innovations + block-Hankel
    (`estimators.arma.fit_arma`) for ``model="arma"``, and a
    restricted-lag Yule-Walker solve (:func:`fit_seasonal_ar`) for
    ``model="auto"``;
  * the **carried tail halo** (the last ``W_fused − 1`` samples the
    engine already retains) is exactly the history the recurrence needs —
    forecasting reads no data beyond what estimation already carries.

Multi-horizon predictions come from :func:`lagged_forecast`, a
``lax.scan`` over the model's companion-matrix recurrence (the scan state
IS the companion vector [X_t, …, X_{t−L+1}]; one step multiplies by the
companion matrix written in its lag-block form).  Everything here is
trace-safe: `FrameSession._finalize_batch` vmaps these finalizers across
tenants into ONE jitted program, which is how `StatsGateway` serves
forecasts coalesced per tick.

``model="auto"`` (arXiv 1810.07776): the plan must also carry a Welch
member; :func:`detect_period` reads the dominant non-DC bin of the
finalized spectrum and the fit augments the short-lag AR structure with
one seasonal lag at the detected period — per tenant independently.  On
the single-frame path (`SeriesFrame.collect`, `FrameSession.query`) the
finalize runs eagerly, so the selection happens host-side from the
finalized spectrum; under ``query_batch`` the same selection traces into
the one vmapped program (the period is data, not structure, so N tenants
with N different periods still share a single compiled recurrence).

Anomaly scoring rides the same fit: the steady-state innovations filter
(`estimators.prediction.arma_innovations_filter`) runs over the carried
tail against the fitted model, and residuals are standardized by the
innovation covariance from the innovation recursion (V_m — what
``fit_arma`` returns; the Yule-Walker Σ for the AR models).  The first
max(p, q) scored positions carry the filter's zero-init transient (the
paper notes it decays exponentially for causal+invertible models).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .estimators.prediction import arma_innovations_filter

__all__ = [
    "forecast_request",
    "anomaly_request",
    "ModelSpec",
    "resolve_model_spec",
    "detect_period",
    "fit_seasonal_ar",
    "lagged_forecast",
    "standardized_innovations",
    "make_forecast_finalizer",
    "make_anomaly_finalizer",
]

MODELS = ("ar", "arma", "auto")
DEFAULT_MAX_PERIOD = 32
# Absolute ridge on the innovation-recursion V_k solves in the arma fit:
# keeps a batched finalize finite for degenerate (near-empty) tenants
# without measurably moving coefficients fitted from real data.
ARMA_RIDGE = 1e-8


# ---------------------------------------------------------------- requests
def forecast_request(
    horizon: int,
    model: str = "ar",
    p: int = 4,
    q: int = 1,
    m: Optional[int] = None,
    max_period: Optional[int] = None,
    name: Optional[str] = None,
):
    """Multi-horizon forecast from the plan's carried lag state.

    Finalizes to ``{"pred": (horizon, d), "sigma": (d, d)}`` (plus
    ``"period"`` for ``model="auto"``).

    Args:
      horizon: number of steps ahead (≥ 1).
      model: ``"ar"`` (Yule-Walker order-p), ``"arma"`` (innovations-fit
        ARMA(p, q)), or ``"auto"`` (short-lag AR of order p plus one
        seasonal lag at the detected period; the plan must also carry a
        Welch member).
      p / q / m: model orders; ``m`` is the arma innovation-recursion
        depth (default ``p + q``), ignored otherwise.
      max_period: auto only — the largest detectable seasonal lag (sets
        the member's window, default ``32``).
    """
    from .plan import StatRequest

    spec = resolve_model_spec(model, p, q, m, max_period)  # validates
    if horizon < 1:
        raise ValueError(f"forecast horizon must be >= 1, got {horizon}")
    del spec
    return StatRequest(
        "forecast", name, (int(horizon), model, int(p), int(q), m, max_period)
    )


def anomaly_request(
    model: str = "ar",
    p: int = 4,
    q: int = 1,
    m: Optional[int] = None,
    max_period: Optional[int] = None,
    name: Optional[str] = None,
):
    """Standardized innovation residuals over the carried tail window.

    Finalizes to ``{"z": (W−1, d), "score": (W−1,), "valid": (W−1,),
    "sigma": (d, d)}``: ``z`` is the per-dimension standardized innovation,
    ``score`` the Mahalanobis norm under the fitted innovation covariance,
    ``valid`` masks the right-aligned rows actually covered by ingested
    samples.  Model selection matches :func:`forecast_request`.
    """
    from .plan import StatRequest

    resolve_model_spec(model, p, q, m, max_period)  # validates
    return StatRequest("anomaly", name, (model, int(p), int(q), m, max_period))


# ---------------------------------------------------------------- model spec
@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Resolved static structure of one forecast/anomaly member."""

    model: str
    p: int
    q: int
    m: int          # arma innovation-recursion depth (0 otherwise)
    lag_span: int   # largest lag the member reads → member window − 1

    @property
    def needs_welch(self) -> bool:
        return self.model == "auto"


def resolve_model_spec(
    model: str,
    p: int,
    q: int,
    m: Optional[int] = None,
    max_period: Optional[int] = None,
) -> ModelSpec:
    """Validate orders and resolve the member's static lag span."""
    if model not in MODELS:
        raise ValueError(f"model must be one of {MODELS}, got {model!r}")
    if p < 1:
        raise ValueError(f"need p >= 1, got p={p}")
    if q < 0:
        raise ValueError(f"need q >= 0, got q={q}")
    if model == "arma":
        depth = max(m if m is not None else p + q, p + q)
        return ModelSpec(model, p, q, depth, depth)
    if model == "auto":
        span = DEFAULT_MAX_PERIOD if max_period is None else int(max_period)
        # the seasonal lag lives in (p, span]; p short lags + 1 seasonal
        if span < p + 1:
            raise ValueError(
                f"max_period={span} leaves no room for a seasonal lag "
                f"beyond the p={p} short lags; need max_period >= {p + 1}"
            )
        return ModelSpec(model, p, 0, 0, span)
    return ModelSpec(model, p, 0, 0, p)  # "ar"


# ------------------------------------------------------------- periodicity
def detect_period(
    psd: jax.Array, nperseg: int, min_period: int, max_period: int
) -> jax.Array:
    """Dominant period from a finalized one-sided PSD (arXiv 1810.07776).

    Picks the non-DC bin with the largest total power (summed over
    dimensions), converts bin k → period ``nperseg / k``, and clips into
    ``[min_period, max_period]``.  Pure jnp — runs eagerly (host-side) on
    the per-frame path and traces under the vmapped batch finalize, where
    each tenant's period is data, not program structure.
    """
    power = jnp.sum(jnp.asarray(psd), axis=-1)
    power = power.at[0].set(-jnp.inf)  # DC is trend, not seasonality
    k = jnp.maximum(jnp.argmax(power), 1)
    period = jnp.round(nperseg / k).astype(jnp.int32)
    return jnp.clip(period, min_period, max_period)


# ------------------------------------------------------------ seasonal fit
def fit_seasonal_ar(
    gamma: jax.Array, lags: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Yule-Walker restricted to an arbitrary lag set (traced lags OK).

    Fits ``X_t = Σ_a A_a X_{t−ℓ_a} + ε_t`` by orthogonality against the
    regressors: ``γ(ℓ_b) = Σ_a γ(ℓ_b − ℓ_a)ᵀ A_aᵀ`` stacked over b.  With
    ``lags == 1..p`` this is exactly `estimators.yule_walker.yule_walker`
    (same block-Toeplitz system); distinct non-contiguous lags (the
    seasonal structure of ``model="auto"``) just gather different γ̂
    blocks.  The lag values may be traced ints — the system's *shape* is
    static in ``len(lags)``, which is what lets N tenants with N detected
    periods share one vmapped program.

    Args:
      gamma: (≥max(lags)+1, d, d) stacked autocovariances.
      lags: (r,) distinct positive lags.

    Returns: A (r, d, d) aligned with ``lags``, sigma (d, d).
    """
    lags = jnp.asarray(lags, jnp.int32)
    r = lags.shape[0]
    d = gamma.shape[1]
    H = lags[:, None] - lags[None, :]                       # ℓ_b − ℓ_a
    G = jnp.take(gamma, jnp.abs(H), axis=0)                 # (r, r, d, d)
    G = jnp.where((H >= 0)[..., None, None], G, jnp.swapaxes(G, -1, -2))
    M = G.transpose(0, 2, 1, 3).reshape(r * d, r * d)
    Gl = jnp.take(gamma, lags, axis=0)                      # γ(ℓ_a)
    sol = jnp.linalg.solve(M, Gl.reshape(r * d, d))         # stacked A_aᵀ
    A = jnp.swapaxes(sol.reshape(r, d, d), -1, -2)
    sigma = gamma[0] - jnp.einsum("aij,ajk->ik", A, Gl)
    return A, sigma


# ------------------------------------------------------------- recurrence
def lagged_forecast(
    Phi: jax.Array, Theta: jax.Array, xlag: jax.Array, elag: jax.Array,
    steps: int,
) -> jax.Array:
    """Multi-horizon prediction via the companion-matrix recurrence.

    One ``lax.scan`` step multiplies the companion vector
    ``[X̂_t, …, X̂_{t−L+1}]`` by the companion matrix written in lag-block
    form (top row = the Φ blocks, subdiagonal = identity shifts) — with
    future innovations at their mean (zero), so the MA contribution fades
    after q steps.  With ``Phi == A`` (L == p) this is bit-identical to
    `estimators.prediction.ar_forecast` / ``arma_forecast``'s iteration;
    dense zero-padded Φ rows add exact zeros, so padded layouts (the
    fused-plan members) stay on the oracle's numbers.

    Args:
      Phi: (L, d, d) lag coefficients, Φ_l at index l−1 (zeros elsewhere).
      Theta: (q, d, d) innovation coefficients.
      xlag: (L, d) observations newest-first.
      elag: (q, d) innovations newest-first.
      steps: forecast horizon.

    Returns: (steps, d) predictions X̂_{t+1..t+steps}.
    """
    d = Phi.shape[1]
    q = Theta.shape[0]

    def body(carry, _):
        xlag, elag = carry
        pred = jnp.einsum("lij,lj->i", Phi, xlag)
        if q > 0:
            pred = pred + jnp.einsum("qij,qj->i", Theta, elag)
        if Phi.shape[0] > 0:
            xlag = jnp.concatenate([pred[None], xlag[:-1]], axis=0)
        if q > 0:
            elag = jnp.concatenate([jnp.zeros((1, d)), elag[:-1]], axis=0)
        return (xlag, elag), pred

    _, preds = jax.lax.scan(body, (xlag, elag), None, length=steps)
    return preds


def standardized_innovations(
    Phi: jax.Array, Theta: jax.Array, x: jax.Array, sigma: jax.Array,
    eps: float = 1e-9,
) -> Tuple[jax.Array, jax.Array]:
    """Innovation residuals of ``x`` under the fitted model, standardized.

    Runs the steady-state innovations filter (zero init) and scales by the
    innovation covariance from the innovation recursion: ``z`` divides each
    dimension by its innovation standard deviation, ``score`` is the
    Mahalanobis norm ``√(ε̂ᵀ Σ⁻¹ ε̂)`` (a χ_d-distributed magnitude under
    the model, so one thresholdable scalar per sample).

    Returns: z (T, d), score (T,).
    """
    _, innov = arma_innovations_filter(Phi, Theta, x)
    d = sigma.shape[0]
    var = jnp.clip(jnp.diagonal(sigma), eps, None)
    z = innov / jnp.sqrt(var)[None, :]
    w = jnp.linalg.solve(sigma + eps * jnp.eye(d), innov.T).T
    score = jnp.sqrt(jnp.clip(jnp.sum(innov * w, axis=-1), 0.0))
    return z, score


# -------------------------------------------------------- plan finalizers
def _fitted_model(group, state, spec: ModelSpec):
    """(Phi dense (lag_span, d, d), Theta (q, d, d), sigma, period|None)
    from the plan group's tail-corrected lag sums."""
    from .estimators.stats import gamma_normalizer

    s = group._corrected_gamma_sums(state, spec.lag_span)
    norm = gamma_normalizer(state.length, spec.lag_span, "standard")
    gamma = s * norm[:, None, None]
    d = group.d
    L = spec.lag_span
    period = None
    if spec.model == "ar":
        from .estimators.yule_walker import yule_walker

        A, sigma = yule_walker(gamma, spec.p)
        Phi, Theta = A, jnp.zeros((0, d, d))
    elif spec.model == "arma":
        from .estimators.arma import fit_arma

        A, B, sigma = fit_arma(gamma, spec.p, spec.q, spec.m, ridge=ARMA_RIDGE)
        Phi = jnp.zeros((L, d, d)).at[: spec.p].set(A)
        Theta = B
    else:  # auto: short lags 1..p plus one seasonal lag at the period
        info = group._welch_info[0]
        welch_member = next(
            mem for mem in group.members if mem.name == info.name
        )
        _, psd = welch_member.finalize(state)
        period = detect_period(psd, info.nperseg, spec.p + 1, L)
        lags = jnp.concatenate(
            [jnp.arange(1, spec.p + 1, dtype=jnp.int32), period[None]]
        )
        A, sigma = fit_seasonal_ar(gamma, lags)
        Phi = jnp.zeros((L, d, d)).at[lags - 1].set(A)
        Theta = jnp.zeros((0, d, d))
    return Phi, Theta, sigma, period


def make_forecast_finalizer(group, horizon: int, spec: ModelSpec):
    """Finalizer for one forecast member of a `_PlanGroup`.

    Fits the model from the shared lagged entry, seeds the companion
    recurrence from the carried tail halo (for arma, innovations come from
    filtering that same tail — the weak-memory window, zero-init as in
    paper §4.2), and scans out ``horizon`` predictions.  Trace-safe: this
    is what `FrameSession._finalize_batch` vmaps across tenants.
    """

    def fin(state):
        Phi, Theta, sigma, period = _fitted_model(group, state, spec)
        d = group.d
        L = spec.lag_span
        xlag = state.tail[-1 : -L - 1 : -1]          # newest first
        if spec.q > 0:
            _, innov = arma_innovations_filter(Phi, Theta, state.tail)
            elag = innov[-1 : -spec.q - 1 : -1]
        else:
            elag = jnp.zeros((0, d))
        out = {
            "pred": lagged_forecast(Phi, Theta, xlag, elag, horizon),
            "sigma": sigma,
        }
        if period is not None:
            out["period"] = period
        return out

    return fin


def make_anomaly_finalizer(group, spec: ModelSpec):
    """Finalizer for one anomaly member: standardized innovations over the
    carried tail, with a validity mask for the right-aligned zero-fill
    (rows older than the series, or beyond the retained horizon in
    eviction mode, score zero and are flagged invalid)."""

    def fin(state):
        Phi, Theta, sigma, period = _fitted_model(group, state, spec)
        tail = state.tail
        carry = tail.shape[0]
        z, score = standardized_innovations(Phi, Theta, tail, sigma)
        rows = jnp.arange(carry)
        valid = rows >= carry - jnp.minimum(state.length, carry)
        out = {
            "z": jnp.where(valid[:, None], z, 0.0),
            "score": jnp.where(valid, score, 0.0),
            "valid": valid,
            "sigma": sigma,
        }
        if period is not None:
            out["period"] = period
        return out

    return fin
