"""SeriesFrame — the lazy, placement-aware front door to every read path.

The paper's algebra says every weak-memory statistic is one computational
pattern: map a short-window kernel, ⊕-reduce the partials over an
overlapping distributed structure.  The repo grew four public spellings of
that pattern — raw estimator calls, `plan.analyze`, `StreamingEstimator`,
`RollingStatsService` — each forcing the caller to pick a traversal
strategy by hand.  This module is the single front door that removes the
choice:

  * a :class:`SeriesFrame` holds data **placement** (a materialized array,
    a stream of chunks, or mesh-placed overlapping shards) plus a set of
    **deferred estimator requests**.  ``.autocovariance(h)``,
    ``.yule_walker(p)``, ``.arma(p, q)``, ``.moments(w)``, ``.welch(...)``
    and ``.map_reduce(kernel, ...)`` each return a :class:`Deferred` handle
    and read nothing;
  * ``.collect()`` compiles everything pending into ONE fused
    `repro.core.plan.StatPlan` and picks the execution strategy **from the
    placement**: a monolithic jitted traversal for arrays, a
    ``consume``-style ``lax.scan`` over equal-length chunk stacks for
    streams, and halo-complete per-shard partials reduced with the single
    psum of `repro.parallel.sharding.psum_tree` for mesh-placed frames.
    However many requests are pending, the series is walked once;
  * results are **memoized**: a second ``.collect()`` (or
    ``Deferred.result()``) with no ingest in between reads the cache —
    zero traversals, zero primitive calls;
  * ``.append(chunk)`` invalidates the memo and folds the new samples into
    the carried fused `PartialState` — the weak-memory ⊕, so re-collecting
    after an append costs one walk of the *new* samples only.  History is
    never re-read.

Placement-aware laziness goes one level deeper for ``from_sharded``: when
built from a raw series, the overlapping blocks are not placed until the
first ``.collect()`` — by which point the fused plan knows the widest
member window, so the replicated halo is sized exactly (``W_fused − 1``)
instead of guessed.

:class:`FrameSession` is the multi-tenant variant (the ROADMAP
"multi-tenant plan serving" item): the same deferred-request surface, but
the carried state is one stacked per-user fused-plan state inside
`repro.serving.rolling.RollingStatsService` — ingest is a single donated
scatter program shared by every user, queries gather + ⊕-fold + finalize.
``window=`` turns on the sliding-window eviction mode (a ring of
window-aligned sub-states; see `RollingStatsService`), so served
statistics cover only the retained horizon.

`plan.analyze` and `repro.timeseries.StreamingEstimator` are thin shims
over this module — there is exactly one query path to maintain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .backend import BackendSpec, get_backend
from .plan import (
    StatPlan,
    StatRequest,
    anomaly_request,
    arma_request,
    autocovariance_request,
    forecast_request,
    kernel_request,
    moments_request,
    welch_request,
    yule_walker_request,
)
from .streaming import PartialState, StreamingEngine

__all__ = ["SeriesFrame", "FrameSession", "Deferred"]


@dataclasses.dataclass(frozen=True, eq=False)
class Deferred:
    """Handle to one pending request of a frame.

    ``result()`` triggers the frame's (memoized) ``collect()`` and returns
    this request's entry — so touching N handles still costs one traversal.
    """

    frame: "SeriesFrame"
    name: str

    def result(self) -> Any:
        return self.frame.collect()[self.name]


class _DeferredRequests:
    """The deferred-request surface shared by SeriesFrame and FrameSession.

    Subclasses implement ``_defer(request) -> handle``; every method below
    records one `repro.core.plan.StatRequest` and reads no data.
    """

    def _defer(self, req: StatRequest):
        raise NotImplementedError

    def _unique_name(self, base: str) -> str:
        counts = self._name_counts
        counts[base] = counts.get(base, 0) + 1
        return base if counts[base] == 1 else f"{base}_{counts[base]}"

    def autocovariance(self, max_lag: int, normalization: str = "paper",
                       name: Optional[str] = None):
        """Defer γ̂(0..max_lag) — shares the plan's lagged-sum entry."""
        return self._defer(autocovariance_request(max_lag, normalization, name))

    def yule_walker(self, p: int, normalization: str = "standard",
                    name: Optional[str] = None):
        """Defer an order-p AR fit (A, Σ)."""
        return self._defer(yule_walker_request(p, normalization, name))

    def arma(self, p: int, q: int, m: Optional[int] = None,
             name: Optional[str] = None):
        """Defer an ARMA(p, q) fit (A, B, Σ)."""
        return self._defer(arma_request(p, q, m, name))

    def moments(self, window: int, name: Optional[str] = None):
        """Defer aggregate windowed moments ({"mean", "var", "count"}).

        Distinct windows across several ``moments`` calls still ride ONE
        traversal: the backend's multi-window ``fused_lagged_moments``
        accumulates every window from the same resident tile.
        """
        return self._defer(moments_request(window, name))

    def welch(self, nperseg: int = 256, overlap: Optional[int] = None,
              fs: float = 1.0, name: Optional[str] = None):
        """Defer a Welch PSD (freqs, psd)."""
        return self._defer(welch_request(nperseg, overlap, fs, name))

    def forecast(self, horizon: int, model: str = "ar", p: int = 4,
                 q: int = 1, m: Optional[int] = None,
                 max_period: Optional[int] = None,
                 name: Optional[str] = None):
        """Defer a multi-horizon forecast served from the plan's carried
        lag state: ``{"pred": (horizon, d), "sigma": (d, d)}`` (plus
        ``"period"`` when ``model="auto"``, which also needs a deferred
        ``.welch(...)`` member for periodicity detection).  See
        `repro.core.forecast.forecast_request`."""
        return self._defer(
            forecast_request(horizon, model, p, q, m, max_period, name)
        )

    def anomaly_scores(self, model: str = "ar", p: int = 4, q: int = 1,
                       m: Optional[int] = None,
                       max_period: Optional[int] = None,
                       name: Optional[str] = None):
        """Defer standardized innovation residuals over the carried tail
        window (per-dim ``z`` and a Mahalanobis ``score``, with a validity
        mask).  See `repro.core.forecast.anomaly_request`."""
        return self._defer(anomaly_request(model, p, q, m, max_period, name))

    def map_reduce(self, chunk_kernel: Callable, h_right: int, h_left: int = 0,
                   stride: int = 1, takes_offset: bool = False,
                   finalizer: Optional[Callable] = None,
                   name: str = "map_reduce"):
        """Defer a generic weak-memory member (any `ChunkKernel`); see
        `repro.core.plan.kernel_request` for the kernel/finalizer contract."""
        return self._defer(
            kernel_request(name, chunk_kernel, h_right, h_left, stride,
                           takes_offset, finalizer)
        )


class SeriesFrame(_DeferredRequests):
    """Lazy dataframe-style session over one series: defer, collect, append.

    Build with :meth:`from_array`, :meth:`from_chunks`, :meth:`from_sharded`
    (or :meth:`from_engine` for the raw-engine mode `StreamingEstimator`
    wraps).  See the module docstring for the execution model.
    """

    def __init__(self, placement: str, d: Optional[int], backend: BackendSpec):
        self._placement = placement
        self._d = d
        self._backend = get_backend(backend)
        # deferred requests (names already deduped) not yet / already compiled
        self._recorded: list[StatRequest] = []
        self._name_counts: dict[str, int] = {}
        self._new_requests = False
        # compiled query state
        self._plan: Optional[StatPlan] = None
        self._states: Optional[tuple] = None
        self._results: Optional[dict] = None
        # placement payloads
        self._x: Optional[jax.Array] = None          # array placement
        self._chunk_source = None                    # chunks: undrained source
        self._chunk_list: Optional[list] = None      # chunks: drained, pre-ingest
        self._store = None                           # sharded: TimeSeriesStore
        self._mesh: Optional[Mesh] = None
        self._axis = "data"
        self._block_size = 8192
        self._store_owned = False                    # frame built the store
        self._appended: list = []                    # array appends (lazy concat)
        self._pending: list = []                     # sharded appends (replay)
        self._replayable = True
        self._n = 0

    # ------------------------------------------------------------ builders
    @classmethod
    def from_array(cls, x: jax.Array, backend: BackendSpec = None) -> "SeriesFrame":
        """Frame over a fully materialized (n,) or (n, d) series.

        Collect strategy: ONE monolithic jitted traversal.  The array is
        retained, so adding new requests after a collect replans (one fresh
        traversal serving everything) instead of failing.
        """
        x = _as_2d(jnp.asarray(x))
        frame = cls("array", x.shape[1], backend)
        frame._x = x
        frame._n = x.shape[0]
        return frame

    @classmethod
    def from_chunks(
        cls,
        chunks,
        backend: BackendSpec = None,
        chunk_size: int = 4096,
    ) -> "SeriesFrame":
        """Frame over a stream of time-ordered chunks.

        ``chunks`` is any iterable of (c, d) arrays — or a
        `repro.timeseries.TimeSeriesStore`, streamed via
        ``iter_chunks(chunk_size)``.  Nothing is read until ``collect()``,
        which folds equal-length runs with the scan-driven ``consume``
        ingest (one ``lax.scan`` program, donated carry) and then discards
        the raw chunks — the weak-memory placement.  Consequently new
        requests after the first collect raise: declare everything up
        front, or use :meth:`from_array`.
        """
        frame = cls("chunks", None, backend)
        frame._chunk_source = (chunks, chunk_size)
        return frame

    @classmethod
    def from_sharded(
        cls,
        data,
        mesh: Optional[Mesh] = None,
        axis: str = "data",
        block_size: int = 8192,
        backend: BackendSpec = None,
    ) -> "SeriesFrame":
        """Frame over mesh-placed overlapping shards (paper §10).

        ``data`` is a raw series — placed lazily at the first ``collect()``,
        when the compiled plan knows the widest member window, so the
        replicated halo is sized exactly ``W_fused − 1`` — or an existing
        `TimeSeriesStore` (``h_left`` must be 0 and ``h_right`` must cover
        the plan's widest window).  Collect strategy: per-shard
        halo-complete partials, reduced with the single psum of
        `repro.parallel.sharding.psum_tree`; the raw data never moves.
        """
        frame = cls("sharded", None, backend)
        if hasattr(data, "spec") and hasattr(data, "blocks"):  # TimeSeriesStore
            frame._store = data
            frame._d = data.blocks.shape[-1]
            frame._n = data.spec.n
            frame._mesh = data.mesh
            frame._axis = data.axis
        else:
            x = _as_2d(jnp.asarray(data))
            frame._x = x
            frame._d = x.shape[1]
            frame._n = x.shape[0]
            frame._mesh = mesh
            frame._axis = axis
            frame._block_size = block_size
        return frame

    @classmethod
    def from_engine(
        cls,
        engine: StreamingEngine,
        batch: Optional[int] = None,
        t0: int | jax.Array = 0,
    ) -> "SeriesFrame":
        """Raw-engine mode: the frame carries ONE engine's PartialState and
        only provides the ingest machinery (update / scan consume / merge)
        plus :meth:`finalize_with`.  This is the state-keeping core
        `repro.timeseries.StreamingEstimator` is a shim over; request-mode
        frames compile their fused plan onto the same machinery.
        """
        frame = cls("engine", engine.d, engine.backend)
        frame._engine = engine
        frame._batch = batch
        if batch is None:
            frame._e_state = engine.init(t0)
            frame._e_update = engine.update_jit
            frame._e_merge = engine.merge_jit
            frame._e_consume = engine.consume
        else:
            frame._e_state = engine.init_batch(batch, t0)
            frame._e_update = engine.update_batch
            frame._e_merge = engine.merge_batch
            frame._e_consume = engine.consume_batch
        return frame

    # ------------------------------------------------------- request intake
    def _defer(self, req: StatRequest) -> Deferred:
        if self._placement == "engine":
            raise ValueError(
                "engine-mode frames carry a raw StreamingEngine state; "
                "deferred estimator requests need a data-placement frame "
                "(from_array / from_chunks / from_sharded)"
            )
        if not isinstance(req, StatRequest):
            raise TypeError(
                f"requests must be StatRequest (see the *_request factories), "
                f"got {type(req).__name__}"
            )
        name = self._unique_name(req.name or req.default_name())
        self._recorded.append(dataclasses.replace(req, name=name))
        self._new_requests = True
        return Deferred(self, name)

    # -------------------------------------------------------------- collect
    def collect(self) -> dict:
        """Run (or read back) every deferred request: ``{name: result}``.

        First call compiles ONE fused plan and traverses the data once with
        the placement's strategy; repeated calls with no ingest in between
        return the memoized results without touching the data.
        """
        if self._placement == "engine":
            raise ValueError("engine-mode frames finalize with finalize_with()")
        if not self._recorded:
            raise ValueError(
                "nothing to collect — defer at least one request first "
                "(.autocovariance / .yule_walker / .arma / .moments / "
                ".welch / .map_reduce)"
            )
        if self._plan is not None and not self._new_requests:
            if self._results is None:
                self._results = self._plan.finalize(self._states)
            return dict(self._results)

        if self._plan is not None and not self._replayable:
            raise ValueError(
                "new requests after the first collect need the history, but "
                "this placement discarded it (weak memory); declare every "
                "request before collecting, or build with from_array"
            )
        plan = StatPlan(list(self._recorded), d=self._require_d(),
                        backend=self._backend)
        self._states = self._traverse(plan)
        self._plan = plan
        self._new_requests = False
        self._results = plan.finalize(self._states)
        return dict(self._results)

    @property
    def num_traversals(self) -> int:
        """Traversal groups one evaluation costs (1 unless non-offset-aware
        strided generic kernels force grouped sub-plans)."""
        if self._plan is None:
            plan = StatPlan(list(self._recorded), d=self._require_d(),
                            backend=self._backend)
            return plan.num_traversals
        return self._plan.num_traversals

    # --------------------------------------------------------------- append
    def append(self, chunk: jax.Array) -> "SeriesFrame":
        """Absorb new samples at the end of the series.

        Invalidates the memoized results; if a plan is already compiled the
        chunk folds into the carried fused `PartialState` with the
        weak-memory ⊕ — history is never re-read, so a following
        ``collect()`` costs one walk of these samples only.  The fold runs
        through the engines' cached *donated* jitted updates: the carried
        states' buffers are reused in place, so a steady append stream of
        same-shape chunks re-traces nothing and allocates nothing per
        chunk, with zero device→host copies on the whole path.
        """
        if self._placement == "engine":
            self._e_state = self._e_update(self._e_state, chunk)
            return self
        chunk = _as_2d(jnp.asarray(chunk))
        if self._d is not None and chunk.shape[1] != self._d:
            raise ValueError(f"chunk has d={chunk.shape[1]}, frame has d={self._d}")
        self._results = None
        if self._placement == "array":
            # buffered, not concatenated: an O(history) copy per append
            # would defeat the incremental fold.  The buffer is only
            # materialized if a replan (new requests) re-reads the series.
            self._appended.append(chunk)
        elif self._placement == "chunks":
            if self._plan is None:
                self._tail_chunks().append(chunk)
        elif self._can_scatter_append():
            # sharded with an owned single-host store: the chunk scatters
            # INTO the device store (one donated scatter program), so
            # replans re-read a complete series — no host-side replay list.
            self._store.append_rows(chunk)
        else:  # sharded pre-plan / mesh / user store: retained for replans
            self._pending.append(chunk)
        if self._plan is not None:
            self._states = self._plan.update_donated(self._states, chunk)
        self._n += chunk.shape[0]
        return self

    def _can_scatter_append(self) -> bool:
        """Sharded appends scatter into the store when the frame owns a
        single-host replicate-mode store with causal halos — the
        `TimeSeriesStore.append_rows` contract.  Mesh-placed or caller-owned
        stores keep the host-side pending list (a growth step there would
        reshard or mutate shared state)."""
        return (
            self._store is not None
            and self._store_owned
            and self._store.mesh is None
            and self._store.halo_mode == "replicate"
            and self._store.spec.h_left == 0
        )

    @property
    def length(self) -> int | jax.Array:
        """Samples ingested so far (engine mode: per the carried state)."""
        if self._placement == "engine":
            return self._e_state.length
        return self._n

    @property
    def backend(self):
        """The compute backend every traversal runs through."""
        if self._placement == "engine":
            return self._engine.backend
        return self._backend

    # ----------------------------------------------------- engine-mode API
    @property
    def state(self) -> PartialState:
        """The carried PartialState (engine mode)."""
        self._require_engine()
        return self._e_state

    @state.setter
    def state(self, value: PartialState) -> None:
        self._require_engine()
        self._e_state = value

    def consume(self, chunk_stack: jax.Array) -> "SeriesFrame":
        """Scan-driven ingest of an equal-length chunk stack (engine mode):
        one ``lax.scan`` program, carried state donated."""
        self._require_engine()
        self._e_state = self._e_consume(self._e_state, chunk_stack)
        return self

    def merge_state(self, other: PartialState) -> "SeriesFrame":
        """⊕ a peer's PartialState into this frame's (engine mode)."""
        self._require_engine()
        self._e_state = self._e_merge(self._e_state, other)
        return self

    def finalize_with(self, finalizer: Callable, *args, **kwargs) -> Any:
        """Apply an estimator front-end ``finalizer(engine, state, ...)`` to
        the carried state (engine mode); vmapped over the batch axis."""
        self._require_engine()
        if self._batch is None:
            return finalizer(self._engine, self._e_state, *args, **kwargs)
        return jax.vmap(
            lambda s: finalizer(self._engine, s, *args, **kwargs)
        )(self._e_state)

    def _require_engine(self):
        if self._placement != "engine":
            raise ValueError("this frame is not in engine mode (from_engine)")

    # ------------------------------------------------------------ internals
    def _require_d(self) -> int:
        if self._d is None:
            self._drain_chunks()
        if self._d is None:
            raise ValueError("cannot infer the series dimension from an empty "
                             "chunk source; ingest at least one chunk")
        return self._d

    def _tail_chunks(self) -> list:
        if self._chunk_list is None:
            self._chunk_list = []
        return self._chunk_list

    def _drain_chunks(self) -> list:
        """Materialize the chunk source exactly once (chunks placement)."""
        if self._chunk_source is not None:
            source, chunk_size = self._chunk_source
            if hasattr(source, "iter_chunks"):  # TimeSeriesStore
                source = source.iter_chunks(chunk_size)
            drained = [_as_2d(jnp.asarray(c)) for c in source]
            # user appends recorded before the first collect come after the
            # source, in arrival order (their lengths are already counted)
            self._chunk_list = drained + (self._chunk_list or [])
            self._chunk_source = None
            for c in drained:
                self._n += c.shape[0]
            if self._chunk_list:
                self._d = self._chunk_list[0].shape[1]
        return self._chunk_list or []

    def _traverse(self, plan: StatPlan) -> tuple:
        if self._placement == "array":
            if self._appended:
                self._x = jnp.concatenate([self._x] + self._appended)
                self._appended = []
            return jax.jit(plan.from_chunk)(self._x)
        if self._placement == "chunks":
            return self._traverse_chunks(plan)
        return self._traverse_sharded(plan)

    def _traverse_chunks(self, plan: StatPlan) -> tuple:
        chunks = self._drain_chunks()
        states = plan.init()
        i = 0
        while i < len(chunks):
            j = i
            while (
                j < len(chunks)
                and chunks[j].shape[0] == chunks[i].shape[0]
                and chunks[j].shape[0] > 0
            ):
                j += 1
            if j == i:  # zero-length chunk: neutral, skip
                i += 1
                continue
            run = chunks[i:j]
            if len(run) > 1:
                states = plan.consume(states, jnp.stack(run))
            else:
                states = plan.update(states, run[0])
            i = j
        # weak memory: the raw chunks are gone once folded
        self._chunk_list = []
        self._replayable = False
        return states

    # -- sharded strategy ---------------------------------------------------
    def _ensure_store(self, plan: StatPlan):
        carry_max = max(g.engine.carry for g in plan.groups)
        if self._store is not None:
            spec = self._store.spec
            if spec.h_left != 0 or spec.h_right < carry_max:
                if not self._store_owned:
                    raise ValueError(
                        f"the supplied store's halo (h_left={spec.h_left}, "
                        f"h_right={spec.h_right}) cannot serve the plan's "
                        f"widest window ({carry_max + 1}); rebuild it with "
                        f"h_left=0, h_right>={carry_max}"
                    )
                # frame-built store from an earlier, narrower plan: re-place
                # with the exact halo (a replan is already a full traversal)
                self._x = self._store.to_series()
                self._store = None
        if self._store is None:
            from ..timeseries.dataset import TimeSeriesStore

            self._store = TimeSeriesStore.from_series(
                self._x,
                block_size=min(self._block_size, max(self._x.shape[0], 1)),
                h_left=0,
                h_right=carry_max,
                mesh=self._mesh,
                axis=self._axis,
            )
            self._store_owned = True
            self._x = None  # the store owns the data now
        return self._store

    def _traverse_sharded(self, plan: StatPlan) -> tuple:
        store = self._ensure_store(plan)
        spec = store.spec
        B, n = spec.block_size, spec.n
        groups = plan.groups

        def per_block(block, bid):
            g_starts = bid * B + jnp.arange(B)
            stats = []
            for g in groups:
                # same start set as the monolithic walk: full fused window
                # inside the global series, group-stride aligned
                mask = g_starts + g.engine.window <= n
                if g.stride > 1:
                    mask = mask & (g_starts % g.stride == 0)
                stats.append(
                    g.engine._call_kernel(
                        block[: B + g.engine.carry], mask, bid * B
                    )
                )
            core_valid = (g_starts < n)[:, None]
            ssum = jnp.sum(jnp.where(core_valid, block[:B], 0.0), axis=0)
            return tuple(stats), ssum

        if store.mesh is None:
            blocks = store.padded_blocks_single_host()
            stats, ssums = jax.vmap(per_block)(
                blocks, jnp.arange(spec.num_blocks)
            )
            stat_sum = jax.tree.map(lambda l: jnp.sum(l, axis=0), stats)
            sample_sum = jnp.sum(ssums, axis=0)
        else:
            from ..parallel.sharding import psum_tree, shard_map_compat

            per_dev = spec.num_blocks // store.mesh.shape[store.axis]

            def local(blocks_local):
                offset = jax.lax.axis_index(store.axis) * per_dev
                padded = store.padded_blocks_local(blocks_local)
                stats, ssums = jax.vmap(per_block)(
                    padded, offset + jnp.arange(per_dev)
                )
                partial = (
                    jax.tree.map(lambda l: jnp.sum(l, axis=0), stats),
                    jnp.sum(ssums, axis=0),
                )
                return psum_tree(partial, store.axis)

            fn = shard_map_compat(
                local, mesh=store.mesh, in_specs=P(store.axis), out_specs=P()
            )
            stat_sum, sample_sum = fn(store.blocks)

        carry_max = max(g.engine.carry for g in groups)
        head_full, tail_full = self._series_edges(store, carry_max)
        # Each group's state must own ITS OWN buffers: the donated append
        # path (`StatPlan.update_donated`) consumes group states in place
        # one by one, so a leaf shared between two groups would be freed by
        # the first group's update and read-after-delete by the second.
        # Single-group plans (every built-in request) skip the copies.
        own = (lambda a: a) if len(groups) == 1 else jnp.copy
        states = []
        for g, stat in zip(groups, stat_sum):
            c = g.engine.carry
            states.append(
                PartialState(
                    stat=stat,
                    sample_sum=own(sample_sum),
                    head=own(head_full[:c]),
                    tail=own(tail_full[carry_max - c :]) if c > 0
                    else jnp.zeros((0, self._d)),
                    length=jnp.asarray(n, jnp.int32),
                    t0=jnp.asarray(0, jnp.int32),
                )
            )
        states = tuple(states)
        for chunk in self._pending:
            states = plan.update(states, chunk)
        if self._pending and self._can_scatter_append():
            # appends buffered before the store existed migrate into it now
            # (one donated scatter each), so future replans re-read a
            # complete series and the host-side replay list dies here.
            for chunk in self._pending:
                self._store.append_rows(chunk)
            self._pending = []
        return states

    def _series_edges(self, store, carry_max: int):
        """First / last ``carry_max`` samples of the stored series, gathered
        from the block cores (a ``carry_max × d`` read, never the series):
        head left-aligned, tail right-aligned, zero where off-range — the
        exact `PartialState` halo contract."""
        spec = store.spec
        n, B = spec.n, spec.block_size
        d = store.blocks.shape[-1]
        if carry_max == 0:
            empty = jnp.zeros((0, d))
            return empty, empty
        rows = jnp.arange(carry_max)
        hv = rows < n
        hr = jnp.clip(rows, 0, n - 1)
        head = jnp.where(hv[:, None], store.blocks[hr // B, hr % B], 0.0)
        gidx = n - carry_max + rows
        tv = gidx >= 0
        tr = jnp.clip(gidx, 0, n - 1)
        tail = jnp.where(tv[:, None], store.blocks[tr // B, tr % B], 0.0)
        return head, tail


class FrameSession(_DeferredRequests):
    """Multi-tenant deferred statistics: one fused plan, millions of users.

    The session compiles its deferred requests into ONE
    `repro.core.plan.StatPlan` at the first ingest and carries a single
    stacked per-user fused-plan state inside
    `repro.serving.rolling.RollingStatsService` — so every user's N
    statistics ride one donated scatter-ingest program on the write path
    and one gather + ⊕-fold + fused finalize on the read path.  Per-user
    results equal a dedicated per-user :class:`SeriesFrame` to float
    round-off (pinned by tests/test_frame.py).

    Args:
      d: series dimension.
      num_users: number of user series served.
      requests: optional pre-built `StatRequest` list; the deferred-request
        methods (``.autocovariance(...)`` etc.) also work until the first
        ingest compiles the plan.
      num_shards: independent ingest lanes (growing mode only).
      window / num_buckets: sliding-window eviction mode — per-user state
        is a ring of ``num_buckets`` window-aligned sub-states retaining
        the last ≤ ``window`` samples; queries cover only the retained
        horizon (see `RollingStatsService`).
      backend: compute-backend spec for every traversal.
      compensated: thread Neumaier error companions through every group's
        ⊕-folds (long-horizon drift control for always-on sessions; see
        `repro.core.integrity`).  Snapshots from a compensated session only
        restore into a compensated session (the extra companion leaves are
        part of the state's structure).
    """

    def __init__(
        self,
        d: int,
        num_users: int,
        requests: Optional[Sequence[StatRequest]] = None,
        num_shards: int = 1,
        window: Optional[int] = None,
        num_buckets: Optional[int] = None,
        backend: BackendSpec = None,
        compensated: bool = False,
    ):
        self.d = d
        self.num_users = num_users
        self.num_shards = num_shards
        self.window = window
        self._num_buckets = num_buckets
        self._backend = backend
        self.compensated = compensated
        self._recorded: list[StatRequest] = []
        self._name_counts: dict[str, int] = {}
        self._plan: Optional[StatPlan] = None
        self._services: Optional[list] = None
        for req in requests or []:
            self._defer(req)

    def _defer(self, req: StatRequest) -> str:
        if self._plan is not None:
            raise ValueError(
                "the session's fused plan is compiled at the first ingest; "
                "declare every request before ingesting"
            )
        if not isinstance(req, StatRequest):
            raise TypeError(
                f"requests must be StatRequest (see the *_request factories), "
                f"got {type(req).__name__}"
            )
        name = self._unique_name(req.name or req.default_name())
        self._recorded.append(dataclasses.replace(req, name=name))
        return name

    @property
    def plan(self) -> StatPlan:
        self._ensure_plan()
        return self._plan

    @property
    def request_names(self) -> tuple:
        """Names of every deferred request, in declaration order — the keys
        of ``query`` / ``query_batch`` results (and the valid values for
        the gateway's ``only=`` query-kind filter)."""
        return tuple(r.name for r in self._recorded)

    def _ensure_plan(self):
        if self._plan is not None:
            return
        if not self._recorded:
            raise ValueError("a session needs at least one deferred request")
        self._plan = StatPlan(list(self._recorded), d=self.d,
                              backend=self._backend,
                              compensated=self.compensated)
        from ..serving.rolling import RollingStatsService

        self._services = [
            RollingStatsService(
                g.engine,
                self.num_users,
                num_shards=self.num_shards,
                window=self.window,
                num_buckets=self._num_buckets,
            )
            for g in self._plan.groups
        ]
        # jit caches one trace per requested batch size, so a steady read
        # load (the gateway's per-tick coalesced query) re-traces nothing:
        # the whole multi-user read is the services' gather/⊕-fold programs
        # plus this ONE vmapped fused-finalize program.
        self._finalize_batch = jax.jit(
            jax.vmap(lambda states: self._plan.finalize(tuple(states),
                                                        cache=False))
        )

    # -- write path ----------------------------------------------------------
    def ingest(
        self,
        user_ids: jax.Array,
        chunks: jax.Array,
        shard: int = 0,
        t0: Optional[jax.Array] = None,
    ) -> None:
        """Absorb one arrival batch: ``chunks[i]`` extends user
        ``user_ids[i]``'s series (see `RollingStatsService.ingest`).
        Built-in requests compile to a single plan group, so this is ONE
        donated scatter-update program however many statistics the session
        tracks."""
        self._ensure_plan()
        for svc in self._services:
            svc.ingest(user_ids, chunks, shard=shard, t0=t0)

    # -- read path -----------------------------------------------------------
    def query(self, user_id: int) -> dict:
        """All deferred statistics for one user: ``{request_name: result}``,
        equal to a dedicated per-user SeriesFrame's ``collect()``."""
        self._ensure_plan()
        states = tuple(svc.partial(user_id) for svc in self._services)
        return self._plan.finalize(states, cache=False)

    def query_batch(self, user_ids) -> dict:
        """Vmapped multi-user read: one gather + one compiled ⊕-fold per
        plan group, then ONE jit-cached vmapped fused finalize — results
        have a leading ``len(user_ids)`` axis."""
        self._ensure_plan()
        merged = tuple(svc.partials_batch(user_ids) for svc in self._services)
        return self._finalize_batch(merged)

    # -- durability ----------------------------------------------------------
    def export_state(self) -> dict:
        """Host snapshot of everything the session serves from: one entry
        per plan group, each the stacked lane pytree + eviction cursor of
        its `RollingStatsService` (host copies — safe across later donating
        ingests).  The snapshot round-trips through
        `repro.checkpoint.manager.save_pytree` / ``restore_pytree`` with
        this same dict as the restore template; :meth:`import_state` on a
        freshly built session with the same requests/config then serves
        answers identical to the exporter's, with zero re-ingest.  This is
        the durability hook `repro.serving.gateway.StatsGateway` snapshots
        through."""
        self._ensure_plan()
        return {
            f"group_{i}": svc.export_state()
            for i, svc in enumerate(self._services)
        }

    def import_state(self, state: dict) -> None:
        """Install an :meth:`export_state` snapshot (same requests, same
        num_users/num_shards/window/backend config)."""
        self._ensure_plan()
        keys = {f"group_{i}" for i in range(len(self._services))}
        if set(state) != keys:
            raise ValueError(
                f"snapshot has groups {sorted(state)} but this session's "
                f"plan compiled {sorted(keys)} — the deferred requests must "
                "match the exporter's"
            )
        for i, svc in enumerate(self._services):
            svc.import_state(state[f"group_{i}"])

    def state_template(self) -> dict:
        """Zero-copy view of the live state with :meth:`export_state`'s
        structure — shapes/dtypes for checkpoint-restore templates without
        a full device→host transfer."""
        self._ensure_plan()
        return {
            f"group_{i}": svc.state_template()
            for i, svc in enumerate(self._services)
        }

    # -- integrity -----------------------------------------------------------
    def audit(self):
        """Finite-sweep every tenant's stacked lane state on-device: one
        compiled program + one host sync per plan group.  Returns a host
        (num_users,) bool — True where every group's every lane is healthy
        (see `RollingStatsService.audit`)."""
        self._ensure_plan()
        healthy = None
        for svc in self._services:
            h = svc.audit()
            healthy = h if healthy is None else healthy & h
        return healthy

    def export_tenant(self, user_id: int) -> dict:
        """Host snapshot of ONE tenant's slice of every group's state
        (:meth:`import_tenant`'s input; also produced by
        `repro.checkpoint.manager.restore_tenant_pytree` from a full
        session checkpoint)."""
        self._ensure_plan()
        return {
            f"group_{i}": svc.export_tenant(user_id)
            for i, svc in enumerate(self._services)
        }

    def import_tenant(self, user_id: int, state: dict) -> None:
        """Surgically restore ONE tenant's lanes from a per-tenant snapshot,
        leaving every other tenant's live state untouched and re-tracing
        nothing (see `RollingStatsService.import_tenant`)."""
        self._ensure_plan()
        keys = {f"group_{i}" for i in range(len(self._services))}
        if set(state) != keys:
            raise ValueError(
                f"tenant snapshot has groups {sorted(state)} but this "
                f"session's plan compiled {sorted(keys)}"
            )
        for i, svc in enumerate(self._services):
            svc.import_tenant(user_id, state[f"group_{i}"])

    def tenant_slice(self, state: dict, user_id: int) -> dict:
        """Extract ONE tenant's slice from a full :meth:`export_state`
        snapshot (host-side; no device work)."""
        self._ensure_plan()
        keys = {f"group_{i}" for i in range(len(self._services))}
        if set(state) != keys:
            raise ValueError(
                f"snapshot has groups {sorted(state)}, expected {sorted(keys)}"
            )
        return {
            f"group_{i}": svc.tenant_slice(state[f"group_{i}"], user_id)
            for i, svc in enumerate(self._services)
        }

    def tenant_axes(self) -> dict:
        """Flat checkpoint-key → tenant-axis map for every leaf of
        :meth:`export_state`, keyed exactly as
        `repro.checkpoint.manager.save_pytree` flattens them.  Recorded
        into each snapshot's manifest (``meta["tenant_axes"]``) so
        ``restore_tenant_pytree`` can slice ONE tenant out of a checkpoint
        without loading the session: lane leaves carry tenants on axis 1
        (``(num_lanes, num_users, ...)``), eviction cursors on axis 0."""
        from ..checkpoint.manager import path_key

        axes = {}
        for path, _leaf in jax.tree_util.tree_flatten_with_path(
            self.state_template()
        )[0]:
            field = getattr(path[1], "key", None)
            axes[path_key(path)] = 1 if field == "lanes" else 0
        return axes

    def lengths(self) -> jax.Array:
        """(num_users,) samples ingested per user (total, incl. evicted)."""
        self._ensure_plan()
        return self._services[0].lengths()

    def retained_lengths(self) -> jax.Array:
        """(num_users,) samples a query covers right now (= ``lengths`` in
        growing mode; the ring-retained span in eviction mode)."""
        self._ensure_plan()
        return self._services[0].retained_lengths()


def _as_2d(x: jax.Array) -> jax.Array:
    return x[:, None] if x.ndim == 1 else x
