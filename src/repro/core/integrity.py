"""Data-plane integrity primitives: sentinel scan, lane health, Neumaier ⊕.

The weak-memory scheme's defining property — per-tenant state is only ever
⊕-folded, never recomputed from raw data — makes the data plane uniquely
fragile in two ways the control-plane hardening (breakers, verified
checkpoints, degraded mode) cannot see:

  * **poison is permanent**: one NaN/Inf sample absorbed into a tenant's
    `PartialState` contaminates every future merge of that lane, and no
    amount of clean data dilutes it back out (NaN + x = NaN);
  * **drift is permanent**: float rounding in the monoid sums accumulates
    monotonically over months-long sessions, and there is no second pass
    over the series to re-derive the exact value.

This module holds the shared numeric machinery for both defenses.  The
policy layers live where the state lives: the ingest sentinel in
`repro.serving.gateway.StatsGateway` (per-tenant reject / sanitize /
quarantine, chaos site ``ingest.payload``), the audit/rebuild surface in
`repro.serving.rolling.RollingStatsService`, and the opt-in compensated
accumulation mode in `repro.core.streaming.StreamingEngine`.

Contracts:

  * :func:`sentinel_scan` — ONE fused jitted program per coalesced ingest
    batch computing the per-chunk all-finite verdict AND the sanitized
    (non-finite → 0) copy together; exactly one device→host sync (the
    verdict — the sanitized batch stays on device for the scatter);
  * :func:`lane_health` — traced per-(lane, user) finite reduction over a
    stacked lane pytree, jitted once by the serving layer so an ``audit()``
    sweep is one device program + one host sync however many leaves the
    fused plan carries;
  * :func:`tree_neumaier_merge` / :func:`tree_neumaier_add` — the monoid ⊕
    in Neumaier compensated form: each stat pytree carries an
    error-companion pytree of the rounding residue, recovered at readout by
    ``stat + err`` (`repro.core.streaming.resolved_stat`).  Exact for
    integer leaves (the correction is identically zero), well-defined for
    complex leaves (``abs`` is the modulus).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SENTINEL_POLICIES",
    "lane_health",
    "sentinel_scan",
    "tree_neumaier_add",
    "tree_neumaier_merge",
]

# Per-tenant sentinel policies (GatewayConfig.sentinel_policy / per-tenant
# overrides): "reject" fails the chunk's future with PoisonedChunk,
# "sanitize" masks non-finite values to 0 and ingests the rest, and
# "quarantine" additionally fences the tenant off from ingest AND query
# until rebuild_tenant() restores a verified state.
SENTINEL_POLICIES = ("reject", "sanitize", "quarantine")


@jax.jit
def _sentinel_program(batch: jax.Array) -> Tuple[jax.Array, jax.Array]:
    finite = jnp.isfinite(batch)
    verdict = jnp.all(finite, axis=tuple(range(1, batch.ndim)))
    return verdict, jnp.where(finite, batch, 0.0)


def sentinel_scan(batch) -> Tuple[np.ndarray, jax.Array]:
    """All-finite verdict + sanitized copy for one coalesced ingest batch.

    ``batch`` is the tick's stacked (k, c, d) arrival batch.  Returns
    ``(verdict, clean)`` where ``verdict`` is a HOST (k,) bool array (one
    ``True`` per fully-finite chunk — the call's single device→host sync)
    and ``clean`` is the DEVICE batch with non-finite entries masked to 0,
    ready to feed the scatter without a second transfer.  When every chunk
    is finite, ``clean`` is bit-identical to ``batch`` — feeding it through
    changes no served answer.
    """
    verdict, clean = _sentinel_program(jnp.asarray(batch))
    return np.asarray(verdict), clean


def lane_health(lanes: Any) -> jax.Array:
    """Per-(lane, user) all-finite reduction over a stacked lane pytree.

    ``lanes`` carries leading ``(num_lanes, num_users)`` axes on every leaf
    (the `RollingStatsService` storage layout).  Returns a traced
    ``(num_lanes, num_users)`` bool: True iff every trailing element of
    every leaf is finite there.  Integer leaves (length, t0) are always
    finite and cost one trivially-true reduction.  Callers jit this once —
    the whole audit sweep is then one compiled program per service.
    """
    ok = None
    for leaf in jax.tree.leaves(lanes):
        fin = jnp.isfinite(leaf)
        if leaf.ndim > 2:
            fin = jnp.all(fin, axis=tuple(range(2, leaf.ndim)))
        ok = fin if ok is None else ok & fin
    return ok


def _comp(a, b, t):
    # Neumaier's branch-free correction for t = a + b: whichever operand is
    # larger in magnitude, (larger - t) + smaller recovers the rounding
    # residue exactly (Neumaier 1974; exact 0 for integer dtypes).
    return jnp.where(jnp.abs(a) >= jnp.abs(b), (a - t) + b, (b - t) + a)


def tree_neumaier_merge(
    stat_a: Any, err_a: Any, stat_b: Any, err_b: Any
) -> Tuple[Any, Any]:
    """Compensated ⊕ of two (stat, err) pairs, leaf-wise over the pytrees.

    Returns ``(stat, err)`` with ``stat = stat_a + stat_b`` (the same
    float32 sum the plain monoid computes — compensation never changes the
    carried stat, only tracks what rounding discarded) and ``err`` the
    summed error companions plus this addition's own residue.
    """
    stat = jax.tree.map(lambda a, b: a + b, stat_a, stat_b)
    err = jax.tree.map(
        lambda a, b, t, ea, eb: ea + eb + _comp(a, b, t),
        stat_a, stat_b, stat, err_a, err_b,
    )
    return stat, err


def tree_neumaier_add(stat: Any, err: Any, delta: Any) -> Tuple[Any, Any]:
    """Compensated ``stat ⊕ delta`` for a fresh contribution (no companion
    of its own — a chunk kernel's output).  Returns the new ``(stat, err)``.
    """
    new = jax.tree.map(lambda s, v: s + v, stat, delta)
    new_err = jax.tree.map(
        lambda s, v, t, e: e + _comp(s, v, t),
        stat, delta, new, err,
    )
    return new, new_err
