"""Halo materialization strategies (paper §10 vs. beyond-paper exchange).

The paper's scheme *pre-replicates* halos at ingest ("replication" mode):
after that, zero communication — optimal when the same blocks are swept many
times (SGD epochs over a calibration set).

On a TPU mesh, the alternative is to keep blocks disjoint and exchange the
halo once per sweep with ``jax.lax.ppermute`` (collective-permute over ICI) —
"exchange" mode.  Memory cost drops from ``(P-1)·(h_l+h_r)·d`` replicated
elements to zero; communication cost rises from zero to one neighbour
permute of ``(h_l+h_r)·d`` elements per sweep.  Both are exposed; the
paper-faithful mode is the recorded baseline in EXPERIMENTS.md §Perf and the
exchange mode is the beyond-paper variant.

These helpers run **inside shard_map** — `x` is the local shard.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["halo_exchange", "halo_exchange_grouped", "edge_zeros_note"]


def _shift(x: jax.Array, axis_name: str, direction: int) -> jax.Array:
    """Send local data to the neighbour at index+direction along axis_name.

    Devices with no source (ends of the line) receive zeros — exactly the
    zero-filled boundary slots of `repro.core.overlap.make_overlapping_blocks`.
    """
    n = jax.lax.axis_size(axis_name)
    perm = [(i, i + direction) for i in range(n) if 0 <= i + direction < n]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange(
    x: jax.Array,
    h_left: int,
    h_right: int,
    axis_name: str,
    *,
    time_axis: int = 0,
) -> jax.Array:
    """Pad the local time shard with its neighbours' boundary samples.

    Args:
      x: local shard, time along ``time_axis``.
      h_left: number of trailing samples to pull from the *previous* shard.
      h_right: number of leading samples to pull from the *next* shard.
      axis_name: mesh axis the time dimension is sharded over.

    Returns:
      local shard extended to ``h_left + T_local + h_right`` along
      ``time_axis``; out-of-range boundary slots are zeros.
    """
    parts = []
    if h_left > 0:
        tail = jax.lax.slice_in_dim(
            x, x.shape[time_axis] - h_left, x.shape[time_axis], axis=time_axis
        )
        parts.append(_shift(tail, axis_name, +1))  # prev shard's tail → me
    parts.append(x)
    if h_right > 0:
        head = jax.lax.slice_in_dim(x, 0, h_right, axis=time_axis)
        parts.append(_shift(head, axis_name, -1))  # next shard's head → me
    return jnp.concatenate(parts, axis=time_axis)


def halo_exchange_grouped(
    x: jax.Array,
    h_left: int,
    h_right: int,
    axis_name: str,
    *,
    time_axis: int = 0,
    ring: bool = False,
) -> jax.Array:
    """Variant used by sequence-parallel model layers (SWA attention, SSM
    chunk state): optionally a ring (wrap-around) permute for rotary-free
    periodic workloads; zero-fill line permute by default (causal LMs)."""
    if not ring:
        return halo_exchange(x, h_left, h_right, axis_name, time_axis=time_axis)
    n = jax.lax.axis_size(axis_name)
    perm_next = [(i, (i + 1) % n) for i in range(n)]
    perm_prev = [(i, (i - 1) % n) for i in range(n)]
    parts = []
    if h_left > 0:
        tail = jax.lax.slice_in_dim(
            x, x.shape[time_axis] - h_left, x.shape[time_axis], axis=time_axis
        )
        parts.append(jax.lax.ppermute(tail, axis_name, perm_next))
    parts.append(x)
    if h_right > 0:
        head = jax.lax.slice_in_dim(x, 0, h_right, axis=time_axis)
        parts.append(jax.lax.ppermute(head, axis_name, perm_prev))
    return jnp.concatenate(parts, axis=time_axis)


def edge_zeros_note() -> str:
    return (
        "line-topology ppermute zero-fills missing neighbours; this matches "
        "the zero-filled boundary halo slots of make_overlapping_blocks, so "
        "exchange mode and replication mode are bit-identical (property-tested)."
    )
