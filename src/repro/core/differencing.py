"""Differencing / integration (paper §1.4, §10.3 — long-memory reduction).

Integrated processes become weak-memory after Δ^I; the overlapping structure
then applies.  Δ itself is an order-1 weak-memory kernel, so it composes
with the block machinery (a block with h_left=1 computes its differences
locally — used by `timeseries.dataset` when ingesting integrated series).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["difference", "integrate", "difference_blocked"]


def difference(x: jax.Array, order: int = 1) -> jax.Array:
    """Δ^order x — paper convention: Δ(x)_t = x_{t+1} − x_t, length N−order."""
    for _ in range(order):
        x = x[1:] - x[:-1]
    return x


def integrate(dx: jax.Array, initial: jax.Array, order: int = 1) -> jax.Array:
    """Inverse of :func:`difference`: reconstruct x from Δ^order x and the
    ``order`` leading values it dropped.

    Args:
      dx: (N−order, …) differenced series.
      initial: (order, …) the first samples of each integration level —
        initial[k] is the first element of Δ^k x (k = 0 .. order−1).
    """
    for k in reversed(range(order)):
        x0 = initial[k]
        x = jnp.concatenate([x0[None], x0[None] + jnp.cumsum(dx, axis=0)], axis=0)
        dx = x
    return dx


def difference_blocked(blocks: jax.Array, order: int = 1) -> jax.Array:
    """Per-block differencing: a block padded with h_left ≥ order differences
    its own data with no communication; the result is a valid overlapping
    block structure with h_left reduced by ``order``."""
    for _ in range(order):
        blocks = blocks[:, 1:, :] - blocks[:, :-1, :]
    return blocks


def fractional_diff_weights(d: float, truncation: int) -> jax.Array:
    """Truncated binomial weights of (1−L)^d  (paper §10.3: partially
    integrated processes become weak-memory once the partial-differentiation
    kernel is approximated by a finite-support kernel).

    w_0 = 1,  w_k = w_{k-1} · (k − 1 − d) / k.
    """
    ws = [1.0]
    for k in range(1, truncation + 1):
        ws.append(ws[-1] * (k - 1 - d) / k)
    return jnp.asarray(ws, jnp.float32)


def fractional_difference(x: jax.Array, d: float, truncation: int = 64) -> jax.Array:
    """(1−L)^d x with a ``truncation``-lag kernel — an order-``truncation``
    weak-memory map; composes with the overlapping-block machinery exactly
    like Δ (halo h_left = truncation).

    Returns (N − truncation, dims): only positions with a full kernel
    support (matching the block map-reduce's center-validity rule).
    """
    if x.ndim == 1:
        x = x[:, None]
    w = fractional_diff_weights(d, truncation)  # (K+1,) for lags 0..K
    n = x.shape[0]
    k = truncation

    def at(t):
        # y_t = Σ_j w_j x_{t-j}
        window = jax.lax.dynamic_slice_in_dim(x, t - k, k + 1, axis=0)
        return jnp.einsum("j,jd->d", w[::-1], window)

    return jax.vmap(at)(jnp.arange(k, n))
