"""Fused statistics plans: one data traversal for N weak-memory estimators.

The paper's algebra (§7–§10) says every second-order weak-memory statistic
is the same computation — map a short-window kernel over overlapping
chunks, reduce with ⊕.  This module exploits the corollary: a query for
autocovariance AND Yule-Walker AND rolling moments AND a Welch periodogram
should cost **one** pass over the data, not four.  A :class:`StatPlan`
compiles a set of estimator requests into a single fused
`repro.core.streaming.StreamingEngine` whose chunk kernel evaluates every
member against the same resident chunk, and whose carried
:class:`~repro.core.streaming.PartialState` is the **product monoid** of
the members' partial states.

The product-monoid construction
-------------------------------
If (S₁, ⊕₁), …, (S_N, ⊕_N) are the member monoids, their product
(S₁ × … × S_N, component-wise ⊕) is again a monoid — so one PartialState
whose ``stat`` is a pytree of member stats streams, merges, and shards
exactly like any single-estimator state.  The shared halo buffers are
sized to the *widest* member: ``W_fused = max_m(h_left_m + 1 + h_right_m)``
(here ``max(h_left)``/``max(h_right)`` collapse to one width because every
member's window is start-aligned), so the carried ``head``/``tail`` context
of any narrower member is a prefix/suffix view of the fused halo.  The
traversal invariant is: after any sequence of updates and merges, every
member's stat holds the ⊕-sum over window starts ``s ∈ [t0, t0+length −
W_fused]`` — the starts whose *fused* window is complete.  A narrower
member (window w < W_fused) is missing exactly the starts
``s ∈ (t0+length−W_fused, t0+length−w]``, all of which live inside the
carried ``tail`` halo — its per-member finalizer recovers them with one
contraction over at most ``W_fused − 1`` samples.  Fusion therefore costs
nothing in accuracy: member results are bit-comparable (≤ float round-off)
to independent estimator calls.

Shared components: every lag-family member (autocovariance, Yule-Walker,
ARMA — and the forecast/anomaly members of `repro.core.forecast`, whose
fits derive from the same γ̂ sums and whose recurrences seed from the
carried tail halo) reads slices of ONE ``(H_max+1, d, d)`` lagged-sum
entry, so adding a Yule-Walker fit to a plan that already tracks
autocovariance is free.
Whenever at least two primitive FAMILIES are members (lag sums, windowed
moments, Welch segments), the whole chunk update collapses into one
``fused_plan_update`` call — the persistent megakernel
(`repro.kernels.fused_plan`): the grid walks the chunk once, each tile is
staged into VMEM once, and every family is fed from the same resident
block (one kernel launch and one HBM read, down from one per family).
The call is offset-aware — the chunk's global index ``z0`` rides into the
kernel's stride-alignment tables, so mixed Welch strides and
`FrameSession`/gateway scatter-ingest ride the same launch.  Plans with a
single family keep the narrower primitives (``fused_lagged_moments`` /
``masked_lagged_sums``).  The optional ``stage_dtype="bfloat16"`` plan
flag narrows the megakernel's HBM↔VMEM staging (accumulation stays f32).

When is fusion legal?
---------------------
Members sharing the start-aligned window grid of one chunk walk share a
traversal — which covers every built-in request, *including mixed strides*:
the fused chunk kernel receives the global index of its first row
(``kernel_takes_offset``), so a strided member (Welch segments every
``nperseg − overlap`` samples) applies its own alignment inside the shared
pass.  A generic :func:`kernel_request` whose kernel is NOT offset-aware
cannot re-derive alignment from the shared grid; such members with
``stride > 1`` fall back to **grouped sub-plans** — one extra traversal per
distinct leftover stride, still fused within each group.  ``analyze``
reports one state per group; built-in requests always compile to a single
group (one traversal).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .backend import BackendSpec, get_backend
from .forecast import (
    anomaly_request,
    forecast_request,
    make_anomaly_finalizer,
    make_forecast_finalizer,
    resolve_model_spec,
)
from .mapreduce import tree_sum
from .streaming import PartialState, StreamingEngine

__all__ = [
    "StatPlan",
    "fused_engine",
    "analyze",
    "autocovariance_request",
    "yule_walker_request",
    "arma_request",
    "moments_request",
    "welch_request",
    "kernel_request",
    "forecast_request",
    "anomaly_request",
]


# ---------------------------------------------------------------- requests
@dataclasses.dataclass(frozen=True)
class StatRequest:
    """One estimator request inside a plan (see the factory functions)."""

    kind: str
    name: Optional[str] = None
    params: Tuple = ()

    def default_name(self) -> str:
        return self.kind


def autocovariance_request(
    max_lag: int, normalization: str = "paper", name: Optional[str] = None
) -> StatRequest:
    """γ̂(0..max_lag) — shares the plan's lagged-sum entry."""
    return StatRequest("autocovariance", name, (max_lag, normalization))


def yule_walker_request(
    p: int, normalization: str = "standard", name: Optional[str] = None
) -> StatRequest:
    """Order-p AR fit (A, Σ) — shares the plan's lagged-sum entry."""
    return StatRequest("yule_walker", name, (p, normalization))


def arma_request(
    p: int, q: int, m: Optional[int] = None, name: Optional[str] = None
) -> StatRequest:
    """ARMA(p, q) fit (A, B, Σ) — shares the plan's lagged-sum entry
    (lags up to ``m = max(m or p+q, p+q)``)."""
    return StatRequest("arma", name, (p, q, m))


def moments_request(window: int, name: Optional[str] = None) -> StatRequest:
    """Aggregate windowed moments ({"mean", "var", "count"}) — emitted by the
    same ``fused_lagged_moments`` traversal as the lag family."""
    return StatRequest("moments", name, (window,))


def welch_request(
    nperseg: int = 256,
    overlap: Optional[int] = None,
    fs: float = 1.0,
    name: Optional[str] = None,
) -> StatRequest:
    """Welch PSD (freqs, psd) — strided segments gathered inside the shared
    traversal via the offset-aware chunk kernel."""
    return StatRequest("welch", name, (nperseg, overlap, fs))


def kernel_request(
    name: str,
    chunk_kernel: Callable,
    h_right: int,
    h_left: int = 0,
    stride: int = 1,
    takes_offset: bool = False,
    finalizer: Optional[Callable] = None,
) -> StatRequest:
    """Generic member: any `repro.core.streaming.ChunkKernel`.

    ``finalizer(member, state, raw_stat)`` (member exposes ``.window`` /
    ``.stride``; ``state`` is the group PartialState) may correct for the
    fused halo from ``state.tail``; default returns a copy of the raw stat.
    A custom finalizer must likewise return freshly derived arrays, never
    ``state``'s own leaves by identity — the donated append ingest consumes
    the carried state in place, which would delete a result the caller is
    still holding.  A non-offset-aware kernel with ``stride > 1`` forces a
    grouped sub-plan (its own traversal) — see the module docstring.
    """
    return StatRequest(
        "kernel", name, (chunk_kernel, h_right, h_left, stride, takes_offset, finalizer)
    )


# ---------------------------------------------------------------- members
@dataclasses.dataclass
class _Member:
    """A compiled plan member: how it contributes to the fused traversal
    (``traverse``) and how its result is read out (``finalize``)."""

    name: str
    window: int
    stride: int
    # (y_padded, start_mask, z0) -> stat pytree for this member's key(s);
    # None for members served by the shared fused_lagged_moments call.
    traverse: Optional[Callable]
    # (plan_group, state) -> user-facing result
    finalize: Callable


def _tail_ones(carry: int) -> jax.Array:
    return jnp.ones((carry,), jnp.bool_)


@dataclasses.dataclass(frozen=True)
class _WelchInfo:
    """What the megakernel path needs to serve one Welch member."""

    name: str
    nperseg: int
    step: int
    scale: jax.Array
    taper: jax.Array


class _PlanGroup:
    """One fused traversal: members compiled onto a shared StreamingEngine.

    ``stride`` is the engine-level stride of the group (1 for the main
    group; non-offset-aware generic kernels grouped by their stride rely on
    the engine's alignment mask instead of in-kernel offsets)."""

    def __init__(
        self,
        requests: Sequence[StatRequest],
        names,
        d: int,
        backend,
        stride: int = 1,
        stage_dtype: Optional[str] = None,
        compensated: bool = False,
    ):
        self.backend = backend
        self.d = d
        self.stride = stride
        self.stage_dtype = stage_dtype
        self.compensated = compensated
        self.members: list[_Member] = []
        self._welch_info: list[_WelchInfo] = []

        lag_specs = []      # (name, request) needing the shared lagged entry
        moment_windows = {}  # window -> key
        traverse_extra = []  # offset-aware per-member traversal callables
        auto_members = []   # forecast/anomaly model="auto": need a welch member

        max_lag = 0
        windows = [1]
        for req, name in zip(requests, names):
            if req.kind == "autocovariance":
                H, normalization = req.params
                max_lag = max(max_lag, H)
                windows.append(H + 1)
                self.members.append(
                    _Member(name, H + 1, 1, None, self._autocov_finalizer(H, normalization))
                )
            elif req.kind == "yule_walker":
                p, normalization = req.params
                max_lag = max(max_lag, p)
                windows.append(p + 1)
                self.members.append(
                    _Member(name, p + 1, 1, None, self._yw_finalizer(p, normalization))
                )
            elif req.kind == "arma":
                p, q, m = req.params
                m = max(m if m is not None else p + q, p + q)
                max_lag = max(max_lag, m)
                windows.append(m + 1)
                self.members.append(
                    _Member(name, m + 1, 1, None, self._arma_finalizer(p, q, m))
                )
            elif req.kind == "forecast":
                horizon, model, p, q, m, max_period = req.params
                spec = resolve_model_spec(model, p, q, m, max_period)
                max_lag = max(max_lag, spec.lag_span)
                windows.append(spec.lag_span + 1)
                self.members.append(
                    _Member(name, spec.lag_span + 1, 1, None,
                            make_forecast_finalizer(self, horizon, spec))
                )
                if spec.needs_welch:
                    auto_members.append(name)
            elif req.kind == "anomaly":
                model, p, q, m, max_period = req.params
                spec = resolve_model_spec(model, p, q, m, max_period)
                max_lag = max(max_lag, spec.lag_span)
                windows.append(spec.lag_span + 1)
                self.members.append(
                    _Member(name, spec.lag_span + 1, 1, None,
                            make_anomaly_finalizer(self, spec))
                )
                if spec.needs_welch:
                    auto_members.append(name)
            elif req.kind == "moments":
                (w,) = req.params
                moment_windows.setdefault(w, f"w{w}")
                windows.append(w)
                self.members.append(
                    _Member(name, w, 1, None, self._moments_finalizer(w))
                )
            elif req.kind == "welch":
                nperseg, overlap, fs = req.params
                overlap = nperseg // 2 if overlap is None else overlap
                if not 0 <= overlap < nperseg:
                    raise ValueError(
                        f"need 0 <= overlap < nperseg, got {overlap}/{nperseg}"
                    )
                step = nperseg - overlap
                windows.append(nperseg)
                member = self._compile_welch(name, nperseg, step, fs)
                traverse_extra.append(member)
                self.members.append(member)
            elif req.kind == "kernel":
                ck, h_right, h_left, stride, takes_offset, finalizer = req.params
                w = h_left + 1 + h_right
                windows.append(w)
                member = self._compile_kernel(
                    name, ck, w, stride, takes_offset, finalizer
                )
                traverse_extra.append(member)
                self.members.append(member)
            else:  # pragma: no cover - guarded by _group_requests
                raise ValueError(f"unknown request kind {req.kind!r}")

        self.window = max(windows)
        self.max_lag = max_lag
        self.has_lagged = any(
            r.kind in ("autocovariance", "yule_walker", "arma",
                       "forecast", "anomaly")
            for r in requests
        )
        if auto_members and not self._welch_info:
            raise ValueError(
                f"model='auto' members {auto_members} seed their seasonal "
                "lag from the plan's Welch spectrum; add a welch member "
                "(welch_request / .welch(...)) to the same plan"
            )
        self.moment_windows = dict(sorted(moment_windows.items()))
        self._traverse_extra = traverse_extra
        welch_names = {info.name for info in self._welch_info}
        self._non_welch_extra = [
            m for m in traverse_extra if m.name not in welch_names
        ]
        # The megakernel engages when ≥2 primitive families share the
        # traversal AND the backend implements the seventh primitive
        # (third-party backends without it keep the per-family path).
        families = (
            int(self.has_lagged)
            + int(bool(self.moment_windows))
            + int(bool(self._welch_info))
        )
        self._use_megakernel = families >= 2 and hasattr(
            backend, "fused_plan_update"
        )

        self.engine = StreamingEngine(
            d=d,
            h_left=0,
            h_right=self.window - 1,
            chunk_kernel=self._fused_chunk_kernel,
            stride=stride,
            backend=backend,
            kernel_takes_offset=True,
            compensated=compensated,
        )

    def _stat_entry(self, state: PartialState, key: str):
        """One member's slot of ``state.stat``, with the Neumaier error
        companion folded in when the group runs compensated — the single
        readout point for every finalizer (including the megakernel path's
        jnp oracle: compensation wraps the monoid ⊕ *around* whichever
        chunk kernel produced the contributions)."""
        entry = state.stat[key]
        if state.stat_err is None:
            return entry
        return jax.tree.map(lambda s, e: s + e, entry, state.stat_err[key])

    # -- the one traversal -------------------------------------------------
    def _fused_chunk_kernel(self, y: jax.Array, mask: jax.Array, z0: jax.Array):
        be = self.backend
        out = {}
        if self._use_megakernel:
            # ONE backend call — on Pallas one persistent kernel launch —
            # serves the shared lagged entry, every moment window, AND every
            # Welch member: each chunk tile is staged into VMEM once and
            # feeds all member families (offset-aware: z0 enters the
            # segment stride alignment).
            ws = tuple(self.moment_windows)
            lag, mom, psds, n_segs = be.fused_plan_update(
                y,
                mask,
                z0,
                self.max_lag,
                ws,
                tuple(i.nperseg for i in self._welch_info),
                tuple(i.step for i in self._welch_info),
                tuple(i.taper for i in self._welch_info),
                stage_dtype=self.stage_dtype,
            )
            if self.has_lagged:
                out["lagged"] = lag
            if ws:
                count = jnp.sum(mask.astype(jnp.float32))
                out["moments"] = {
                    key: {"sums": mom[k], "count": count}
                    for k, (w, key) in enumerate(self.moment_windows.items())
                }
            for info, psd, n_seg in zip(self._welch_info, psds, n_segs):
                out[info.name] = {"psd": psd * info.scale, "n_seg": n_seg}
            for member in self._non_welch_extra:
                out[member.name] = member.traverse(y, mask, z0)
            return out
        if self.moment_windows:
            # ONE fused call serves the shared lagged entry AND every moment
            # window: the multi-window primitive accumulates all K windows
            # against the same resident tile (one HBM read total).
            ws = tuple(self.moment_windows)
            lag, moms = be.fused_lagged_moments(y, mask, self.max_lag, ws)
            count = jnp.sum(mask.astype(jnp.float32))
            if self.has_lagged:
                out["lagged"] = lag
            out["moments"] = {
                key: {"sums": moms[k], "count": count}
                for k, (w, key) in enumerate(self.moment_windows.items())
            }
        elif self.has_lagged:
            # lag-only plan: no moment member to fuse with — skip the fused
            # primitive's window accumulation entirely.
            out["lagged"] = be.masked_lagged_sums(y, mask, self.max_lag)
        for member in self._traverse_extra:
            out[member.name] = member.traverse(y, mask, z0)
        return out

    # -- shared tail recovery ----------------------------------------------
    def _corrected_gamma_sums(self, state: PartialState, H: int) -> jax.Array:
        """Serial lag sums S(0..H) from the fused state: the plan's shared
        ``lagged`` entry covers starts with a full fused window; every
        missing serial pair (k, k+h) starts inside the carried tail, and the
        tail's right-aligned zero-fill kills k+h past the series end — one
        masked contraction recovers them exactly (the streaming engine's
        ragged-tail trick, widened to the fused halo)."""
        s = self._stat_entry(state, "lagged")[: H + 1]
        carry = self.engine.carry
        if carry > 0:
            s = s + self.backend.masked_lagged_sums(
                state.tail, _tail_ones(carry), H
            )
        return s

    def _autocov_finalizer(self, H: int, normalization: str):
        from .estimators.stats import gamma_normalizer

        def fin(state: PartialState):
            s = self._corrected_gamma_sums(state, H)
            norm = gamma_normalizer(state.length, H, normalization)
            return s * norm[:, None, None]

        return fin

    def _yw_finalizer(self, p: int, normalization: str):
        from .estimators.stats import gamma_normalizer
        from .estimators.yule_walker import yule_walker

        def fin(state: PartialState):
            s = self._corrected_gamma_sums(state, p)
            norm = gamma_normalizer(state.length, p, normalization)
            return yule_walker(s * norm[:, None, None], p)

        return fin

    def _arma_finalizer(self, p: int, q: int, m: int):
        from .estimators.arma import fit_arma
        from .estimators.stats import gamma_normalizer

        def fin(state: PartialState):
            s = self._corrected_gamma_sums(state, m)
            norm = gamma_normalizer(state.length, m, "standard")
            return fit_arma(s * norm[:, None, None], p, q, m)

        return fin

    def _moments_finalizer(self, w: int):
        key = f"w{w}"

        def fin(state: PartialState):
            entry = self._stat_entry(state, "moments")[key]
            sums, count = entry["sums"], entry["count"]
            carry = self.engine.carry
            if carry >= w:
                # starts missing from the fused traversal: the last
                # W_fused − w full member windows, all inside the tail.
                rows = jnp.arange(carry)
                mask = (rows >= carry - state.length) & (rows <= carry - w)
                _, mom = self.backend.fused_lagged_moments(state.tail, mask, 0, w)
                sums = sums + mom
                count = count + jnp.sum(mask.astype(jnp.float32))
            total = count * w
            m1 = sums[0] / total
            m2 = sums[1] / total
            return {
                "mean": m1,
                "var": jnp.maximum(m2 - m1 * m1, 0.0),
                "count": count,
            }

        return fin

    def _compile_welch(self, name: str, nperseg: int, step: int, fs: float):
        from .estimators.spectral import _one_sided, hann_window, welch_chunk_kernel

        w = hann_window(nperseg)
        scale = 1.0 / (fs * jnp.sum(w**2))
        ck = welch_chunk_kernel(nperseg, step, scale, self.backend)
        # the megakernel path serves this member from the shared launch;
        # the standalone chunk kernel remains the finalizer's tail path.
        self._welch_info.append(_WelchInfo(name, nperseg, step, scale, w))

        def fin(state: PartialState):
            entry = self._stat_entry(state, name)
            carry = self.engine.carry
            if carry >= nperseg:
                rows = jnp.arange(carry)
                mask = (rows >= carry - state.length) & (rows <= carry - nperseg)
                z0 = state.t0 + state.length - carry
                entry = tree_sum(entry, ck(state.tail, mask, z0))
            psd = entry["psd"] / entry["n_seg"]
            return _one_sided(psd, nperseg, fs)

        return _Member(name, nperseg, step, ck, fin)

    def _compile_kernel(self, name, ck, w, stride, takes_offset, finalizer):
        if takes_offset:
            traverse = ck
        else:
            traverse = lambda y, mask, z0: ck(y, mask)

        member = _Member(name, w, stride, traverse, None)

        def fin(state: PartialState):
            raw = self._stat_entry(state, name)
            if finalizer is None:
                # Hand out COPIES, never the carried stat's own buffers:
                # the donated append path (`update_donated`) consumes the
                # state in place, which would delete a result the caller
                # is still holding.  Built-in members always derive fresh
                # arrays (normalize / divide), so only this raw-readout
                # path needs the copy.
                return jax.tree.map(jnp.copy, raw)
            return finalizer(member, state, raw)

        member.finalize = fin
        return member

    # -- readout -----------------------------------------------------------
    def finalize(self, state: PartialState) -> dict:
        return {m.name: m.finalize(state) for m in self.members}


def _group_requests(requests: Sequence[StatRequest]):
    """Group-0 holds everything fusable into one traversal; non-offset-aware
    generic kernels with stride > 1 get one sub-plan per distinct stride
    (the engine-level stride mask supplies their alignment)."""
    named = []
    seen = {}
    for req in requests:
        if not isinstance(req, StatRequest):
            raise TypeError(
                f"requests must be StatRequest (see the *_request factories), "
                f"got {type(req).__name__}"
            )
        base = req.name or req.default_name()
        seen[base] = seen.get(base, 0) + 1
        named.append((req, base if seen[base] == 1 else f"{base}_{seen[base]}"))

    groups: dict[int, list] = {}
    for req, name in named:
        stride = 1
        if req.kind == "kernel":
            _, _, _, k_stride, takes_offset, _ = req.params
            if not takes_offset:
                stride = k_stride
        groups.setdefault(stride, []).append((req, name))
    return [(k, groups[k]) for k in sorted(groups)]


class StatPlan:
    """N estimator requests compiled into (almost always) one traversal.

    The monoid quartet mirrors `StreamingEngine` but carries a *tuple* of
    group states (one PartialState per fused traversal group; built-in
    requests always compile to a single group):

      ``init() / from_chunk / update / merge / consume / finalize``

    ``finalize`` returns ``{request_name: result}`` with results matching
    the independent estimator calls to float round-off.
    """

    def __init__(
        self,
        requests: Sequence[StatRequest],
        d: int,
        backend: BackendSpec = None,
        stage_dtype: Optional[str] = None,
        compensated: bool = False,
    ):
        if not requests:
            raise ValueError("a plan needs at least one request")
        self.backend = get_backend(backend)
        self.d = d
        self.stage_dtype = stage_dtype
        self.compensated = compensated
        self.groups = [
            _PlanGroup(
                [r for r, _ in grp],
                [n for _, n in grp],
                d,
                self.backend,
                stride,
                stage_dtype=stage_dtype,
                compensated=compensated,
            )
            for stride, grp in _group_requests(requests)
        ]
        # last (states, results) pair — see finalize().
        self._finalize_cache: Optional[Tuple[tuple, dict]] = None

    @property
    def engine(self) -> StreamingEngine:
        """The fused engine (single-group plans — every built-in request)."""
        if len(self.groups) != 1:
            raise ValueError(
                f"plan has {len(self.groups)} traversal groups; use the "
                f"group-tuple API (init/update/merge) instead of .engine"
            )
        return self.groups[0].engine

    @property
    def num_traversals(self) -> int:
        """Data passes one full evaluation costs (== number of groups)."""
        return len(self.groups)

    # -- monoid over the tuple of group states -----------------------------
    def init(self, t0: int | jax.Array = 0):
        return tuple(g.engine.init(t0) for g in self.groups)

    def from_chunk(self, chunk: jax.Array, t0: int | jax.Array = 0):
        return tuple(g.engine.from_chunk(chunk, t0) for g in self.groups)

    def update(self, states, chunk: jax.Array):
        return tuple(
            g.engine.update(s, chunk) for g, s in zip(self.groups, states)
        )

    def update_jit(self, states, chunk: jax.Array):
        """``update`` through each engine's cached jitted program — repeated
        ingest of same-shape chunks never re-traces (the append hot path)."""
        return tuple(
            g.engine.update_jit(s, chunk) for g, s in zip(self.groups, states)
        )

    def update_donated(self, states, chunk: jax.Array):
        """``update_jit`` with the carried group states DONATED: the old
        states' buffers are reused in place, so steady-state append ingest
        allocates nothing per chunk.  Callers must own ``states``
        exclusively — every other alias of the old tuple's arrays dies
        (`SeriesFrame.append` does; its memo caches compare by identity
        only and never re-read donated buffers)."""
        return tuple(
            g.engine.update_donated(s, chunk)
            for g, s in zip(self.groups, states)
        )

    def merge(self, a, b):
        return tuple(g.engine.merge(x, y) for g, x, y in zip(self.groups, a, b))

    def consume(self, states, chunks: jax.Array):
        """Scan-driven ingest of a (k, c, d) equal-length chunk stack —
        one ``lax.scan`` program per group, carried states donated."""
        return tuple(
            g.engine.consume(s, chunks) for g, s in zip(self.groups, states)
        )

    def finalize(self, states, cache: bool = True) -> dict:
        """Read out ``{request_name: result}`` for every member.

        Repeated queries against the SAME states tuple (no ingest between
        them) return the memoized results — zero primitive calls, zero
        traversals.  The cache is identity-keyed: any ``update`` / ``merge``
        / ``consume`` produces fresh state objects, which is exactly the
        invalidation rule.  Pass ``cache=False`` from traced contexts
        (vmapped multi-user finalizes) where memoizing tracers would be
        meaningless.
        """
        if (
            cache
            and self._finalize_cache is not None
            and len(self._finalize_cache[0]) == len(states)
            and all(a is b for a, b in zip(self._finalize_cache[0], states))
        ):
            return dict(self._finalize_cache[1])
        out = {}
        for g, s in zip(self.groups, states):
            out.update(g.finalize(s))
        if cache:
            self._finalize_cache = (tuple(states), out)
        return dict(out)


def fused_engine(
    requests: Sequence[StatRequest],
    d: int,
    backend: BackendSpec = None,
    stage_dtype: Optional[str] = None,
    compensated: bool = False,
) -> StatPlan:
    """Compile estimator requests into a fused :class:`StatPlan` (the
    product-monoid engine behind :func:`analyze`).  ``stage_dtype``
    (e.g. ``"bfloat16"``) narrows the megakernel's series staging while
    keeping f32 accumulation; ``compensated=True`` threads Neumaier error
    companions through every group's ⊕-folds (long-horizon drift control —
    see `repro.core.integrity`)."""
    return StatPlan(
        requests, d, backend, stage_dtype=stage_dtype, compensated=compensated
    )


def analyze(
    series: jax.Array,
    requests: Sequence[StatRequest],
    backend: BackendSpec = None,
    chunk_size: Optional[int] = None,
) -> dict:
    """Serve N estimator requests from one read of ``series``.

    Thin shim over the session API (`repro.core.frame.SeriesFrame`) — the
    one query path: requests are deferred onto a frame whose placement
    matches the call (a materialized array, or a chunked stream when
    ``chunk_size`` is given) and collected in a single fused traversal.

    Args:
      series: (n,) or (n, d).
      requests: built with the ``*_request`` factories, e.g.
        ``analyze(x, [autocovariance_request(8), yule_walker_request(4),
        moments_request(64), welch_request(256)])``.
      backend: compute-backend spec for every member contraction.
      chunk_size: when given, ingest scan-driven over equal chunks of this
        length (plus one ragged remainder update) instead of a monolithic
        chunk — the serving-shaped path; results are identical.

    Returns: {request_name: result} matching independent estimator calls.
    """
    from .frame import SeriesFrame

    x = series[:, None] if series.ndim == 1 else series
    if chunk_size is None:
        frame = SeriesFrame.from_array(x, backend=backend)
    else:
        n = x.shape[0]
        chunks = [
            x[lo : min(lo + chunk_size, n)] for lo in range(0, n, chunk_size)
        ]
        frame = SeriesFrame.from_chunks(chunks, backend=backend)
    for req in requests:
        frame._defer(req)
    return frame.collect()
