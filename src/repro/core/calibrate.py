"""Measured backend crossovers: the "auto" policy learns from the hardware.

The compute-backend registry (`repro.core.backend`) dispatches each of the
six primitive contractions to jnp or the Pallas tile kernels.  Where the
crossover sits — the problem size above which the tile kernel beats XLA's
fusion — is a property of the *hardware* (HBM bandwidth, MXU shape, grid
launch overhead), not something a constant in the source can know.  PR 2
shipped a single hard-coded ``min_rows=4096`` guess; this module replaces
it with measurement:

  * :func:`calibrate` microbenchmarks every registered primitive on both
    backends across a grid of problem sizes and derives a per-primitive
    **crossover threshold** — the smallest grid size at which the Pallas
    kernel wins and keeps winning for every larger size (``inf`` when it
    never does, e.g. interpret mode off-TPU);
  * the resulting :class:`CalibrationTable` is persisted to a per-platform
    cache file (:func:`save_table` / :func:`load_table`; the path honours
    ``REPRO_CALIB_CACHE``), so one calibration pass serves every later
    process on the same machine;
  * `repro.core.backend.AutoBackend` resolves its thresholds lazily at the
    first dispatch through :func:`resolve_table`: a cached measured table
    if one exists, else — on TPU, or when ``REPRO_AUTO_CALIBRATE=1`` — a
    fresh :func:`calibrate` run persisted for next time, else the built-in
    :func:`default_table` (off-accelerator the Pallas path is interpret
    mode, never profitable, so the default is "always jnp").

The built-in defaults are a *fallback*, not policy: any measured table,
cached or injected (``AutoBackend(table=...)``), overrides them.

Since the megakernel PR the table carries a second product next to the
crossovers: **tuned tile configurations**.  :func:`tune_blocks` searches the
per-primitive block-size space (``block_t`` for the windowed contractions
and the fused-plan megakernel, ``block_s`` for the segment-DFT family,
``block_rows`` for the banded matvec) on the Pallas backend and records the
winner in ``CalibrationTable.blocks``; every ``kernels/*`` ops entry point
resolves its tile size through :func:`active_blocks` (via
`repro.kernels.tiling.resolve_block`) instead of a hard-coded literal.
``calibrate(tune_blocks=True)`` runs both passes and persists one table.

Run it from the shell::

    python -m repro.core.calibrate --show          # resolved table
    python -m repro.core.calibrate --tune          # measure crossovers + blocks
    python -m repro.core.calibrate --bless t.json  # install a table file
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "PRIMITIVES",
    "TUNABLE_BLOCKS",
    "CalibrationTable",
    "block_all",
    "default_table",
    "cache_path",
    "load_table",
    "save_table",
    "resolve_table",
    "active_table",
    "active_blocks",
    "set_active_table",
    "calibrate",
    "tune_blocks",
    "main",
]

# The registered primitive contractions (`repro.core.backend.Backend`).
PRIMITIVES: Tuple[str, ...] = (
    "lagged_sums",
    "masked_lagged_sums",
    "windowed_moments",
    "segment_fft_power",
    "segment_csd",
    "banded_matvec",
    "fused_lagged_moments",
    "fused_plan_update",
)

# Built-in fallback crossovers when no measured table exists.  On TPU these
# are the PR 2 reasoning (tiles fill around 4k rows; the matmul-DFT needs
# more samples to amortize its O(L²) constant); everywhere else Pallas runs
# in interpret mode — a validation vehicle, never a serving path — so the
# crossover is "never".
_TPU_DEFAULTS: Dict[str, float] = {
    "lagged_sums": 4096.0,
    "masked_lagged_sums": 4096.0,
    "windowed_moments": 4096.0,
    "fused_lagged_moments": 4096.0,
    "fused_plan_update": 4096.0,
    "banded_matvec": 4096.0,
    "segment_fft_power": 32768.0,
    "segment_csd": 32768.0,
}

# Which tile parameter each primitive exposes to the block tuner, and the
# candidate grids :func:`tune_blocks` searches.
TUNABLE_BLOCKS: Dict[str, Tuple[str, ...]] = {
    "lagged_sums": ("block_t",),
    "masked_lagged_sums": ("block_t",),
    "windowed_moments": ("block_t",),
    "fused_lagged_moments": ("block_t",),
    "fused_plan_update": ("block_t",),
    "segment_fft_power": ("block_s",),
    "segment_csd": ("block_s",),
    "banded_matvec": ("block_rows",),
}
BLOCK_CANDIDATES: Dict[str, Tuple[int, ...]] = {
    "block_t": (128, 256, 512, 1024),
    "block_s": (2, 4, 8, 16),
    "block_rows": (128, 256, 512),
}


def _builtin_thresholds(platform: str) -> Dict[str, float]:
    if platform == "tpu":
        return dict(_TPU_DEFAULTS)
    return {p: math.inf for p in PRIMITIVES}


@dataclasses.dataclass
class CalibrationTable:
    """Per-primitive crossover thresholds + tuned tile configs, one platform.

    ``thresholds[name]`` is the problem size (rows for the windowed
    contractions, banded dimension for the matvec, total staged samples
    S·L for the segment DFT) at which the ``"auto"`` policy starts routing
    that primitive to the Pallas backend; ``math.inf`` means never.
    ``blocks[name]`` is the tuned tile configuration for that primitive's
    kernel (``{"block_t": 256}``, …) — written by :func:`tune_blocks`, read
    by every ``kernels/*`` ops entry point through
    `repro.kernels.tiling.resolve_block`.
    ``source`` records provenance: "default", "measured", or "cache".
    """

    platform: str
    thresholds: Dict[str, float]
    source: str = "default"
    blocks: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)

    def crossover(self, primitive: str) -> float:
        """Dispatch threshold for ``primitive``.

        A primitive absent from the table — e.g. a cached measurement that
        predates the primitive's registration — falls back to the BUILT-IN
        default for this table's platform, never to a KeyError and never
        to a blanket "always pallas": a stale cache degrades to the
        reasoned default, exactly what an uncalibrated machine gets.
        """
        if primitive in self.thresholds:
            return float(self.thresholds[primitive])
        return float(_builtin_thresholds(self.platform).get(primitive, math.inf))

    def block_config(self, primitive: str) -> Dict[str, int]:
        """Tuned tile config for ``primitive`` ({} when never tuned)."""
        return dict(self.blocks.get(primitive, {}))

    def to_json(self) -> dict:
        return {
            "platform": self.platform,
            # inf is not valid JSON — encode as null.
            "thresholds": {
                k: (None if math.isinf(v) else v)
                for k, v in self.thresholds.items()
            },
            "blocks": {
                k: {p: int(v) for p, v in cfg.items()}
                for k, cfg in self.blocks.items()
            },
            "source": self.source,
        }

    @classmethod
    def from_json(cls, payload: dict) -> "CalibrationTable":
        thresholds = {
            k: (math.inf if v is None else float(v))
            for k, v in payload.get("thresholds", {}).items()
        }
        blocks = {
            k: {p: int(v) for p, v in cfg.items()}
            for k, cfg in payload.get("blocks", {}).items()
        }
        return cls(
            platform=payload.get("platform", "unknown"),
            thresholds=thresholds,
            source=payload.get("source", "cache"),
            blocks=blocks,
        )


def default_table(platform: Optional[str] = None) -> CalibrationTable:
    """The built-in fallback table for ``platform`` (default: current)."""
    platform = platform or jax.default_backend()
    return CalibrationTable(
        platform, _builtin_thresholds(platform), source="default"
    )


def cache_path(platform: Optional[str] = None) -> str:
    """Where the measured table persists: ``$REPRO_CALIB_CACHE`` when set
    (one file, platform recorded inside), else
    ``~/.cache/repro/calibration_<platform>.json``."""
    env = os.environ.get("REPRO_CALIB_CACHE")
    if env:
        return env
    platform = platform or jax.default_backend()
    base = os.environ.get(
        "XDG_CACHE_HOME", os.path.join(os.path.expanduser("~"), ".cache")
    )
    return os.path.join(base, "repro", f"calibration_{platform}.json")


def load_table(platform: Optional[str] = None) -> Optional[CalibrationTable]:
    """The cached measured table for ``platform``, or None.  A cache written
    on a different platform is ignored, never misapplied.  A corrupt cache
    — truncated write, hand-edit gone wrong, valid JSON of the wrong shape
    — degrades to the built-in defaults with a warning instead of taking
    down every ``"auto"``-backend caller at first dispatch."""
    platform = platform or jax.default_backend()
    path = cache_path(platform)
    try:
        with open(path) as f:
            payload = json.load(f)
        table = CalibrationTable.from_json(payload)
    except OSError:
        return None
    except (ValueError, TypeError, KeyError, AttributeError) as e:
        warnings.warn(
            f"ignoring corrupt calibration cache {path!r} "
            f"({type(e).__name__}: {e}); using built-in defaults — "
            f"delete the file or re-run calibration to silence this",
            RuntimeWarning,
        )
        return None
    if table.platform != platform:
        return None
    table.source = "cache"
    return table


def save_table(table: CalibrationTable, path: Optional[str] = None) -> str:
    path = path or cache_path(table.platform)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(table.to_json(), f, indent=2)
        f.write("\n")
    return path


def _autocalibrate_default(platform: str) -> bool:
    env = os.environ.get("REPRO_AUTO_CALIBRATE")
    if env is not None:
        return env not in ("", "0", "false", "False")
    # First use on a TPU pays one measurement pass and caches it; elsewhere
    # the interpret-mode "measurement" would only confirm the default inf.
    return platform == "tpu"


def resolve_table(
    platform: Optional[str] = None, autocalibrate: Optional[bool] = None
) -> CalibrationTable:
    """The table the ``"auto"`` backend should dispatch with, resolved at
    first use: cached measurement > fresh measurement (TPU or
    ``REPRO_AUTO_CALIBRATE=1``) > built-in default."""
    platform = platform or jax.default_backend()
    cached = load_table(platform)
    if cached is not None:
        set_active_table(cached)
        return cached
    if autocalibrate is None:
        autocalibrate = _autocalibrate_default(platform)
    if autocalibrate:
        return calibrate(save=True)
    table = default_table(platform)
    set_active_table(table)
    return table


# The table tile-size resolution reads (`repro.kernels.tiling.resolve_block`
# → :func:`active_blocks`).  Split from the AutoBackend's lazy ``table``
# because block resolution must NEVER trigger a measurement pass: the
# measurement itself calls the kernels, which resolve their blocks — a
# recursive calibration would never terminate.  ``_ACTIVE`` is set by
# explicit installs (resolve_table / calibrate / tune_blocks /
# ``AutoBackend.set_table``); until one happens, reads fall through to the
# persisted cache (memoized on the file's mtime) or the defaults.
_ACTIVE: Optional[CalibrationTable] = None
_READ_CACHE: Optional[tuple] = None  # ((path, mtime), table)


def set_active_table(table: Optional[CalibrationTable]) -> None:
    """Install ``table`` as the process-wide tile/threshold source (None
    resets to lazy read-through — tests use this for isolation)."""
    global _ACTIVE
    _ACTIVE = table


def active_table() -> CalibrationTable:
    """The table block resolution dispatches with, WITHOUT ever measuring:
    the explicitly installed table > the persisted platform cache > the
    built-in defaults."""
    if _ACTIVE is not None:
        return _ACTIVE
    global _READ_CACHE
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        mtime = None
    key = (path, mtime)
    if _READ_CACHE is not None and _READ_CACHE[0] == key:
        return _READ_CACHE[1]
    table = load_table() or default_table()
    _READ_CACHE = (key, table)
    return table


def active_blocks(primitive: str) -> Dict[str, int]:
    """Tuned tile config for ``primitive`` from the active table ({} when
    never tuned — `repro.kernels.tiling` then applies its defaults)."""
    return active_table().block_config(primitive)


# ---------------------------------------------------------------- measurement
def block_all(out) -> None:
    """Block on EVERY jax leaf of ``out``, explicitly.

    A measurement must not return while any async leaf is still in flight:
    with donated-carry programs the visible leaf can materialize while
    sibling buffers are still being rewritten in place — blocking only the
    first leaf under-reports exactly the donation wins being measured.
    Non-array leaves (Python scalars in result dicts) are skipped.  Shared
    with the benchmark harness (`benchmarks.common`).
    """
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _time(fn, iters: int, warmup: int) -> float:
    """Median wall seconds per call, blocking on every output leaf."""
    for _ in range(warmup):
        block_all(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_all(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _workloads(
    n: int, d: int, max_lag: int, window: int, nperseg: int, bandwidth: int
) -> Dict[str, callable]:
    """One closure per primitive at problem size ``n``: builds the inputs
    once (outside the timed region) and returns ``fn(backend) -> callable``.
    Sizes are clamped so tiny grid points stay valid."""
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 4)
    H = min(max_lag, max(n - 1, 0))
    w = min(window, n)
    x = jax.random.normal(ks[0], (n, d))
    y = jax.random.normal(ks[1], (n + max(H, w - 1, 1), d))
    mask = jnp.ones((n,), jnp.bool_)
    L = min(nperseg, n)
    S = max(n // max(L, 1), 1)
    segs = jax.random.normal(ks[2], (S, L, d))
    taper = 0.5 - 0.5 * jnp.cos(2 * jnp.pi * jnp.arange(L) / max(L, 1))
    b = min(bandwidth, max((n - 1) // 2, 0))
    diags = jax.random.normal(ks[3], (n, 2 * b + 1))
    v = x[:, 0]

    z0 = jnp.asarray(0, jnp.int32)
    return {
        "lagged_sums": lambda be: (lambda: be.lagged_sums(x, H)),
        "masked_lagged_sums": lambda be: (
            lambda: be.masked_lagged_sums(y, mask, H)
        ),
        "windowed_moments": lambda be: (lambda: be.windowed_moments(x, w)),
        "segment_fft_power": lambda be: (
            lambda: be.segment_fft_power(segs, taper)
        ),
        "segment_csd": lambda be: (lambda: be.segment_csd(segs, taper)),
        "banded_matvec": lambda be: (lambda: be.banded_matvec(diags, v)),
        "fused_lagged_moments": lambda be: (
            lambda: be.fused_lagged_moments(y, mask, H, w)
        ),
        # the megakernel: a 3-family plan chunk update (lag + moments + DFT)
        "fused_plan_update": lambda be: (
            lambda: be.fused_plan_update(
                y, mask, z0, H, (w,), (L,), (max(L // 2, 1),), (taper,)
            )
        ),
    }


def calibrate(
    sizes: Sequence[int] = (512, 2048, 8192, 32768),
    d: int = 8,
    max_lag: int = 8,
    window: int = 64,
    nperseg: int = 256,
    bandwidth: int = 8,
    iters: int = 3,
    warmup: int = 1,
    backends: Tuple[str, str] = ("jnp", "pallas"),
    save: bool = True,
    path: Optional[str] = None,
    verbose: bool = False,
    tune_blocks: bool = False,
) -> CalibrationTable:
    """Measure per-primitive backend crossovers on THIS machine.

    For every primitive and every grid size, times the ``backends`` pair
    (median of ``iters`` after ``warmup``, blocking on every output leaf)
    and derives the crossover: the smallest grid size where the alternate
    backend is at least as fast as the baseline *and stays so for every
    larger size* — a single fluky win at one size does not flip the policy.
    ``inf`` (never) when no such size exists.

    Returns the measured :class:`CalibrationTable`; with ``save=True``
    (default) it is also persisted to the platform cache file so later
    processes skip the measurement.  Inject into a live policy with
    ``get_backend("auto").set_table(table)`` (a fresh process picks the
    cache up automatically).

    ``tune_blocks=True`` additionally runs the tile-size search
    (:func:`tune_blocks`) and records the winning per-primitive block
    configs in the same table — one calibration artifact carrying both the
    dispatch policy and the kernel geometry.
    """
    from .backend import get_backend

    base_be, alt_be = (get_backend(b) for b in backends)
    platform = jax.default_backend()
    sizes = sorted(set(int(s) for s in sizes))
    if not sizes:
        raise ValueError("need at least one calibration grid size")

    wins: Dict[str, list] = {p: [] for p in PRIMITIVES}
    for n in sizes:
        loads = _workloads(n, d, max_lag, window, nperseg, bandwidth)
        for prim in PRIMITIVES:
            t_base = _time(loads[prim](base_be), iters, warmup)
            t_alt = _time(loads[prim](alt_be), iters, warmup)
            wins[prim].append(t_alt <= t_base)
            if verbose:
                print(
                    f"calibrate {prim:<22s} n={n:<8d} "
                    f"{backends[0]}={t_base * 1e6:10.1f}us "
                    f"{backends[1]}={t_alt * 1e6:10.1f}us "
                    f"{'<<' if t_alt <= t_base else ''}"
                )

    thresholds: Dict[str, float] = {}
    for prim in PRIMITIVES:
        thr = math.inf
        # smallest size from which the alternate backend never loses again
        for i in range(len(sizes) - 1, -1, -1):
            if not wins[prim][i]:
                break
            thr = float(sizes[i])
        thresholds[prim] = thr

    table = CalibrationTable(platform, thresholds, source="measured")
    if tune_blocks:
        _tune_blocks_into(
            table,
            n=sizes[-1],
            d=d,
            max_lag=max_lag,
            window=window,
            nperseg=nperseg,
            bandwidth=bandwidth,
            iters=iters,
            warmup=warmup,
            verbose=verbose,
        )
    set_active_table(table)
    if save:
        # The measured table is the product; the cache is an optimization.
        # ``calibrate`` can run implicitly at the auto backend's first
        # dispatch (resolve_table), so an unwritable cache location must
        # not crash the user's first estimator call.
        try:
            save_table(table, path)
        except OSError as e:
            import warnings

            warnings.warn(
                f"calibration succeeded but the cache could not be written "
                f"({e}); the measured table is used for this process only"
            )
    return table


def _tune_blocks_into(
    table: CalibrationTable,
    n: int,
    d: int = 8,
    max_lag: int = 8,
    window: int = 64,
    nperseg: int = 256,
    bandwidth: int = 8,
    iters: int = 3,
    warmup: int = 1,
    verbose: bool = False,
) -> None:
    """Search :data:`BLOCK_CANDIDATES` per tunable primitive on the Pallas
    backend and record each winner in ``table.blocks`` (in place).

    The search times the SAME workload closures the crossover pass uses, one
    fresh ``PallasBackend`` per candidate so the tile size under test is the
    explicit override — the resolution chain (override > table > default)
    guarantees the measurement cannot read the very table it is writing.
    """
    from .backend import PallasBackend

    loads = _workloads(n, d, max_lag, window, nperseg, bandwidth)
    for prim, params in TUNABLE_BLOCKS.items():
        cfg: Dict[str, int] = {}
        for param in params:
            best_c, best_t = None, math.inf
            for cand in BLOCK_CANDIDATES[param]:
                be = PallasBackend(**{param: cand})
                t = _time(loads[prim](be), iters, warmup)
                if verbose:
                    print(
                        f"tune {prim:<22s} {param}={cand:<6d} "
                        f"{t * 1e6:10.1f}us"
                    )
                if t < best_t:
                    best_c, best_t = cand, t
            if best_c is not None:
                cfg[param] = int(best_c)
        if cfg:
            table.blocks[prim] = cfg


def tune_blocks(
    n: int = 32768,
    iters: int = 3,
    warmup: int = 1,
    save: bool = True,
    path: Optional[str] = None,
    verbose: bool = False,
) -> CalibrationTable:
    """Tile-size autotuning on top of the currently active table.

    Starts from :func:`active_table` (never triggers a crossover
    measurement), searches :data:`BLOCK_CANDIDATES` for every primitive in
    :data:`TUNABLE_BLOCKS`, merges the winners into ``table.blocks``,
    installs the result as the active table and (with ``save=True``)
    persists it to the platform cache.  ``calibrate(tune_blocks=True)`` is
    the one-shot that measures crossovers AND tunes blocks together.
    """
    base = active_table()
    table = CalibrationTable(
        platform=base.platform,
        thresholds=dict(base.thresholds),
        source=base.source,
        blocks={k: dict(v) for k, v in base.blocks.items()},
    )
    _tune_blocks_into(
        table, n=n, iters=iters, warmup=warmup, verbose=verbose
    )
    set_active_table(table)
    if save:
        try:
            save_table(table, path)
        except OSError as e:
            import warnings

            warnings.warn(
                f"block tuning succeeded but the cache could not be written "
                f"({e}); the tuned table is used for this process only"
            )
    return table


# ------------------------------------------------------------------------ CLI
def _print_table(table: CalibrationTable) -> None:
    print(f"platform: {table.platform}   source: {table.source}")
    print("crossover thresholds (rows; inf = always jnp):")
    for prim in PRIMITIVES:
        thr = table.crossover(prim)
        star = "" if prim in table.thresholds else "  (built-in default)"
        print(f"  {prim:<22s} {thr!r:>10}{star}")
    print("tuned tile configs (empty = kernels use built-in defaults):")
    if not table.blocks:
        print("  (none)")
    for prim, cfg in sorted(table.blocks.items()):
        pretty = ", ".join(f"{k}={v}" for k, v in sorted(cfg.items()))
        print(f"  {prim:<22s} {pretty}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    """``python -m repro.core.calibrate`` — inspect / measure / install the
    calibration table.

    ``--show``         print the resolved active table (default action)
    ``--tune``         measure crossovers AND tune tile sizes, persist
    ``--tune-blocks``  tile-size search only, on top of the active table
    ``--bless PATH``   install a table JSON file as this platform's cache
    """
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.core.calibrate",
        description="Measure, inspect, or install the backend calibration "
        "table (crossover thresholds + tuned tile configs).",
    )
    parser.add_argument(
        "--show", action="store_true",
        help="print the resolved active table (default when no action given)",
    )
    parser.add_argument(
        "--tune", action="store_true",
        help="measure backend crossovers and tune tile sizes, then persist "
        "to the platform cache",
    )
    parser.add_argument(
        "--tune-blocks", action="store_true",
        help="run only the tile-size search on top of the active table",
    )
    parser.add_argument(
        "--bless", metavar="PATH", default=None,
        help="validate the table JSON at PATH and install it as this "
        "platform's cache file",
    )
    parser.add_argument(
        "--no-save", action="store_true",
        help="with --tune/--tune-blocks: measure but do not write the cache",
    )
    parser.add_argument("-v", "--verbose", action="store_true")
    args = parser.parse_args(argv)

    if args.bless:
        try:
            with open(args.bless) as f:
                table = CalibrationTable.from_json(json.load(f))
        except OSError as e:
            print(f"cannot read {args.bless}: {e}")
            return 1
        except (ValueError, TypeError, KeyError, AttributeError) as e:
            print(
                f"refusing to bless {args.bless}: not a valid calibration "
                f"table ({type(e).__name__}: {e})"
            )
            return 1
        platform = jax.default_backend()
        if table.platform != platform:
            print(
                f"refusing to bless: table platform {table.platform!r} != "
                f"current platform {platform!r}",
            )
            return 1
        dest = save_table(table)
        set_active_table(table)
        print(f"blessed {args.bless} -> {dest}")
        _print_table(table)
        return 0

    if args.tune:
        table = calibrate(
            save=not args.no_save, verbose=args.verbose, tune_blocks=True
        )
    elif args.tune_blocks:
        table = tune_blocks(save=not args.no_save, verbose=args.verbose)
    else:
        table = active_table()
    _print_table(table)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
