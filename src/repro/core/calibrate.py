"""Measured backend crossovers: the "auto" policy learns from the hardware.

The compute-backend registry (`repro.core.backend`) dispatches each of the
six primitive contractions to jnp or the Pallas tile kernels.  Where the
crossover sits — the problem size above which the tile kernel beats XLA's
fusion — is a property of the *hardware* (HBM bandwidth, MXU shape, grid
launch overhead), not something a constant in the source can know.  PR 2
shipped a single hard-coded ``min_rows=4096`` guess; this module replaces
it with measurement:

  * :func:`calibrate` microbenchmarks every registered primitive on both
    backends across a grid of problem sizes and derives a per-primitive
    **crossover threshold** — the smallest grid size at which the Pallas
    kernel wins and keeps winning for every larger size (``inf`` when it
    never does, e.g. interpret mode off-TPU);
  * the resulting :class:`CalibrationTable` is persisted to a per-platform
    cache file (:func:`save_table` / :func:`load_table`; the path honours
    ``REPRO_CALIB_CACHE``), so one calibration pass serves every later
    process on the same machine;
  * `repro.core.backend.AutoBackend` resolves its thresholds lazily at the
    first dispatch through :func:`resolve_table`: a cached measured table
    if one exists, else — on TPU, or when ``REPRO_AUTO_CALIBRATE=1`` — a
    fresh :func:`calibrate` run persisted for next time, else the built-in
    :func:`default_table` (off-accelerator the Pallas path is interpret
    mode, never profitable, so the default is "always jnp").

The built-in defaults are a *fallback*, not policy: any measured table,
cached or injected (``AutoBackend(table=...)``), overrides them.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "PRIMITIVES",
    "CalibrationTable",
    "block_all",
    "default_table",
    "cache_path",
    "load_table",
    "save_table",
    "resolve_table",
    "calibrate",
]

# The six registered primitive contractions (`repro.core.backend.Backend`).
PRIMITIVES: Tuple[str, ...] = (
    "lagged_sums",
    "masked_lagged_sums",
    "windowed_moments",
    "segment_fft_power",
    "banded_matvec",
    "fused_lagged_moments",
)

# Built-in fallback crossovers when no measured table exists.  On TPU these
# are the PR 2 reasoning (tiles fill around 4k rows; the matmul-DFT needs
# more samples to amortize its O(L²) constant); everywhere else Pallas runs
# in interpret mode — a validation vehicle, never a serving path — so the
# crossover is "never".
_TPU_DEFAULTS: Dict[str, float] = {
    "lagged_sums": 4096.0,
    "masked_lagged_sums": 4096.0,
    "windowed_moments": 4096.0,
    "fused_lagged_moments": 4096.0,
    "banded_matvec": 4096.0,
    "segment_fft_power": 32768.0,
}


@dataclasses.dataclass
class CalibrationTable:
    """Per-primitive crossover thresholds for one platform.

    ``thresholds[name]`` is the problem size (rows for the windowed
    contractions, banded dimension for the matvec, total staged samples
    S·L for the segment DFT) at which the ``"auto"`` policy starts routing
    that primitive to the Pallas backend; ``math.inf`` means never.
    ``source`` records provenance: "default", "measured", or "cache".
    """

    platform: str
    thresholds: Dict[str, float]
    source: str = "default"

    def crossover(self, primitive: str) -> float:
        return float(self.thresholds.get(primitive, math.inf))

    def to_json(self) -> dict:
        return {
            "platform": self.platform,
            # inf is not valid JSON — encode as null.
            "thresholds": {
                k: (None if math.isinf(v) else v)
                for k, v in self.thresholds.items()
            },
            "source": self.source,
        }

    @classmethod
    def from_json(cls, payload: dict) -> "CalibrationTable":
        thresholds = {
            k: (math.inf if v is None else float(v))
            for k, v in payload.get("thresholds", {}).items()
        }
        return cls(
            platform=payload.get("platform", "unknown"),
            thresholds=thresholds,
            source=payload.get("source", "cache"),
        )


def default_table(platform: Optional[str] = None) -> CalibrationTable:
    """The built-in fallback table for ``platform`` (default: current)."""
    platform = platform or jax.default_backend()
    if platform == "tpu":
        thresholds = dict(_TPU_DEFAULTS)
    else:
        thresholds = {p: math.inf for p in PRIMITIVES}
    return CalibrationTable(platform, thresholds, source="default")


def cache_path(platform: Optional[str] = None) -> str:
    """Where the measured table persists: ``$REPRO_CALIB_CACHE`` when set
    (one file, platform recorded inside), else
    ``~/.cache/repro/calibration_<platform>.json``."""
    env = os.environ.get("REPRO_CALIB_CACHE")
    if env:
        return env
    platform = platform or jax.default_backend()
    base = os.environ.get(
        "XDG_CACHE_HOME", os.path.join(os.path.expanduser("~"), ".cache")
    )
    return os.path.join(base, "repro", f"calibration_{platform}.json")


def load_table(platform: Optional[str] = None) -> Optional[CalibrationTable]:
    """The cached measured table for ``platform``, or None.  A cache written
    on a different platform is ignored, never misapplied."""
    platform = platform or jax.default_backend()
    path = cache_path(platform)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    table = CalibrationTable.from_json(payload)
    if table.platform != platform:
        return None
    table.source = "cache"
    return table


def save_table(table: CalibrationTable, path: Optional[str] = None) -> str:
    path = path or cache_path(table.platform)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(table.to_json(), f, indent=2)
        f.write("\n")
    return path


def _autocalibrate_default(platform: str) -> bool:
    env = os.environ.get("REPRO_AUTO_CALIBRATE")
    if env is not None:
        return env not in ("", "0", "false", "False")
    # First use on a TPU pays one measurement pass and caches it; elsewhere
    # the interpret-mode "measurement" would only confirm the default inf.
    return platform == "tpu"


def resolve_table(
    platform: Optional[str] = None, autocalibrate: Optional[bool] = None
) -> CalibrationTable:
    """The table the ``"auto"`` backend should dispatch with, resolved at
    first use: cached measurement > fresh measurement (TPU or
    ``REPRO_AUTO_CALIBRATE=1``) > built-in default."""
    platform = platform or jax.default_backend()
    cached = load_table(platform)
    if cached is not None:
        return cached
    if autocalibrate is None:
        autocalibrate = _autocalibrate_default(platform)
    if autocalibrate:
        return calibrate(save=True)
    return default_table(platform)


# ---------------------------------------------------------------- measurement
def block_all(out) -> None:
    """Block on EVERY jax leaf of ``out``, explicitly.

    A measurement must not return while any async leaf is still in flight:
    with donated-carry programs the visible leaf can materialize while
    sibling buffers are still being rewritten in place — blocking only the
    first leaf under-reports exactly the donation wins being measured.
    Non-array leaves (Python scalars in result dicts) are skipped.  Shared
    with the benchmark harness (`benchmarks.common`).
    """
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _time(fn, iters: int, warmup: int) -> float:
    """Median wall seconds per call, blocking on every output leaf."""
    for _ in range(warmup):
        block_all(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_all(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _workloads(
    n: int, d: int, max_lag: int, window: int, nperseg: int, bandwidth: int
) -> Dict[str, callable]:
    """One closure per primitive at problem size ``n``: builds the inputs
    once (outside the timed region) and returns ``fn(backend) -> callable``.
    Sizes are clamped so tiny grid points stay valid."""
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 4)
    H = min(max_lag, max(n - 1, 0))
    w = min(window, n)
    x = jax.random.normal(ks[0], (n, d))
    y = jax.random.normal(ks[1], (n + max(H, w - 1, 1), d))
    mask = jnp.ones((n,), jnp.bool_)
    L = min(nperseg, n)
    S = max(n // max(L, 1), 1)
    segs = jax.random.normal(ks[2], (S, L, d))
    taper = 0.5 - 0.5 * jnp.cos(2 * jnp.pi * jnp.arange(L) / max(L, 1))
    b = min(bandwidth, max((n - 1) // 2, 0))
    diags = jax.random.normal(ks[3], (n, 2 * b + 1))
    v = x[:, 0]

    return {
        "lagged_sums": lambda be: (lambda: be.lagged_sums(x, H)),
        "masked_lagged_sums": lambda be: (
            lambda: be.masked_lagged_sums(y, mask, H)
        ),
        "windowed_moments": lambda be: (lambda: be.windowed_moments(x, w)),
        "segment_fft_power": lambda be: (
            lambda: be.segment_fft_power(segs, taper)
        ),
        "banded_matvec": lambda be: (lambda: be.banded_matvec(diags, v)),
        "fused_lagged_moments": lambda be: (
            lambda: be.fused_lagged_moments(y, mask, H, w)
        ),
    }


def calibrate(
    sizes: Sequence[int] = (512, 2048, 8192, 32768),
    d: int = 8,
    max_lag: int = 8,
    window: int = 64,
    nperseg: int = 256,
    bandwidth: int = 8,
    iters: int = 3,
    warmup: int = 1,
    backends: Tuple[str, str] = ("jnp", "pallas"),
    save: bool = True,
    path: Optional[str] = None,
    verbose: bool = False,
) -> CalibrationTable:
    """Measure per-primitive backend crossovers on THIS machine.

    For every primitive and every grid size, times the ``backends`` pair
    (median of ``iters`` after ``warmup``, blocking on every output leaf)
    and derives the crossover: the smallest grid size where the alternate
    backend is at least as fast as the baseline *and stays so for every
    larger size* — a single fluky win at one size does not flip the policy.
    ``inf`` (never) when no such size exists.

    Returns the measured :class:`CalibrationTable`; with ``save=True``
    (default) it is also persisted to the platform cache file so later
    processes skip the measurement.  Inject into a live policy with
    ``get_backend("auto").set_table(table)`` (a fresh process picks the
    cache up automatically).
    """
    from .backend import get_backend

    base_be, alt_be = (get_backend(b) for b in backends)
    platform = jax.default_backend()
    sizes = sorted(set(int(s) for s in sizes))
    if not sizes:
        raise ValueError("need at least one calibration grid size")

    wins: Dict[str, list] = {p: [] for p in PRIMITIVES}
    for n in sizes:
        loads = _workloads(n, d, max_lag, window, nperseg, bandwidth)
        for prim in PRIMITIVES:
            t_base = _time(loads[prim](base_be), iters, warmup)
            t_alt = _time(loads[prim](alt_be), iters, warmup)
            wins[prim].append(t_alt <= t_base)
            if verbose:
                print(
                    f"calibrate {prim:<22s} n={n:<8d} "
                    f"{backends[0]}={t_base * 1e6:10.1f}us "
                    f"{backends[1]}={t_alt * 1e6:10.1f}us "
                    f"{'<<' if t_alt <= t_base else ''}"
                )

    thresholds: Dict[str, float] = {}
    for prim in PRIMITIVES:
        thr = math.inf
        # smallest size from which the alternate backend never loses again
        for i in range(len(sizes) - 1, -1, -1):
            if not wins[prim][i]:
                break
            thr = float(sizes[i])
        thresholds[prim] = thr

    table = CalibrationTable(platform, thresholds, source="measured")
    if save:
        # The measured table is the product; the cache is an optimization.
        # ``calibrate`` can run implicitly at the auto backend's first
        # dispatch (resolve_table), so an unwritable cache location must
        # not crash the user's first estimator call.
        try:
            save_table(table, path)
        except OSError as e:
            import warnings

            warnings.warn(
                f"calibration succeeded but the cache could not be written "
                f"({e}); the measured table is used for this process only"
            )
    return table
