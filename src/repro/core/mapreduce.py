"""Weak-memory map-reduce engine (paper §7, §8, §10.2.1).

An *order-(h_left, h_right) weak-memory estimator* is

    Est(X)  =  Σ_{t}  k( window(t) ),      window(t) = X[t-h_left : t+h_right]

for a commutative-associative ⊕ (here: pytree sum, or any user ⊕).  This
module provides three execution strategies that are **bit-identical** in
result (property-tested):

  * :func:`serial_window_map_reduce` — the obvious single-node loop
    (vectorized with vmap), the correctness oracle;
  * :func:`block_window_map_reduce` — per-block partial reduction over an
    overlapping block structure (`repro.core.overlap`), then a global
    reduce.  Each block only touches its own padded data — zero shuffle:
    the paper's embarrassingly-parallel scheme;
  * :func:`sharded_window_map_reduce` — the same, with the block axis
    sharded over a mesh axis via shard_map and the final reduce as a single
    `psum` — the cluster-level instantiation.

Estimators that admit a faster algebraic form (autocovariance = lagged
matmuls feeding the MXU) bypass the per-center vmap by passing a
``chunk_kernel`` (masked-window reducer) built from a `repro.core.backend`
primitive — the same registry that picks between pure jnp and the Pallas
VMEM tile kernels of `repro.kernels.window_stats`; see
`repro.core.estimators.stats.block_lag_sums`.

A fourth strategy lives in `repro.core.streaming`: the same ⊕ exposed as an
explicit **PartialState monoid** (init / update(chunk) / merge / finalize)
for data that is not fully materialized — chunks of arbitrary uneven sizes,
arriving over time, possibly on different machines, with an optional
vmapped batch axis over independent series.  Estimators opt in by providing
a ``ChunkKernel`` (masked-window reducer) front-end: `stats.lag_sum_engine`
(autocovariance → Yule-Walker → ARMA) and `spectral.welch_engine` are the
references.  All four strategies are pinned to each other by
`tests/test_streaming.py`.  On a mesh, per-shard partials built from
halo-complete blocks merge with the single psum of
`repro.parallel.sharding.psum_tree`.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .overlap import OverlapSpec, make_overlapping_blocks

__all__ = [
    "tree_sum",
    "tree_zeros_like",
    "serial_window_map_reduce",
    "block_window_map_reduce",
    "scan_window_map_reduce",
    "sharded_window_map_reduce",
    "block_partials",
]

KernelFn = Callable[[jax.Array], Any]  # (window, d) -> pytree contribution


def tree_sum(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_zeros_like(a: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, a)


def _mask_tree(tree: Any, mask: jax.Array) -> Any:
    """Zero out contributions of invalid centers.  mask: (...,) bools matching
    the leading axes of every leaf."""

    def m(leaf):
        mb = mask.reshape(mask.shape + (1,) * (leaf.ndim - mask.ndim))
        return jnp.where(mb, leaf, 0)

    return jax.tree.map(m, tree)


def _windows(x: jax.Array, h_left: int, h_right: int) -> jax.Array:
    """All width-(h_l+1+h_r) windows of x: (n_centers, W, d).

    Centers run over t ∈ [h_left, n - h_right); edge samples with incomplete
    windows are *not* centers (they are exactly the paper's halo samples —
    owned by the neighbouring computation).
    """
    n = x.shape[0]
    w = h_left + 1 + h_right
    n_centers = n - h_left - h_right
    if n_centers <= 0:
        raise ValueError(f"series of length {n} has no full window of width {w}")
    starts = jnp.arange(n_centers)
    return jax.vmap(lambda s: jax.lax.dynamic_slice_in_dim(x, s, w, axis=0))(starts)


def serial_window_map_reduce(
    kernel: KernelFn,
    x: jax.Array,
    h_left: int,
    h_right: int,
) -> Any:
    """Oracle path: Σ_t k(X[t-h_l : t+h_r]) over all complete windows."""
    if x.ndim == 1:
        x = x[:, None]
    wins = _windows(x, h_left, h_right)
    contribs = jax.vmap(kernel)(wins)
    return jax.tree.map(lambda l: jnp.sum(l, axis=0), contribs)


def block_partials(
    kernel: Optional[KernelFn],
    blocks: jax.Array,
    spec: OverlapSpec,
    block_offset: jax.Array | int = 0,
    chunk_kernel: Optional[Callable] = None,
) -> Any:
    """Per-block partial sums: pytree with leading axis P_local.

    Every center in a block's *core* whose full window is globally valid
    contributes; centers whose window would cross the global series boundary
    are masked out (matching the serial estimator's center range exactly).

    ``block_offset`` is the global id of ``blocks[0]`` — pass
    ``jax.lax.axis_index(axis) * blocks_per_device`` when calling from inside
    shard_map on a sharded block axis (it participates in tracing).

    ``chunk_kernel`` (the `repro.core.streaming.ChunkKernel` contract:
    ``(y_padded, start_mask) → pytree``) replaces the per-center vmap with a
    fused masked-window reducer — a halo-padded block IS a valid
    ``y_padded`` with its core starts as the mask.  Build one from a
    `repro.core.backend` primitive (e.g. ``masked_lagged_sums``) to run the
    block engine through the Pallas tile path; ``kernel`` may then be None.
    """
    p_local = blocks.shape[0]
    per_block = _block_reducer(kernel, chunk_kernel, spec)
    block_ids = jnp.asarray(block_offset) + jnp.arange(p_local)
    return jax.vmap(per_block)(blocks, _core_valid_mask(block_ids, spec))


def _core_valid_mask(block_ids: jax.Array, spec: OverlapSpec) -> jax.Array:
    """Validity of each block-core center's full window against the GLOBAL
    series boundary (matching the serial estimator's center range)."""
    centers = block_ids[..., None] * spec.block_size + jnp.arange(spec.block_size)
    valid = (centers - spec.h_left >= 0) & (centers + spec.h_right <= spec.n - 1)
    # Tail padding in the last block duplicates clamped centers; mask those too.
    return valid & (centers < spec.n)


def _block_reducer(
    kernel: Optional[KernelFn], chunk_kernel: Optional[Callable], spec: OverlapSpec
) -> Callable:
    """(block, valid_mask) → pytree partial — shared by the vmapped
    (`block_partials`) and scan-folded (`scan_window_map_reduce`) paths."""
    if chunk_kernel is not None:
        return chunk_kernel
    if kernel is None:
        raise ValueError("need a per-window kernel or a chunk_kernel")

    def per_block(block, mask):
        wins = _windows(block, spec.h_left, spec.h_right)  # (block_size, W, d)
        contribs = jax.vmap(kernel)(wins)
        contribs = _mask_tree(contribs, mask)
        return jax.tree.map(lambda l: jnp.sum(l, axis=0), contribs)

    return per_block


def block_window_map_reduce(
    kernel: Optional[KernelFn],
    x: jax.Array,
    spec: OverlapSpec,
    chunk_kernel: Optional[Callable] = None,
) -> Any:
    """Embarrassingly-parallel path on one host: build overlapping blocks,
    reduce each independently, sum the P partials."""
    blocks, _ = make_overlapping_blocks(x, spec)
    partials = block_partials(kernel, blocks, spec, chunk_kernel=chunk_kernel)
    return jax.tree.map(lambda l: jnp.sum(l, axis=0), partials)


def scan_window_map_reduce(
    kernel: Optional[KernelFn],
    x: jax.Array,
    spec: OverlapSpec,
    chunk_kernel: Optional[Callable] = None,
) -> Any:
    """`block_window_map_reduce` with ``lax.scan`` accumulation: identical
    result, but the running ⊕-carry replaces the materialized (P, …)
    partial stack — O(1) memory in the block count and ONE device program
    for the whole sweep (no per-block Python dispatch).

    This is the single-host analogue of the streaming engine's
    ``consume`` path: use it when P is large enough that a stacked
    partial pytree (or the XLA fusion over it) stops fitting, or when the
    sweep runs inside a jit where sequential accumulation pipelines better
    than a P-way vmap.
    """
    blocks, _ = make_overlapping_blocks(x, spec)
    per_block = _block_reducer(kernel, chunk_kernel, spec)
    masks = _core_valid_mask(jnp.arange(blocks.shape[0]), spec)
    init = jax.eval_shape(per_block, blocks[0], masks[0])
    init = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), init)

    def step(acc, inputs):
        block, mask = inputs
        return tree_sum(acc, per_block(block, mask)), None

    acc, _ = jax.lax.scan(step, init, (blocks, masks))
    return acc


def sharded_window_map_reduce(
    kernel: Optional[KernelFn],
    blocks: jax.Array,
    spec: OverlapSpec,
    mesh: Mesh,
    axis: str = "data",
    chunk_kernel: Optional[Callable] = None,
) -> Any:
    """Cluster path: block axis sharded over ``axis``; one psum at the end.

    ``blocks`` must already be device-put with the leading (P) axis sharded
    over ``axis`` (see `repro.timeseries.dataset.TimeSeriesStore`).  This is
    the paper's Spark scheme verbatim: the only cross-device communication is
    the final reduction of the (tiny) sufficient statistics, never the data.
    """
    if spec.num_blocks % mesh.shape[axis] != 0:
        raise ValueError(
            f"num_blocks {spec.num_blocks} must divide evenly over mesh axis "
            f"{axis}={mesh.shape[axis]}"
        )

    blocks_per_device = spec.num_blocks // mesh.shape[axis]

    def local(blocks_local):
        from ..parallel.sharding import psum_tree

        offset = jax.lax.axis_index(axis) * blocks_per_device
        partials = block_partials(
            kernel, blocks_local, spec, block_offset=offset, chunk_kernel=chunk_kernel
        )
        local_sum = jax.tree.map(lambda l: jnp.sum(l, axis=0), partials)
        return psum_tree(local_sum, axis)

    from ..parallel.sharding import shard_map_compat

    fn = shard_map_compat(local, mesh=mesh, in_specs=P(axis), out_specs=P())
    return fn(blocks)
