"""Unified kernel-backend dispatch: one compute registry for every estimator.

The paper's thesis (§12) is that the overlapping-block weak-memory scheme is
*system-agnostic* — the identical map-reduce runs on Spark executors or on
GPU shared-memory tiles.  This module makes the execution substrate a
pluggable policy instead of a fork in every call site: every weak-memory
estimator in the repo reduces to a handful of primitive contractions, and a
:class:`Backend` supplies one implementation of each:

  ``lagged_sums(x, max_lag)``            S(h) = Σ_k x_k x_{k+h}ᵀ (ragged
                                         full sums, the autocovariance core)
  ``masked_lagged_sums(y, mask, H)``     Σ_{s: mask[s]} y_s y_{s+h}ᵀ — the
                                         streaming ChunkKernel form
  ``windowed_moments(x, window)``        per-window [Σx, Σx²] (rolling
                                         mean/variance)
  ``segment_fft_power(segs, taper)``     per-segment |rfft|² (Welch / Whittle)
  ``banded_matvec(diags, x)``            x̂ = A x for b-banded A (§6.1)
  ``fused_lagged_moments(y, mask, H, w)``  masked lagged sums AND masked
                                         windowed-moment sums from ONE
                                         traversal — the fused-plan
                                         primitive (`repro.core.plan`): on
                                         the Pallas backend both statistics
                                         are emitted from a single VMEM
                                         staging of each tile (one HBM read
                                         instead of two).  ``w`` may be an
                                         int (→ (2, d) moments) or a tuple
                                         of DISTINCT windows (→ (K, 2, d)):
                                         every window is accumulated from
                                         the same resident tile, so a plan
                                         tracking rolling moments at K
                                         horizons still costs one traversal
  ``segment_csd(segs, taper)``           per-segment complex cross-spectral
                                         products rfft_i·conj(rfft_j) — the
                                         Whittle/coherence core; on Pallas
                                         four real contractions of the
                                         resident segment, recombined to
                                         complex64 outside the kernel
  ``fused_plan_update(y, mask, z0, …)``  the fused-plan MEGAKERNEL: masked
                                         lagged sums + K moment windows +
                                         M Welch segment-power accumulators
                                         from ONE grid walk — on Pallas
                                         each chunk tile is staged into
                                         VMEM once and feeds every member
                                         family (one launch, one HBM read,
                                         down from one per family); on jnp
                                         a composition of the primitives
                                         above (the parity oracle)

Backends in the registry:

  ``"jnp"``     pure jax.numpy on whatever XLA device is active — the
                correctness oracle and the CPU/cluster default.
  ``"pallas"``  explicit VMEM tile kernels (`repro.kernels.window_stats`,
                `repro.kernels.banded_matvec`,
                `repro.kernels.segment_dft`) — the TPU re-instantiation of
                the paper's §12 GPU shared-memory scheme.  Runs in interpret
                mode off-TPU so CPU tests exercise the identical tiling.
                Every primitive has a real kernel: the spectral ones
                evaluate the fixed-L real DFT as tiled matmuls against
                precomputed twiddle/window matrices, and
                ``fused_plan_update`` is a persistent MEGAKERNEL serving a
                whole fused plan from one grid walk.  Tile sizes resolve
                through the calibrated block table
                (``calibrate(tune_blocks=True)``) unless pinned explicitly.
  ``"auto"``    per-call policy (the default): each primitive routes to
                Pallas once its problem size crosses a **measured**,
                per-primitive threshold (`repro.core.calibrate`).  The
                thresholds resolve lazily at first dispatch — a cached
                calibration if one exists, a fresh microbenchmark pass on
                TPU (persisted for next time), the built-in default table
                otherwise (off-accelerator that table says "always jnp":
                interpret mode is a testing vehicle, not a serving path).
                There is no hard-coded row constant left in the policy;
                re-measure with ``repro.core.calibrate.calibrate()``.

Registering a new backend (a GPU Triton port, a CPU-vectorized build, …):

    class TritonBackend: ...    # implement the primitive contractions
    register_backend("triton", TritonBackend())
    gamma = autocovariance(x, 8, backend="triton")

Every estimator (`estimators.stats`, `estimators.spectral`,
`estimators.yule_walker`, `estimators.arma`, `estimators.spatial`), the
streaming engine (`core.streaming` — its ChunkKernels are built from
``masked_lagged_sums`` / ``segment_fft_power``), the block/sharded paths
(`core.mapreduce`, `parallel.sharding`), and the serving ingest lanes
(`serving.rolling`) accept ``backend=`` (a name or a Backend instance) and
route through this registry — changing where the math runs is a config knob,
never an estimator rewrite.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Protocol, Union, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = [
    "Backend",
    "JnpBackend",
    "PallasBackend",
    "AutoBackend",
    "CircuitBreakerBackend",
    "PRIMITIVE_NAMES",
    "register_backend",
    "get_backend",
    "list_backends",
    "set_default_backend",
]

BackendSpec = Union[None, str, "Backend"]

# The canonical primitive-contraction names of the Backend protocol below —
# what the circuit breaker quarantines per-name and what
# `repro.core.calibrate` measures per-name.
PRIMITIVE_NAMES: tuple = (
    "lagged_sums",
    "masked_lagged_sums",
    "windowed_moments",
    "segment_fft_power",
    "segment_csd",
    "banded_matvec",
    "fused_lagged_moments",
    "fused_plan_update",
)


@runtime_checkable
class Backend(Protocol):
    """The primitive contractions every weak-memory estimator reduces to."""

    name: str

    def lagged_sums(self, x: jax.Array, max_lag: int) -> jax.Array:
        """(n, d) → (max_lag+1, d, d): S(h) = Σ_{k=0}^{n-1-h} x_k x_{k+h}ᵀ."""
        ...

    def masked_lagged_sums(
        self, y_padded: jax.Array, start_mask: jax.Array, max_lag: int
    ) -> jax.Array:
        """Σ_{s: start_mask[s]} y_s y_{s+h}ᵀ → (max_lag+1, d, d).

        ``y_padded`` carries ≥ L rows (L = len(start_mask)); rows
        [s, s+max_lag] are read for every unmasked start (zero-extended when
        shorter than L + max_lag).  This is the streaming ChunkKernel form.
        """
        ...

    def windowed_moments(self, x: jax.Array, window: int) -> jax.Array:
        """(n, d) → (n-window+1, 2, d) of per-window [Σ x, Σ x²]."""
        ...

    def segment_fft_power(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        """(S, W, d) segments → (S, W//2+1, d) per-segment |rfft|² power."""
        ...

    def banded_matvec(self, diags: jax.Array, x: jax.Array) -> jax.Array:
        """(d, 2b+1) stacked diagonals, x (..., d) → A x (..., d)."""
        ...

    def fused_lagged_moments(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        max_lag: int,
        window: "int | tuple",
    ) -> tuple:
        """One traversal → (lag (max_lag+1, d, d), mom).

        ``lag`` is exactly ``masked_lagged_sums(y_padded, start_mask,
        max_lag)``; ``mom`` is Σ_{s: mask} Σ_{j<w} [y_{s+j}, y²_{s+j}]
        — the product-monoid stat a fused statistics plan carries.
        ``window`` is an int (``mom`` is (2, d)) or a tuple of distinct
        windows (``mom`` is (len(window), 2, d), row k for ``window[k]``);
        either way the series is walked once.
        """
        ...

    def segment_csd(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        """(S, W, d) segments → (S, W//2+1, d, d) complex64 per-segment
        cross-spectral products rfft_i · conj(rfft_j) (Hermitian in i, j)."""
        ...

    def fused_plan_update(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        z0: jax.Array,
        max_lag: int,
        windows: tuple = (),
        seg_lens: tuple = (),
        seg_steps: tuple = (),
        tapers: tuple = (),
        detrend: bool = True,
        stage_dtype: "str | None" = None,
    ) -> tuple:
        """EVERY fused-plan member family from one traversal of the chunk.

        Returns ``(lag, mom, psds, n_segs)``: ``lag`` is
        ``masked_lagged_sums(y_padded, start_mask, max_lag)``; ``mom`` is
        the (K, 2, d) multi-window moment stat of ``fused_lagged_moments``
        (None when ``windows`` is empty); ``psds[j]`` is the (W_j//2+1, d)
        sum of detrended, tapered |rfft|² over every Welch segment of
        member j — segments start at local rows ``c`` with ``(z0 + c) %
        seg_steps[j] == 0``, ``c < L`` and ``start_mask[c]`` — and
        ``n_segs[j]`` counts them.  ``stage_dtype`` (e.g. "bfloat16")
        narrows the series staging; accumulation stays f32.
        """
        ...


def _as_2d(x: jax.Array) -> jax.Array:
    return x[:, None] if x.ndim == 1 else x


class JnpBackend:
    """Pure jax.numpy implementations — the correctness oracle.

    All accumulation happens in float32 whatever the input dtype, matching
    the Pallas kernels' ``preferred_element_type`` so cross-backend parity
    holds for bf16 inputs too.
    """

    name = "jnp"

    def lagged_sums(self, x: jax.Array, max_lag: int) -> jax.Array:
        x = _as_2d(x).astype(jnp.float32)
        n = x.shape[0]

        if n <= max_lag:
            # Tiny series (every lag ragged): direct masked form, O(n·H·d²).
            def one_ragged(h):
                idx = jnp.arange(n)
                valid = (idx + h) <= (n - 1)
                shifted = x[jnp.clip(idx + h, 0, n - 1)]
                shifted = jnp.where(valid[:, None], shifted, 0.0)
                return jnp.einsum("ti,tj->ij", x, shifted)

            return jax.vmap(one_ragged)(jnp.arange(max_lag + 1))

        def one(h):
            head = jax.lax.dynamic_slice_in_dim(x, 0, n - max_lag, axis=0)
            shifted = jax.lax.dynamic_slice_in_dim(x, h, n - max_lag, axis=0)
            # Only the common full-length prefix enters this vectorized form;
            # the ragged tail (k in [n-max_lag, n-h)) is added below.
            return jnp.einsum("ti,tj->ij", head, shifted)

        full = jax.vmap(one)(jnp.arange(max_lag + 1))

        # Ragged tail: for lag h, centers k = n-max_lag .. n-1-h.
        def tail(h):
            ks = jnp.arange(max_lag)  # offsets into the tail region
            k = n - max_lag + ks
            valid = (k + h) <= (n - 1)
            xk = x[jnp.clip(k, 0, n - 1)]
            xkh = x[jnp.clip(k + h, 0, n - 1)]
            contrib = jnp.einsum("ti,tj->tij", xk, xkh)
            return jnp.sum(jnp.where(valid[:, None, None], contrib, 0.0), axis=0)

        if max_lag > 0:
            full = full + jax.vmap(tail)(jnp.arange(max_lag + 1))
        return full

    def masked_lagged_sums(
        self, y_padded: jax.Array, start_mask: jax.Array, max_lag: int
    ) -> jax.Array:
        y_padded = _as_2d(y_padded).astype(jnp.float32)
        L = start_mask.shape[0]
        need = L + max_lag
        if y_padded.shape[0] < need:
            y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
        head = jnp.where(start_mask[:, None], y_padded[:L], 0.0)

        def one(h):
            shifted = jax.lax.dynamic_slice_in_dim(y_padded, h, L, axis=0)
            return jnp.einsum("ti,tj->ij", head, shifted)

        return jax.vmap(one)(jnp.arange(max_lag + 1))

    def windowed_moments(self, x: jax.Array, window: int) -> jax.Array:
        x = _as_2d(x).astype(jnp.float32)
        n, d = x.shape
        if n - window + 1 < 1:
            raise ValueError(f"series of length {n} has no full window of width {window}")
        zero = jnp.zeros((1, d), jnp.float32)
        cs = jnp.concatenate([zero, jnp.cumsum(x, axis=0)])
        cs2 = jnp.concatenate([zero, jnp.cumsum(x * x, axis=0)])
        s1 = cs[window:] - cs[:-window]
        s2 = cs2[window:] - cs2[:-window]
        return jnp.stack([s1, s2], axis=1)

    def segment_fft_power(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        segments = segments.astype(jnp.float32)

        def one(seg):
            if detrend:
                seg = seg - seg.mean(axis=0)
            f = jnp.fft.rfft(seg * taper[:, None], axis=0)
            return jnp.abs(f) ** 2

        return jax.vmap(one)(segments)

    def banded_matvec(self, diags: jax.Array, x: jax.Array) -> jax.Array:
        d, w = diags.shape
        b = (w - 1) // 2
        # gather the b-halo neighbourhood of every row: (..., d, 2b+1)
        cols = jnp.arange(d)[:, None] + jnp.arange(-b, b + 1)[None, :]
        valid = (cols >= 0) & (cols < d)
        xn = jnp.take(x.astype(jnp.float32), jnp.clip(cols, 0, d - 1), axis=-1)
        xn = jnp.where(valid, xn, 0.0)
        return jnp.einsum("...dw,dw->...d", xn, diags.astype(jnp.float32))

    def fused_lagged_moments(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        max_lag: int,
        window: "int | tuple",
    ) -> tuple:
        # leaf-module import (jnp-only): the window validation is shared
        # with the Pallas wrappers without a kernels → core back-edge
        from ..kernels.window_stats.ref import normalize_windows

        windows, single = normalize_windows(window)
        y_padded = _as_2d(y_padded).astype(jnp.float32)
        L = start_mask.shape[0]
        w_max = max(windows)
        need = L + max(max_lag, w_max - 1)
        if y_padded.shape[0] < need:
            y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
        lag = self.masked_lagged_sums(y_padded, start_mask, max_lag)

        # windowed sums per start via ONE cumsum pass shared by every window
        # — no second traversal of the series, and K windows cost K slices.
        zero = jnp.zeros((1, y_padded.shape[1]), jnp.float32)
        y = y_padded[: L + w_max - 1]
        cs = jnp.concatenate([zero, jnp.cumsum(y, axis=0)])
        cs2 = jnp.concatenate([zero, jnp.cumsum(y * y, axis=0)])
        m = start_mask.astype(jnp.float32)[:, None]

        moms = []
        for w in windows:
            s1 = cs[w : L + w] - cs[:L]
            s2 = cs2[w : L + w] - cs2[:L]
            moms.append(
                jnp.stack([jnp.sum(m * s1, axis=0), jnp.sum(m * s2, axis=0)])
            )
        mom = jnp.stack(moms)
        return lag, (mom[0] if single else mom)

    def segment_csd(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        segments = segments.astype(jnp.float32)
        taper = taper.astype(jnp.float32)

        def one(seg):
            if detrend:
                seg = seg - seg.mean(axis=0)
            f = jnp.fft.rfft(seg * taper[:, None], axis=0)  # (F, d)
            return jnp.einsum("fi,fj->fij", f, jnp.conj(f))

        return jax.vmap(one)(segments)

    def fused_plan_update(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        z0: jax.Array,
        max_lag: int,
        windows: tuple = (),
        seg_lens: tuple = (),
        seg_steps: tuple = (),
        tapers: tuple = (),
        detrend: bool = True,
        stage_dtype: "str | None" = None,
    ) -> tuple:
        """Composition oracle: the megakernel's contract restated as calls
        to the existing primitives (lag/moments via ``fused_lagged_moments``,
        spectra via the Welch candidate gather + ``segment_fft_power``).
        ``stage_dtype`` rounds the series through the staging dtype first,
        mirroring the Pallas kernel's narrowed HBM↔VMEM stream bit-for-bit.
        """
        windows = tuple(windows)
        y_padded = _as_2d(y_padded)
        if stage_dtype is not None:
            y_padded = y_padded.astype(jnp.dtype(stage_dtype))
        y_padded = y_padded.astype(jnp.float32)
        L = start_mask.shape[0]
        w_max = max(windows) if windows else 1
        l_max = max(seg_lens) if seg_lens else 1
        need = L + max(max_lag, w_max - 1, l_max - 1)
        if y_padded.shape[0] < need:
            y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))

        if windows:
            lag, mom = self.fused_lagged_moments(
                y_padded, start_mask, max_lag, windows
            )
        else:
            lag = self.masked_lagged_sums(y_padded, start_mask, max_lag)
            mom = None

        z0 = jnp.asarray(z0, jnp.int32)
        psds, n_segs = [], []
        for Lseg, step, taper in zip(seg_lens, seg_steps, tapers):
            K = L // step + 1  # static bound on aligned starts in [z0, z0+L)
            base = (-z0) % step
            cand = base + jnp.arange(K) * step
            valid = (cand < L) & start_mask[jnp.clip(cand, 0, L - 1)]
            wins = jax.vmap(
                lambda s: jax.lax.dynamic_slice_in_dim(y_padded, s, Lseg, axis=0)
            )(jnp.clip(cand, 0, L - 1))
            power = self.segment_fft_power(wins, taper, detrend)
            psds.append(
                jnp.sum(jnp.where(valid[:, None, None], power, 0.0), axis=0)
            )
            n_segs.append(jnp.sum(valid.astype(jnp.float32)))
        return lag, mom, tuple(psds), tuple(n_segs)


class PallasBackend:
    """Explicit VMEM tile kernels (the paper's §12 scheme on TPU).

    Args:
      block_t: core tile length for the windowed-contraction kernels and
        the fused-plan megakernel.
      block_rows: row tile for the banded matvec.
      block_s: segments staged per grid step in the segment-DFT kernels.
      interpret: force Pallas interpret mode.  ``None`` (default) resolves
        per call: compiled on TPU, interpret everywhere else — so the same
        backend object validates on CPU and serves on TPU.

    Every block argument defaults to ``None`` — the ops entry points then
    resolve the tile size through the calibrated per-platform block table
    (`repro.kernels.tiling.resolve_block`; written by
    ``calibrate(tune_blocks=True)``), falling back to the built-in
    defaults.  Pass an int to pin a size explicitly (tests, the tuner).
    """

    name = "pallas"

    def __init__(
        self,
        block_t: Optional[int] = None,
        block_rows: Optional[int] = None,
        block_s: Optional[int] = None,
        interpret: Optional[bool] = None,
    ):
        self.block_t = block_t
        self.block_rows = block_rows
        self.block_s = block_s
        self.interpret = interpret

    def _interp(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    def lagged_sums(self, x: jax.Array, max_lag: int) -> jax.Array:
        from ..kernels.window_stats import ops as ws

        return ws.lagged_sums(
            x, max_lag, block_t=self.block_t, interpret=self._interp()
        )

    def masked_lagged_sums(
        self, y_padded: jax.Array, start_mask: jax.Array, max_lag: int
    ) -> jax.Array:
        from ..kernels.window_stats import ops as ws

        return ws.masked_lagged_sums(
            y_padded, start_mask, max_lag, block_t=self.block_t, interpret=self._interp()
        )

    def windowed_moments(self, x: jax.Array, window: int) -> jax.Array:
        from ..kernels.window_stats import ops as ws

        return ws.windowed_moments(
            x, window, block_t=self.block_t, interpret=self._interp()
        )

    def segment_fft_power(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        from ..kernels.segment_dft import ops as sd

        return sd.segment_fft_power(
            segments,
            taper,
            detrend,
            block_s=self.block_s,
            interpret=self._interp(),
        )

    def banded_matvec(self, diags: jax.Array, x: jax.Array) -> jax.Array:
        from ..kernels.banded_matvec import ops as bmv

        d = diags.shape[0]
        lead = x.shape[:-1]
        # kernel contract is (d, nrhs): fold any leading batch axes into nrhs.
        xr = x.reshape(-1, d).T if lead else x
        y = bmv.banded_matvec(
            diags, xr, block_rows=self.block_rows, interpret=self._interp()
        )
        return y.T.reshape(*lead, d) if lead else y

    def fused_lagged_moments(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        max_lag: int,
        window: "int | tuple",
    ) -> tuple:
        from ..kernels.window_stats import ops as ws

        return ws.fused_lagged_moments(
            y_padded,
            start_mask,
            max_lag,
            window,
            block_t=self.block_t,
            interpret=self._interp(),
        )

    def segment_csd(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        from ..kernels.segment_dft import ops as sd

        return sd.segment_csd(
            segments,
            taper,
            detrend,
            block_s=self.block_s,
            interpret=self._interp(),
        )

    def fused_plan_update(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        z0: jax.Array,
        max_lag: int,
        windows: tuple = (),
        seg_lens: tuple = (),
        seg_steps: tuple = (),
        tapers: tuple = (),
        detrend: bool = True,
        stage_dtype: "str | None" = None,
    ) -> tuple:
        from ..kernels.fused_plan import ops as fp

        return fp.fused_plan_update(
            y_padded,
            start_mask,
            z0,
            max_lag,
            windows,
            seg_lens,
            seg_steps,
            tapers,
            detrend,
            stage_dtype=stage_dtype,
            block_t=self.block_t,
            interpret=self._interp(),
        )


class AutoBackend:
    """Per-call dispatch by *measured* crossover, not a hard-coded constant.

    Each primitive routes to the Pallas tile kernel once its problem size
    (rows for the windowed contractions, banded dimension for the matvec,
    total staged samples S·L for the segment DFT) reaches that primitive's
    calibrated crossover threshold (`repro.core.calibrate`).  The table is
    resolved lazily at the first dispatch: a cached measurement for this
    platform if one exists, a fresh microbenchmark pass on TPU (persisted),
    else the built-in default table — which off-accelerator says "always
    jnp", since interpret-mode Pallas is a validation vehicle ~100× slower
    than XLA.

    Inject or refresh the policy at runtime:

        get_backend("auto").set_table(calibrate())
    """

    name = "auto"

    def __init__(
        self,
        jnp_backend: Optional[JnpBackend] = None,
        pallas_backend: Optional[PallasBackend] = None,
        table=None,
    ):
        self._jnp = jnp_backend or JnpBackend()
        self._pallas = pallas_backend or PallasBackend()
        self._table = table

    @property
    def table(self):
        """The active `repro.core.calibrate.CalibrationTable` (resolving it
        on first access — cache > TPU auto-measure > built-in default)."""
        if self._table is None:
            from .calibrate import resolve_table

            self._table = resolve_table()
        return self._table

    def set_table(self, table) -> None:
        """Swap the crossover table (e.g. a fresh ``calibrate()`` result).

        Also installs it as the process-wide active table so the kernels'
        tile-size resolution (`repro.kernels.tiling.resolve_block`) sees the
        same calibration artifact the dispatch policy uses.
        """
        self._table = table
        from .calibrate import set_active_table

        set_active_table(table)

    def _pick(self, primitive: str, size: int) -> Backend:
        if size >= self.table.crossover(primitive):
            return self._pallas
        return self._jnp

    def lagged_sums(self, x: jax.Array, max_lag: int) -> jax.Array:
        return self._pick("lagged_sums", x.shape[0]).lagged_sums(x, max_lag)

    def masked_lagged_sums(
        self, y_padded: jax.Array, start_mask: jax.Array, max_lag: int
    ) -> jax.Array:
        return self._pick(
            "masked_lagged_sums", start_mask.shape[0]
        ).masked_lagged_sums(y_padded, start_mask, max_lag)

    def windowed_moments(self, x: jax.Array, window: int) -> jax.Array:
        return self._pick("windowed_moments", x.shape[0]).windowed_moments(
            x, window
        )

    def segment_fft_power(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        staged = segments.shape[0] * segments.shape[1]
        return self._pick("segment_fft_power", staged).segment_fft_power(
            segments, taper, detrend
        )

    def banded_matvec(self, diags: jax.Array, x: jax.Array) -> jax.Array:
        return self._pick("banded_matvec", diags.shape[0]).banded_matvec(
            diags, x
        )

    def fused_lagged_moments(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        max_lag: int,
        window: "int | tuple",
    ) -> tuple:
        return self._pick(
            "fused_lagged_moments", start_mask.shape[0]
        ).fused_lagged_moments(y_padded, start_mask, max_lag, window)

    def segment_csd(
        self, segments: jax.Array, taper: jax.Array, detrend: bool = True
    ) -> jax.Array:
        staged = segments.shape[0] * segments.shape[1]
        return self._pick("segment_csd", staged).segment_csd(
            segments, taper, detrend
        )

    def fused_plan_update(
        self,
        y_padded: jax.Array,
        start_mask: jax.Array,
        z0: jax.Array,
        max_lag: int,
        windows: tuple = (),
        seg_lens: tuple = (),
        seg_steps: tuple = (),
        tapers: tuple = (),
        detrend: bool = True,
        stage_dtype: "str | None" = None,
    ) -> tuple:
        # A cached table measured before this primitive existed simply has
        # no entry — CalibrationTable.crossover falls back to the built-in
        # platform default (never a KeyError), so stale caches degrade to
        # the reasoned policy instead of crashing the fused-plan hot path.
        return self._pick(
            "fused_plan_update", start_mask.shape[0]
        ).fused_plan_update(
            y_padded,
            start_mask,
            z0,
            max_lag,
            windows,
            seg_lens,
            seg_steps,
            tapers,
            detrend,
            stage_dtype=stage_dtype,
        )


class CircuitBreakerBackend:
    """Self-healing dispatch: quarantine a raising primitive, keep serving.

    Wraps a ``primary`` backend (default: Pallas) and a ``fallback`` oracle
    (default: jnp).  Each primitive carries its own breaker:

      * **closed** (healthy): dispatch goes to the primary.  A primary
        raise — a kernel build failure, an injected
        ``backend.<primitive>`` fault (`repro.runtime.chaos`) — is caught,
        the call is transparently served by the fallback, and after
        ``trip_after`` consecutive failures the breaker **opens**;
      * **open** (quarantined): the next ``cooldown_calls`` dispatches of
        that primitive go straight to the fallback — the primary is not
        even attempted, so a wedged kernel build can't stall serving;
      * **half-open** (probing): once the cooldown is spent, one dispatch
        probes the primary again.  Success closes the breaker (recovery);
        failure re-opens it for another cooldown.

    Every trip/recovery/fallback is recorded per primitive
    (:meth:`breaker_metrics`) — `repro.serving.gateway.StatsGateway
    .health` surfaces them when the served session runs on a breaker.

    The cooldown is counted in *dispatch calls*, not wall time, so chaos
    schedules replay deterministically.  Note primitive dispatch happens at
    trace time: a jit program that compiled against the fallback keeps
    using it for its shapes until re-traced — recovery applies to new
    traces, which is exactly the safe direction (never resurrect a raising
    kernel inside a cached program).
    """

    name = "breaker"

    def __init__(
        self,
        primary: Optional[Backend] = None,
        fallback: Optional[Backend] = None,
        trip_after: int = 1,
        cooldown_calls: int = 8,
    ):
        if trip_after < 1 or cooldown_calls < 1:
            raise ValueError("trip_after and cooldown_calls must be >= 1")
        self._primary = primary if primary is not None else PallasBackend()
        self._fallback = fallback if fallback is not None else JnpBackend()
        self.trip_after = trip_after
        self.cooldown_calls = cooldown_calls
        self._state: Dict[str, dict] = {}

    def _st(self, primitive: str) -> dict:
        st = self._state.get(primitive)
        if st is None:
            st = self._state[primitive] = {
                "state": "closed",
                "consecutive_failures": 0,
                "cooldown_left": 0,
                "trips": 0,
                "recoveries": 0,
                "probes": 0,
                "primary_calls": 0,
                "fallback_calls": 0,
                "last_error": None,
            }
        return st

    def _dispatch(self, primitive: str, *args, **kwargs):
        from ..runtime import chaos

        st = self._st(primitive)
        if st["state"] == "open":
            st["cooldown_left"] -= 1
            if st["cooldown_left"] > 0:
                st["fallback_calls"] += 1
                return getattr(self._fallback, primitive)(*args, **kwargs)
            st["state"] = "half-open"   # cooldown spent: this call probes
            st["probes"] += 1
        try:
            chaos.fire(f"backend.{primitive}")
            out = getattr(self._primary, primitive)(*args, **kwargs)
        except Exception as e:
            st["consecutive_failures"] += 1
            st["last_error"] = repr(e)
            if (
                st["state"] == "half-open"
                or st["consecutive_failures"] >= self.trip_after
            ):
                if st["state"] == "closed":
                    st["trips"] += 1   # count closed→open transitions only
                st["state"] = "open"
                st["cooldown_left"] = self.cooldown_calls
            st["fallback_calls"] += 1
            return getattr(self._fallback, primitive)(*args, **kwargs)
        if st["state"] == "half-open":
            st["recoveries"] += 1
        st["state"] = "closed"
        st["consecutive_failures"] = 0
        st["primary_calls"] += 1
        return out

    def __getattr__(self, name: str):
        # one wrapper per primitive, lazily bound — a new primitive added
        # to the protocol is covered without touching the breaker
        if name in PRIMITIVE_NAMES:
            fn = functools.partial(self._dispatch, name)
            object.__setattr__(self, name, fn)  # cache for later lookups
            return fn
        raise AttributeError(
            f"{type(self).__name__!s} object has no attribute {name!r}"
        )

    def breaker_metrics(self) -> dict:
        """Per-primitive breaker state plus totals: trips, recoveries,
        probes, primary/fallback call counts, last primary error."""
        per = {k: dict(v) for k, v in sorted(self._state.items())}
        return {
            "primitives": per,
            "trips": sum(v["trips"] for v in per.values()),
            "recoveries": sum(v["recoveries"] for v in per.values()),
            "fallback_calls": sum(v["fallback_calls"] for v in per.values()),
            "open": sorted(
                k for k, v in per.items() if v["state"] != "closed"
            ),
        }

    def reset(self, primitive: Optional[str] = None) -> None:
        """Operator override: forget breaker state (one primitive or all)."""
        if primitive is None:
            self._state.clear()
        else:
            self._state.pop(primitive, None)


_REGISTRY: Dict[str, Backend] = {
    "jnp": JnpBackend(),
    "pallas": PallasBackend(),
    "auto": AutoBackend(),
}
_DEFAULT = "auto"


def register_backend(name: str, backend: Backend) -> None:
    """Add (or replace) a named backend — the one place a new substrate
    (GPU Triton, CPU-vectorized, …) plugs into every estimator at once."""
    _REGISTRY[name] = backend


def list_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def set_default_backend(name: str) -> None:
    """Change what ``backend=None`` resolves to (deployment-wide policy)."""
    global _DEFAULT
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: {list_backends()}")
    _DEFAULT = name


def get_backend(spec: BackendSpec = None) -> Backend:
    """Resolve ``backend=`` arguments: None → default, str → registry lookup,
    Backend instance → itself."""
    if spec is None:
        spec = _DEFAULT
    if isinstance(spec, str):
        try:
            return _REGISTRY[spec]
        except KeyError:
            raise KeyError(
                f"unknown backend {spec!r}; registered: {list_backends()}"
            ) from None
    return spec
