"""Spectral estimation by overlapping segments (Welch) — the paper's data
structure reused in the frequency domain.

A Welch estimate is EXACTLY an order-(nperseg−1) weak-memory map-reduce:
map a windowed periodogram kernel over (overlapping) segments, reduce with
a mean.  The overlapping-block container therefore serves it directly —
50%-overlap Welch is an OverlapSpec with block_size = nperseg/2 = halo.

Univariate PSDs per dimension plus optional cross-spectral density matrix
(needed for frequency-domain Whittle likelihoods of VARMA models).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..overlap import OverlapSpec, make_overlapping_blocks

__all__ = ["hann_window", "welch_psd", "welch_csd", "ar1_theoretical_psd"]


def hann_window(n: int) -> jax.Array:
    return 0.5 - 0.5 * jnp.cos(2 * jnp.pi * jnp.arange(n) / n)


def _segments(x: jax.Array, nperseg: int, overlap: int) -> jax.Array:
    """(n_seg, nperseg, d) overlapping segments via the overlap container."""
    if x.ndim == 1:
        x = x[:, None]
    step = nperseg - overlap
    n = x.shape[0]
    n_seg = (n - overlap) // step
    if n_seg < 1:
        raise ValueError(f"series of length {n} too short for nperseg={nperseg}")
    # overlap container: core = step, right halo = overlap ⇒ padded = nperseg
    spec = OverlapSpec(n=n, block_size=step, h_left=0, h_right=overlap)
    blocks, _ = make_overlapping_blocks(x, spec)
    return blocks[:n_seg], n_seg


def welch_psd(
    x: jax.Array,
    nperseg: int = 256,
    overlap: Optional[int] = None,
    fs: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Welch power spectral density per dimension.

    Returns (freqs (nfreq,), psd (nfreq, d)) with the one-sided convention;
    ∫psd df ≈ var(x) (Parseval — property-tested).
    """
    if x.ndim == 1:
        x = x[:, None]
    overlap = nperseg // 2 if overlap is None else overlap
    segs, n_seg = _segments(x, nperseg, overlap)
    w = hann_window(nperseg)
    scale = 1.0 / (fs * jnp.sum(w**2))

    def kernel(seg):  # (nperseg, d) → (nfreq, d): the weak-memory map
        f = jnp.fft.rfft((seg - seg.mean(axis=0)) * w[:, None], axis=0)
        return (jnp.abs(f) ** 2) * scale

    psd = jnp.mean(jax.vmap(kernel)(segs), axis=0)
    # one-sided: double everything except DC (and Nyquist when nperseg even)
    nfreq = psd.shape[0]
    mult = jnp.ones((nfreq,)).at[1:].set(2.0)
    if nperseg % 2 == 0:
        mult = mult.at[-1].set(1.0)
    psd = psd * mult[:, None]
    freqs = jnp.fft.rfftfreq(nperseg, d=1.0 / fs)
    return freqs, psd


def welch_csd(
    x: jax.Array,
    nperseg: int = 256,
    overlap: Optional[int] = None,
    fs: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-spectral density matrix: (nfreq, d, d) complex (two-sided scale
    per pair, Hermitian in (i, j))."""
    if x.ndim == 1:
        x = x[:, None]
    overlap = nperseg // 2 if overlap is None else overlap
    segs, _ = _segments(x, nperseg, overlap)
    w = hann_window(nperseg)
    scale = 1.0 / (fs * jnp.sum(w**2))

    def kernel(seg):
        f = jnp.fft.rfft((seg - seg.mean(axis=0)) * w[:, None], axis=0)  # (nf, d)
        return jnp.einsum("fi,fj->fij", f, jnp.conj(f)) * scale

    csd = jnp.mean(jax.vmap(kernel)(segs), axis=0)
    freqs = jnp.fft.rfftfreq(nperseg, d=1.0 / fs)
    return freqs, csd


def ar1_theoretical_psd(phi: float, sigma2: float, freqs: jax.Array) -> jax.Array:
    """One-sided theoretical PSD of AR(1): σ²/|1 − φ e^{-iω}|² (fs = 1)."""
    om = 2 * jnp.pi * freqs
    two_sided = sigma2 / (1 + phi**2 - 2 * phi * jnp.cos(om))
    mult = jnp.ones_like(freqs).at[1:].set(2.0)
    if freqs.shape[0] > 1:
        mult = mult.at[-1].set(jnp.where(freqs[-1] == 0.5, 1.0, 2.0))
    return two_sided * mult
