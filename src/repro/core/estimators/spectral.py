"""Spectral estimation by overlapping segments (Welch) — the paper's data
structure reused in the frequency domain.

A Welch estimate is EXACTLY an order-(nperseg−1) weak-memory map-reduce:
map a windowed periodogram kernel over (overlapping) segments, reduce with
a mean.  The overlapping-block container therefore serves it directly —
50%-overlap Welch is an OverlapSpec with block_size = nperseg/2 = halo.

Univariate PSDs per dimension plus optional cross-spectral density matrix
(needed for frequency-domain Whittle likelihoods of VARMA models).

The per-segment periodogram is the backend registry's
``segment_fft_power`` primitive and the cross-spectral matrix the
``segment_csd`` primitive (`repro.core.backend`): jnp evaluates them with
XLA's rfft; the Pallas backend evaluates the fixed-L DFT as tiled matmuls
against precomputed taper-folded twiddle matrices — so both the PSD and
the CSD stay on the VMEM tile path when calibration says it wins.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..backend import BackendSpec, get_backend
from ..overlap import OverlapSpec, make_overlapping_blocks
from ..streaming import PartialState, StreamingEngine, resolved_stat

__all__ = [
    "hann_window",
    "welch_psd",
    "welch_csd",
    "ar1_theoretical_psd",
    "welch_chunk_kernel",
    "welch_engine",
    "streaming_welch",
]


def hann_window(n: int) -> jax.Array:
    return 0.5 - 0.5 * jnp.cos(2 * jnp.pi * jnp.arange(n) / n)


def _one_sided(psd: jax.Array, nperseg: int, fs: float) -> Tuple[jax.Array, jax.Array]:
    """Two-sided → one-sided: double all bins but DC (and Nyquist when
    ``nperseg`` is even); return (freqs, psd).  Shared by the batch and
    streaming Welch paths so the convention can never desynchronize."""
    nfreq = psd.shape[0]
    mult = jnp.ones((nfreq,)).at[1:].set(2.0)
    if nperseg % 2 == 0:
        mult = mult.at[-1].set(1.0)
    freqs = jnp.fft.rfftfreq(nperseg, d=1.0 / fs)
    return freqs, psd * mult[:, None]


def _segments(x: jax.Array, nperseg: int, overlap: int) -> jax.Array:
    """(n_seg, nperseg, d) overlapping segments via the overlap container."""
    if x.ndim == 1:
        x = x[:, None]
    step = nperseg - overlap
    n = x.shape[0]
    n_seg = (n - overlap) // step
    if n_seg < 1:
        raise ValueError(f"series of length {n} too short for nperseg={nperseg}")
    # overlap container: core = step, right halo = overlap ⇒ padded = nperseg
    spec = OverlapSpec(n=n, block_size=step, h_left=0, h_right=overlap)
    blocks, _ = make_overlapping_blocks(x, spec)
    return blocks[:n_seg], n_seg


def welch_psd(
    x: jax.Array,
    nperseg: int = 256,
    overlap: Optional[int] = None,
    fs: float = 1.0,
    backend: BackendSpec = None,
) -> Tuple[jax.Array, jax.Array]:
    """Welch power spectral density per dimension.

    Returns (freqs (nfreq,), psd (nfreq, d)) with the one-sided convention;
    ∫psd df ≈ var(x) (Parseval — property-tested).
    """
    if x.ndim == 1:
        x = x[:, None]
    overlap = nperseg // 2 if overlap is None else overlap
    segs, n_seg = _segments(x, nperseg, overlap)
    w = hann_window(nperseg)
    scale = 1.0 / (fs * jnp.sum(w**2))
    power = get_backend(backend).segment_fft_power(segs, w)  # (S, nfreq, d)
    psd = jnp.mean(power, axis=0) * scale
    return _one_sided(psd, nperseg, fs)


def welch_csd(
    x: jax.Array,
    nperseg: int = 256,
    overlap: Optional[int] = None,
    fs: float = 1.0,
    backend: BackendSpec = None,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-spectral density matrix: (nfreq, d, d) complex (two-sided scale
    per pair, Hermitian in (i, j)).

    Routed through the registry's ``segment_csd`` primitive — on the Pallas
    backend the complex cross-products are four real contractions of each
    resident segment (re/im twiddle matmuls + a channel outer product), so
    cross-spectral members no longer eject to the plain jnp path.
    """
    if x.ndim == 1:
        x = x[:, None]
    overlap = nperseg // 2 if overlap is None else overlap
    segs, _ = _segments(x, nperseg, overlap)
    w = hann_window(nperseg)
    scale = 1.0 / (fs * jnp.sum(w**2))
    csd = get_backend(backend).segment_csd(segs, w)  # (S, nfreq, d, d)
    csd = jnp.mean(csd, axis=0) * scale
    freqs = jnp.fft.rfftfreq(nperseg, d=1.0 / fs)
    return freqs, csd


def welch_chunk_kernel(nperseg: int, step: int, scale: float, be) -> callable:
    """Offset-aware ChunkKernel accumulating Welch segment-PSD partials.

    Because the kernel receives z0 (the global index of its first row), it
    gathers ONLY the stride-aligned candidate starts — ⌈L/step⌉+1 windows
    instead of L — so the FFT cost of a streamed (or fused-plan) Welch
    matches the batch :func:`welch_psd`, not the dense all-starts walk.
    Shared by :func:`welch_engine` and the fused plan layer
    (`repro.core.plan`), so the two can never disagree on segment math.
    """
    w = hann_window(nperseg)

    def chunk_kernel(
        y_padded: jax.Array, start_mask: jax.Array, z0: jax.Array
    ) -> dict:
        L = start_mask.shape[0]
        K = L // step + 1  # static bound on aligned starts in [z0, z0+L)
        base = (-z0) % step  # first local start at a global stride multiple
        cand = base + jnp.arange(K) * step
        in_range = cand < L
        valid = in_range & start_mask[jnp.clip(cand, 0, L - 1)]
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y_padded, s, nperseg, axis=0)
        )(jnp.clip(cand, 0, L - 1))
        power = be.segment_fft_power(wins, w) * scale  # (K, nfreq, d)
        psd = jnp.sum(jnp.where(valid[:, None, None], power, 0.0), axis=0)
        return {"psd": psd, "n_seg": jnp.sum(valid.astype(jnp.float32))}

    return chunk_kernel


def welch_engine(
    nperseg: int = 256,
    overlap: Optional[int] = None,
    d: int = 1,
    fs: float = 1.0,
    backend: BackendSpec = None,
) -> StreamingEngine:
    """Streaming engine accumulating Welch periodogram-segment partials.

    A Welch segment is a width-``nperseg`` window starting at global
    multiples of ``step = nperseg - overlap`` — i.e. an order-(0, nperseg-1)
    weak-memory kernel with ``stride=step``.  The engine's global start
    indices keep segment alignment exact across chunk boundaries and
    merges, so the streamed estimate matches :func:`welch_psd` on the
    concatenated series (segments straddling a chunk boundary are recovered
    from the carried halos).  ``state.stat`` holds the running segment-PSD
    sum and segment count.  The chunk kernel runs every candidate segment
    through the backend's ``segment_fft_power`` primitive and masks out the
    stride-misaligned starts.
    """
    overlap = nperseg // 2 if overlap is None else overlap
    if not 0 <= overlap < nperseg:
        raise ValueError(f"need 0 <= overlap < nperseg, got {overlap}/{nperseg}")
    step = nperseg - overlap
    w = hann_window(nperseg)
    scale = 1.0 / (fs * jnp.sum(w**2))
    be = get_backend(backend)
    chunk_kernel = welch_chunk_kernel(nperseg, step, scale, be)

    engine = StreamingEngine(
        d=d,
        h_left=0,
        h_right=nperseg - 1,
        chunk_kernel=chunk_kernel,
        stride=step,
        backend=be,
        kernel_takes_offset=True,
    )
    engine.welch_fs = fs  # carried to streaming_welch so the frequency grid
    # and the per-segment density scale can never disagree
    return engine


def streaming_welch(
    engine: StreamingEngine, state: PartialState
) -> Tuple[jax.Array, jax.Array]:
    """Finalize Welch partials into (freqs, one-sided psd (nfreq, d)).

    The sample rate is read from the engine (set at :func:`welch_engine`
    construction), where it already entered the per-segment scale.

    If the state has absorbed fewer samples than one full segment
    (``n_seg == 0``) the PSD is undefined and every bin is NaN — check
    ``state.stat["n_seg"]`` before trusting early-stream queries.
    """
    stat = resolved_stat(state)
    psd = stat["psd"] / stat["n_seg"]
    return _one_sided(psd, engine.window, engine.welch_fs)


def ar1_theoretical_psd(phi: float, sigma2: float, freqs: jax.Array) -> jax.Array:
    """One-sided theoretical PSD of AR(1): σ²/|1 − φ e^{-iω}|² (fs = 1)."""
    om = 2 * jnp.pi * freqs
    two_sided = sigma2 / (1 + phi**2 - 2 * phi * jnp.cos(om))
    mult = jnp.ones_like(freqs).at[1:].set(2.0)
    if freqs.shape[0] > 1:
        mult = mult.at[-1].set(jnp.where(freqs[-1] == 0.5, 1.0, 2.0))
    return two_sided * mult
