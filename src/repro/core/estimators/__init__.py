"""M- and Z-estimators for second-order stationary series (paper §2–§6).

Every estimator here is an order-H weak-memory estimator (paper §8) and is
computed through the overlapping-block map-reduce engine — embarrassingly
parallel across time partitions.
"""
from .stats import (
    mean,
    autocovariance,
    autocovariance_blocked,
    autocovariance_sharded,
    autocorrelation,
    partial_autocorrelation,
    windowed_moments,
    lag_sum_engine,
    moment_engine,
    streaming_autocovariance,
    streaming_window_moments,
    streaming_mean,
)
from .yule_walker import yule_walker, levinson_durbin, block_levinson, streaming_yule_walker
from .innovation import innovation_algorithm, fit_ma
from .arma import fit_arma, arma_psi_weights, fit_arma_streaming
from .mle import (
    ar_conditional_nll,
    fit_ar_mle,
    fit_ar_sgd,
    optimal_step_size,
)
from .spatial import (
    BandedARModel,
    banded_predict,
    banded_predict_partitioned,
    fit_banded_ar,
    SpatialPartition,
)
from .prediction import ar_one_step, ar_forecast, arma_innovations_filter, arma_forecast
from .spectral import (
    welch_psd,
    welch_csd,
    hann_window,
    welch_chunk_kernel,
    welch_engine,
    streaming_welch,
)

__all__ = [
    "mean",
    "autocovariance",
    "autocovariance_blocked",
    "autocovariance_sharded",
    "autocorrelation",
    "partial_autocorrelation",
    "windowed_moments",
    "lag_sum_engine",
    "moment_engine",
    "streaming_autocovariance",
    "streaming_window_moments",
    "streaming_mean",
    "yule_walker",
    "levinson_durbin",
    "block_levinson",
    "streaming_yule_walker",
    "innovation_algorithm",
    "fit_ma",
    "fit_arma",
    "arma_psi_weights",
    "fit_arma_streaming",
    "welch_chunk_kernel",
    "welch_engine",
    "streaming_welch",
    "ar_conditional_nll",
    "fit_ar_mle",
    "fit_ar_sgd",
    "optimal_step_size",
    "BandedARModel",
    "banded_predict",
    "banded_predict_partitioned",
    "fit_banded_ar",
    "SpatialPartition",
]
