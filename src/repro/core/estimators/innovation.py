"""The multivariate innovation algorithm and MA fitting (paper §3.3).

Conventions:  γ(h) = E[X_t X_{t+h}ᵀ],  Γ(h) := E[X_{t+h} X_tᵀ] = γ(h)ᵀ.

Recursion (Brockwell & Davis prop. 11.4.2, as derived in the paper):

  V₀ = Γ(0)
  for m = 1, 2, …:
    for k = 0 .. m-1:
      Θ_{m,m-k} = [ Γ(m-k) − Σ_{j=0}^{k-1} Θ_{m,m-j} V_j Θ_{k,k-j}ᵀ ] V_k⁻¹
    V_m = Γ(0) − Σ_{j=0}^{m-1} Θ_{m,m-j} V_j Θ_{m,m-j}ᵀ

For an MA(q) process the estimates Θ_{m,1..q} → B_{1..q} and V_m → Σ_ε as m
grows.  The only data-dependent input is γ̂ — the weak-memory sufficient
statistic computed by the overlapping-block map-reduce; the recursion itself
is O(m² d³) *driver-side* work on tiny matrices (the paper's point: never
touch the raw series again).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["innovation_algorithm", "fit_ma"]


def innovation_algorithm(
    gamma: jax.Array, m_max: int, ridge: float = 0.0
) -> Tuple[jax.Array, jax.Array]:
    """Run the innovation recursion up to order ``m_max``.

    Args:
      gamma: (≥m_max+1, d, d) stacked autocovariances γ(0..m_max).
      m_max: number of recursion steps.
      ridge: absolute Tikhonov term added to each V_k before its solve.
        The default 0.0 is the exact recursion; batched plan finalizers
        (`repro.core.forecast`) pass a tiny ridge so a degenerate tenant
        (near-empty γ̂, singular V_k) yields finite coefficients instead
        of poisoning a whole vmapped batch with NaNs.

    Returns:
      theta: (m_max, m_max, d, d) — theta[m-1, j-1] = Θ_{m,j} for 1 ≤ j ≤ m,
        zero elsewhere.
      V: (m_max+1, d, d) — innovation covariances V_0..V_{m_max}.
    """
    if gamma.shape[0] < m_max + 1:
        raise ValueError(f"need γ̂ up to lag {m_max}, got {gamma.shape[0] - 1}")
    d = gamma.shape[1]
    G = lambda h: gamma[h].T  # Γ(h), h ≥ 0
    reg = ridge * jnp.eye(d)

    theta = [[None] * (m + 1) for m in range(m_max + 1)]  # theta[m][j] = Θ_{m,j}
    V = [G(0)]
    for m in range(1, m_max + 1):
        for k in range(m):
            acc = G(m - k)
            for j in range(k):
                acc = acc - theta[m][m - j] @ V[j] @ theta[k][k - j].T
            # acc @ (V_k + ridge·I)^{-1}
            theta[m][m - k] = jnp.linalg.solve((V[k] + reg).T, acc.T).T
        Vm = G(0)
        for j in range(m):
            Vm = Vm - theta[m][m - j] @ V[j] @ theta[m][m - j].T
        V.append(Vm)

    out = jnp.zeros((m_max, m_max, d, d))
    for m in range(1, m_max + 1):
        for j in range(1, m + 1):
            out = out.at[m - 1, j - 1].set(theta[m][j])
    return out, jnp.stack(V)


def fit_ma(
    gamma: jax.Array, q: int, m: int | None = None
) -> Tuple[jax.Array, jax.Array]:
    """Fit a MA(q) model from autocovariances (paper §3.3).

    Args:
      gamma: (≥m+1, d, d) stacked γ̂; more lags → better innovation estimates.
      q: MA order.
      m: recursion depth (defaults to all available lags).

    Returns:
      B: (q, d, d) — MA coefficient estimates B̂_1..B̂_q.
      sigma: (d, d) — innovation covariance estimate V_m.
    """
    if m is None:
        m = gamma.shape[0] - 1
    if m < q:
        raise ValueError(f"recursion depth m={m} must be ≥ q={q}")
    theta, V = innovation_algorithm(gamma, m)
    B = jnp.stack([theta[m - 1, j] for j in range(q)])
    return B, V[m]
