"""ARMA(p, q) estimation via innovations + block-Toeplitz solve (paper §3.4).

Causal ARMA:  X_t = Σᵢ Aᵢ X_{t-i} + ε_t + Σⱼ Bⱼ ε_{t-j}  admits the MA(∞)
representation X_t = Σⱼ Ψⱼ ε_{t-j} with

    Ψ₀ = I,    Ψⱼ = Bⱼ + Σ_{i=1}^{min(j,p)} Aᵢ Ψ_{j-i}      (Bⱼ = 0 for j>q).

The innovation algorithm applied to γ̂ yields Θ̂_{m,j} → Ψⱼ; the AR part is
then recovered from the linear system over Ψ̂_{q+1-p..p+q} (paper's displayed
block-Hankel system), the MA part by back-substitution, and Σ̂ from V_m.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .innovation import innovation_algorithm

__all__ = ["arma_psi_weights", "solve_arma_from_psi", "fit_arma", "fit_arma_streaming"]


def arma_psi_weights(A: jax.Array, B: jax.Array, n_weights: int) -> jax.Array:
    """Ψ₀..Ψ_{n_weights-1} from ARMA parameters (forward recursion).

    Args:
      A: (p, d, d) AR matrices; B: (q, d, d) MA matrices.

    Returns (n_weights, d, d) with Ψ₀ = I.
    """
    p, d = A.shape[0], A.shape[1]
    q = B.shape[0]
    psis = [jnp.eye(d)]
    for j in range(1, n_weights):
        acc = B[j - 1] if j <= q else jnp.zeros((d, d))
        for i in range(1, min(j, p) + 1):
            acc = acc + A[i - 1] @ psis[j - i]
        psis.append(acc)
    return jnp.stack(psis)


def solve_arma_from_psi(
    psi: jax.Array, p: int, q: int
) -> Tuple[jax.Array, jax.Array]:
    """Recover (A, B) from Ψ₁..Ψ_{p+q} (paper's block system, §3.4).

    For j = q+1 .. q+p:   Ψⱼ = Σ_{i=1}^{p} Aᵢ Ψ_{j-i}   (Bⱼ = 0 there).
    Stacked over rows r = 1..p, unknowns [A₁ … A_p]:

        Σᵢ Aᵢ Ψ_{q+r-i} = Ψ_{q+r}

    which transposes to the block system with blocks Ψ_{q+r-i}ᵀ, exactly the
    matrix displayed in the paper.  Ψ with index < 0 is zero, index 0 is I.

    Args:
      psi: (≥p+q+1, d, d) with psi[0] = I (index j ↔ Ψⱼ).

    Returns: A (p, d, d), B (q, d, d).
    """
    d = psi.shape[1]

    def P(j: int) -> jax.Array:
        if j < 0:
            return jnp.zeros((d, d))
        return psi[j]

    # Row r (1..p):  Σ_i Ψ_{q+r-i}ᵀ A_iᵀ = Ψ_{q+r}ᵀ
    rows = []
    rhs = []
    for r in range(1, p + 1):
        rows.append(jnp.concatenate([P(q + r - i).T for i in range(1, p + 1)], axis=1))
        rhs.append(P(q + r).T)
    M = jnp.concatenate(rows, axis=0)  # (p·d, p·d)
    R = jnp.concatenate(rhs, axis=0)  # (p·d, d)
    sol = jnp.linalg.solve(M, R)  # stacked [A₁ᵀ; …; A_pᵀ]
    A = jnp.stack([sol[i * d : (i + 1) * d, :].T for i in range(p)])

    # Back-substitution for B (paper: B̂ⱼ = Ψ̂ⱼ − Σ Aᵢ Ψ̂_{j-i}).
    Bs = []
    for j in range(1, q + 1):
        acc = P(j)
        for i in range(1, min(j, p) + 1):
            acc = acc - A[i - 1] @ P(j - i)
        Bs.append(acc)
    B = jnp.stack(Bs) if q > 0 else jnp.zeros((0, d, d))
    return A, B


def fit_arma(
    gamma: jax.Array,
    p: int,
    q: int,
    m: int | None = None,
    backend=None,
    ridge: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fit ARMA(p, q) from autocovariances γ̂ (paper §3.4).

    Args:
      gamma: (≥m+1, d, d) stacked γ̂(0..) — the weak-memory statistic; OR a
        raw series (ndim < 3), in which case γ̂(0..m) is computed first
        through the compute-backend registry ("standard" normalization).
      m: innovation recursion depth (default p+q, the paper's choice; larger
        m gives better Ψ estimates at O(m² d³) driver cost).
      backend: compute-backend spec for the series → γ̂ contraction (ignored
        when ``gamma`` is already stacked autocovariances).
      ridge: absolute regularizer on the innovation-recursion solves (see
        `estimators.innovation.innovation_algorithm`); 0.0 is exact.

    Returns: A (p,d,d), B (q,d,d), sigma (d,d).
    """
    if m is None:
        m = p + q
    m = max(m, p + q)
    gamma = jnp.asarray(gamma)
    if gamma.ndim < 3:
        from .stats import autocovariance

        gamma = autocovariance(gamma, m, normalization="standard", backend=backend)
    theta, V = innovation_algorithm(gamma, m, ridge=ridge)
    d = gamma.shape[1]
    # Θ̂_{m,j} estimates Ψⱼ ; prepend Ψ₀ = I.
    psi = jnp.concatenate(
        [jnp.eye(d)[None], jnp.stack([theta[m - 1, j - 1] for j in range(1, p + q + 1)])]
    )
    A, B = solve_arma_from_psi(psi, p, q)
    return A, B, V[m]


def fit_arma_streaming(
    engine,
    state,
    p: int,
    q: int,
    m: int | None = None,
    normalization: str = "standard",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fit ARMA(p, q) from a streaming lag-sum PartialState.

    Same innovations + block-Hankel pipeline as :func:`fit_arma`, but the
    γ̂ input comes from the mergeable streaming sufficient statistic
    (`estimators.stats.lag_sum_engine`) instead of a materialized series.
    ``engine.h_right`` must cover the recursion depth (≥ m, default p+q).
    """
    m_eff = max(m if m is not None else p + q, p + q)
    if engine.h_right < m_eff:
        raise ValueError(
            f"state tracks lags 0..{engine.h_right}, innovation recursion "
            f"needs {m_eff}"
        )
    from .stats import streaming_autocovariance

    gamma = streaming_autocovariance(engine, state, normalization)
    return fit_arma(gamma, p, q, m_eff)
