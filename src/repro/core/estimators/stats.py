"""Sufficient statistics of second-order stationary series (paper §2, §7.1).

All estimators are M-estimators of order-H weak memory: a windowed kernel
mapped over time, reduced with a sum.  Three equivalent execution paths are
provided (serial oracle / overlapping blocks / sharded blocks); equality is
property-tested.

Every lagged contraction routes through the compute-backend registry
(`repro.core.backend`): the block path's per-block lag sums, the serial
path, and the streaming ChunkKernel are all the same primitive —
``lagged_sums`` / ``masked_lagged_sums`` — executed by whichever backend the
caller picks (``"jnp"``, the Pallas VMEM tile kernels of
`repro.kernels.window_stats`, or ``"auto"``).  No estimator owns a private
matmul; changing the substrate is a ``backend=`` argument.

A fourth, *streaming* path (`core.streaming`) computes the same statistic
over data arriving in chunks of arbitrary uneven sizes:
:func:`lag_sum_engine` builds a `StreamingEngine` whose chunk kernel is the
backend's masked lagged matmul, and :func:`streaming_autocovariance`
finalizes a `PartialState` into γ̂ — equal to the serial estimator within
float round-off (the ragged end-of-series terms are recovered from the
state's carried tail halo, again through the backend).
"""
from __future__ import annotations

from typing import Literal, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..backend import BackendSpec, get_backend
from ..overlap import OverlapSpec, make_overlapping_blocks
from ..streaming import PartialState, StreamingEngine, resolved_stat

Normalization = Literal["paper", "standard"]

__all__ = [
    "mean",
    "raw_lag_sums",
    "block_lag_sums",
    "autocovariance",
    "autocovariance_blocked",
    "autocovariance_sharded",
    "autocorrelation",
    "partial_autocorrelation",
    "gamma_normalizer",
    "windowed_moments",
    "lag_sum_engine",
    "moment_engine",
    "streaming_autocovariance",
    "streaming_window_moments",
    "streaming_mean",
]


def mean(x: jax.Array) -> jax.Array:
    """μ̂ = (1/N) Σ X_k — the order-0 weak-memory estimator (paper §2.1.1)."""
    if x.ndim == 1:
        x = x[:, None]
    return jnp.mean(x, axis=0)


def gamma_normalizer(n: int, max_lag: int, normalization: Normalization) -> jax.Array:
    """Per-lag normalizers for γ̂(h), h = 0..max_lag.

    "paper":    1/(N-h-1)  (paper §2.1.2 — unbiased-style, not PSD-safe)
    "standard": 1/N        (biased, guarantees a PSD block-Toeplitz matrix;
                            preferred when feeding Yule-Walker solves)

    The "paper" divisor is clamped to ≥ 1: when ``max_lag`` is within 1 of
    the series length, N-h-1 reaches 0 (or below) and the paper's formula is
    undefined — those (degenerate, one-sample) lags fall back to divisor 1
    instead of producing ±inf.
    """
    h = jnp.arange(max_lag + 1)
    if normalization == "paper":
        return 1.0 / jnp.maximum(n - h - 1, 1)
    return jnp.full((max_lag + 1,), 1.0 / n)


def raw_lag_sums(
    x: jax.Array, max_lag: int, backend: BackendSpec = None
) -> jax.Array:
    """S(h) = Σ_{k=0}^{N-1-h} X_k X_{k+h}ᵀ, h = 0..max_lag: (max_lag+1, d, d).

    Thin front-end over the backend ``lagged_sums`` primitive (the jnp
    implementation is the serial correctness oracle).
    """
    return get_backend(backend).lagged_sums(x, max_lag)


def block_lag_sums(
    blocks: jax.Array,
    spec: OverlapSpec,
    max_lag: int,
    backend: BackendSpec = None,
) -> jax.Array:
    """Per-block lag sums via the backend's masked lagged matmul:
    (P, max_lag+1, d, d).

    Requires ``spec.h_left == 0`` and ``spec.h_right >= max_lag`` (causal
    forward window).  Boundary correctness is automatic: halo slots beyond
    the global series end are zero-filled, so their products vanish — every
    block start stays unmasked (the paper's Fig. 2 scheme).
    """
    if spec.h_left != 0 or spec.h_right < max_lag:
        raise ValueError(
            f"autocovariance at max_lag={max_lag} needs h_left=0, "
            f"h_right>={max_lag}; got ({spec.h_left},{spec.h_right})"
        )
    be = get_backend(backend)
    nb = spec.block_size
    ones = jnp.ones((nb,), jnp.bool_)

    def per_block(block):
        return be.masked_lagged_sums(block[: nb + max_lag], ones, max_lag)

    return jax.vmap(per_block)(blocks)


def autocovariance(
    x: jax.Array,
    max_lag: int,
    normalization: Normalization = "paper",
    center: bool = False,
    backend: BackendSpec = None,
) -> jax.Array:
    """Serial γ̂(h), h = 0..max_lag: (max_lag+1, d, d).  γ̂(-h) = γ̂(h)ᵀ."""
    if x.ndim == 1:
        x = x[:, None]
    if center:
        x = x - mean(x)[None, :]
    s = get_backend(backend).lagged_sums(x, max_lag)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def autocovariance_blocked(
    x: jax.Array,
    max_lag: int,
    block_size: int,
    normalization: Normalization = "paper",
    center: bool = False,
    backend: BackendSpec = None,
) -> jax.Array:
    """Embarrassingly-parallel γ̂ over overlapping blocks (paper Fig. 2/4)."""
    if x.ndim == 1:
        x = x[:, None]
    if center:
        x = x - mean(x)[None, :]
    spec = OverlapSpec(n=x.shape[0], block_size=block_size, h_left=0, h_right=max_lag)
    blocks, _ = make_overlapping_blocks(x, spec)
    partial = block_lag_sums(blocks, spec, max_lag, backend=backend)
    s = jnp.sum(partial, axis=0)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def autocovariance_sharded(
    blocks: jax.Array,
    spec: OverlapSpec,
    max_lag: int,
    mesh: Mesh,
    axis: str = "data",
    normalization: Normalization = "paper",
    backend: BackendSpec = None,
) -> jax.Array:
    """Cluster path: blocks pre-sharded over ``axis``; one psum of (H+1,d,d).

    Data never moves between devices — only the (max_lag+1)·d² sufficient
    statistic is reduced.  This is the paper's core scaling claim.  The
    per-shard local contraction runs through the backend registry, so each
    shard can hit the Pallas tile kernel while the collective stays the
    backend-agnostic psum.
    """

    from ...parallel.sharding import psum_tree, shard_map_compat

    def local(blocks_local):
        partial = block_lag_sums(blocks_local, spec, max_lag, backend=backend)
        return psum_tree(jnp.sum(partial, axis=0), axis)

    s = shard_map_compat(local, mesh=mesh, in_specs=P(axis), out_specs=P())(blocks)
    norm = gamma_normalizer(spec.n, max_lag, normalization)
    return s * norm[:, None, None]


def windowed_moments(
    x: jax.Array, window: int, backend: BackendSpec = None
) -> dict:
    """Rolling mean/variance over every full width-``window`` slice.

    Returns {"mean": (n_win, d), "var": (n_win, d)} (population variance),
    computed from the backend's ``windowed_moments`` sum/sum-of-squares
    primitive — one VPU tile pass on the Pallas backend.

    The variance pass runs on the globally centered series: Var is
    shift-invariant, and E[x²]−E[x]² in f32 cancels catastrophically for
    high-mean series (a 1e4 offset swamps a 1e-3 signal), so the second
    moment is taken about the global mean and clamped at 0.
    """
    if x.ndim == 1:
        x = x[:, None]
    be = get_backend(backend)
    mu = mean(x)
    s = be.windowed_moments(x - mu[None, :], window)
    m_c = s[:, 0] / window
    var = jnp.maximum(s[:, 1] / window - m_c * m_c, 0.0)
    return {"mean": m_c + mu[None, :], "var": var}


def lag_sum_engine(
    max_lag: int, d: int, backend: BackendSpec = None
) -> StreamingEngine:
    """Streaming engine for the lag-sum sufficient statistic S(0..max_lag).

    ``state.stat`` is (max_lag+1, d, d); each chunk update carries only the
    last ``max_lag`` samples of context.  The chunk kernel is the backend's
    ``masked_lagged_sums`` — with ``backend="pallas"`` every streaming
    ``update``/``merge`` runs the VMEM tile kernel.  Finalize with
    :func:`streaming_autocovariance` (γ̂, feeds Yule-Walker/ARMA) or read
    the raw windowed sums directly.
    """
    be = get_backend(backend)

    def ck(y_padded: jax.Array, start_mask: jax.Array) -> jax.Array:
        return be.masked_lagged_sums(y_padded, start_mask, max_lag)

    return StreamingEngine(
        d=d, h_left=0, h_right=max_lag, chunk_kernel=ck, backend=be
    )


def moment_engine(
    window: int, d: int, backend: BackendSpec = None
) -> StreamingEngine:
    """Streaming engine for aggregate windowed moments (paper §2.1.1's
    order-0/1 statistics lifted to the window walk).

    ``state.stat`` is {"sums": (2, d) of Σ_s [Σ_j x_{s+j}, Σ_j x²_{s+j}],
    "count": ()} over every full width-``window`` start s — a fixed-size
    mergeable reduction of the rolling-moment kernel (unlike
    :func:`windowed_moments`, which materializes every window's value and
    therefore cannot stream).  The chunk kernel is the backend's fused
    primitive at ``max_lag=0``, so a standalone moment stream and a fused
    plan member run the identical contraction.  Finalize with
    :func:`streaming_window_moments`.
    """
    be = get_backend(backend)

    def ck(y_padded: jax.Array, start_mask: jax.Array) -> dict:
        _, mom = be.fused_lagged_moments(y_padded, start_mask, 0, window)
        return {"sums": mom, "count": jnp.sum(start_mask.astype(jnp.float32))}

    return StreamingEngine(
        d=d, h_left=0, h_right=window - 1, chunk_kernel=ck, backend=be
    )


def streaming_window_moments(engine: StreamingEngine, state: PartialState) -> dict:
    """Finalize a moment-engine PartialState into aggregate rolling moments.

    Returns {"mean": (d,), "var": (d,), "count": ()} where mean/var are the
    population moments over all samples of all full windows (overlapping
    windows weight interior samples up, exactly as the windowed walk
    defines).  ``count`` is the number of windows; with count == 0 the
    moments are NaN — check before trusting early-stream queries.
    """
    w = engine.window
    stat = resolved_stat(state)
    total = stat["count"] * w
    m1 = stat["sums"][0] / total
    m2 = stat["sums"][1] / total
    return {
        "mean": m1,
        "var": jnp.maximum(m2 - m1 * m1, 0.0),
        "count": stat["count"],
    }


def streaming_autocovariance(
    engine: StreamingEngine,
    state: PartialState,
    normalization: Normalization = "paper",
) -> jax.Array:
    """Finalize a lag-sum PartialState into γ̂(0..max_lag): (H+1, d, d).

    Equivalent to :func:`autocovariance` on the concatenated stream (the
    cross-strategy equivalence suite pins this to 1e-5).

    The windowed stream counts only starts with a *full* forward window
    (s ≤ n-1-max_lag); the serial estimator is ragged — lag h keeps starts
    up to n-1-h.  The missing end-of-series pairs live entirely within the
    last ``max_lag`` samples, i.e. in ``state.tail`` (right-aligned, zero
    where invalid), and are recovered here with one more masked lagged
    contraction through the engine's backend.
    """
    H = engine.h_right
    s = resolved_stat(state)
    if H > 0:
        tail_sums = engine.backend.masked_lagged_sums(
            jnp.concatenate([state.tail, jnp.zeros_like(state.tail)]),
            jnp.ones((H,), jnp.bool_),
            H,
        )
        s = s + tail_sums
    norm = gamma_normalizer(state.length, H, normalization)
    return s * norm[:, None, None]


def streaming_mean(state: PartialState) -> jax.Array:
    """μ̂ from any PartialState — the order-0 rolling statistic."""
    return state.sample_sum / state.length


def autocorrelation(gamma: jax.Array) -> jax.Array:
    """ρ̂(h) = diag(γ̂(0))^{-1/2} γ̂(h) diag(γ̂(0))^{-1/2} (paper §2.1.3)."""
    d0 = jnp.sqrt(jnp.diagonal(gamma[0]))
    inv = 1.0 / d0
    return gamma * inv[None, :, None] * inv[None, None, :]


def partial_autocorrelation(gamma: jax.Array, max_order: Optional[int] = None) -> jax.Array:
    """κ̂(p) for p = 1..max_order from γ̂ (paper §2.1.3, "from auto-correlation
    to partial auto-correlation" linear system), solved per order with the
    dense block-Toeplitz system; the scalable recursion lives in
    `yule_walker.block_levinson`.

    Returns (max_order, d, d): entry p-1 is U_p^{(p)}.
    """
    H = gamma.shape[0] - 1
    if max_order is None:
        max_order = H
    if max_order > H:
        raise ValueError(f"need γ̂ up to lag {max_order}, got {H}")
    d = gamma.shape[1]
    out = []
    for p in range(1, max_order + 1):
        from .yule_walker import _block_toeplitz, _stack_rhs

        G = _block_toeplitz(gamma, p)
        rhs = _stack_rhs(gamma, p)
        sol = jnp.linalg.solve(G, rhs)  # (p·d, d) of [U_1ᵀ; ...; U_pᵀ]
        u_p_T = sol[(p - 1) * d : p * d, :]
        out.append(u_p_T.T)
    return jnp.stack(out)
