"""Sufficient statistics of second-order stationary series (paper §2, §7.1).

All estimators are M-estimators of order-H weak memory: a windowed kernel
mapped over time, reduced with a sum.  Three equivalent execution paths are
provided (serial oracle / overlapping blocks / sharded blocks); equality is
property-tested.

The block path does NOT vmap a per-center kernel: the lag-h cross-product
sum over a block is the matmul ``core.T @ shifted_h`` between the block core
and its h-shifted padded view — this is the TPU adaptation of the paper's
per-thread GPU kernel (one MXU matmul computes every center of the block at
once; the halo makes the shifted view local).  `repro.kernels.window_stats`
implements the same contraction as an explicit Pallas VMEM kernel.

A fourth, *streaming* path (`core.streaming`) computes the same statistic
over data arriving in chunks of arbitrary uneven sizes:
:func:`lag_sum_engine` builds a `StreamingEngine` whose chunk kernel is the
same lagged matmul, and :func:`streaming_autocovariance` finalizes a
`PartialState` into γ̂ — equal to the serial estimator within float
round-off (the ragged end-of-series terms are recovered from the state's
carried tail halo).
"""
from __future__ import annotations

from typing import Literal, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..overlap import OverlapSpec, make_overlapping_blocks
from ..streaming import PartialState, StreamingEngine

Normalization = Literal["paper", "standard"]

__all__ = [
    "mean",
    "raw_lag_sums",
    "block_lag_sums",
    "autocovariance",
    "autocovariance_blocked",
    "autocovariance_sharded",
    "autocorrelation",
    "partial_autocorrelation",
    "gamma_normalizer",
    "lag_sum_engine",
    "streaming_autocovariance",
    "streaming_mean",
]


def mean(x: jax.Array) -> jax.Array:
    """μ̂ = (1/N) Σ X_k — the order-0 weak-memory estimator (paper §2.1.1)."""
    if x.ndim == 1:
        x = x[:, None]
    return jnp.mean(x, axis=0)


def gamma_normalizer(n: int, max_lag: int, normalization: Normalization) -> jax.Array:
    """Per-lag normalizers for γ̂(h), h = 0..max_lag.

    "paper":    1/(N-h-1)  (paper §2.1.2 — unbiased-style, not PSD-safe)
    "standard": 1/N        (biased, guarantees a PSD block-Toeplitz matrix;
                            preferred when feeding Yule-Walker solves)
    """
    h = jnp.arange(max_lag + 1)
    if normalization == "paper":
        return 1.0 / (n - h - 1)
    return jnp.full((max_lag + 1,), 1.0 / n)


def raw_lag_sums(x: jax.Array, max_lag: int) -> jax.Array:
    """Serial oracle: S(h) = Σ_{k=0}^{N-1-h} X_k X_{k+h}^T, h = 0..max_lag.

    Returns (max_lag+1, d, d).
    """
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]

    def one(h):
        head = jax.lax.dynamic_slice_in_dim(x, 0, n - max_lag, axis=0)
        shifted = jax.lax.dynamic_slice_in_dim(x, h, n - max_lag, axis=0)
        # Only the common full-length prefix enters this vectorized form;
        # the ragged tail (k in [n-max_lag, n-h)) is added below.
        return jnp.einsum("ti,tj->ij", head, shifted)

    full = jax.vmap(one)(jnp.arange(max_lag + 1))

    # Ragged tail: for lag h, centers k = n-max_lag .. n-1-h.
    def tail(h):
        ks = jnp.arange(max_lag)  # offsets into the tail region
        k = n - max_lag + ks
        valid = (k + h) <= (n - 1)
        xk = x[jnp.clip(k, 0, n - 1)]
        xkh = x[jnp.clip(k + h, 0, n - 1)]
        contrib = jnp.einsum("ti,tj->tij", xk, xkh)
        return jnp.sum(jnp.where(valid[:, None, None], contrib, 0.0), axis=0)

    if max_lag > 0:
        full = full + jax.vmap(tail)(jnp.arange(max_lag + 1))
    return full


def block_lag_sums(blocks: jax.Array, spec: OverlapSpec, max_lag: int) -> jax.Array:
    """Per-block lag sums via lagged matmuls: (P, max_lag+1, d, d).

    Requires ``spec.h_left == 0`` and ``spec.h_right >= max_lag`` (causal
    forward window).  Boundary correctness is automatic: halo slots beyond
    the global series end are zero-filled, so their products vanish — no
    masks needed (the paper's Fig. 2 scheme).
    """
    if spec.h_left != 0 or spec.h_right < max_lag:
        raise ValueError(
            f"autocovariance at max_lag={max_lag} needs h_left=0, "
            f"h_right>={max_lag}; got ({spec.h_left},{spec.h_right})"
        )
    nb = spec.block_size

    def per_block(block):
        core = block[:nb]  # h_left == 0 → core leads

        def one(h):
            shifted = jax.lax.dynamic_slice_in_dim(block, h, nb, axis=0)
            return jnp.einsum("ti,tj->ij", core, shifted)

        return jax.vmap(one)(jnp.arange(max_lag + 1))

    return jax.vmap(per_block)(blocks)


def autocovariance(
    x: jax.Array,
    max_lag: int,
    normalization: Normalization = "paper",
    center: bool = False,
) -> jax.Array:
    """Serial γ̂(h), h = 0..max_lag: (max_lag+1, d, d).  γ̂(-h) = γ̂(h)ᵀ."""
    if x.ndim == 1:
        x = x[:, None]
    if center:
        x = x - mean(x)[None, :]
    s = raw_lag_sums(x, max_lag)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def autocovariance_blocked(
    x: jax.Array,
    max_lag: int,
    block_size: int,
    normalization: Normalization = "paper",
    center: bool = False,
) -> jax.Array:
    """Embarrassingly-parallel γ̂ over overlapping blocks (paper Fig. 2/4)."""
    if x.ndim == 1:
        x = x[:, None]
    if center:
        x = x - mean(x)[None, :]
    spec = OverlapSpec(n=x.shape[0], block_size=block_size, h_left=0, h_right=max_lag)
    blocks, _ = make_overlapping_blocks(x, spec)
    partial = block_lag_sums(blocks, spec, max_lag)
    s = jnp.sum(partial, axis=0)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def autocovariance_sharded(
    blocks: jax.Array,
    spec: OverlapSpec,
    max_lag: int,
    mesh: Mesh,
    axis: str = "data",
    normalization: Normalization = "paper",
) -> jax.Array:
    """Cluster path: blocks pre-sharded over ``axis``; one psum of (H+1,d,d).

    Data never moves between devices — only the (max_lag+1)·d² sufficient
    statistic is reduced.  This is the paper's core scaling claim.
    """

    from ...parallel.sharding import psum_tree, shard_map_compat

    def local(blocks_local):
        partial = block_lag_sums(blocks_local, spec, max_lag)
        return psum_tree(jnp.sum(partial, axis=0), axis)

    s = shard_map_compat(local, mesh=mesh, in_specs=P(axis), out_specs=P())(blocks)
    norm = gamma_normalizer(spec.n, max_lag, normalization)
    return s * norm[:, None, None]


def _lag_sum_chunk_kernel(max_lag: int):
    """Masked-window lag sums in the MXU matmul form (ChunkKernel contract).

    For y_padded (L + max_lag, d) and start_mask (L,):
    S(h) = Σ_{s: mask[s]} y_s y_{s+h}ᵀ — one lagged matmul per lag, never a
    per-center vmap (same contraction as :func:`block_lag_sums`).
    """

    def ck(y_padded: jax.Array, start_mask: jax.Array) -> jax.Array:
        L = start_mask.shape[0]
        head = jnp.where(start_mask[:, None], y_padded[:L], 0.0)

        def one(h):
            shifted = jax.lax.dynamic_slice_in_dim(y_padded, h, L, axis=0)
            return jnp.einsum("ti,tj->ij", head, shifted)

        return jax.vmap(one)(jnp.arange(max_lag + 1))

    return ck


def lag_sum_engine(max_lag: int, d: int) -> StreamingEngine:
    """Streaming engine for the lag-sum sufficient statistic S(0..max_lag).

    ``state.stat`` is (max_lag+1, d, d); each chunk update carries only the
    last ``max_lag`` samples of context.  Finalize with
    :func:`streaming_autocovariance` (γ̂, feeds Yule-Walker/ARMA) or read
    the raw windowed sums directly.
    """
    return StreamingEngine(
        d=d, h_left=0, h_right=max_lag, chunk_kernel=_lag_sum_chunk_kernel(max_lag)
    )


def _ragged_tail_lag_sums(tail: jax.Array, max_lag: int) -> jax.Array:
    """End-of-series correction: Σ_{j} t_j t_{j+h}ᵀ over the carried tail.

    The windowed stream counts only starts with a *full* forward window
    (s ≤ n-1-max_lag); the serial :func:`raw_lag_sums` is ragged — lag h
    keeps starts up to n-1-h.  The missing pairs live entirely within the
    last ``max_lag`` samples, i.e. in ``state.tail`` (right-aligned, zero
    where invalid, so the masked rows vanish from the products).
    """
    H = max_lag
    tpad = jnp.concatenate([tail, jnp.zeros_like(tail)])

    def one(h):
        shifted = jax.lax.dynamic_slice_in_dim(tpad, h, H, axis=0)
        return jnp.einsum("ti,tj->ij", tail, shifted)

    return jax.vmap(one)(jnp.arange(H + 1))


def streaming_autocovariance(
    engine: StreamingEngine,
    state: PartialState,
    normalization: Normalization = "paper",
) -> jax.Array:
    """Finalize a lag-sum PartialState into γ̂(0..max_lag): (H+1, d, d).

    Equivalent to :func:`autocovariance` on the concatenated stream (the
    cross-strategy equivalence suite pins this to 1e-5).
    """
    H = engine.h_right
    s = state.stat + _ragged_tail_lag_sums(state.tail, H)
    norm = gamma_normalizer(state.length, H, normalization)
    return s * norm[:, None, None]


def streaming_mean(state: PartialState) -> jax.Array:
    """μ̂ from any PartialState — the order-0 rolling statistic."""
    return state.sample_sum / state.length


def autocorrelation(gamma: jax.Array) -> jax.Array:
    """ρ̂(h) = diag(γ̂(0))^{-1/2} γ̂(h) diag(γ̂(0))^{-1/2} (paper §2.1.3)."""
    d0 = jnp.sqrt(jnp.diagonal(gamma[0]))
    inv = 1.0 / d0
    return gamma * inv[None, :, None] * inv[None, None, :]


def partial_autocorrelation(gamma: jax.Array, max_order: Optional[int] = None) -> jax.Array:
    """κ̂(p) for p = 1..max_order from γ̂ (paper §2.1.3, "from auto-correlation
    to partial auto-correlation" linear system), solved per order with the
    dense block-Toeplitz system; the scalable recursion lives in
    `yule_walker.block_levinson`.

    Returns (max_order, d, d): entry p-1 is U_p^{(p)}.
    """
    H = gamma.shape[0] - 1
    if max_order is None:
        max_order = H
    if max_order > H:
        raise ValueError(f"need γ̂ up to lag {max_order}, got {H}")
    d = gamma.shape[1]
    out = []
    for p in range(1, max_order + 1):
        from .yule_walker import _block_toeplitz, _stack_rhs

        G = _block_toeplitz(gamma, p)
        rhs = _stack_rhs(gamma, p)
        sol = jnp.linalg.solve(G, rhs)  # (p·d, d) of [U_1ᵀ; ...; U_pᵀ]
        u_p_T = sol[(p - 1) * d : p * d, :]
        out.append(u_p_T.T)
    return jnp.stack(out)
