"""Linear prediction with AR/ARMA models (paper §4).

AR one-step-ahead is a windowed (order-p weak-memory) kernel; multi-step
re-injects predictions recursively.  ARMA prediction runs the innovation
recursion in a streaming fashion — each step needs only max(p, q) past
observations/innovations, which is the paper's point: forecasting is itself
a weak-memory computation and can run block-parallel for stable models
(initialization error decays exponentially with the causal spectral gap).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ar_one_step", "ar_forecast", "arma_innovations_filter", "arma_forecast"]


def ar_one_step(A: jax.Array, history: jax.Array) -> jax.Array:
    """X̂_{t+1} from the last p observations.  history: (≥p, d), newest last."""
    p = A.shape[0]
    lags = history[-1 : -p - 1 : -1]  # X_t, X_{t-1}, …, X_{t-p+1}
    return jnp.einsum("pij,pj->i", A, lags)


def ar_forecast(A: jax.Array, history: jax.Array, steps: int) -> jax.Array:
    """Iterated multi-step AR forecast (paper §4.1): (steps, d)."""
    p, d = A.shape[0], A.shape[1]
    # history[-0:] is the WHOLE series, not an empty buffer — degenerate
    # p=0 (pure-noise model) must forecast the mean (zero) from no lags.
    buf = history[-p:] if p > 0 else jnp.zeros((0, d))

    def body(buf, _):
        nxt = jnp.einsum("pij,pj->i", A, buf[::-1])
        if p > 0:
            buf = jnp.concatenate([buf[1:], nxt[None]], axis=0)
        return buf, nxt

    _, preds = jax.lax.scan(body, buf, None, length=steps)
    return preds


def arma_innovations_filter(
    A: jax.Array, B: jax.Array, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Streaming one-step predictions + innovation estimates (paper §4.2).

    Uses the steady-state recursion (valid for t ≥ max(p, q), stable models):

        X̂_{t+1} = Σᵢ Aᵢ X_{t+1-i} + Σⱼ Bⱼ ε̂_{t+1-j},   ε̂_s = X_s − X̂_s

    with zero initialization (the paper notes init errors decay
    exponentially for causal+invertible models, enabling approximate
    block-parallel execution).

    Returns:
      preds: (T, d) one-step predictions X̂_t (pred[0] = 0).
      innov: (T, d) innovation estimates.
    """
    p, d = A.shape[0], A.shape[1]
    q = B.shape[0]
    T = x.shape[0]
    xlag0 = jnp.zeros((p, d))  # newest first: X_t, X_{t-1}, ...
    elag0 = jnp.zeros((q, d)) if q > 0 else jnp.zeros((0, d))

    def body(carry, x_t):
        xlag, elag = carry
        pred = jnp.einsum("pij,pj->i", A, xlag)
        if q > 0:
            pred = pred + jnp.einsum("qij,qj->i", B, elag)
        innov = x_t - pred
        xlag = jnp.concatenate([x_t[None], xlag[:-1]], axis=0) if p > 0 else xlag
        if q > 0:
            elag = jnp.concatenate([innov[None], elag[:-1]], axis=0)
        return (xlag, elag), (pred, innov)

    _, (preds, innovs) = jax.lax.scan(body, (xlag0, elag0), x)
    return preds, innovs


def arma_forecast(
    A: jax.Array, B: jax.Array, history: jax.Array, steps: int
) -> jax.Array:
    """Multi-step ARMA forecast: filter the history, then iterate with
    future innovations set to their mean (zero)."""
    p, d = A.shape[0], A.shape[1]
    q = B.shape[0]
    _, innovs = arma_innovations_filter(A, B, history)
    xlag = history[-1 : -p - 1 : -1] if p > 0 else jnp.zeros((0, d))
    elag = innovs[-1 : -q - 1 : -1] if q > 0 else jnp.zeros((0, d))

    def body(carry, _):
        xlag, elag = carry
        pred = jnp.einsum("pij,pj->i", A, xlag)
        if q > 0:
            pred = pred + jnp.einsum("qij,qj->i", B, elag)
        if p > 0:
            xlag = jnp.concatenate([pred[None], xlag[:-1]], axis=0)
        if q > 0:
            elag = jnp.concatenate([jnp.zeros((1, d)), elag[:-1]], axis=0)
        return (xlag, elag), pred

    _, preds = jax.lax.scan(body, (xlag, elag), None, length=steps)
    return preds
