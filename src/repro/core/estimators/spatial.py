"""Banded spatial AR models — very-high-d weak memory in SPACE (paper §6).

When the AR(1) transition A is b-banded (numerical-differentiation stencils,
road networks, sensor lattices), the paper row-partitions the state into P
pieces P_i with spatial halos P_i⁺ = P_i ∪ b-neighbours and shows:

  * one-step prediction x̂_{t+1} = A x_t is embarrassingly parallel across
    row partitions, O(d·(2b+1)) total instead of O(d²)  (§6.1);
  * with block-diagonal noise precision Π (blocks aligned to the partition),
    the conditional likelihood — and its gradient — SEPARATES per partition
    (§6.2): node i needs only (X^{P_i⁺}_t)_t, zero shuffle;
  * first-order methods with the §6.3 step size converge exponentially.

The banded matrix is stored as stacked diagonals, shape (d, 2b+1):
``diags[i, b+o] = A[i, i+o]`` for offsets o ∈ [-b, b] (zero where out of
range) — the same layout `repro.kernels.banded_matvec` tiles into VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..backend import BackendSpec, get_backend

__all__ = [
    "BandedARModel",
    "banded_to_dense",
    "dense_to_banded",
    "banded_predict",
    "SpatialPartition",
    "banded_predict_partitioned",
    "banded_nll",
    "fit_banded_ar",
]


@dataclasses.dataclass(frozen=True)
class BandedARModel:
    """x_{t+1} = A x_t + ε_t with b-banded A stored as diagonals."""

    diags: jax.Array  # (d, 2b+1)

    @property
    def d(self) -> int:
        return self.diags.shape[0]

    @property
    def bandwidth(self) -> int:
        return (self.diags.shape[1] - 1) // 2


def banded_to_dense(diags: jax.Array) -> jax.Array:
    """(d, 2b+1) diagonals → dense (d, d) banded matrix."""
    d, w = diags.shape
    b = (w - 1) // 2
    rows = jnp.arange(d)[:, None]
    cols = rows + jnp.arange(-b, b + 1)[None, :]
    valid = (cols >= 0) & (cols < d)
    dense = jnp.zeros((d, d))
    return dense.at[rows, jnp.clip(cols, 0, d - 1)].add(jnp.where(valid, diags, 0.0))


def dense_to_banded(A: jax.Array, b: int) -> jax.Array:
    """Extract the (d, 2b+1) diagonals of a dense matrix (drops off-band)."""
    d = A.shape[0]
    rows = jnp.arange(d)[:, None]
    cols = rows + jnp.arange(-b, b + 1)[None, :]
    valid = (cols >= 0) & (cols < d)
    return jnp.where(valid, A[rows, jnp.clip(cols, 0, d - 1)], 0.0)


def banded_predict(
    diags: jax.Array, x: jax.Array, backend: BackendSpec = None
) -> jax.Array:
    """x̂ = A x from the diagonal form — O(d·(2b+1)) (paper §6.1).

    Routes through the compute-backend registry's ``banded_matvec``
    primitive (`repro.core.backend`): gather-einsum on "jnp", the row-tiled
    VMEM kernel of `repro.kernels.banded_matvec` on "pallas".  The Pallas
    kernel carries a custom VJP (Aᵀ g is another banded matvec against the
    transposed band), so differentiable paths (`banded_nll`,
    `fit_banded_ar`) run on any backend.

    Args:
      diags: (d, 2b+1);  x: (..., d).
    Returns (..., d).
    """
    return get_backend(backend).banded_matvec(diags, x)


@dataclasses.dataclass(frozen=True)
class SpatialPartition:
    """Row partitioning of a d-dim state with b-halos (paper §6.1, P_i / P_i⁺)."""

    d: int
    num_parts: int
    bandwidth: int

    def __post_init__(self):
        if self.d % self.num_parts != 0:
            raise ValueError(f"d={self.d} must divide into {self.num_parts} parts")

    @property
    def part_size(self) -> int:
        return self.d // self.num_parts

    def padded_indices(self) -> np.ndarray:
        """(P, part_size + 2b) global row index of every padded slot (clamped)."""
        starts = np.arange(self.num_parts) * self.part_size - self.bandwidth
        idx = starts[:, None] + np.arange(self.part_size + 2 * self.bandwidth)[None, :]
        return idx

    def padded_mask(self) -> np.ndarray:
        idx = self.padded_indices()
        return (idx >= 0) & (idx < self.d)


def banded_predict_partitioned(
    diags: jax.Array, x: jax.Array, part: SpatialPartition
) -> jax.Array:
    """Partitioned predictor: each part computes its rows from x^{P_i⁺} only.

    Bit-identical to :func:`banded_predict` (property-tested); the P axis is
    vmapped here and sharded over a mesh axis in
    `repro.parallel` / `examples/spatial_ar.py`.
    """
    b = part.bandwidth
    ps = part.part_size
    idx = jnp.asarray(part.padded_indices())
    mask = jnp.asarray(part.padded_mask())
    x_parts = jnp.where(mask, jnp.take(x, jnp.clip(idx, 0, part.d - 1), axis=-1), 0.0)
    diags_parts = diags.reshape(part.num_parts, ps, -1)

    def one(diag_p, xp):
        # row r of this part sees padded slots [r, r+2b]
        cols = jnp.arange(ps)[:, None] + jnp.arange(2 * b + 1)[None, :]
        xn = xp[cols]
        return jnp.einsum("rw,rw->r", xn, diag_p)

    out = jax.vmap(one)(diags_parts, jnp.moveaxis(x_parts, 0, 0))
    return out.reshape(part.d)


def banded_nll(
    diags: jax.Array,
    x: jax.Array,
    block_precisions: Optional[jax.Array] = None,
    part: Optional[SpatialPartition] = None,
    backend: BackendSpec = None,
) -> jax.Array:
    """Mean conditional NLL with block-diagonal precision (paper §6.2).

    Args:
      diags: (d, 2b+1) banded transition.
      x: (T, d) observations.
      block_precisions: (P, ps, ps) diagonal blocks π_i of Π (defaults I).
      part: spatial partitioning (defaults to one part).
      backend: compute-backend spec for the predictor contraction.  The
        loss is differentiated; the Pallas banded matvec has a custom VJP,
        so any backend works (previously "jnp" was pinned here).

    The separability claim: this loss is a sum over partitions i of terms
    that only read X^{P_i⁺} — verified in tests by comparing against the
    dense-precision computation.
    """
    d = diags.shape[0]
    if part is None:
        part = SpatialPartition(d=d, num_parts=1, bandwidth=(diags.shape[1] - 1) // 2)
    pred = banded_predict(diags, x[:-1], backend=backend)  # (T-1, d)
    resid = x[1:] - pred
    ps = part.part_size
    r = resid.reshape(resid.shape[0], part.num_parts, ps)
    if block_precisions is None:
        quad = jnp.sum(r * r)
        logdet = 0.0
    else:
        quad = jnp.einsum("tpi,pij,tpj->", r, block_precisions, r)
        logdet = jnp.sum(jnp.linalg.slogdet(block_precisions)[1])
    n = resid.shape[0]
    return 0.5 * quad / n - 0.5 * logdet


class BandedFitResult(NamedTuple):
    diags: jax.Array
    nll_trace: jax.Array


def fit_banded_ar(
    x: jax.Array,
    bandwidth: int,
    *,
    n_steps: int = 300,
    step_size: Optional[float] = None,
    num_parts: int = 1,
    block_precisions: Optional[jax.Array] = None,
    backend: BackendSpec = None,
) -> BandedFitResult:
    """First-order conditional MLE of the banded model (paper §6.2–6.3).

    The gradient w.r.t. the (d, 2b+1) diagonals separates across row
    partitions; jax.grad through :func:`banded_nll` realizes exactly the
    paper's per-node gradient with time complexity O(N·(2b+1)²) per row.
    ``backend`` picks the predictor substrate for both the forward loss and
    (via the kernel's custom VJP) the gradient — the fit is no longer
    pinned to the jnp path.
    """
    d = x.shape[1]
    part = SpatialPartition(d=d, num_parts=num_parts, bandwidth=bandwidth)
    diags = jnp.zeros((d, 2 * bandwidth + 1))
    if step_size is None:
        c = jnp.cov(x, rowvar=False).reshape(d, d)
        ev = jnp.linalg.eigvalsh(c)
        step_size = float(2.0 / (ev[0] + ev[-1]))

    loss = lambda dg: banded_nll(dg, x, block_precisions, part, backend=backend)

    @jax.jit
    def step(dg):
        v, g = jax.value_and_grad(loss)(dg)
        return dg - step_size * g, v

    trace = []
    for _ in range(n_steps):
        diags, v = step(diags)
        trace.append(v)
    return BandedFitResult(diags, jnp.stack(trace))
