"""Z-estimators: conditional-MLE AR fitting by first-order methods (paper §5).

The conditional Gaussian log-likelihood of an AR(p) sample decomposes as a
sum over t of terms that each touch only the window (X_{t-p}, …, X_t) — an
order-p weak-memory estimator (paper §7.2).  Its gradient therefore runs
through the same overlapping-block map-reduce as the M-estimators, and both
full-batch gradient ascent and SGD are embarrassingly parallel across blocks.

Paper §6.3 step sizes:
  * Π = I:        Hessian blocks are Ĉov(X); step 2/(m̂+L̂) with m̂, L̂ the
                  extreme eigenvalues of Ĉov(X) gives an exponential rate.
  * Π diagonal:   Hessian = Π ⊗ Ĉov(X); step 2/(m̂_Π m̂_C + L̂_Π L̂_C)-style
                  bound; we use eig extremes of the Kronecker product.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..mapreduce import block_window_map_reduce, serial_window_map_reduce
from ..overlap import OverlapSpec

__all__ = [
    "ar_residual",
    "ar_conditional_nll",
    "ar_nll_and_grad_blocked",
    "optimal_step_size",
    "fit_ar_mle",
    "fit_ar_sgd",
]


def ar_residual(A: jax.Array, window: jax.Array) -> jax.Array:
    """ε̂_t = X_t − Σᵢ Aᵢ X_{t-i} for one window (p+1, d) → (d,).

    window[-1] is X_t (the center), window[-1-i] is X_{t-i}.
    """
    p = A.shape[0]
    x_t = window[-1]
    lags = window[-2::-1]  # X_{t-1}, …, X_{t-p}
    pred = jnp.einsum("pij,pj->i", A, lags[:p])
    return x_t - pred


def _nll_kernel(A: jax.Array, precision: jax.Array, window: jax.Array):
    """Per-window contribution: (½ rᵀ Π r, 1).  The constant −½ log det Π per
    sample is added by the caller (it does not depend on the data)."""
    r = ar_residual(A, window)
    return 0.5 * r @ precision @ r, jnp.asarray(1.0)


def ar_conditional_nll(
    A: jax.Array, precision: jax.Array, x: jax.Array
) -> jax.Array:
    """Mean conditional negative log-likelihood (up to an additive constant).

    −(1/T) Σ_t [ log f(ε̂_t) ] = ½ mean(rᵀΠr) − ½ log det Π + const.
    """
    p = A.shape[0]
    quad, count = serial_window_map_reduce(
        functools.partial(_nll_kernel, A, precision), x, h_left=p, h_right=0
    )
    _, logdet = jnp.linalg.slogdet(precision)
    return quad / count - 0.5 * logdet


def ar_nll_and_grad_blocked(
    A: jax.Array,
    precision: jax.Array,
    x: jax.Array,
    block_size: int,
) -> Tuple[jax.Array, jax.Array]:
    """(nll, ∂nll/∂A) through the embarrassingly-parallel block path.

    jax.grad differentiates *through* the overlapping-block map-reduce: each
    block contributes its local gradient, the final sum is the only
    reduction — the paper's Z-estimator scheme verbatim (§7.2).
    """
    p = A.shape[0]
    spec = OverlapSpec(n=x.shape[0], block_size=block_size, h_left=p, h_right=0)

    def objective(A_):
        quad, count = block_window_map_reduce(
            functools.partial(_nll_kernel, A_, precision), x, spec
        )
        _, logdet = jnp.linalg.slogdet(precision)
        return quad / count - 0.5 * logdet

    return jax.value_and_grad(objective)(A)


def optimal_step_size(x: jax.Array, precision: Optional[jax.Array] = None) -> jax.Array:
    """Paper §6.3: 2/(m̂+L̂) from the extreme eigenvalues of the Hessian.

    With Π = I the Hessian blocks are Ĉov(X); with diagonal Π it is
    Π ⊗ Ĉov(X), whose eigen-extremes are products of the factors' extremes.
    """
    if x.ndim == 1:
        x = x[:, None]
    c = jnp.cov(x, rowvar=False).reshape(x.shape[1], x.shape[1])
    ev = jnp.linalg.eigvalsh(c)
    m_c, L_c = ev[0], ev[-1]
    if precision is None:
        return 2.0 / (m_c + L_c)
    pv = jnp.linalg.eigvalsh(precision)
    return 2.0 / (pv[0] * m_c + pv[-1] * L_c)


class FitResult(NamedTuple):
    A: jax.Array
    precision: jax.Array
    nll_trace: jax.Array


def fit_ar_mle(
    x: jax.Array,
    p: int,
    *,
    n_steps: int = 200,
    block_size: int = 1024,
    step_size: Optional[float] = None,
    update_precision_every: int = 0,
    seed_A: Optional[jax.Array] = None,
) -> FitResult:
    """Full-batch gradient-descent conditional MLE (paper §5.1.1, §6.3).

    Alternate maximization: gradient steps on A with Π fixed; optional
    closed-form Π update (inverse residual covariance) every k steps — the
    paper's argument-wise alternate scheme (§5.1.1 last paragraph).
    """
    if x.ndim == 1:
        x = x[:, None]
    d = x.shape[1]
    A = seed_A if seed_A is not None else jnp.zeros((p, d, d))
    precision = jnp.eye(d)
    lr = optimal_step_size(x) if step_size is None else step_size
    block_size = min(block_size, x.shape[0])

    @jax.jit
    def step(A_, prec_):
        nll, g = ar_nll_and_grad_blocked(A_, prec_, x, block_size)
        return A_ - lr * g, nll

    trace = []
    for i in range(n_steps):
        A, nll = step(A, precision)
        trace.append(nll)
        if update_precision_every and (i + 1) % update_precision_every == 0:
            precision = _residual_precision(A, x)
    return FitResult(A, precision, jnp.stack(trace))


def _residual_precision(A: jax.Array, x: jax.Array) -> jax.Array:
    """Closed-form Π update: inverse of the empirical residual covariance."""
    p = A.shape[0]

    def kern(window):
        r = ar_residual(A, window)
        return jnp.outer(r, r), jnp.asarray(1.0)

    s, n = serial_window_map_reduce(kern, x, h_left=p, h_right=0)
    cov = s / n
    d = cov.shape[0]
    return jnp.linalg.inv(cov + 1e-8 * jnp.eye(d))


def fit_ar_sgd(
    x: jax.Array,
    p: int,
    *,
    n_steps: int = 2000,
    batch: int = 64,
    lr0: Optional[float] = None,
    decay: float = 0.05,
    key: Optional[jax.Array] = None,
) -> FitResult:
    """Stochastic first-order conditional MLE (paper §5.1.3).

    Samples a minibatch of window centers t ∈ {p…N-1} uniformly, computes the
    local gradient (each term touches only X_{t-p..t} — weak memory), and
    applies a hyperbolically decaying step (paper: 1/n rate for the squared
    L₂ error under strong concavity).
    """
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    A = jnp.zeros((p, d, d))
    precision = jnp.eye(d)
    lr0 = float(optimal_step_size(x)) if lr0 is None else lr0

    windows_start = jnp.arange(n - p)  # window [s, s+p]; center t = s+p

    def minibatch_nll(A_, starts):
        wins = jax.vmap(lambda s: jax.lax.dynamic_slice_in_dim(x, s, p + 1, axis=0))(
            starts
        )
        quads = jax.vmap(lambda w: _nll_kernel(A_, precision, w)[0])(wins)
        return jnp.mean(quads)

    @jax.jit
    def step(A_, key_, i):
        key_, sub = jax.random.split(key_)
        starts = jax.random.choice(sub, windows_start, shape=(batch,))
        nll, g = jax.value_and_grad(minibatch_nll)(A_, starts)
        lr = lr0 / (1.0 + decay * i)
        return A_ - lr * g, key_, nll

    trace = []
    for i in range(n_steps):
        A, key, nll = step(A, key, jnp.asarray(i, dtype=jnp.float32))
        if i % max(1, n_steps // 100) == 0:
            trace.append(nll)
    return FitResult(A, precision, jnp.stack(trace))
