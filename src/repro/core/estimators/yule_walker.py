"""Yule-Walker AR estimation (paper §3.2) and Levinson-type recursions.

Conventions used throughout (self-consistent, test-verified):
  γ(h) = E[X_t X_{t+h}ᵀ]  for h ≥ 0,   γ(-h) = γ(h)ᵀ.

The YW system, with rows j = 1..p and S = [A₁ᵀ; …; A_pᵀ] stacked (p·d, d):

    [γ(j-i)]_{j,i=1..p}  S  =  [γ(j)]_{j=1..p}

and the innovation covariance  Σ_ε = γ(0) − Σ_i A_i γ(i).

Three solvers:
  * :func:`yule_walker` — dense (p·d × p·d) solve; O(p³d³), fine for p ≪ d
    but cubic in the stacked size; the correctness oracle.
  * :func:`levinson_durbin` — univariate O(p²) recursion (paper cites
    Durbin-Levinson).
  * :func:`block_levinson` — Whittle's multivariate recursion, the
    O(p²·d³)-time / O(p·d²)-space algorithm the paper attributes to Akaike;
    also yields the PACF sequence κ(m) = Φ_{m,m} for free.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "yule_walker",
    "levinson_durbin",
    "block_levinson",
    "streaming_yule_walker",
    "_block_toeplitz",
    "_stack_rhs",
]


def _gamma_at(gamma: jax.Array, h: int) -> jax.Array:
    """γ(h) for any sign, from the stacked (H+1, d, d) non-negative lags."""
    return gamma[h] if h >= 0 else gamma[-h].T


def _block_toeplitz(gamma: jax.Array, p: int) -> jax.Array:
    """(p·d, p·d) block-Toeplitz with block (r, c) = γ(r - c), 0-indexed."""
    rows = []
    for r in range(p):
        rows.append(jnp.concatenate([_gamma_at(gamma, r - c) for c in range(p)], axis=1))
    return jnp.concatenate(rows, axis=0)


def _stack_rhs(gamma: jax.Array, p: int) -> jax.Array:
    """(p·d, d) stacked [γ(1); …; γ(p)]."""
    return jnp.concatenate([gamma[j] for j in range(1, p + 1)], axis=0)


def yule_walker(
    gamma: jax.Array,
    p: int,
    backend=None,
    normalization: str = "standard",
) -> Tuple[jax.Array, jax.Array]:
    """Dense YW solve from γ̂(0..p) — or straight from a raw series.

    Args:
      gamma: (≥p+1, d, d) stacked autocovariances, γ(h) = E[X_t X_{t+h}ᵀ];
        OR a raw series ((n,) or (n, d) — anything with ndim < 3), in which
        case γ̂ is computed first through the compute-backend registry
        (`repro.core.backend`) with the given ``normalization`` (PSD-safe
        "standard" by default).
      p: AR order.
      backend: compute-backend spec for the series → γ̂ contraction (ignored
        when ``gamma`` is already stacked autocovariances).

    Returns:
      A: (p, d, d) coefficient matrices A₁..A_p.
      sigma: (d, d) innovation covariance estimate.
    """
    gamma = jnp.asarray(gamma)
    if gamma.ndim < 3:
        from .stats import autocovariance

        gamma = autocovariance(gamma, p, normalization=normalization, backend=backend)
    if gamma.shape[0] < p + 1:
        raise ValueError(f"need γ̂ up to lag {p}, got {gamma.shape[0] - 1}")
    d = gamma.shape[1]
    G = _block_toeplitz(gamma, p)
    rhs = _stack_rhs(gamma, p)
    sol = jnp.linalg.solve(G, rhs)  # stacked [A₁ᵀ; …; A_pᵀ]
    A = jnp.stack([sol[i * d : (i + 1) * d, :].T for i in range(p)])
    sigma = gamma[0] - sum(A[i] @ gamma[i + 1] for i in range(p))
    return A, sigma


def streaming_yule_walker(
    engine, state, p: int, normalization: str = "standard"
) -> Tuple[jax.Array, jax.Array]:
    """YW solve straight from a streaming lag-sum PartialState.

    The state is the mergeable sufficient statistic
    (`estimators.stats.lag_sum_engine`); only γ̂ finalization touches it —
    the solve itself never sees the raw series (paper's point, now rolling).

    Args:
      engine: the `StreamingEngine` the state was built with
        (``engine.h_right`` must be ≥ p).
      state: lag-sum PartialState.
      p: AR order.

    Returns: (A (p, d, d), sigma (d, d)) — as :func:`yule_walker`.
    """
    if engine.h_right < p:
        raise ValueError(
            f"state tracks lags 0..{engine.h_right}, need {p} for order-{p} YW"
        )
    from .stats import streaming_autocovariance

    gamma = streaming_autocovariance(engine, state, normalization)
    return yule_walker(gamma[: p + 1], p)


def levinson_durbin(gamma: jax.Array, p: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Univariate Durbin-Levinson, O(p²) (paper §3.2).

    Args:
      gamma: (≥p+1,) autocovariances γ(0..p).

    Returns:
      phi: (p,) AR coefficients of the order-p model.
      v: scalar innovation variance.
      pacf: (p,) partial autocorrelations φ_{m,m}, m = 1..p.
    """
    gamma = jnp.asarray(gamma).reshape(-1)
    phi = jnp.zeros((p,))
    pacf = jnp.zeros((p,))
    v = gamma[0]
    for m in range(1, p + 1):
        if m == 1:
            k = gamma[1] / gamma[0]
        else:
            acc = jnp.dot(phi[: m - 1], gamma[1:m][::-1])
            k = (gamma[m] - acc) / v
        new_phi = phi.at[m - 1].set(k)
        if m > 1:
            new_phi = new_phi.at[: m - 1].set(phi[: m - 1] - k * phi[: m - 1][::-1])
        phi = new_phi
        pacf = pacf.at[m - 1].set(k)
        v = v * (1.0 - k**2)
    return phi, v, pacf


def block_levinson(gamma: jax.Array, p: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Whittle's multivariate Levinson recursion (the paper's Akaike solver).

    O(p²) matrix products of size d (i.e. O(p² d³) time, O(p d²) space)
    instead of the dense O(p³ d³) solve — the scalable path when p ≪ d.

    Args:
      gamma: (≥p+1, d, d) stacked autocovariances, γ(h) = E[X_t X_{t+h}ᵀ].

    Returns:
      A: (p, d, d) forward AR coefficients (order-p model).
      sigma: (d, d) forward innovation covariance V_p.
      pacf: (p, d, d) partial autocorrelation matrices κ(m) = Φ_{m,m}.
    """
    d = gamma.shape[1]
    # Γ(h) := E[X_{t+h} X_tᵀ] = γ(h)ᵀ — the convention Whittle's recursion is
    # usually stated in.
    G = lambda h: gamma[h].T if h >= 0 else gamma[-h]

    fwd = []  # Φ_{m,1..m}
    bwd = []  # backward coefficients Ψ_{m,1..m}
    V = G(0)  # forward prediction error covariance  V_{m-1}
    W = G(0)  # backward prediction error covariance W_{m-1}
    pacf = []
    for m in range(1, p + 1):
        acc = G(m)
        for j in range(1, m):
            acc = acc - fwd[j - 1] @ G(m - j)
        Phi_mm = jnp.linalg.solve(W.T, acc.T).T  # acc @ W^{-1}
        accb = G(m).T
        for j in range(1, m):
            accb = accb - bwd[j - 1] @ G(m - j).T
        Psi_mm = jnp.linalg.solve(V.T, accb.T).T  # accb @ V^{-1}

        new_fwd = [fwd[j - 1] - Phi_mm @ bwd[m - j - 1] for j in range(1, m)] + [Phi_mm]
        new_bwd = [bwd[j - 1] - Psi_mm @ fwd[m - j - 1] for j in range(1, m)] + [Psi_mm]
        V_new = V - Phi_mm @ W @ Phi_mm.T
        W_new = W - Psi_mm @ V @ Psi_mm.T
        V, W = V_new, W_new
        fwd, bwd = new_fwd, new_bwd
        pacf.append(Phi_mm)
    A = jnp.stack(fwd)
    sigma = gamma[0] - sum(A[i] @ gamma[i + 1] for i in range(p))
    return A, sigma, jnp.stack(pacf)
