"""Time-series data substrate: synthetic generation, distributed storage,
irregular-series alignment."""
from .generator import (
    random_stable_var,
    random_invertible_ma,
    simulate_var,
    simulate_vma,
    simulate_varma,
    companion_matrix,
    spectral_radius,
)
from .dataset import TimeSeriesStore
from .streaming import StreamingEstimator
from .irregular import regularize

__all__ = [
    "random_stable_var",
    "random_invertible_ma",
    "simulate_var",
    "simulate_vma",
    "simulate_varma",
    "companion_matrix",
    "spectral_radius",
    "TimeSeriesStore",
    "StreamingEstimator",
    "regularize",
]
