"""Synthetic VAR / VMA / VARMA generation with stability control (paper §1.3).

Causality: the companion matrix of A(z) must have spectral radius < 1; we
sample random coefficient matrices and rescale the companion spectrum to a
target radius, guaranteeing a causal (stationary) simulation.  Invertibility
of the MA part is enforced the same way on B's companion.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "companion_matrix",
    "spectral_radius",
    "random_stable_var",
    "random_invertible_ma",
    "simulate_var",
    "simulate_vma",
    "simulate_varma",
]


def companion_matrix(A: np.ndarray) -> np.ndarray:
    """(p·d, p·d) companion of coefficient stack A (p, d, d) — paper §1.2."""
    p, d = A.shape[0], A.shape[1]
    top = np.concatenate([A[i] for i in range(p)], axis=1)
    if p == 1:
        return top
    eye = np.eye((p - 1) * d)
    bottom = np.concatenate([eye, np.zeros(((p - 1) * d, d))], axis=1)
    return np.concatenate([top, bottom], axis=0)


def spectral_radius(A: np.ndarray) -> float:
    return float(np.max(np.abs(np.linalg.eigvals(companion_matrix(A)))))


def _rescale_to_radius(A: np.ndarray, radius: float) -> np.ndarray:
    """Scale A_i ← s^i A_i so the companion spectral radius becomes ``radius``
    (eigenvalues of the rescaled companion are s·λ)."""
    p = A.shape[0]
    rho = spectral_radius(A)
    if rho == 0:
        return A
    s = radius / rho
    return np.stack([A[i] * s ** (i + 1) for i in range(p)])


def random_stable_var(
    key: jax.Array, p: int, d: int, radius: float = 0.7
) -> jnp.ndarray:
    """Random causal AR coefficients (p, d, d) with companion radius ``radius``."""
    a = jax.random.normal(key, (p, d, d)) / np.sqrt(d * p)
    return jnp.asarray(_rescale_to_radius(np.asarray(a), radius))


def random_invertible_ma(
    key: jax.Array, q: int, d: int, radius: float = 0.5
) -> jnp.ndarray:
    """Random invertible MA coefficients (q, d, d) (paper §1.3.2: spectrum of
    the −B companion bounded by 1)."""
    b = jax.random.normal(key, (q, d, d)) / np.sqrt(d * q)
    return jnp.asarray(_rescale_to_radius(np.asarray(b), radius))


def _noise(key: jax.Array, n: int, d: int, sigma: Optional[jnp.ndarray]) -> jnp.ndarray:
    eps = jax.random.normal(key, (n, d))
    if sigma is not None:
        chol = jnp.linalg.cholesky(sigma)
        eps = eps @ chol.T
    return eps


def simulate_var(
    key: jax.Array,
    A: jnp.ndarray,
    n: int,
    sigma: Optional[jnp.ndarray] = None,
    burn_in: int = 256,
) -> jnp.ndarray:
    """Simulate a causal VAR(p): (n, d).  Burn-in discards init transients."""
    p, d = A.shape[0], A.shape[1]
    eps = _noise(key, n + burn_in, d, sigma)

    def body(lags, e):
        x = jnp.einsum("pij,pj->i", A, lags) + e
        lags = jnp.concatenate([x[None], lags[:-1]], axis=0)
        return lags, x

    _, xs = jax.lax.scan(body, jnp.zeros((p, d)), eps)
    return xs[burn_in:]


def simulate_vma(
    key: jax.Array,
    B: jnp.ndarray,
    n: int,
    sigma: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Simulate a VMA(q): X_t = ε_t + Σ B_j ε_{t-j} — exact, no burn-in."""
    q, d = B.shape[0], B.shape[1]
    eps = _noise(key, n + q, d, sigma)

    def at(t):
        x = eps[t + q]
        for j in range(1, q + 1):
            x = x + B[j - 1] @ eps[t + q - j]
        return x

    return jax.vmap(at)(jnp.arange(n))


def simulate_varma(
    key: jax.Array,
    A: jnp.ndarray,
    B: jnp.ndarray,
    n: int,
    sigma: Optional[jnp.ndarray] = None,
    burn_in: int = 256,
) -> jnp.ndarray:
    """Simulate a causal ARMA(p, q): (n, d)."""
    p, d = A.shape[0], A.shape[1]
    q = B.shape[0]
    eps = _noise(key, n + burn_in + q, d, sigma)

    def body(carry, t):
        xlags, = carry
        e_t = eps[t + q]
        ma = e_t
        for j in range(1, q + 1):
            ma = ma + B[j - 1] @ eps[t + q - j]
        x = jnp.einsum("pij,pj->i", A, xlags) + ma
        xlags = jnp.concatenate([x[None], xlags[:-1]], axis=0)
        return (xlags,), x

    _, xs = jax.lax.scan(body, (jnp.zeros((p, d)),), jnp.arange(n + burn_in))
    return xs[burn_in:]
