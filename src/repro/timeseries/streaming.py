"""StreamingEstimator — the ingestion driver over the weak-memory monoid.

Binds a `repro.core.streaming.StreamingEngine` to a stream of chunks (any
iterator of (c, d) arrays — `TimeSeriesStore.iter_chunks`, a socket, a
queue) and maintains the rolling `PartialState`.  Two axes of scale:

  * **time** — chunks of arbitrary uneven sizes are absorbed with
    ``h_left + h_right`` carried samples of context, never the series;
  * **series** — with ``batch=B`` every operation runs vmapped over B
    independent series in one device pass (states are pytrees with a
    leading batch axis).

Estimator results are read out through the front-end finalizers
(``estimators.stats.streaming_autocovariance``,
``estimators.yule_walker.streaming_yule_walker``,
``estimators.arma.fit_arma_streaming``,
``estimators.spectral.streaming_welch``) via :meth:`finalize`.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp

from ..core.streaming import PartialState, StreamingEngine

__all__ = ["StreamingEstimator"]


class StreamingEstimator:
    """Stateful driver: ingest chunks, merge peers, finalize estimates.

    Args:
      engine: the estimator's streaming engine (defines kernel + halo).
      batch: number of independent series (None → a single series).
        With a batch, every ingested chunk is (batch, c, d) and updates all
        series in one vmapped device pass.
      t0: global start index (scalar, or per-series (batch,) array).
    """

    def __init__(
        self,
        engine: StreamingEngine,
        batch: Optional[int] = None,
        t0: int | jax.Array = 0,
    ):
        self.engine = engine
        self.batch = batch
        # The engine's cached jitted programs: repeated ingest of same-shape
        # chunks never re-traces, and `consume` folds a whole chunk stack in
        # one lax.scan device program (donating the carried state buffers).
        if batch is None:
            self.state = engine.init(t0)
            self._update = engine.update_jit
            self._merge = engine.merge_jit
            self._consume = engine.consume
        else:
            self.state = engine.init_batch(batch, t0)
            self._update = engine.update_batch
            self._merge = engine.merge_batch
            self._consume = engine.consume_batch

    @classmethod
    def from_store(
        cls, engine: StreamingEngine, store, chunk_size: int
    ) -> "StreamingEstimator":
        """Stream a `TimeSeriesStore` through the engine chunk by chunk."""
        est = cls(engine)
        est.ingest_iter(store.iter_chunks(chunk_size))
        return est

    def ingest(self, chunk: jax.Array) -> "StreamingEstimator":
        """Absorb the next chunk ((c, d), or (batch, c, d) when batched)."""
        self.state = self._update(self.state, chunk)
        return self

    def ingest_iter(self, chunks: Iterable[jax.Array]) -> "StreamingEstimator":
        for chunk in chunks:
            self.ingest(chunk)
        return self

    def consume(self, chunk_stack: jax.Array) -> "StreamingEstimator":
        """Scan-driven ingest of a stack of equal-length chunks.

        ``chunk_stack`` is (k, c, d) — or (k, batch, c, d) when batched —
        and the whole stack is absorbed by ONE ``lax.scan`` device program
        (`repro.core.streaming.StreamingEngine.consume`): no per-chunk
        Python dispatch, no k host round-trips, and the carried
        PartialState's buffers are donated (long ingest loops allocate
        nothing per chunk).  Equivalent to ``ingest_iter(chunk_stack)``.
        """
        self.state = self._consume(self.state, chunk_stack)
        return self

    def merge_from(self, other: "StreamingEstimator | PartialState") -> "StreamingEstimator":
        """⊕ another partial into this one (adjacent segment, any order)."""
        state = other.state if isinstance(other, StreamingEstimator) else other
        self.state = self._merge(self.state, state)
        return self

    def finalize(self, finalizer: Callable, *args, **kwargs) -> Any:
        """Apply an estimator front-end finalizer to the current state.

        ``finalizer(engine, state, *args, **kwargs)`` — e.g.
        ``est.finalize(streaming_autocovariance, normalization="standard")``.
        Batched drivers vmap the finalizer over the series axis.
        """
        if self.batch is None:
            return finalizer(self.engine, self.state, *args, **kwargs)
        return jax.vmap(lambda s: finalizer(self.engine, s, *args, **kwargs))(
            self.state
        )

    @property
    def length(self) -> jax.Array:
        """Samples absorbed so far (per series when batched)."""
        return self.state.length

    @property
    def backend(self):
        """The compute backend (`repro.core.backend`) ingestion runs through
        — fixed at engine construction (e.g. ``lag_sum_engine(backend=…)``)."""
        return self.engine.backend
