"""StreamingEstimator — the ingestion driver over the weak-memory monoid.

Binds a `repro.core.streaming.StreamingEngine` to a stream of chunks (any
iterator of (c, d) arrays — `TimeSeriesStore.iter_chunks`, a socket, a
queue) and maintains the rolling `PartialState`.  Two axes of scale:

  * **time** — chunks of arbitrary uneven sizes are absorbed with
    ``h_left + h_right`` carried samples of context, never the series;
  * **series** — with ``batch=B`` every operation runs vmapped over B
    independent series in one device pass (states are pytrees with a
    leading batch axis).

Since the SeriesFrame redesign this class is a thin shim over
`repro.core.frame.SeriesFrame.from_engine` — the frame owns the carried
state and every ingest/merge/finalize program, so the chunk-driver and the
lazy dataframe-style API share one query path.  Prefer the frame for new
code: `SeriesFrame.from_chunks(...)` plus deferred requests replaces the
(engine, finalizer) pairing entirely.

Estimator results are read out through the front-end finalizers
(``estimators.stats.streaming_autocovariance``,
``estimators.yule_walker.streaming_yule_walker``,
``estimators.arma.fit_arma_streaming``,
``estimators.spectral.streaming_welch``) via :meth:`finalize`.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Optional

import jax

from ..core.frame import SeriesFrame
from ..core.streaming import PartialState, StreamingEngine

__all__ = ["StreamingEstimator"]


class StreamingEstimator:
    """Stateful driver: ingest chunks, merge peers, finalize estimates.

    Args:
      engine: the estimator's streaming engine (defines kernel + halo).
      batch: number of independent series (None → a single series).
        With a batch, every ingested chunk is (batch, c, d) and updates all
        series in one vmapped device pass.
      t0: global start index (scalar, or per-series (batch,) array).
    """

    def __init__(
        self,
        engine: StreamingEngine,
        batch: Optional[int] = None,
        t0: int | jax.Array = 0,
    ):
        self.engine = engine
        self.batch = batch
        # The frame carries the state and the engine's cached jitted
        # programs: repeated ingest of same-shape chunks never re-traces,
        # and `consume` folds a whole chunk stack in one lax.scan device
        # program (donating the carried state buffers).
        self._frame = SeriesFrame.from_engine(engine, batch=batch, t0=t0)

    @classmethod
    def from_store(
        cls, engine: StreamingEngine, store, chunk_size: int
    ) -> "StreamingEstimator":
        """Stream a `TimeSeriesStore` through the engine chunk by chunk."""
        est = cls(engine)
        est.ingest_iter(store.iter_chunks(chunk_size))
        return est

    @property
    def state(self) -> PartialState:
        """The carried PartialState (lives on the underlying frame)."""
        return self._frame.state

    @state.setter
    def state(self, value: PartialState) -> None:
        self._frame.state = value

    def ingest(self, chunk: jax.Array) -> "StreamingEstimator":
        """Absorb the next chunk ((c, d), or (batch, c, d) when batched)."""
        self._frame.append(chunk)
        return self

    def ingest_iter(self, chunks: Iterable[jax.Array]) -> "StreamingEstimator":
        for chunk in chunks:
            self.ingest(chunk)
        return self

    def consume(self, chunk_stack: jax.Array) -> "StreamingEstimator":
        """Scan-driven ingest of a stack of equal-length chunks.

        ``chunk_stack`` is (k, c, d) — or (k, batch, c, d) when batched —
        and the whole stack is absorbed by ONE ``lax.scan`` device program
        (`repro.core.streaming.StreamingEngine.consume`): no per-chunk
        Python dispatch, no k host round-trips, and the carried
        PartialState's buffers are donated (long ingest loops allocate
        nothing per chunk).  Equivalent to ``ingest_iter(chunk_stack)``.
        """
        self._frame.consume(chunk_stack)
        return self

    def merge_from(self, other: "StreamingEstimator | PartialState") -> "StreamingEstimator":
        """⊕ another partial into this one (adjacent segment, any order)."""
        state = other.state if isinstance(other, StreamingEstimator) else other
        self._frame.merge_state(state)
        return self

    def finalize(self, finalizer: Callable, *args, **kwargs) -> Any:
        """Apply an estimator front-end finalizer to the current state.

        ``finalizer(engine, state, *args, **kwargs)`` — e.g.
        ``est.finalize(streaming_autocovariance, normalization="standard")``.
        Batched drivers vmap the finalizer over the series axis.
        """
        return self._frame.finalize_with(finalizer, *args, **kwargs)

    @property
    def length(self) -> jax.Array:
        """Samples absorbed so far (per series when batched)."""
        return self._frame.state.length

    @property
    def backend(self):
        """The compute backend (`repro.core.backend`) ingestion runs through
        — fixed at engine construction (e.g. ``lag_sum_engine(backend=…)``)."""
        return self.engine.backend
