"""Irregular → regular alignment (paper §1: LOCF / linear interpolation).

The paper's GPU treatment of irregular series (skip lists, per-thread binary
search, §12.3) is pointer-chasing with no TPU analogue; per DESIGN.md we
regularize at ingest instead — which is also what the paper's own §1
prescribes for the estimation path ("an interpolation technique is often
used in order to align observations on a regular time index grid").
Vectorized searchsorted = the TPU-friendly binary search.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

__all__ = ["regularize"]


def regularize(
    t: jax.Array,
    x: jax.Array,
    grid: jax.Array,
    method: Literal["locf", "linear"] = "locf",
) -> jax.Array:
    """Sample an irregular series onto a regular grid.

    Args:
      t: (n,) strictly increasing observation timestamps.
      x: (n, d) observations.
      grid: (m,) query timestamps (must lie within [t[0], t[-1]]).
      method: "locf" (last observation carried forward) or "linear".

    Returns (m, d).
    """
    if x.ndim == 1:
        x = x[:, None]
    idx = jnp.searchsorted(t, grid, side="right") - 1
    idx = jnp.clip(idx, 0, t.shape[0] - 1)
    left = x[idx]
    if method == "locf":
        return left
    idx_next = jnp.clip(idx + 1, 0, t.shape[0] - 1)
    t0 = t[idx]
    t1 = t[idx_next]
    dt = jnp.where(t1 > t0, t1 - t0, 1.0)
    w = jnp.clip((grid - t0) / dt, 0.0, 1.0)
    right = x[idx_next]
    return left + w[:, None] * (right - left)
