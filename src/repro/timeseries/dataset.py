"""TimeSeriesStore — the distributed overlapping dataset (paper §10, Fig. 4).

Owns a (possibly huge) series partitioned **along time** across a mesh axis.
Construction replicates the halo once at ingest (the paper's scheme); the
store then serves embarrassingly-parallel estimator sweeps with zero data
motion.  Alternatively a disjoint store can materialize halos on demand via
collective-permute (`halo_mode="exchange"`) — the beyond-paper variant.

On one host this degrades gracefully to a (P, W, d) array with a vmap axis;
on a mesh the leading axis is sharded (NamedSharding over ``axis``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Literal, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.overlap import OverlapSpec, make_overlapping_blocks, reconstruct
from ..core.mapreduce import block_partials
from ..core import halo as halo_mod

HaloMode = Literal["replicate", "exchange"]

__all__ = ["TimeSeriesStore"]


@functools.partial(
    jax.jit, static_argnames=("B", "width"), donate_argnums=0
)
def _scatter_append_rows(blocks, chunk, n0, *, B: int, width: int):
    """Scatter ``chunk`` (global rows [n0, n0+c)) into every padded block
    slot that owns it: row g lives in block j at slot g − j·B for every j
    with j·B ≤ g < j·B + width — its core block plus the right-halo region
    of up to ⌈(width−B)/B⌉ predecessors.  The ``blocks`` buffer is donated:
    steady-state ingest rewrites the store in place.  Out-of-range copies
    are dropped by routing them to a past-the-end block index."""
    c = chunk.shape[0]
    g = n0 + jnp.arange(c)
    copies = (width - 1) // B + 1
    for k in range(copies):
        j = g // B - k
        slot = g - j * B
        valid = (j >= 0) & (slot < width)
        jj = jnp.where(valid, j, blocks.shape[0])
        blocks = blocks.at[jj, slot].set(chunk, mode="drop")
    return blocks


@dataclasses.dataclass
class TimeSeriesStore:
    """Distributed overlapping time-series container.

    Attributes:
      blocks: (P, width, d) — padded blocks (replicate mode) or disjoint
        cores (exchange mode).
      spec: the overlap geometry.
      mesh / axis: where the block axis lives (None → single host).
      halo_mode: "replicate" (paper) or "exchange" (ppermute on demand).
    """

    blocks: jax.Array
    spec: OverlapSpec
    mesh: Optional[Mesh] = None
    axis: str = "data"
    halo_mode: HaloMode = "replicate"

    # -- construction ------------------------------------------------------
    @classmethod
    def from_series(
        cls,
        x: jax.Array,
        block_size: int,
        h_left: int,
        h_right: int,
        mesh: Optional[Mesh] = None,
        axis: str = "data",
        halo_mode: HaloMode = "replicate",
    ) -> "TimeSeriesStore":
        if x.ndim == 1:
            x = x[:, None]
        spec = OverlapSpec(
            n=x.shape[0], block_size=block_size, h_left=h_left, h_right=h_right
        )
        if halo_mode == "replicate":
            blocks, _ = make_overlapping_blocks(x, spec)
        else:
            # Disjoint cores; halos materialized per sweep by ppermute.
            pad = spec.num_blocks * spec.block_size - spec.n
            xp = jnp.pad(x, ((0, pad), (0, 0)))
            blocks = xp.reshape(spec.num_blocks, spec.block_size, x.shape[1])
        if mesh is not None:
            if spec.num_blocks % mesh.shape[axis] != 0:
                raise ValueError(
                    f"num_blocks={spec.num_blocks} must divide over mesh axis "
                    f"{axis}={mesh.shape[axis]}"
                )
            sharding = NamedSharding(mesh, P(axis))
            blocks = jax.device_put(blocks, sharding)
        return cls(blocks=blocks, spec=spec, mesh=mesh, axis=axis, halo_mode=halo_mode)

    # -- growth --------------------------------------------------------------
    def append_rows(self, chunk: jax.Array) -> None:
        """Absorb ``chunk`` new samples at the end of the stored series with
        ONE donated device scatter — no host-side re-placement, no re-read
        of the existing blocks.

        Each appended row lands in its owning block's core AND in the
        right-halo slots of up to ``ceil(h_right / block_size)`` earlier
        blocks, so the store stays exactly
        ``from_series(concat(series, chunk), ...)`` (property-tested).  The
        block array grows by whole zero blocks only when the appended rows
        overflow the allocated capacity.  Single-host replicate-mode stores
        with causal halos only (``h_left == 0``, no mesh): a mesh-sharded
        store would need a resharding collective per growth step — callers
        there fall back to carrying the chunk in their own partial state.
        """
        if self.mesh is not None:
            raise ValueError("append_rows is single-host only (mesh stores "
                             "re-place on the next full traversal)")
        if self.halo_mode != "replicate":
            raise ValueError("append_rows requires replicate-mode halos")
        if self.spec.h_left != 0:
            raise ValueError("append_rows requires causal halos (h_left == 0)")
        if chunk.ndim == 1:
            chunk = chunk[:, None]
        c = chunk.shape[0]
        if c == 0:
            return
        if chunk.shape[1] != self.blocks.shape[-1]:
            raise ValueError(
                f"chunk has d={chunk.shape[1]}, store has d={self.blocks.shape[-1]}"
            )
        s = self.spec
        B = s.block_size
        width = s.h_left + B + s.h_right
        new_n = s.n + c
        blocks = self.blocks
        need_blocks = -(-new_n // B)
        if need_blocks > blocks.shape[0]:
            # Geometric growth: capacity at least doubles, so a steady
            # append stream pays O(log n) full-store copies (amortized O(1)
            # per row) and O(log n) retraces of the donated scatter —
            # growing to the exact need would copy the whole store every
            # block_size rows.  Over-allocated trailing blocks are all-zero
            # and sliced off by the num_blocks-aware readers.
            new_cap = max(need_blocks, 2 * blocks.shape[0])
            blocks = jnp.concatenate(
                [
                    blocks,
                    jnp.zeros(
                        (new_cap - blocks.shape[0], width, blocks.shape[-1]),
                        blocks.dtype,
                    ),
                ]
            )
        self.blocks = _scatter_append_rows(
            blocks,
            chunk.astype(blocks.dtype),
            jnp.asarray(s.n, jnp.int32),
            B=B,
            width=width,
        )
        self.spec = dataclasses.replace(s, n=new_n)

    # -- views ---------------------------------------------------------------
    def padded_blocks_local(self, blocks_local: jax.Array) -> jax.Array:
        """Inside shard_map: return halo-padded blocks for local computation.

        replicate mode: identity (halos were materialized at ingest).
        exchange mode: stitch neighbouring cores with one collective-permute.
        The two paths are bit-identical (property-tested).
        """
        if self.halo_mode == "replicate":
            return blocks_local
        s = self.spec
        p_local, nb, d = blocks_local.shape
        flat = blocks_local.reshape(p_local * nb, d)
        padded_flat = halo_mod.halo_exchange(
            flat, s.h_left, s.h_right, self.axis, time_axis=0
        )
        # Re-window into per-block padded views.
        idx = (
            jnp.arange(p_local)[:, None] * nb
            + jnp.arange(s.h_left + nb + s.h_right)[None, :]
        )
        return padded_flat[idx]

    def padded_blocks_single_host(self) -> jax.Array:
        """Single-host padded view (for tests / examples without a mesh):
        exactly ``spec.num_blocks`` blocks — any over-allocated growth
        capacity from :meth:`append_rows` is sliced off."""
        if self.halo_mode == "replicate":
            k = self.spec.num_blocks
            return self.blocks if self.blocks.shape[0] == k else self.blocks[:k]
        s = self.spec
        flat = self.blocks.reshape(-1, self.blocks.shape[-1])[: s.n]
        blocks, _ = make_overlapping_blocks(flat, s)
        return blocks

    # -- compute ---------------------------------------------------------------
    def map_reduce(self, kernel: Callable[[jax.Array], Any]) -> Any:
        """Run a weak-memory estimator over the store (paper §10.2.1).

        Single reduction of the sufficient statistic; data never moves.
        """
        s = self.spec
        if self.mesh is None:
            blocks = self.padded_blocks_single_host()
            partials = block_partials(kernel, blocks, s)
            return jax.tree.map(lambda l: jnp.sum(l, axis=0), partials)

        blocks_per_device = s.num_blocks // self.mesh.shape[self.axis]

        def local(blocks_local):
            from ..parallel.sharding import psum_tree

            offset = jax.lax.axis_index(self.axis) * blocks_per_device
            padded = self.padded_blocks_local(blocks_local)
            partials = block_partials(kernel, padded, s, block_offset=offset)
            local_sum = jax.tree.map(lambda l: jnp.sum(l, axis=0), partials)
            return psum_tree(local_sum, self.axis)

        from ..parallel.sharding import shard_map_compat

        fn = shard_map_compat(
            local, mesh=self.mesh, in_specs=P(self.axis), out_specs=P()
        )
        return fn(self.blocks)

    def iter_chunks(self, chunk_size: int):
        """Yield contiguous ``(≤chunk_size, d)`` chunks of the series in time
        order — the ingestion-side view of the store, consumed by
        `repro.timeseries.streaming.StreamingEstimator`.

        The final chunk may be shorter; the streaming monoid is indifferent
        to chunk granularity (property-tested).  Small-data path: gathers
        the series on the host first.
        """
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        x = self.to_series()
        for start in range(0, self.spec.n, chunk_size):
            yield x[start : min(start + chunk_size, self.spec.n)]

    def to_series(self) -> jax.Array:
        """Gather back the contiguous (n, d) series (small-data paths only)."""
        if self.halo_mode == "replicate":
            return reconstruct(self.padded_blocks_single_host(), self.spec)
        flat = self.blocks.reshape(-1, self.blocks.shape[-1])
        return flat[: self.spec.n]

    @property
    def replication_overhead(self) -> float:
        from ..core.overlap import replication_overhead as ro

        return ro(self.spec) if self.halo_mode == "replicate" else 0.0
