"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import ZAMBA2_7B

CONFIG = ZAMBA2_7B
