"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import LLAMA4_MAVERICK

CONFIG = LLAMA4_MAVERICK
