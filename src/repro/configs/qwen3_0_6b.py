"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import QWEN3_0_6B

CONFIG = QWEN3_0_6B
