"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import LLAVA_NEXT_34B

CONFIG = LLAVA_NEXT_34B
