"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import PHI3_MEDIUM

CONFIG = PHI3_MEDIUM
