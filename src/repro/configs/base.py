"""Architecture + shape configuration system.

One `ArchConfig` per assigned architecture (exact numbers from the brief in
`configs/<id>.py`), plus `reduced()` — a tiny same-family config for CPU
smoke tests.  `ShapeConfig` describes the four input-shape suites; the
(arch × shape) product defines the 40 dry-run cells.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
AttnKind = Literal["gqa", "mla"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    num_shared: int = 0  # shared (always-on) experts
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # "gather" (index-based, default) or "einsum" (GShard one-hot — kept as
    # the §Perf iteration-0 reference; costs O(T·E·C·d) extra matmul flops)
    dispatch: str = "gather"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 → direct q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    attn: AttnKind = "gqa"
    qk_norm: bool = False
    swa_window: Optional[int] = None  # sliding-window attention width
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    shared_attn_every: int = 0
    # encdec (whisper)
    enc_layers: int = 0
    # vlm (llava): number of image patch embeddings prefixed to the text
    n_patches: int = 0
    # xlstm: indices pattern — place an sLSTM block every k blocks (rest mLSTM)
    slstm_every: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # costing-only switch: python-unrolled layer stack instead of lax.scan
    # (see launch/costing.py — cost_analysis counts scan bodies once)
    unroll_layers: bool = False
    # activation-checkpoint policy for the layer scan: 'full' recomputes
    # everything in backward; 'dots' saves matmul outputs (§Perf A3)
    remat_policy: str = "full"
    # Megatron-SP-style residual stream: sequence-shard the inter-block
    # activations over the model axis so GSPMD lowers the TP partial-sum
    # all-reduces as reduce-scatter (+ later all-gather) — §Perf B5
    seq_parallel_residual: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §5 skip rule)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step; all assigned archs here
        are decoder-bearing (whisper has a decoder)."""
        return True

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads // max(1, self.n_heads // 4))),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            swa_window=16 if self.swa_window else None,
            shared_attn_every=3 if self.shared_attn_every else 0,
            enc_layers=min(self.enc_layers, 2),
            n_patches=8 if self.n_patches else 0,
            slstm_every=self.slstm_every,
        )
        if self.moe:
            r = dataclasses.replace(
                r,
                moe=MoEConfig(
                    num_experts=4,
                    top_k=min(2, self.moe.top_k),
                    num_shared=min(1, self.moe.num_shared),
                    d_ff_expert=64,
                    # dropless for any routing (capacity = T·k): keeps the
                    # reduced-config smoke/consistency tests deterministic
                    capacity_factor=4.0,
                ),
            )
        if self.mla:
            r = dataclasses.replace(
                r,
                mla=MLAConfig(
                    kv_lora_rank=32, q_lora_rank=0, rope_head_dim=8,
                    nope_head_dim=16, v_head_dim=16,
                ),
            )
        if self.ssm:
            r = dataclasses.replace(
                r,
                ssm=SSMConfig(state_dim=16, head_dim=16, conv_width=4, chunk=32, expand=2),
            )
        return r


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The four assigned shape suites (brief).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The brief's skip rules for the 40-cell matrix."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "pure full-attention arch — long_500k skipped (brief rule)"
    if shape.kind == "decode" and not arch.has_decode:
        return False, "encoder-only arch — no decode step"
    return True, ""
