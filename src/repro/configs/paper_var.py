"""The paper's own workload configs: large-scale VAR/VARMA estimation.

These parameterize the time-series benchmarks/examples (the paper has no
named model sizes; these are the regimes its scaling arguments address:
dense moderate-d, high-d banded spatial, and graph-embedded series).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class VARWorkload:
    name: str
    n: int  # time steps
    d: int  # spatial dimensions
    p: int  # AR order
    q: int = 0  # MA order
    bandwidth: int = 0  # 0 → dense coefficient matrices
    block_size: int = 4096


PAPER_VAR_CONFIGS = {
    "var-dense-small": VARWorkload("var-dense-small", n=100_000, d=8, p=3),
    "var-dense-wide": VARWorkload("var-dense-wide", n=1_000_000, d=64, p=2),
    "varma": VARWorkload("varma", n=500_000, d=8, p=2, q=1),
    "var-banded-highd": VARWorkload(
        "var-banded-highd", n=200_000, d=16_384, p=1, bandwidth=4
    ),
}
