"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import DEEPSEEK_V2

CONFIG = DEEPSEEK_V2
