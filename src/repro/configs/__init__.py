"""Configs: assigned architectures, input-shape suites, paper workload."""
from .base import ArchConfig, MoEConfig, MLAConfig, SSMConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME, cell_is_runnable
from .registry import ARCHS, ALIASES, get_arch, list_archs
from .paper_var import PAPER_VAR_CONFIGS
