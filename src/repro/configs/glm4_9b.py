"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import GLM4_9B

CONFIG = GLM4_9B
