"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import H2O_DANUBE

CONFIG = H2O_DANUBE
