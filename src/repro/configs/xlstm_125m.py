"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import XLSTM_125M

CONFIG = XLSTM_125M
