"""The 10 assigned architectures (exact configs from the brief) + the
paper's own VAR workload config.  ``get_arch(id)`` / ``list_archs()``."""
from __future__ import annotations

from typing import Dict

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

# — LM-family transformers (brief, verbatim numbers) —

LLAMA4_MAVERICK = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,  # expert FFN width
    vocab=202048,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=1, num_shared=1, d_ff_expert=8192),
    rope_theta=500000.0,
)

DEEPSEEK_V2 = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,  # expert FFN width
    vocab=102400,
    attn="mla",
    mla=MLAConfig(
        kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
        nope_head_dim=128, v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_ff_expert=1536),
    rope_theta=10000.0,
)

GLM4_9B = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    rope_theta=10000.0,
)

QWEN3_0_6B = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)

H2O_DANUBE = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    swa_window=4096,  # llama+mistral mix with sliding-window attention
    rope_theta=10000.0,
)

PHI3_MEDIUM = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    rope_theta=10000.0,
)

WHISPER_BASE = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,  # decoder layers
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
)

LLAVA_NEXT_34B = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_patches=2880,  # anyres tiling budget (frontend stubbed per brief)
    rope_theta=5000000.0,
)

XLSTM_125M = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # block-internal projections (xLSTM style)
    vocab=50304,
    slstm_every=2,  # alternate sLSTM / mLSTM blocks
)

ZAMBA2_7B = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,  # shared attention block MLP width
    vocab=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, chunk=256, expand=2),
    shared_attn_every=6,
)

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        LLAMA4_MAVERICK,
        DEEPSEEK_V2,
        GLM4_9B,
        QWEN3_0_6B,
        H2O_DANUBE,
        PHI3_MEDIUM,
        WHISPER_BASE,
        LLAVA_NEXT_34B,
        XLSTM_125M,
        ZAMBA2_7B,
    )
}

# short aliases for --arch
ALIASES = {
    "llama4": "llama4-maverick-400b-a17b",
    "deepseek-v2": "deepseek-v2-236b",
    "glm4": "glm4-9b",
    "qwen3": "qwen3-0.6b",
    "danube": "h2o-danube-1.8b",
    "phi3": "phi3-medium-14b",
    "whisper": "whisper-base",
    "llava": "llava-next-34b",
    "xlstm": "xlstm-125m",
    "zamba2": "zamba2-7b",
}


def get_arch(name: str) -> ArchConfig:
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
