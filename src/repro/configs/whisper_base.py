"""Assigned architecture config (see registry.py for the numbers)."""
from .registry import WHISPER_BASE

CONFIG = WHISPER_BASE
