"""repro — Embarrassingly-parallel weak-memory time-series analysis, at scale.

JAX reimplementation (TPU target) of Belletti et al., "Embarrassingly Parallel
Time Series Analysis for Large Scale Weak Memory Systems", plus the
framework-scale substrates (model zoo, distribution, training, serving,
checkpointing) required to run it on multi-pod TPU meshes.

Public entry points:
  repro.SeriesFrame — the lazy, placement-aware session API: defer
                      estimator requests, collect them in ONE fused
                      traversal, append and re-collect incrementally
  repro.FrameSession— the multi-tenant variant (per-user fused-plan states
                      behind one donated scatter-ingest program)
  repro.core        — overlapping-block data structure + weak-memory estimators
  repro.timeseries  — synthetic generators, distributed series store
  repro.models      — assigned-architecture model zoo
  repro.configs     — architecture configs + input-shape suites
  repro.launch      — production mesh, dry-run, roofline, train/serve drivers
"""

__version__ = "1.0.0"

from .core.frame import Deferred, FrameSession, SeriesFrame

__all__ = ["SeriesFrame", "FrameSession", "Deferred", "__version__"]
