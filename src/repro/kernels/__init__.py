"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel package contains:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, vmapping, interpret switch)
  ref.py    — pure-jnp oracle, allclose-tested against the kernel

Kernels:
  window_stats  — lagged cross-product sums S(h)=Σ X_k X_{k+h}ᵀ, h=0..H.
                  The TPU re-instantiation of the paper's §12 GPU
                  shared-memory scheme: each grid step stages its N_B core
                  tile plus the H-halo (realized as the neighbouring tile)
                  into VMEM and computes every lag as an MXU matmul.
  swa_attention — sliding-window causal flash attention: the paper's
                  weak-memory window applied to LM serving (h2o-danube SWA,
                  long_500k cells); communication/compute ∝ window, not seq.
  banded_matvec — §6.1 banded predictor x̂=Ax from the stacked-diagonal
                  form, row-tiled with spatial halos.
"""
from .window_stats import ops as window_stats_ops  # noqa: F401
# lazy: subpackages import independently

