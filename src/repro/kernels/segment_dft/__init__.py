from .ops import segment_fft_power, segment_fft_power_reference  # noqa: F401
