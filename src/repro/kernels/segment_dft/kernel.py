"""Pallas TPU kernel: framed real-DFT power via tiled twiddle matmuls.

There is no Pallas FFT — but the spectral member of a fused plan does not
need one.  A Welch/Whittle periodogram evaluates a *fixed* segment length L,
so the real DFT is a constant (L, L//2+1) linear map: precompute the
taper-folded twiddle matrices

    C[t, f]  =  taper[t] · cos(2π t f / L)
    S[t, f]  = −taper[t] · sin(2π t f / L)

and each segment's one-sided power spectrum is two MXU contractions plus a
VPU square-and-add:

    re = Cᵀ y,   im = Sᵀ y,   |rfft(y · taper)|² = re² + im²

(with the optional per-segment detrend y ← y − mean(y) folded in before the
contraction).  Complexity is O(L²) per segment instead of the FFT's
O(L log L) — but the constant is a 128×128 systolic array fed from VMEM, and
for the segment lengths Welch uses (L ≤ a few thousand) the matmul form is
bandwidth-bound like every other kernel in this package: each segment is
staged into VMEM exactly once (one HBM read), the twiddle matrices are
resident across the whole grid, and the (S, F, d) output streams out tile by
tile.  This is what lets a fused statistics plan containing a Welch request
keep ALL of its members on the tile path — previously the spectral
primitive silently ejected to jnp.

Grid scheme: ``block_s`` segments per grid step; the segment block, the two
twiddle matrices (revisited — same block every step), and the output block
live in VMEM.  ops.py pads the segment count to a multiple of ``block_s``
with zero segments (their power is zero and is sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dft_power_kernel(
    seg_ref, cos_ref, sin_ref, out_ref, *, detrend: bool, block_s: int
):
    cosm = cos_ref[...]  # (L, F) taper-folded twiddles
    sinm = sin_ref[...]
    for j in range(block_s):
        y = seg_ref[j].astype(jnp.float32)  # (L, d)
        if detrend:
            y = y - jnp.mean(y, axis=0, keepdims=True)
        # Two MXU contractions per segment: every frequency bin of every
        # channel at once, contracted over the resident time axis.
        re = jax.lax.dot_general(
            cosm, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (F, d)
        im = jax.lax.dot_general(
            sinm, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        out_ref[j] = re * re + im * im


def _csd_kernel(
    seg_ref, cos_ref, sin_ref, re_ref, im_ref, *, detrend: bool, block_s: int
):
    cosm = cos_ref[...]  # (L, F) taper-folded twiddles
    sinm = sin_ref[...]
    for j in range(block_s):
        y = seg_ref[j].astype(jnp.float32)  # (L, d)
        if detrend:
            y = y - jnp.mean(y, axis=0, keepdims=True)
        re = jax.lax.dot_general(
            cosm, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (F, d)
        im = jax.lax.dot_general(
            sinm, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        # f_i conj(f_j) with f = re + i·im, emitted as two real planes
        # (Pallas has no complex dtypes); ops.py recombines re + i·im.
        re_ref[j] = re[:, :, None] * re[:, None, :] + im[:, :, None] * im[:, None, :]
        im_ref[j] = im[:, :, None] * re[:, None, :] - re[:, :, None] * im[:, None, :]


def segment_csd_pallas(
    segments: jax.Array,
    cos_mat: jax.Array,
    sin_mat: jax.Array,
    *,
    detrend: bool = True,
    block_s: int = 8,
    interpret: bool = False,
) -> tuple:
    """Per-segment cross-spectral products of a zero-padded segment stack.

    Same tiling scheme as :func:`segment_dft_power_pallas`; per segment the
    two twiddle contractions are followed by a VPU batched outer product
    over the channel axis.  Returns (re, im), both (S_padded, F, d, d)
    float32 — the real and imaginary planes of ``rfft_i · conj(rfft_j)``.
    """
    s_pad, L, d = segments.shape
    F = cos_mat.shape[1]
    if cos_mat.shape != (L, F) or sin_mat.shape != (L, F):
        raise ValueError(
            f"twiddle matrices must be ({L}, {F}), got {cos_mat.shape}/{sin_mat.shape}"
        )
    if s_pad % block_s != 0:
        raise ValueError(
            f"padded segment count {s_pad} must be a multiple of block_s={block_s}"
        )
    grid = (s_pad // block_s,)

    return pl.pallas_call(
        functools.partial(_csd_kernel, detrend=detrend, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, L, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((L, F), lambda i: (0, 0)),  # resident twiddles
            pl.BlockSpec((L, F), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, F, d, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_s, F, d, d), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, F, d, d), jnp.float32),
            jax.ShapeDtypeStruct((s_pad, F, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(segments, cos_mat, sin_mat)


def segment_dft_power_pallas(
    segments: jax.Array,
    cos_mat: jax.Array,
    sin_mat: jax.Array,
    *,
    detrend: bool = True,
    block_s: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Per-segment one-sided DFT power of a zero-padded segment stack.

    Args:
      segments: (S_padded, L, d) float32 with S_padded % block_s == 0
        (ops.py pads with all-zero segments).
      cos_mat / sin_mat: (L, F) taper-folded twiddle matrices (see module
        docstring); F = L // 2 + 1.
      detrend: subtract each segment's per-channel mean before the taper.

    Returns (S_padded, F, d) float32: |rfft((seg − mean) · taper)|².
    """
    s_pad, L, d = segments.shape
    F = cos_mat.shape[1]
    if cos_mat.shape != (L, F) or sin_mat.shape != (L, F):
        raise ValueError(
            f"twiddle matrices must be ({L}, {F}), got {cos_mat.shape}/{sin_mat.shape}"
        )
    if s_pad % block_s != 0:
        raise ValueError(
            f"padded segment count {s_pad} must be a multiple of block_s={block_s}"
        )
    grid = (s_pad // block_s,)

    return pl.pallas_call(
        functools.partial(_dft_power_kernel, detrend=detrend, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, L, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((L, F), lambda i: (0, 0)),  # resident twiddles
            pl.BlockSpec((L, F), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, F, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, F, d), jnp.float32),
        interpret=interpret,
    )(segments, cos_mat, sin_mat)
