"""Pure-jnp oracles for the segment-DFT power kernel.

Two independent references: the matmul form restated without Pallas (the
tiling oracle) and the rfft form (the numerical ground truth every backend
is pinned against in tests/test_backend.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dft_power_matrices(L: int, taper: jax.Array):
    """Taper-folded real-DFT twiddle matrices, both (L, L//2+1).

    ``rfft(y · taper)[f] = Σ_t y_t C[t, f] + i Σ_t y_t S[t, f]`` — the fixed
    linear map the Pallas kernel contracts each segment against.

    The phase index ``t·f`` grows to ~L²/2, which float32 cannot represent
    past L ≈ 4k — exactly the sizes the calibrated auto policy routes to
    this kernel.  The twiddles are L-periodic, so the index is reduced
    ``mod L`` in exact host integer arithmetic first (L is static); the
    reduced phase (< L) is float32-exact and the angle error stays O(ulp)
    at every segment length.
    """
    F = L // 2 + 1
    phase = np.mod(
        np.outer(np.arange(L, dtype=np.int64), np.arange(F, dtype=np.int64)), L
    )
    ang = jnp.asarray(phase, jnp.float32) * jnp.float32(2.0 * np.pi / L)
    taper = taper.astype(jnp.float32)[:, None]
    return taper * jnp.cos(ang), -taper * jnp.sin(ang)


def segment_dft_power_ref(
    segments: jax.Array, taper: jax.Array, detrend: bool = True
) -> jax.Array:
    """Matmul-form oracle: (S, L, d) segments → (S, L//2+1, d) power."""
    y = segments.astype(jnp.float32)
    if detrend:
        y = y - jnp.mean(y, axis=1, keepdims=True)
    C, S = dft_power_matrices(segments.shape[1], taper)
    re = jnp.einsum("std,tf->sfd", y, C)
    im = jnp.einsum("std,tf->sfd", y, S)
    return re * re + im * im


def segment_csd_ref(
    segments: jax.Array, taper: jax.Array, detrend: bool = True
) -> jax.Array:
    """rfft-form oracle: (S, L, d) segments → (S, L//2+1, d, d) complex64
    per-segment cross-spectral products ``rfft_i · conj(rfft_j)``."""
    y = segments.astype(jnp.float32)
    if detrend:
        y = y - jnp.mean(y, axis=1, keepdims=True)
    f = jnp.fft.rfft(y * taper.astype(jnp.float32)[None, :, None], axis=1)
    return jnp.einsum("sfi,sfj->sfij", f, jnp.conj(f))
