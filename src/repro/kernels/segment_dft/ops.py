"""Public jit'd wrapper for the segment-DFT power kernel.

Handles: segment-count padding to a ``block_s`` multiple (with all-zero
segments, sliced off after the call), twiddle-matrix construction, f32
promotion, and the interpret switch for CPU validation.  This is the Pallas
half of the compute-backend registry's ``segment_fft_power`` primitive
(`repro.core.backend.PallasBackend`); prefer routing through the registry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import segment_dft_power_pallas
from .ref import dft_power_matrices, segment_dft_power_ref


@functools.partial(
    jax.jit, static_argnames=("detrend", "block_s", "interpret")
)
def segment_fft_power(
    segments: jax.Array,
    taper: jax.Array,
    detrend: bool = True,
    *,
    block_s: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Per-segment one-sided power |rfft((seg − mean)·taper)|², via Pallas.

    Drop-in for the jnp rfft form (`repro.core.backend.JnpBackend
    .segment_fft_power`): the DFT of a fixed segment length is a constant
    linear map, evaluated here as two MXU matmuls per segment against
    precomputed taper-folded twiddle matrices — one VMEM staging per
    segment, no FFT primitive needed.

    Args:
      segments: (S, L, d), any float dtype (f32 accumulation).
      taper: (L,) window function (e.g. Hann).

    Returns (S, L//2+1, d) float32.
    """
    if segments.ndim != 3:
        raise ValueError(f"segments must be (S, L, d), got {segments.shape}")
    s, L, d = segments.shape
    if taper.shape != (L,):
        raise ValueError(f"taper must be ({L},), got {taper.shape}")
    C, Sn = dft_power_matrices(L, taper)
    block_s = max(1, min(block_s, max(s, 1)))
    s_pad = -(-max(s, 1) // block_s) * block_s
    segs = jnp.pad(
        segments.astype(jnp.float32), ((0, s_pad - s), (0, 0), (0, 0))
    )
    out = segment_dft_power_pallas(
        segs, C, Sn, detrend=detrend, block_s=block_s, interpret=interpret
    )
    return out[:s]


def segment_fft_power_reference(
    segments: jax.Array, taper: jax.Array, detrend: bool = True
) -> jax.Array:
    """Matmul-form oracle re-export used by tests/benchmarks."""
    return segment_dft_power_ref(segments, taper, detrend)
