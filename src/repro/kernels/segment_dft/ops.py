"""Public jit'd wrappers for the segment-DFT kernels.

Handles: segment-count padding to a ``block_s`` multiple (with all-zero
segments, sliced off after the call), twiddle-matrix construction, f32
promotion, complex recombination for the CSD form, and the interpret
switch for CPU validation.  These are the Pallas half of the compute
registry's ``segment_fft_power`` / ``segment_csd`` primitives
(`repro.core.backend.PallasBackend`); prefer routing through the registry.

``block_s`` resolves through the calibrated block table
(`repro.kernels.tiling.resolve_block`) OUTSIDE the jit boundary — a newly
installed table changes the next call's geometry instead of being baked
into a stale trace; pass ``block_s=`` explicitly to override.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..tiling import resolve_block
from .kernel import segment_csd_pallas, segment_dft_power_pallas
from .ref import dft_power_matrices, segment_csd_ref, segment_dft_power_ref


def _pad_segments(segments: jax.Array, block_s: int):
    s = segments.shape[0]
    block_s = max(1, min(block_s, max(s, 1)))
    s_pad = -(-max(s, 1) // block_s) * block_s
    segs = jnp.pad(
        segments.astype(jnp.float32), ((0, s_pad - s), (0, 0), (0, 0))
    )
    return segs, block_s


def _check_segments(segments: jax.Array, taper: jax.Array):
    if segments.ndim != 3:
        raise ValueError(f"segments must be (S, L, d), got {segments.shape}")
    L = segments.shape[1]
    if taper.shape != (L,):
        raise ValueError(f"taper must be ({L},), got {taper.shape}")


@functools.partial(
    jax.jit, static_argnames=("detrend", "block_s", "interpret")
)
def _segment_fft_power_jit(
    segments: jax.Array,
    taper: jax.Array,
    *,
    detrend: bool,
    block_s: int,
    interpret: bool,
) -> jax.Array:
    s, L, d = segments.shape
    C, Sn = dft_power_matrices(L, taper)
    segs, block_s = _pad_segments(segments, block_s)
    out = segment_dft_power_pallas(
        segs, C, Sn, detrend=detrend, block_s=block_s, interpret=interpret
    )
    return out[:s]


def segment_fft_power(
    segments: jax.Array,
    taper: jax.Array,
    detrend: bool = True,
    *,
    block_s: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Per-segment one-sided power |rfft((seg − mean)·taper)|², via Pallas.

    Drop-in for the jnp rfft form (`repro.core.backend.JnpBackend
    .segment_fft_power`): the DFT of a fixed segment length is a constant
    linear map, evaluated here as two MXU matmuls per segment against
    precomputed taper-folded twiddle matrices — one VMEM staging per
    segment, no FFT primitive needed.

    Args:
      segments: (S, L, d), any float dtype (f32 accumulation).
      taper: (L,) window function (e.g. Hann).
      block_s: segments per grid step; None resolves through the calibrated
        block table, else the built-in default.

    Returns (S, L//2+1, d) float32.
    """
    _check_segments(segments, taper)
    block_s = resolve_block("segment_fft_power", "block_s", block_s)
    return _segment_fft_power_jit(
        segments, taper, detrend=detrend, block_s=block_s, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("detrend", "block_s", "interpret")
)
def _segment_csd_jit(
    segments: jax.Array,
    taper: jax.Array,
    *,
    detrend: bool,
    block_s: int,
    interpret: bool,
) -> jax.Array:
    s, L, d = segments.shape
    C, Sn = dft_power_matrices(L, taper)
    segs, block_s = _pad_segments(segments, block_s)
    re, im = segment_csd_pallas(
        segs, C, Sn, detrend=detrend, block_s=block_s, interpret=interpret
    )
    return jax.lax.complex(re[:s], im[:s])


def segment_csd(
    segments: jax.Array,
    taper: jax.Array,
    detrend: bool = True,
    *,
    block_s: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Per-segment cross-spectral products ``rfft_i · conj(rfft_j)``.

    The complex cross-spectra enter the kernel as four REAL contractions of
    the same resident segment (re/im twiddle matmuls, then a channel outer
    product); the complex dtype only materializes on the way out — Pallas
    carries no complex arrays.

    Args:
      segments: (S, L, d), any float dtype (f32 accumulation).
      taper: (L,) window function.

    Returns (S, L//2+1, d, d) complex64, Hermitian in (i, j).
    """
    _check_segments(segments, taper)
    block_s = resolve_block("segment_csd", "block_s", block_s)
    return _segment_csd_jit(
        segments, taper, detrend=detrend, block_s=block_s, interpret=interpret
    )


def segment_fft_power_reference(
    segments: jax.Array, taper: jax.Array, detrend: bool = True
) -> jax.Array:
    """Matmul-form oracle re-export used by tests/benchmarks."""
    return segment_dft_power_ref(segments, taper, detrend)


def segment_csd_reference(
    segments: jax.Array, taper: jax.Array, detrend: bool = True
) -> jax.Array:
    """rfft-form oracle re-export used by tests/benchmarks."""
    return segment_csd_ref(segments, taper, detrend)
