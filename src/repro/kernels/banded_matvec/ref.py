"""Pure-jnp oracle for the banded predictor (paper §6.1).

y[r] = Σ_{o=-b..b} diags[r, b+o] · x[r+o]   (out-of-range x treated as 0)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def banded_matvec_ref(diags: jax.Array, x: jax.Array) -> jax.Array:
    """diags: (d, 2b+1);  x: (d, nrhs) → (d, nrhs)."""
    d, w = diags.shape
    b = (w - 1) // 2
    cols = jnp.arange(d)[:, None] + jnp.arange(-b, b + 1)[None, :]
    valid = (cols >= 0) & (cols < d)
    xn = x[jnp.clip(cols, 0, d - 1)]  # (d, 2b+1, nrhs)
    xn = jnp.where(valid[..., None], xn, 0.0)
    return jnp.einsum("dwn,dw->dn", xn, diags)
