"""Pallas TPU kernel: banded matrix-vector product, row-tiled with halos.

Paper §6.1: with a b-banded transition A, node i computes its rows of
x̂ = A x from x^{P_i⁺} (own rows ± b halo) — O(d·(2b+1)) total work.  The
VMEM instantiation: each grid step stages its row tile of the diagonals plus
THREE x tiles (previous/core/next — the spatial halo) and contracts the 2b+1
shifted views with the diagonal columns on the VPU.

Requires b ≤ block_rows (one-tile halo), the same constraint as the paper's
b ≪ d partitioning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(diags_ref, x_prev_ref, x_core_ref, x_next_ref, y_ref, *, bandwidth: int, block_rows: int, d: int):
    i = pl.program_id(0)
    b = bandwidth
    r = block_rows

    diags = diags_ref[...]  # (r, 2b+1)
    xs = jnp.concatenate([x_prev_ref[...], x_core_ref[...], x_next_ref[...]], axis=0)
    # global row of tile start; rows are i·r + [0, r)
    row0 = i * r
    acc = jnp.zeros(y_ref.shape, jnp.float32)
    for o in range(-b, b + 1):
        # x[row + o] lives at local index (r + o) + [0, r) within xs
        xo = jax.lax.dynamic_slice_in_dim(xs, r + o, r, axis=0)  # (r, nrhs)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (r, 1), 0)
        valid = ((rows + o) >= 0) & ((rows + o) < d)
        contrib = diags[:, b + o][:, None] * xo
        acc = acc + jnp.where(valid, contrib, 0.0)
    y_ref[...] = acc.astype(y_ref.dtype)


def banded_matvec_pallas(
    diags: jax.Array,
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = A x from stacked diagonals.

    Args:
      diags: (d, 2b+1) with d % block_rows == 0 (ops.py pads) and
        b ≤ block_rows.
      x: (d, nrhs).

    Returns (d, nrhs) float32.
    """
    d, w = diags.shape
    b = (w - 1) // 2
    nrhs = x.shape[1]
    if d % block_rows:
        raise ValueError(f"d={d} must be a multiple of block_rows={block_rows}")
    if b > block_rows:
        raise ValueError(f"bandwidth {b} must be ≤ block_rows {block_rows}")
    n_tiles = d // block_rows
    grid = (n_tiles,)
    return pl.pallas_call(
        functools.partial(_kernel, bandwidth=b, block_rows=block_rows, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, nrhs), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((block_rows, nrhs), lambda i: (i, 0)),
            pl.BlockSpec(
                (block_rows, nrhs), lambda i: (jnp.minimum(i + 1, n_tiles - 1), 0)
            ),
        ],
        out_specs=pl.BlockSpec((block_rows, nrhs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, nrhs), jnp.float32),
        interpret=interpret,
    )(diags, x, x, x)
