"""Public wrapper for the banded matvec kernel (paper §6.1 predictor).

The op is differentiable: a custom VJP makes the Pallas forward usable
inside `jax.grad` (the §6.2 conditional-MLE loss of
`repro.core.estimators.spatial.fit_banded_ar`), where previously the jnp
backend was pinned.  Both cotangents are banded-local:

  * ∂L/∂x = Aᵀ g — ANOTHER banded matvec, run through the same Pallas
    kernel against the transposed band (:func:`band_transpose`);
  * ∂L/∂diags[r, b+o] = g_r · x_{r+o} — a (d, 2b+1)-shaped neighbourhood
    gather-product (VPU-shaped, evaluated as one fused jnp contraction on
    device; there is no matmul to tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import banded_matvec_pallas
from .ref import banded_matvec_ref


def band_transpose(diags: jax.Array) -> jax.Array:
    """Diagonal storage of Aᵀ from the diagonal storage of A.

    ``Aᵀ[r, r+o] = A[r+o, r]``, so ``out[r, b+o] = diags[r+o, b−o]`` with
    zeros where ``r+o`` falls off the matrix.
    """
    d, w = diags.shape
    b = (w - 1) // 2
    rows = jnp.arange(d)[:, None] + jnp.arange(-b, b + 1)[None, :]
    valid = (rows >= 0) & (rows < d)
    cols = jnp.arange(w - 1, -1, -1)[None, :]
    vals = diags[jnp.clip(rows, 0, d - 1), jnp.broadcast_to(cols, rows.shape)]
    return jnp.where(valid, vals, 0.0)


def _forward(diags, x, block_rows: int, interpret: bool):
    """Padded Pallas forward for (d, 2b+1) diags and (d, nrhs) x."""
    d, w = diags.shape
    b = (w - 1) // 2
    br = max(min(block_rows, d), b)
    d_pad = -(-d // br) * br
    if d_pad != d:
        diags = jnp.pad(diags, ((0, d_pad - d), (0, 0)))
        x = jnp.pad(x, ((0, d_pad - d), (0, 0)))
    # NOTE: the kernel masks by the PADDED d; rows beyond the true d have
    # zero diagonals so their outputs are zero, and true rows reading into
    # the pad region read zero x — both exact.
    return banded_matvec_pallas(
        diags.astype(jnp.float32),
        x.astype(jnp.float32),
        block_rows=br,
        interpret=interpret,
    )[:d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _banded_matvec_vjp(diags, x, block_rows, interpret):
    return _forward(diags, x, block_rows, interpret)


def _banded_matvec_fwd(diags, x, block_rows, interpret):
    return _forward(diags, x, block_rows, interpret), (diags, x)


def _banded_matvec_bwd(block_rows, interpret, res, g):
    diags, x = res
    d, w = diags.shape
    b = (w - 1) // 2
    # dx = Aᵀ g: the same tiled kernel, transposed band.
    dx = _forward(band_transpose(diags), g, block_rows, interpret)
    # ddiags[r, b+o] = Σ_n g[r, n] · x[r+o, n] (0 where r+o off-range).
    cols = jnp.arange(d)[:, None] + jnp.arange(-b, b + 1)[None, :]
    valid = (cols >= 0) & (cols < d)
    xn = x.astype(jnp.float32)[jnp.clip(cols, 0, d - 1)]  # (d, w, nrhs)
    xn = jnp.where(valid[..., None], xn, 0.0)
    ddiags = jnp.einsum("dn,dwn->dw", g.astype(jnp.float32), xn)
    return ddiags.astype(diags.dtype), dx.astype(x.dtype)


_banded_matvec_vjp.defvjp(_banded_matvec_fwd, _banded_matvec_bwd)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _banded_matvec_jit(
    diags: jax.Array,
    x: jax.Array,
    *,
    block_rows: int,
    interpret: bool,
) -> jax.Array:
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = _banded_matvec_vjp(diags, x, block_rows, interpret)
    return y[:, 0] if squeeze else y


def banded_matvec(
    diags: jax.Array,
    x: jax.Array,
    *,
    block_rows: "int | None" = None,
    interpret: bool = False,
) -> jax.Array:
    """y = A x with b-banded A in diagonal storage.  Differentiable (custom
    VJP; both cotangents stay banded-local — see the module docstring).

    ``block_rows=None`` resolves through the calibrated block table
    (`repro.kernels.tiling.resolve_block`), outside the jit boundary.

    Args:
      diags: (d, 2b+1);  x: (d,) or (d, nrhs).

    Returns y with x's trailing shape, float32.
    """
    from ..tiling import resolve_block

    block_rows = resolve_block("banded_matvec", "block_rows", block_rows)
    return _banded_matvec_jit(
        diags, x, block_rows=block_rows, interpret=interpret
    )


def banded_matvec_reference(diags: jax.Array, x: jax.Array) -> jax.Array:
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = banded_matvec_ref(diags.astype(jnp.float32), x.astype(jnp.float32))
    return y[:, 0] if squeeze else y
