"""Public wrapper for the banded matvec kernel (paper §6.1 predictor)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import banded_matvec_pallas
from .ref import banded_matvec_ref


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def banded_matvec(
    diags: jax.Array,
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = A x with b-banded A in diagonal storage.

    Args:
      diags: (d, 2b+1);  x: (d,) or (d, nrhs).

    Returns y with x's trailing shape, float32.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    d, w = diags.shape
    b = (w - 1) // 2
    block_rows = min(block_rows, d)
    block_rows = max(block_rows, b)
    d_pad = -(-d // block_rows) * block_rows
    if d_pad != d:
        diags = jnp.pad(diags, ((0, d_pad - d), (0, 0)))
        x = jnp.pad(x, ((0, d_pad - d), (0, 0)))
    # NOTE: the kernel masks by the PADDED d; rows beyond the true d have
    # zero diagonals so their outputs are zero, and true rows reading into
    # the pad region read zero x — both exact.
    y = banded_matvec_pallas(
        diags.astype(jnp.float32),
        x.astype(jnp.float32),
        block_rows=block_rows,
        interpret=interpret,
    )[:d]
    return y[:, 0] if squeeze else y


def banded_matvec_reference(diags: jax.Array, x: jax.Array) -> jax.Array:
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = banded_matvec_ref(diags.astype(jnp.float32), x.astype(jnp.float32))
    return y[:, 0] if squeeze else y
