"""Public entry point for the fused-plan megakernel.

Handles everything the device kernel must not: reach-aware zero-extension,
tile padding with a halo tile only when some member reaches past its start
row, per-Welch-member candidate-offset tables (the stride alignment math,
done once in jnp so the kernel's segment loop is a static unroll), twiddle
construction, optional bf16 staging, and the tile-size resolution through
the calibrated block table (`repro.kernels.tiling.resolve_block`).

The block size is resolved OUTSIDE the jit boundary: the inner program is
traced with a concrete ``block_t``, so installing a new calibration table
(``calibrate(tune_blocks=True)``) changes the geometry of the next call
instead of being baked into a stale trace.

This is the Pallas half of the ``fused_plan_update`` backend primitive
(`repro.core.backend.PallasBackend`); the jnp half composes the existing
primitives and is the parity oracle.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..segment_dft.ref import dft_power_matrices
from ..tiling import clamp_block_t, pad_tiles, resolve_block
from .kernel import fused_plan_megakernel_pallas


def _candidate_offsets(
    z0: jax.Array,
    L: int,
    num_tiles: int,
    block_t: int,
    step: int,
    start_mask: jax.Array,
) -> jax.Array:
    """(num_tiles, n_cand) int32 local segment starts per tile, −1 invalid.

    A candidate is a local row ``c`` whose global index ``z0 + c`` is a
    multiple of ``step`` with ``c < L`` and ``start_mask[c]`` — exactly the
    segment grid of `repro.core.estimators.spectral.welch_chunk_kernel`,
    re-derived per tile: entry ``[i, k]`` is ``c − i·block_t`` (the start's
    offset inside tile i's resident rows) so the kernel can slice the
    segment straight out of VMEM.  ``n_cand = block_t // step + 1`` bounds
    the aligned starts any single tile can contain.
    """
    n_cand = block_t // step + 1
    tile0 = jnp.arange(num_tiles, dtype=jnp.int32)[:, None] * block_t
    base = (-(z0 + tile0)) % step  # first aligned local row ≥ tile start
    c = tile0 + base + jnp.arange(n_cand, dtype=jnp.int32)[None, :] * step
    off = c - tile0
    valid = (
        (off < block_t) & (c < L) & start_mask[jnp.clip(c, 0, L - 1)]
    )
    return jnp.where(valid, off, -1).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_lag",
        "windows",
        "seg_lens",
        "seg_steps",
        "detrend",
        "block_t",
        "interpret",
        "stage_dtype",
    ),
)
def _fused_plan_update_jit(
    y_padded: jax.Array,
    start_mask: jax.Array,
    z0: jax.Array,
    tapers: tuple,
    *,
    max_lag: int,
    windows: tuple,
    seg_lens: tuple,
    seg_steps: tuple,
    detrend: bool,
    block_t: int,
    interpret: bool,
    stage_dtype: Optional[str],
):
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    L = start_mask.shape[0]
    w_max = max(windows) if windows else 1
    l_max = max(seg_lens) if seg_lens else 1
    reach = max(max_lag, w_max - 1, l_max - 1)
    need = L + reach
    y = y_padded.astype(jnp.float32)
    if y.shape[0] < need:
        y = jnp.pad(y, ((0, need - y.shape[0]), (0, 0)))
    head = jnp.where(start_mask[:, None], y[:L], 0.0)
    head = jnp.pad(head, ((0, y.shape[0] - L), (0, 0)))
    m = jnp.pad(
        start_mask.astype(jnp.float32)[:, None], ((0, y.shape[0] - L), (0, 0))
    )

    n = y.shape[0]
    bt = clamp_block_t(block_t, n, max(reach, 1))
    halo = 1 if reach > 0 else 0
    head_p = pad_tiles(head, bt, halo=halo)
    y_p = pad_tiles(y, bt, halo=halo)
    m_p = pad_tiles(m, bt, halo=halo)
    num_tiles = y_p.shape[0] // bt

    if stage_dtype is not None:
        # bf16 staging: the HBM↔VMEM stream narrows; the kernel widens back
        # to f32 right after the load, so accumulation precision is kept.
        dt = jnp.dtype(stage_dtype)
        head_p = head_p.astype(dt)
        y_p = y_p.astype(dt)

    z0 = jnp.asarray(z0, jnp.int32)
    offset_tables = tuple(
        _candidate_offsets(z0, L, num_tiles, bt, step, start_mask)
        for step in seg_steps
    )
    twiddles = [
        dft_power_matrices(Lseg, taper)
        for Lseg, taper in zip(seg_lens, tapers)
    ]
    cos_mats = tuple(c for c, _ in twiddles)
    sin_mats = tuple(s for _, s in twiddles)

    lag, mom, psds = fused_plan_megakernel_pallas(
        head_p,
        y_p,
        m_p,
        offset_tables,
        cos_mats,
        sin_mats,
        max_lag,
        windows,
        seg_lens,
        detrend=detrend,
        block_t=bt,
        interpret=interpret,
    )
    n_segs = tuple(
        jnp.sum((offs >= 0).astype(jnp.float32)) for offs in offset_tables
    )
    return lag, mom, psds, n_segs


def fused_plan_update(
    y_padded: jax.Array,
    start_mask: jax.Array,
    z0,
    max_lag: int,
    windows: Tuple[int, ...] = (),
    seg_lens: Tuple[int, ...] = (),
    seg_steps: Tuple[int, ...] = (),
    tapers: tuple = (),
    detrend: bool = True,
    *,
    stage_dtype: Optional[str] = None,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> tuple:
    """Every member family of a fused plan from ONE grid walk of the chunk.

    The seventh backend primitive: masked lagged sums (``max_lag``), K
    multi-window moment sums (``windows``), and per-member Welch segment-DFT
    power sums (``seg_lens[j]``/``seg_steps[j]``/``tapers[j]``, stride
    alignment against the global index ``z0``) — each tile of the chunk is
    staged into VMEM once and feeds all three families.

    Args:
      y_padded: (≥ L, d) chunk rows (zero-extended to the widest member
        reach when shorter).
      start_mask: (L,) bool window-start validity.
      z0: global index of row 0 (traced ok) — Welch stride alignment.
      windows: distinct moment windows (may be empty).
      seg_lens / seg_steps / tapers: per Welch member; ``tapers[j]`` is the
        (seg_lens[j],) window function.
      stage_dtype: e.g. ``"bfloat16"`` — narrow the HBM↔VMEM staging of the
        series; accumulation stays f32.
      block_t: tile length override; None resolves through the calibrated
        block table (``calibrate(tune_blocks=True)``), else the built-in
        default.

    Returns:
      lag: (max_lag+1, d, d) f32 — Σ_{s: mask} y_s y_{s+h}ᵀ.
      mom: (K, 2, d) f32 (None when ``windows`` is empty).
      psds: tuple of (seg_lens[j]//2+1, d) f32 raw power sums.
      n_segs: tuple of f32 scalars — valid segment counts.
    """
    windows = tuple(int(w) for w in windows)
    if len(set(windows)) != len(windows):
        raise ValueError(f"moment windows must be distinct, got {windows}")
    seg_lens = tuple(int(v) for v in seg_lens)
    seg_steps = tuple(int(v) for v in seg_steps)
    tapers = tuple(tapers)
    if not (len(seg_lens) == len(seg_steps) == len(tapers)):
        raise ValueError(
            f"seg_lens/seg_steps/tapers must align, got lengths "
            f"{len(seg_lens)}/{len(seg_steps)}/{len(tapers)}"
        )
    if any(s <= 0 for s in seg_steps):
        raise ValueError(f"seg_steps must be positive, got {seg_steps}")
    block_t = resolve_block("fused_plan_update", "block_t", block_t)
    return _fused_plan_update_jit(
        y_padded,
        start_mask,
        jnp.asarray(z0, jnp.int32),
        tapers,
        max_lag=max_lag,
        windows=windows,
        seg_lens=seg_lens,
        seg_steps=seg_steps,
        detrend=detrend,
        block_t=block_t,
        interpret=interpret,
        stage_dtype=stage_dtype,
    )
