"""Naive jnp oracle for the fused-plan megakernel.

Restates the megakernel's contract with the most direct jnp expressions
available — per-lag einsums, per-window cumulative sums, per-segment rfft —
with no tiling, no offset tables, and no shared code with the kernel
beyond the argument convention.  tests/test_megakernel.py pins both the
Pallas megakernel and the backend-level jnp composition against this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_plan_update_ref(
    y_padded: jax.Array,
    start_mask: jax.Array,
    z0,
    max_lag: int,
    windows: tuple = (),
    seg_lens: tuple = (),
    seg_steps: tuple = (),
    tapers: tuple = (),
    detrend: bool = True,
) -> tuple:
    """(lag, mom | None, psds, n_segs) by direct evaluation.

    Same contract as the backend primitive: ``lag[h] = Σ_{s: mask} y_s
    y_{s+h}ᵀ``; ``mom[k] = Σ_{s: mask} Σ_{j<windows[k]} [y_{s+j},
    y²_{s+j}]``; for Welch member j, ``psds[j]`` sums the detrended,
    tapered |rfft|² of every segment whose global start ``z0 + c`` is a
    multiple of ``seg_steps[j]`` with ``c < L`` and ``start_mask[c]``, and
    ``n_segs[j]`` counts them.
    """
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    y = y_padded.astype(jnp.float32)
    L = start_mask.shape[0]
    w_max = max(windows) if windows else 1
    l_max = max(seg_lens) if seg_lens else 1
    need = L + max(max_lag, w_max - 1, l_max - 1)
    if y.shape[0] < need:
        y = jnp.pad(y, ((0, need - y.shape[0]), (0, 0)))
    m = start_mask.astype(jnp.float32)

    head = jnp.where(start_mask[:, None], y[:L], 0.0)
    lag = jnp.stack(
        [jnp.einsum("ti,tj->ij", head, y[h : L + h]) for h in range(max_lag + 1)]
    )

    mom = None
    if windows:
        rows = []
        for w in windows:
            s1 = jnp.stack([jnp.sum(y[s : s + w], axis=0) for s in range(L)])
            s2 = jnp.stack(
                [jnp.sum(y[s : s + w] ** 2, axis=0) for s in range(L)]
            )
            rows.append(
                jnp.stack(
                    [jnp.sum(m[:, None] * s1, axis=0), jnp.sum(m[:, None] * s2, axis=0)]
                )
            )
        mom = jnp.stack(rows)

    z0 = jnp.asarray(z0, jnp.int32)
    psds, n_segs = [], []
    for Lseg, step, taper in zip(seg_lens, seg_steps, tapers):
        taper = taper.astype(jnp.float32)
        psd = jnp.zeros((Lseg // 2 + 1, y.shape[1]), jnp.float32)
        n = jnp.asarray(0.0, jnp.float32)
        for c in range(L):
            aligned = (z0 + c) % step == 0
            ok = jnp.logical_and(aligned, start_mask[c]).astype(jnp.float32)
            seg = y[c : c + Lseg]
            if detrend:
                seg = seg - jnp.mean(seg, axis=0, keepdims=True)
            f = jnp.fft.rfft(seg * taper[:, None], axis=0)
            psd = psd + ok * jnp.abs(f) ** 2
            n = n + ok
        psds.append(psd)
        n_segs.append(n)
    return lag, mom, tuple(psds), tuple(n_segs)
