"""Pallas TPU megakernel: a whole fused statistics plan per VMEM staging.

The fused-plan layer (`repro.core.plan`) already collapses N estimator
requests into one *logical* traversal — but its chunk kernel still issued
one Pallas launch per primitive family: ``fused_lagged_moments`` for the
lag/moment members plus one ``segment_fft_power`` per Welch member, each
re-staging the same chunk rows from HBM.  This kernel is the paper's
"one map over overlapping windows" claim taken to the device limit: the
grid walks the chunk ONCE, stages each ``(block_t, d)`` tile into VMEM
once (the halo is the usual second BlockSpec view shifted one tile), and
feeds every member family from the same resident block:

  * MXU lag contractions — one ``dot_general`` per lag h ≤ max_lag,
    masked-start left factor against the h-shifted resident rows
    (identical math to ``fused_lag_moments_pallas``);
  * VPU moment accumulation — ascending-window shared accumulator, K
    moment windows for the cost of the widest one;
  * taper-folded segment-DFT power — per Welch member, a small static
    table of per-tile candidate starts (stride-aligned against the
    member's global grid, −1 when masked/misaligned) selects which
    resident rows form segments; each candidate costs two MXU twiddle
    contractions and a weighted square-accumulate.  Invalid candidates
    run with weight 0 — no divergent control flow on the grid.

All accumulator outputs are revisited by every grid step (sequential TPU
grid) and initialized at step 0.  ops.py guarantees the padding contract:
tile-multiple length with a trailing all-zero halo tile whenever any
member's reach extends past its start row.

Inputs may be staged in bf16 (the optional plan-level
``stage_dtype="bfloat16"`` mode): every accumulation still happens in
f32 — values are widened after the VMEM load, so only the HBM↔VMEM
traffic narrows, not the arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _megakernel(
    *refs,
    max_lag: int,
    windows: tuple,
    seg_lens: tuple,
    detrend: bool,
    block_t: int,
):
    n_seg = len(seg_lens)
    it = iter(refs)
    head_ref = next(it)  # (block_t, d) mask-zeroed left factor
    y_core_ref = next(it)  # (block_t, d) raw series, core tile
    y_next_ref = next(it)  # (block_t, d) halo view (next tile, clamped)
    m_ref = next(it)  # (block_t, 1) f32 start mask
    offs_refs, cos_refs, sin_refs = [], [], []
    for _ in range(n_seg):
        offs_refs.append(next(it))  # (1, n_cand) int32 local starts, -1 pad
        cos_refs.append(next(it))  # (L_j, F_j) taper-folded twiddles
        sin_refs.append(next(it))
    lag_ref = next(it)  # (max_lag+1, d, d) accumulator
    mom_ref = next(it) if windows else None  # (K, 2, d) accumulator
    psd_refs = [next(it) for _ in range(n_seg)]  # (F_j, d) accumulators

    i = pl.program_id(0)

    head = head_ref[...].astype(jnp.float32)
    both = jnp.concatenate(
        [y_core_ref[...], y_next_ref[...]], axis=0
    ).astype(jnp.float32)  # (2·block_t, d) resident rows — the ONE staging
    m = m_ref[...]  # (block_t, 1)

    @pl.when(i == 0)
    def _init():
        lag_ref[...] = jnp.zeros_like(lag_ref)
        if mom_ref is not None:
            mom_ref[...] = jnp.zeros_like(mom_ref)
        for r in psd_refs:
            r[...] = jnp.zeros_like(r)

    # -- MXU half: one contraction per lag, every masked start of the tile.
    for h in range(max_lag + 1):
        shifted = jax.lax.dynamic_slice_in_dim(both, h, block_t, axis=0)
        lag_ref[h, :, :] += jax.lax.dot_general(
            head,
            shifted,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # -- VPU half: ascending-window shared accumulator (K windows for the
    # cost of the widest), masked reduce over the tile's starts.
    if windows:

        def body(j, carry):
            acc, acc2 = carry
            seg = jax.lax.dynamic_slice_in_dim(both, j, block_t, axis=0)
            return acc + seg, acc2 + seg * seg

        zeros = jnp.zeros((block_t, head.shape[1]), jnp.float32)
        carry = (zeros, zeros)
        prev_w = 0
        for k in sorted(range(len(windows)), key=lambda q: windows[q]):
            carry = jax.lax.fori_loop(prev_w, windows[k], body, carry)
            prev_w = windows[k]
            acc, acc2 = carry
            mom_ref[k, 0, :] += jnp.sum(m * acc, axis=0)
            mom_ref[k, 1, :] += jnp.sum(m * acc2, axis=0)

    # -- Spectral members: per-tile candidate starts (precomputed by ops.py,
    # -1 = masked/misaligned) select resident rows; two twiddle matmuls and
    # a weighted square-accumulate per candidate.  The candidate count is a
    # static bound (block_t // step + 1), so the loop fully unrolls — no
    # data-dependent control flow on the TPU grid.
    for j, L in enumerate(seg_lens):
        cosm = cos_refs[j][...]
        sinm = sin_refs[j][...]
        offs = offs_refs[j]
        n_cand = offs.shape[1]
        for c in range(n_cand):
            off = offs[0, c]
            weight = (off >= 0).astype(jnp.float32)
            seg = jax.lax.dynamic_slice_in_dim(
                both, jnp.maximum(off, 0), L, axis=0
            )  # (L, d)
            if detrend:
                seg = seg - jnp.mean(seg, axis=0, keepdims=True)
            re = jax.lax.dot_general(
                cosm,
                seg,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (F, d)
            im = jax.lax.dot_general(
                sinm,
                seg,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            psd_refs[j][...] += weight * (re * re + im * im)


def fused_plan_megakernel_pallas(
    head: jax.Array,
    y: jax.Array,
    m: jax.Array,
    offset_tables: tuple,
    cos_mats: tuple,
    sin_mats: tuple,
    max_lag: int,
    windows: tuple,
    seg_lens: tuple,
    *,
    detrend: bool = True,
    block_t: int = 512,
    interpret: bool = False,
) -> tuple:
    """One persistent grid walk serving lag sums + K moment windows + M
    segment-DFT power accumulators.

    Args:
      head: (n_padded, d) mask-zeroed left factor (rows of ``y`` where the
        start mask holds, zero elsewhere).
      y: (n_padded, d) raw padded series; both padded to a ``block_t``
        multiple, ending with one all-zero halo tile whenever any member
        reaches past its start row (ops.py guarantees this).  ``head``/``y``
        may be bf16 (staging dtype); accumulation is always f32.
      m: (n_padded, 1) f32 start mask.
      offset_tables: per Welch member, (num_tiles, n_cand) int32 — local
        candidate starts inside each tile (−1 when out of range, masked, or
        stride-misaligned; those candidates run with weight 0).
      cos_mats / sin_mats: per member, (L_j, F_j) taper-folded twiddles.
      max_lag: H ≤ block_t.  windows: distinct moment windows, each
        ≤ block_t + 1 (may be empty).  seg_lens: per-member segment length
        L_j ≤ block_t + 1.

    Returns (lag (H+1, d, d), mom (K, 2, d) | None, psds tuple of
    (F_j, d)) — raw sums, all f32; normalization happens in the callers.
    """
    n, d = y.shape
    windows = tuple(windows)
    seg_lens = tuple(int(L) for L in seg_lens)
    if head.shape != y.shape:
        raise ValueError(f"head/y shapes must match, got {head.shape} vs {y.shape}")
    if m.shape != (n, 1):
        raise ValueError(f"mask must be ({n}, 1), got {m.shape}")
    if n % block_t != 0:
        raise ValueError(f"padded length {n} must be a multiple of block_t={block_t}")
    if max_lag > block_t:
        raise ValueError(f"max_lag={max_lag} must be ≤ block_t={block_t}")
    if windows and max(windows) > block_t + 1:
        raise ValueError(f"windows={windows} must all be ≤ block_t+1={block_t + 1}")
    if seg_lens and max(seg_lens) > block_t + 1:
        raise ValueError(
            f"seg_lens={seg_lens} must all be ≤ block_t+1={block_t + 1}"
        )
    if not (len(offset_tables) == len(cos_mats) == len(sin_mats) == len(seg_lens)):
        raise ValueError("per-member argument tuples must have equal length")
    grid = (n // block_t,)
    num_tiles = grid[0]
    K = len(windows)

    in_specs = [
        pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # head core tile
        pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # y core tile
        pl.BlockSpec(  # halo: next y tile (clamped; last tile is zeros)
            (block_t, d), lambda i: (jnp.minimum(i + 1, num_tiles - 1), 0)
        ),
        pl.BlockSpec((block_t, 1), lambda i: (i, 0)),  # start-mask tile
    ]
    operands = [head, y, y, m]
    for j, L in enumerate(seg_lens):
        offs = offset_tables[j]
        if offs.shape[0] != num_tiles:
            raise ValueError(
                f"offset table {j} must have {num_tiles} tile rows, "
                f"got {offs.shape}"
            )
        F = cos_mats[j].shape[1]
        if cos_mats[j].shape != (L, F) or sin_mats[j].shape != (L, F):
            raise ValueError(
                f"twiddle matrices for member {j} must be ({L}, {F}), got "
                f"{cos_mats[j].shape}/{sin_mats[j].shape}"
            )
        n_cand = offs.shape[1]
        in_specs.append(pl.BlockSpec((1, n_cand), lambda i: (i, 0)))
        in_specs.append(pl.BlockSpec((L, F), lambda i: (0, 0)))  # resident
        in_specs.append(pl.BlockSpec((L, F), lambda i: (0, 0)))
        operands += [offs, cos_mats[j], sin_mats[j]]

    out_specs = [pl.BlockSpec((max_lag + 1, d, d), lambda i: (0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((max_lag + 1, d, d), jnp.float32)]
    if K:
        out_specs.append(pl.BlockSpec((K, 2, d), lambda i: (0, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((K, 2, d), jnp.float32))
    for j, L in enumerate(seg_lens):
        F = cos_mats[j].shape[1]
        out_specs.append(pl.BlockSpec((F, d), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((F, d), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(
            _megakernel,
            max_lag=max_lag,
            windows=windows,
            seg_lens=seg_lens,
            detrend=detrend,
            block_t=block_t,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)

    lag = outs[0]
    mom = outs[1] if K else None
    psds = tuple(outs[1 + (1 if K else 0) :])
    return lag, mom, psds
