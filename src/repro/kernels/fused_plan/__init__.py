"""Persistent fused-plan megakernel: one grid walk serves a whole StatPlan.

See `repro.kernels.fused_plan.kernel` for the device code and
`repro.kernels.fused_plan.ops` for the public jit'd entry point
(`repro.core.backend.PallasBackend.fused_plan_update` routes here).
"""
from .ops import fused_plan_update  # noqa: F401
from .ref import fused_plan_update_ref  # noqa: F401
