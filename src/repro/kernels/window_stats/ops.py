"""Public jit'd wrappers for the window_stats kernels.

Handles: zero-padding to a tile multiple (plus one all-zero halo tile
whenever the kernel reaches past its start row — halo-free calls skip it,
see `repro.kernels.tiling.pad_tiles`), dtype promotion (f32 accumulation),
normalization into autocovariances, and the interpret switch for CPU
validation.  These wrappers are the Pallas half of the compute-backend
registry (`repro.core.backend.PallasBackend`); prefer routing through the
registry unless you need the raw kernels.

Tile sizes resolve through the calibrated block table
(`repro.kernels.tiling.resolve_block`) OUTSIDE the jit boundary — a newly
installed table (``calibrate(tune_blocks=True)``) changes the next call's
geometry instead of being baked into a stale trace; pass ``block_t=``
explicitly to override.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..tiling import clamp_block_t, pad_tiles, resolve_block
from .kernel import (
    cross_window_stats_pallas,
    fused_lag_moments_pallas,
    window_moments_pallas,
)
from .ref import normalize_windows, window_stats_ref


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def _cross_lagged_sums_jit(
    a: jax.Array,
    b: jax.Array,
    max_lag: int,
    *,
    block_t: int,
    interpret: bool,
) -> jax.Array:
    if a.ndim == 1:
        a = a[:, None]
    if b.ndim == 1:
        b = b[:, None]
    if a.shape[0] < b.shape[0]:
        a = jnp.pad(a, ((0, b.shape[0] - a.shape[0]), (0, 0)))
    n = b.shape[0]
    block_t = clamp_block_t(block_t, n, max_lag)
    halo = 1 if max_lag > 0 else 0
    return cross_window_stats_pallas(
        pad_tiles(a, block_t, halo=halo),
        pad_tiles(b, block_t, halo=halo),
        max_lag,
        block_t=block_t,
        interpret=interpret,
    )


def cross_lagged_sums(
    a: jax.Array,
    b: jax.Array,
    max_lag: int,
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_k a_k b_{k+h}ᵀ for h = 0..max_lag, via the Pallas kernel.

    ``a`` may be shorter than ``b`` (it is zero-extended on the right); both
    are computed in f32 accumulation whatever the input dtype.
    """
    block_t = resolve_block("lagged_sums", "block_t", block_t)
    return _cross_lagged_sums_jit(
        a, b, max_lag, block_t=block_t, interpret=interpret
    )


def lagged_sums(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_k X_k X_{k+h}ᵀ for h = 0..max_lag, via the Pallas kernel.

    Args:
      x: (n, d) series, any float dtype (computed in f32 accumulation).
    """
    block_t = resolve_block("lagged_sums", "block_t", block_t)
    return _cross_lagged_sums_jit(
        x, x, max_lag, block_t=block_t, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def _masked_lagged_sums_jit(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    *,
    block_t: int,
    interpret: bool,
) -> jax.Array:
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    L = start_mask.shape[0]
    need = L + max_lag
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    head = jnp.where(start_mask[:, None], y_padded[:L].astype(jnp.float32), 0.0)
    return _cross_lagged_sums_jit(
        head, y_padded, max_lag, block_t=block_t, interpret=interpret
    )


def masked_lagged_sums(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_{s: start_mask[s]} y_s y_{s+h}ᵀ — the ChunkKernel contract.

    The masked form reduces to a *cross*-lagged sum between the mask-zeroed
    head rows and the raw padded series, so the streaming engine's update and
    merge both hit the same MXU tile kernel as the batch path.

    Args:
      y_padded: (≥ L, d) — rows [s, s+max_lag] are read for every unmasked
        start (zero-extended if shorter than L + max_lag).
      start_mask: (L,) bool.
    """
    block_t = resolve_block("masked_lagged_sums", "block_t", block_t)
    return _masked_lagged_sums_jit(
        y_padded, start_mask, max_lag, block_t=block_t, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("window", "block_t", "interpret"))
def _windowed_moments_jit(
    x: jax.Array,
    window: int,
    *,
    block_t: int,
    interpret: bool,
) -> jax.Array:
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    n_win = n - window + 1
    block_t = clamp_block_t(block_t, n, window)
    halo = 1 if window > 1 else 0
    out = window_moments_pallas(
        pad_tiles(x, block_t, halo=halo),
        window,
        block_t=block_t,
        interpret=interpret,
    )
    return jnp.moveaxis(out[:, :n_win], 0, 1)


def windowed_moments(
    x: jax.Array,
    window: int,
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Sliding-window moment sums: (n_win, 2, d) of [Σ x, Σ x²] per window.

    Windows are the n - window + 1 full width-``window`` slices of x.
    """
    n = x.shape[0]
    if n - window + 1 < 1:
        raise ValueError(f"series of length {n} has no full window of width {window}")
    block_t = resolve_block("windowed_moments", "block_t", block_t)
    return _windowed_moments_jit(
        x, window, block_t=block_t, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("max_lag", "window", "block_t", "interpret")
)
def _fused_lagged_moments_jit(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    window: tuple,
    *,
    block_t: int,
    interpret: bool,
) -> tuple:
    windows, single = normalize_windows(window)
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    L = start_mask.shape[0]
    reach = max(max_lag, max(windows) - 1)
    need = L + reach
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    y = y_padded.astype(jnp.float32)
    head = jnp.where(start_mask[:, None], y[:L], 0.0)
    head = jnp.pad(head, ((0, y.shape[0] - L), (0, 0)))
    m = jnp.pad(start_mask.astype(jnp.float32)[:, None], ((0, y.shape[0] - L), (0, 0)))

    n = y.shape[0]
    block_t = clamp_block_t(block_t, n, max(reach, 1))
    halo = 1 if reach > 0 else 0
    lag, mom = fused_lag_moments_pallas(
        pad_tiles(head, block_t, halo=halo),
        pad_tiles(y, block_t, halo=halo),
        pad_tiles(m, block_t, halo=halo),
        max_lag,
        windows,
        block_t=block_t,
        interpret=interpret,
    )
    return lag, (mom[0] if single else mom)


def fused_lagged_moments(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    window: "int | tuple",
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
) -> tuple:
    """Masked lagged sums AND masked windowed-moment sums, one HBM read.

    The fused-plan device primitive: a single staging of each VMEM tile
    feeds both the MXU lag contractions and the VPU moment accumulation, so
    a plan serving autocovariance-family and rolling-moment statistics
    costs one traversal instead of two.

    Args:
      y_padded: (≥ L, d) — rows [s, s + max(max_lag, max(windows)-1)] are
        read for every unmasked start (zero-extended when shorter).
      start_mask: (L,) bool.
      window: one moment window, or a tuple of distinct windows — every
        window is accumulated against the same resident VMEM tile, so K
        windows still cost one HBM traversal.

    Returns:
      lag: (max_lag+1, d, d) — Σ_{s: mask} y_s y_{s+h}ᵀ.
      mom: (2, d) for an int window, (K, 2, d) for a tuple —
        Σ_{s: mask} Σ_{j<w} [y_{s+j}, y²_{s+j}] per window w.
    """
    window = window if isinstance(window, int) else tuple(window)
    block_t = resolve_block("fused_lagged_moments", "block_t", block_t)
    return _fused_lagged_moments_jit(
        y_padded, start_mask, max_lag, window, block_t=block_t, interpret=interpret
    )


def autocovariance(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: Optional[int] = None,
    interpret: bool = False,
    normalization: str = "paper",
) -> jax.Array:
    """γ̂(0..max_lag) through the kernel (drop-in for stats.autocovariance)."""
    # function-level import: stats pulls in core.backend, which only reaches
    # back into kernels lazily inside backend methods — no module cycle.
    from ...core.estimators.stats import gamma_normalizer

    if x.ndim == 1:
        x = x[:, None]
    s = lagged_sums(x, max_lag, block_t=block_t, interpret=interpret)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def lagged_sums_reference(x: jax.Array, max_lag: int) -> jax.Array:
    """Oracle re-export used by tests/benchmarks."""
    if x.ndim == 1:
        x = x[:, None]
    return window_stats_ref(x.astype(jnp.float32), max_lag)
