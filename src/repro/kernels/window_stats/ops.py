"""Public jit'd wrapper for the window_stats kernel.

Handles: zero-padding to a tile multiple PLUS one guaranteed all-zero halo
tile (the kernel's boundary contract), dtype promotion, normalization into
autocovariances, and the interpret switch for CPU validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import window_stats_pallas
from .ref import window_stats_ref


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def lagged_sums(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_k X_k X_{k+h}ᵀ for h = 0..max_lag, via the Pallas kernel.

    Args:
      x: (n, d) series, any float dtype (computed in f32 accumulation).
    """
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    block_t = min(block_t, max(max_lag, 1) if n < block_t else block_t)
    block_t = max(block_t, max_lag)
    # pad to a multiple of block_t, then one extra zero tile as the halo of
    # the final core tile.
    n_pad = -(-n // block_t) * block_t + block_t
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    return window_stats_pallas(xp, max_lag, block_t=block_t, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("max_lag", "block_t", "interpret", "normalization")
)
def autocovariance(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
    normalization: str = "paper",
) -> jax.Array:
    """γ̂(0..max_lag) through the kernel (drop-in for stats.autocovariance)."""
    if x.ndim == 1:
        x = x[:, None]
    s = lagged_sums(x, max_lag, block_t=block_t, interpret=interpret)
    n = x.shape[0]
    h = jnp.arange(max_lag + 1)
    if normalization == "paper":
        norm = 1.0 / (n - h - 1)
    else:
        norm = jnp.full((max_lag + 1,), 1.0 / n)
    return s * norm[:, None, None]


def lagged_sums_reference(x: jax.Array, max_lag: int) -> jax.Array:
    """Oracle re-export used by tests/benchmarks."""
    if x.ndim == 1:
        x = x[:, None]
    return window_stats_ref(x.astype(jnp.float32), max_lag)
