"""Public jit'd wrappers for the window_stats kernels.

Handles: zero-padding to a tile multiple PLUS one guaranteed all-zero halo
tile (the kernels' boundary contract), dtype promotion (f32 accumulation),
normalization into autocovariances, and the interpret switch for CPU
validation.  These wrappers are the Pallas half of the compute-backend
registry (`repro.core.backend.PallasBackend`); prefer routing through the
registry unless you need the raw kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (
    cross_window_stats_pallas,
    fused_lag_moments_pallas,
    window_moments_pallas,
)
from .ref import normalize_windows, window_stats_ref


def _clamp_block_t(block_t: int, n: int, min_tile: int) -> int:
    """Positive, contract-satisfying tile size for ANY series length.

    The tile never exceeds the (rounded-up) series length, never drops below
    the kernel's per-tile window requirement (``min_tile``: max_lag for the
    lag kernel, window for the moments kernel), and is at least 1 — so the
    grid ``n_pad // block_t`` is always ≥ 1, including tiny series with
    n < max_lag and the degenerate n == 0.
    """
    return max(min(block_t, max(n, 1)), min_tile, 1)


def _pad_tiles(x: jax.Array, block_t: int) -> jax.Array:
    """Zero-pad (n, d) to a multiple of block_t plus one all-zero halo tile."""
    n = x.shape[0]
    n_pad = -(-max(n, 1) // block_t) * block_t + block_t
    return jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def cross_lagged_sums(
    a: jax.Array,
    b: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_k a_k b_{k+h}ᵀ for h = 0..max_lag, via the Pallas kernel.

    ``a`` may be shorter than ``b`` (it is zero-extended on the right); both
    are computed in f32 accumulation whatever the input dtype.
    """
    if a.ndim == 1:
        a = a[:, None]
    if b.ndim == 1:
        b = b[:, None]
    if a.shape[0] < b.shape[0]:
        a = jnp.pad(a, ((0, b.shape[0] - a.shape[0]), (0, 0)))
    n = b.shape[0]
    block_t = _clamp_block_t(block_t, n, max_lag)
    return cross_window_stats_pallas(
        _pad_tiles(a, block_t),
        _pad_tiles(b, block_t),
        max_lag,
        block_t=block_t,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def lagged_sums(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_k X_k X_{k+h}ᵀ for h = 0..max_lag, via the Pallas kernel.

    Args:
      x: (n, d) series, any float dtype (computed in f32 accumulation).
    """
    return cross_lagged_sums(x, x, max_lag, block_t=block_t, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_lag", "block_t", "interpret"))
def masked_lagged_sums(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """S(h) = Σ_{s: start_mask[s]} y_s y_{s+h}ᵀ — the ChunkKernel contract.

    The masked form reduces to a *cross*-lagged sum between the mask-zeroed
    head rows and the raw padded series, so the streaming engine's update and
    merge both hit the same MXU tile kernel as the batch path.

    Args:
      y_padded: (≥ L, d) — rows [s, s+max_lag] are read for every unmasked
        start (zero-extended if shorter than L + max_lag).
      start_mask: (L,) bool.
    """
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    L = start_mask.shape[0]
    need = L + max_lag
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    head = jnp.where(start_mask[:, None], y_padded[:L].astype(jnp.float32), 0.0)
    return cross_lagged_sums(
        head, y_padded, max_lag, block_t=block_t, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("window", "block_t", "interpret"))
def windowed_moments(
    x: jax.Array,
    window: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sliding-window moment sums: (n_win, 2, d) of [Σ x, Σ x²] per window.

    Windows are the n - window + 1 full width-``window`` slices of x.
    """
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    n_win = n - window + 1
    if n_win < 1:
        raise ValueError(f"series of length {n} has no full window of width {window}")
    block_t = _clamp_block_t(block_t, n, window)
    out = window_moments_pallas(
        _pad_tiles(x, block_t), window, block_t=block_t, interpret=interpret
    )
    return jnp.moveaxis(out[:, :n_win], 0, 1)


@functools.partial(
    jax.jit, static_argnames=("max_lag", "window", "block_t", "interpret")
)
def fused_lagged_moments(
    y_padded: jax.Array,
    start_mask: jax.Array,
    max_lag: int,
    window: "int | tuple",
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> tuple:
    """Masked lagged sums AND masked windowed-moment sums, one HBM read.

    The fused-plan device primitive: a single staging of each VMEM tile
    feeds both the MXU lag contractions and the VPU moment accumulation, so
    a plan serving autocovariance-family and rolling-moment statistics
    costs one traversal instead of two.

    Args:
      y_padded: (≥ L, d) — rows [s, s + max(max_lag, max(windows)-1)] are
        read for every unmasked start (zero-extended when shorter).
      start_mask: (L,) bool.
      window: one moment window, or a tuple of distinct windows — every
        window is accumulated against the same resident VMEM tile, so K
        windows still cost one HBM traversal.

    Returns:
      lag: (max_lag+1, d, d) — Σ_{s: mask} y_s y_{s+h}ᵀ.
      mom: (2, d) for an int window, (K, 2, d) for a tuple —
        Σ_{s: mask} Σ_{j<w} [y_{s+j}, y²_{s+j}] per window w.
    """
    windows, single = normalize_windows(window)
    if y_padded.ndim == 1:
        y_padded = y_padded[:, None]
    L = start_mask.shape[0]
    reach = max(max_lag, max(windows) - 1)
    need = L + reach
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    y = y_padded.astype(jnp.float32)
    head = jnp.where(start_mask[:, None], y[:L], 0.0)
    head = jnp.pad(head, ((0, y.shape[0] - L), (0, 0)))
    m = jnp.pad(start_mask.astype(jnp.float32)[:, None], ((0, y.shape[0] - L), (0, 0)))

    n = y.shape[0]
    block_t = _clamp_block_t(block_t, n, max(reach, 1))
    lag, mom = fused_lag_moments_pallas(
        _pad_tiles(head, block_t),
        _pad_tiles(y, block_t),
        _pad_tiles(m, block_t),
        max_lag,
        windows,
        block_t=block_t,
        interpret=interpret,
    )
    return lag, (mom[0] if single else mom)


@functools.partial(
    jax.jit, static_argnames=("max_lag", "block_t", "interpret", "normalization")
)
def autocovariance(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
    normalization: str = "paper",
) -> jax.Array:
    """γ̂(0..max_lag) through the kernel (drop-in for stats.autocovariance)."""
    # function-level import: stats pulls in core.backend, which only reaches
    # back into kernels lazily inside backend methods — no module cycle.
    from ...core.estimators.stats import gamma_normalizer

    if x.ndim == 1:
        x = x[:, None]
    s = lagged_sums(x, max_lag, block_t=block_t, interpret=interpret)
    norm = gamma_normalizer(x.shape[0], max_lag, normalization)
    return s * norm[:, None, None]


def lagged_sums_reference(x: jax.Array, max_lag: int) -> jax.Array:
    """Oracle re-export used by tests/benchmarks."""
    if x.ndim == 1:
        x = x[:, None]
    return window_stats_ref(x.astype(jnp.float32), max_lag)
