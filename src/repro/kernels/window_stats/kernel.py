"""Pallas TPU kernel: lagged cross-product sums over overlapping VMEM tiles.

Paper §12.2 (Fig. 9) stages blocks of size N_B + 2H into GPU shared memory so
every thread's window is local.  The TPU adaptation (DESIGN.md §2):

  * the "shared memory block" is a VMEM tile; the halo is realized by giving
    the grid step a *second* BlockSpec view of the same HBM array shifted by
    one tile (core tile i + tile i+1 ⇒ all windows with h ≤ N_B are local);
  * instead of one thread per window centre, one MXU matmul per lag computes
    EVERY centre of the tile at once:  S_tile(h) = coreᵀ @ shifted_h, a
    (d × N_B)·(N_B × d) contraction — systolic-array-aligned when
    N_B % 128 == 0 and d % 128 == 0 (padded by ops.py otherwise);
  * the output block (H+1, d, d) is revisited by every grid step
    (accumulation over the sequential TPU grid), initialized at step 0.

Zero-fill boundary handling: ops.py pads the series with one extra zero tile
so the last core tile's "next" view is all zeros — out-of-range products
vanish without any masking (the same trick the overlap data structure uses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_core_ref, x_next_ref, out_ref, *, max_lag: int, block_t: int):
    i = pl.program_id(0)

    core = x_core_ref[...]  # (block_t, d)
    nxt = x_next_ref[...]  # (block_t, d)
    both = jnp.concatenate([core, nxt], axis=0)  # (2·block_t, d)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # One MXU contraction per lag: every window centre of the tile at once.
    for h in range(max_lag + 1):
        shifted = jax.lax.dynamic_slice_in_dim(both, h, block_t, axis=0)
        contrib = jax.lax.dot_general(
            core,
            shifted,
            (((0,), (0,)), ((), ())),  # contract over time: (d, d)
            preferred_element_type=jnp.float32,
        )
        out_ref[h, :, :] += contrib


def window_stats_pallas(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw lagged sums S(0..max_lag) of a zero-padded series.

    Args:
      x: (n_padded, d) with n_padded % block_t == 0, REQUIRED to end with at
        least one all-zero tile (ops.py guarantees this) and max_lag ≤ block_t.
      max_lag: H.
      block_t: core tile length N_B (the VMEM block).

    Returns (max_lag+1, d, d) float32.
    """
    n, d = x.shape
    if n % block_t != 0:
        raise ValueError(f"padded length {n} must be a multiple of block_t={block_t}")
    if max_lag > block_t:
        raise ValueError(f"max_lag={max_lag} must be ≤ block_t={block_t}")
    grid = (n // block_t,)
    num_tiles = grid[0]

    return pl.pallas_call(
        functools.partial(_kernel, max_lag=max_lag, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # core tile
            pl.BlockSpec(  # halo: the next tile (clamped; last tile is zeros)
                (block_t, d), lambda i: (jnp.minimum(i + 1, num_tiles - 1), 0)
            ),
        ],
        out_specs=pl.BlockSpec((max_lag + 1, d, d), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((max_lag + 1, d, d), jnp.float32),
        interpret=interpret,
    )(x, x)
