"""Pallas TPU kernels: windowed contractions over overlapping VMEM tiles.

Paper §12.2 (Fig. 9) stages blocks of size N_B + 2H into GPU shared memory so
every thread's window is local.  The TPU adaptation (DESIGN.md §2):

  * the "shared memory block" is a VMEM tile; the halo is realized by giving
    the grid step a *second* BlockSpec view of the same HBM array shifted by
    one tile (core tile i + tile i+1 ⇒ all windows with h ≤ N_B are local);
  * instead of one thread per window centre, one MXU matmul per lag computes
    EVERY centre of the tile at once:  S_tile(h) = coreᵀ @ shifted_h, a
    (d × N_B)·(N_B × d) contraction — systolic-array-aligned when
    N_B % 128 == 0 and d % 128 == 0 (padded by ops.py otherwise);
  * the output block (H+1, d, d) is revisited by every grid step
    (accumulation over the sequential TPU grid), initialized at step 0.

Three kernels share the tiling scheme:

  :func:`cross_window_stats_pallas` — cross-lagged sums Σ_k a_k b_{k+h}ᵀ.
    With a = b this is the plain lagged-sum statistic; with a = mask·b it is
    the *masked* form the streaming engine's ChunkKernel contract needs
    (`repro.core.backend.PallasBackend.masked_lagged_sums`).
  :func:`window_moments_pallas` — per-window first/second moment sums
    (rolling mean/variance), one VPU accumulation pass per tile.
  :func:`fused_lag_moments_pallas` — lagged sums AND masked windowed-moment
    sums from ONE staging of each VMEM tile: the series is read from HBM
    once, the MXU lag contractions and the VPU moment accumulation both run
    against the same resident tile pair.  This is the device half of the
    fused statistics-plan layer (`repro.core.plan`): a plan serving
    autocovariance + Yule-Walker + rolling moments costs one HBM traversal
    instead of one per statistic.

Zero-fill boundary handling: ops.py pads the series with one extra zero tile
so the last core tile's "next" view is all zeros — out-of-range products
vanish without any masking (the same trick the overlap data structure uses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lag_kernel(a_core_ref, b_core_ref, b_next_ref, out_ref, *, max_lag: int, block_t: int):
    i = pl.program_id(0)

    core = a_core_ref[...]  # (block_t, d) — the (possibly masked) left factor
    both = jnp.concatenate([b_core_ref[...], b_next_ref[...]], axis=0)  # (2·block_t, d)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # One MXU contraction per lag: every window centre of the tile at once.
    for h in range(max_lag + 1):
        shifted = jax.lax.dynamic_slice_in_dim(both, h, block_t, axis=0)
        contrib = jax.lax.dot_general(
            core,
            shifted,
            (((0,), (0,)), ((), ())),  # contract over time: (d, d)
            preferred_element_type=jnp.float32,
        )
        out_ref[h, :, :] += contrib


def cross_window_stats_pallas(
    a: jax.Array,
    b: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Cross-lagged sums S(h) = Σ_k a_k b_{k+h}ᵀ of two zero-padded series.

    Args:
      a, b: (n_padded, d) with n_padded % block_t == 0, REQUIRED to end with
        at least one all-zero tile (ops.py guarantees this) and
        max_lag ≤ block_t.  Pass a is b for the plain lagged sums.
      max_lag: H.
      block_t: core tile length N_B (the VMEM block).

    Returns (max_lag+1, d, d) float32.
    """
    n, d = b.shape
    if a.shape != b.shape:
        raise ValueError(f"a/b shapes must match, got {a.shape} vs {b.shape}")
    if n % block_t != 0:
        raise ValueError(f"padded length {n} must be a multiple of block_t={block_t}")
    if max_lag > block_t:
        raise ValueError(f"max_lag={max_lag} must be ≤ block_t={block_t}")
    grid = (n // block_t,)
    num_tiles = grid[0]

    return pl.pallas_call(
        functools.partial(_lag_kernel, max_lag=max_lag, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # a core tile
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # b core tile
            pl.BlockSpec(  # halo: the next b tile (clamped; last tile is zeros)
                (block_t, d), lambda i: (jnp.minimum(i + 1, num_tiles - 1), 0)
            ),
        ],
        out_specs=pl.BlockSpec((max_lag + 1, d, d), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((max_lag + 1, d, d), jnp.float32),
        interpret=interpret,
    )(a, b, b)


def window_stats_pallas(
    x: jax.Array,
    max_lag: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw lagged sums S(0..max_lag) of a zero-padded series (a = b case)."""
    return cross_window_stats_pallas(
        x, x, max_lag, block_t=block_t, interpret=interpret
    )


def _fused_kernel(
    a_core_ref,
    b_core_ref,
    b_next_ref,
    m_core_ref,
    lag_ref,
    mom_ref,
    *,
    max_lag: int,
    windows: tuple,
    block_t: int,
):
    i = pl.program_id(0)

    core = a_core_ref[...]  # (block_t, d) mask-zeroed left factor
    both = jnp.concatenate([b_core_ref[...], b_next_ref[...]], axis=0)
    m = m_core_ref[...]  # (block_t, 1) f32 start mask

    @pl.when(i == 0)
    def _init():
        lag_ref[...] = jnp.zeros_like(lag_ref)
        mom_ref[...] = jnp.zeros_like(mom_ref)

    # MXU half: one contraction per lag, every window start of the tile.
    for h in range(max_lag + 1):
        shifted = jax.lax.dynamic_slice_in_dim(both, h, block_t, axis=0)
        lag_ref[h, :, :] += jax.lax.dot_general(
            core,
            shifted,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # VPU half on the SAME resident tile pair: per-start window sums, then a
    # masked reduce over starts — (2, d) moment partials per grid step and
    # per requested window.  Windows are visited in ascending order so the
    # running per-start accumulator is SHARED: window w_k's sums extend
    # w_{k-1}'s with rows [w_{k-1}, w_k) — total work is O(max(windows)) per
    # tile whatever K is, and every window reads the same resident tile pair
    # (one HBM staging for all of them).
    def body(j, carry):
        acc, acc2 = carry
        seg = jax.lax.dynamic_slice_in_dim(both, j, block_t, axis=0)
        seg = seg.astype(jnp.float32)
        return acc + seg, acc2 + seg * seg

    zeros = jnp.zeros((block_t, core.shape[1]), jnp.float32)
    carry = (zeros, zeros)
    prev_w = 0
    for k in sorted(range(len(windows)), key=lambda q: windows[q]):
        carry = jax.lax.fori_loop(prev_w, windows[k], body, carry)
        prev_w = windows[k]
        acc, acc2 = carry
        mom_ref[k, 0, :] += jnp.sum(m * acc, axis=0)
        mom_ref[k, 1, :] += jnp.sum(m * acc2, axis=0)


def fused_lag_moments_pallas(
    a: jax.Array,
    b: jax.Array,
    m: jax.Array,
    max_lag: int,
    windows: tuple,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> tuple:
    """Masked lagged sums + masked windowed-moment sums in one tile pass.

    Args:
      a: (n_padded, d) mask-zeroed left factor (rows of b with the start
        mask applied) — exactly the masked_lagged_sums contract.
      b: (n_padded, d) raw padded series, ending with one all-zero tile.
      m: (n_padded, 1) f32 start mask (1.0 at valid starts).
      max_lag: H (≤ block_t); windows: tuple of distinct moment windows
        (each ≤ block_t + 1) — all accumulated from the same resident tile.

    Returns:
      lag: (max_lag+1, d, d) f32 — Σ_{s: m_s} b_s b_{s+h}ᵀ.
      mom: (K, 2, d) f32 — row k is Σ_{s: m_s} Σ_{j<windows[k]}
        [b_{s+j}, b²_{s+j}].
    """
    n, d = b.shape
    windows = tuple(windows)
    if a.shape != b.shape:
        raise ValueError(f"a/b shapes must match, got {a.shape} vs {b.shape}")
    if m.shape != (n, 1):
        raise ValueError(f"mask must be ({n}, 1), got {m.shape}")
    if n % block_t != 0:
        raise ValueError(f"padded length {n} must be a multiple of block_t={block_t}")
    if max_lag > block_t:
        raise ValueError(f"max_lag={max_lag} must be ≤ block_t={block_t}")
    if not windows:
        raise ValueError("need at least one moment window")
    if max(windows) > block_t + 1:
        raise ValueError(
            f"windows={windows} must all be ≤ block_t+1={block_t + 1}"
        )
    grid = (n // block_t,)
    num_tiles = grid[0]
    K = len(windows)

    return pl.pallas_call(
        functools.partial(
            _fused_kernel, max_lag=max_lag, windows=windows, block_t=block_t
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # masked a tile
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),  # b core tile
            pl.BlockSpec(  # halo: next b tile (clamped; last tile is zeros)
                (block_t, d), lambda i: (jnp.minimum(i + 1, num_tiles - 1), 0)
            ),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),  # start mask tile
        ],
        out_specs=[
            pl.BlockSpec((max_lag + 1, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, 2, d), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((max_lag + 1, d, d), jnp.float32),
            jax.ShapeDtypeStruct((K, 2, d), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, b, m)


def _moments_kernel(x_core_ref, x_next_ref, out_ref, *, window: int, block_t: int):
    core = x_core_ref[...]  # (block_t, d)
    both = jnp.concatenate([core, x_next_ref[...]], axis=0)  # (2·block_t, d)

    # VPU accumulation: window starts s = tile offset + [0, block_t); sample
    # s + j lives at local row s + j of `both` (j ≤ window-1 ≤ block_t).
    # fori_loop keeps the traced kernel body O(1) in window — a Python loop
    # would unroll `window` slice+add pairs into the lowered program.
    def body(j, carry):
        acc, acc2 = carry
        seg = jax.lax.dynamic_slice_in_dim(both, j, block_t, axis=0)
        seg = seg.astype(jnp.float32)
        return acc + seg, acc2 + seg * seg

    zeros = jnp.zeros(core.shape, jnp.float32)
    acc, acc2 = jax.lax.fori_loop(0, window, body, (zeros, zeros))
    out_ref[0, :, :] = acc
    out_ref[1, :, :] = acc2


def window_moments_pallas(
    x: jax.Array,
    window: int,
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Per-window moment sums of a zero-padded series.

    Args:
      x: (n_padded, d), n_padded % block_t == 0, ending with one all-zero
        tile; window ≤ block_t + 1.

    Returns (2, n_padded, d) float32: out[0, s] = Σ_{j<window} x_{s+j},
    out[1, s] = Σ_{j<window} x²_{s+j}.  Starts whose window runs into the
    padding are sliced off by ops.py.
    """
    n, d = x.shape
    if n % block_t != 0:
        raise ValueError(f"padded length {n} must be a multiple of block_t={block_t}")
    if window > block_t + 1:
        raise ValueError(f"window={window} must be ≤ block_t+1={block_t + 1}")
    grid = (n // block_t,)
    num_tiles = grid[0]

    return pl.pallas_call(
        functools.partial(_moments_kernel, window=window, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec(
                (block_t, d), lambda i: (jnp.minimum(i + 1, num_tiles - 1), 0)
            ),
        ],
        out_specs=pl.BlockSpec((2, block_t, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((2, n, d), jnp.float32),
        interpret=interpret,
    )(x, x)
