"""Pure-jnp oracle for the lagged cross-product sums.

S(h) = Σ_{k=0}^{N-1-h} X_k X_{k+h}ᵀ   for h = 0..H   →  (H+1, d, d)

This is `repro.core.estimators.stats.raw_lag_sums` restated minimally so the
kernel test depends on nothing but jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normalize_windows(window: "int | tuple") -> tuple:
    """Normalize the fused primitive's ``window`` argument: returns
    (windows tuple, was_single).  Tuples must hold distinct positive ints.
    Lives in this leaf module (jnp-only, no Pallas import) so the kernel
    wrappers AND `repro.core.backend` share one validation without a
    kernels → core back-edge.
    """
    if isinstance(window, int):
        windows: tuple = (window,)
        single = True
    else:
        windows = tuple(window)
        single = False
    if not windows:
        raise ValueError("need at least one moment window")
    if any((not isinstance(w, int)) or w < 1 for w in windows):
        raise ValueError(f"moment windows must be positive ints, got {windows}")
    if len(set(windows)) != len(windows):
        raise ValueError(f"moment windows must be distinct, got {windows}")
    return windows, single


def window_stats_ref(x: jax.Array, max_lag: int) -> jax.Array:
    n = x.shape[0]

    def one(h):
        idx = jnp.arange(n)
        valid = (idx + h) <= (n - 1)
        shifted = x[jnp.clip(idx + h, 0, n - 1)]
        shifted = jnp.where(valid[:, None], shifted, 0.0)
        return jnp.einsum("ti,tj->ij", x, shifted)

    return jax.vmap(one)(jnp.arange(max_lag + 1)).astype(jnp.float32)


def window_moments_ref(x: jax.Array, window: int) -> jax.Array:
    """(n_win, 2, d) of [Σ x, Σ x²] over every full width-``window`` slice."""
    n = x.shape[0]
    n_win = n - window + 1
    starts = jnp.arange(n_win)
    wins = jax.vmap(lambda s: jax.lax.dynamic_slice_in_dim(x, s, window, axis=0))(
        starts
    ).astype(jnp.float32)
    return jnp.stack([jnp.sum(wins, axis=1), jnp.sum(wins**2, axis=1)], axis=1)


def fused_lag_moments_ref(
    y_padded: jax.Array, start_mask: jax.Array, max_lag: int, window: "int | tuple"
) -> tuple:
    """Oracle for the fused primitive: per-start windows materialized naively.

    Returns (lag (max_lag+1, d, d), mom) matching
    `ops.fused_lagged_moments` / `JnpBackend.fused_lagged_moments`: ``mom``
    is (2, d) for an int window and (K, 2, d) for a tuple of windows.
    """
    windows, single = normalize_windows(window)
    L = start_mask.shape[0]
    reach = max(max_lag, max(windows) - 1)
    need = L + reach
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    y = y_padded.astype(jnp.float32)
    m = start_mask.astype(jnp.float32)

    def one(h):
        shifted = jax.lax.dynamic_slice_in_dim(y, h, L, axis=0)
        return jnp.einsum("t,ti,tj->ij", m, y[:L], shifted)

    lag = jax.vmap(one)(jnp.arange(max_lag + 1))

    moms = []
    for w in windows:
        wins = jax.vmap(
            lambda s: jax.lax.dynamic_slice_in_dim(y, s, w, axis=0)
        )(jnp.arange(L))  # (L, w, d)
        m1 = jnp.einsum("t,twd->d", m, wins)
        m2 = jnp.einsum("t,twd->d", m, wins**2)
        moms.append(jnp.stack([m1, m2]))
    mom = jnp.stack(moms)
    return lag, (mom[0] if single else mom)
