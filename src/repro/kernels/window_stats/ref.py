"""Pure-jnp oracle for the lagged cross-product sums.

S(h) = Σ_{k=0}^{N-1-h} X_k X_{k+h}ᵀ   for h = 0..H   →  (H+1, d, d)

This is `repro.core.estimators.stats.raw_lag_sums` restated minimally so the
kernel test depends on nothing but jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def window_stats_ref(x: jax.Array, max_lag: int) -> jax.Array:
    n = x.shape[0]

    def one(h):
        idx = jnp.arange(n)
        valid = (idx + h) <= (n - 1)
        shifted = x[jnp.clip(idx + h, 0, n - 1)]
        shifted = jnp.where(valid[:, None], shifted, 0.0)
        return jnp.einsum("ti,tj->ij", x, shifted)

    return jax.vmap(one)(jnp.arange(max_lag + 1)).astype(jnp.float32)
