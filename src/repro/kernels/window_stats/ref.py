"""Pure-jnp oracle for the lagged cross-product sums.

S(h) = Σ_{k=0}^{N-1-h} X_k X_{k+h}ᵀ   for h = 0..H   →  (H+1, d, d)

This is `repro.core.estimators.stats.raw_lag_sums` restated minimally so the
kernel test depends on nothing but jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def window_stats_ref(x: jax.Array, max_lag: int) -> jax.Array:
    n = x.shape[0]

    def one(h):
        idx = jnp.arange(n)
        valid = (idx + h) <= (n - 1)
        shifted = x[jnp.clip(idx + h, 0, n - 1)]
        shifted = jnp.where(valid[:, None], shifted, 0.0)
        return jnp.einsum("ti,tj->ij", x, shifted)

    return jax.vmap(one)(jnp.arange(max_lag + 1)).astype(jnp.float32)


def window_moments_ref(x: jax.Array, window: int) -> jax.Array:
    """(n_win, 2, d) of [Σ x, Σ x²] over every full width-``window`` slice."""
    n = x.shape[0]
    n_win = n - window + 1
    starts = jnp.arange(n_win)
    wins = jax.vmap(lambda s: jax.lax.dynamic_slice_in_dim(x, s, window, axis=0))(
        starts
    ).astype(jnp.float32)
    return jnp.stack([jnp.sum(wins, axis=1), jnp.sum(wins**2, axis=1)], axis=1)


def fused_lag_moments_ref(
    y_padded: jax.Array, start_mask: jax.Array, max_lag: int, window: int
) -> tuple:
    """Oracle for the fused primitive: per-start windows materialized naively.

    Returns (lag (max_lag+1, d, d), mom (2, d)) matching
    `ops.fused_lagged_moments` / `JnpBackend.fused_lagged_moments`.
    """
    L = start_mask.shape[0]
    d = y_padded.shape[1]
    reach = max(max_lag, window - 1)
    need = L + reach
    if y_padded.shape[0] < need:
        y_padded = jnp.pad(y_padded, ((0, need - y_padded.shape[0]), (0, 0)))
    y = y_padded.astype(jnp.float32)
    m = start_mask.astype(jnp.float32)

    def one(h):
        shifted = jax.lax.dynamic_slice_in_dim(y, h, L, axis=0)
        return jnp.einsum("t,ti,tj->ij", m, y[:L], shifted)

    lag = jax.vmap(one)(jnp.arange(max_lag + 1))

    wins = jax.vmap(
        lambda s: jax.lax.dynamic_slice_in_dim(y, s, window, axis=0)
    )(jnp.arange(L))  # (L, window, d)
    m1 = jnp.einsum("t,twd->d", m, wins)
    m2 = jnp.einsum("t,twd->d", m, wins**2)
    return lag, jnp.stack([m1, m2])
