"""Public wrapper for sliding-window flash attention.

Handles GQA head grouping, sequence padding to tile multiples, and the
interpret switch.  The backward pass is the padded-chunk reference
(`repro.models.attention.local_attention_chunked` is the differentiable
training path — see DESIGN.md: the kernel is the serving/forward hot-spot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import swa_attention_pallas
from .ref import swa_attention_ref


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret")
)
def swa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Sliding-window causal attention with GQA.

    Args:
      q: (B, H, S, D);  k, v: (B, KVH, S, D) with H % KVH == 0.
      window: attend to the previous ``window`` positions (incl. self).

    Returns (B, H, S, D).
    """
    b, h, s, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    # pad sequence to a tile multiple; padded queries attend to themselves
    # only (masked by causality) and are sliced away.
    tile = max(block_q, block_k)
    s_pad = -(-s // tile) * tile
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    bq = min(block_q, s_pad)
    bk = min(block_k, s_pad)
    if bq % bk:
        bk = bq
    # GQA: repeat kv heads to full head count, flatten (B, H) → BH.
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    qf = q.reshape(b * h, s_pad, d)
    kf = k.reshape(b * h, s_pad, d)
    vf = v.reshape(b * h, s_pad, d)
    out = swa_attention_pallas(
        qf, kf, vf, window, block_q=bq, block_k=bk, interpret=interpret
    )
    return out.reshape(b, h, s_pad, d)[:, :, :s, :]


def swa_attention_reference(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int
) -> jax.Array:
    """(B, H, S, D) GQA oracle."""
    group = q.shape[1] // k.shape[1]
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    return swa_attention_ref(q, k, v, window)
