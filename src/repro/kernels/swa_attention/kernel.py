"""Pallas TPU kernel: sliding-window causal flash attention.

The paper's weak-memory window applied to attention: position q attends only
to k ∈ (q − W, q].  Each query tile therefore needs exactly
``1 + ceil((W−1)/block_k)`` key tiles — its VMEM halo — instead of the whole
prefix.  Compute and HBM traffic are O(S·W), not O(S²): the weak-memory
claim at the kernel level.

Grid: (batch·heads, n_q_tiles, n_kv_tiles_per_q), innermost axis sequential
(online-softmax accumulation in VMEM scratch, canonical flash pattern).
Block sizes default to 128×128 — MXU-aligned.  Boundary tiles are handled by
index-map clamping + explicit probability masking (NOT -inf arithmetic:
fully-masked tiles must contribute exactly zero probability mass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scratch,
    l_scratch,
    acc_scratch,
    *,
    window: int,
    block_q: int,
    block_k: int,
    n_kv: int,
    scale: float,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0]  # (block_q, d)
    k = k_ref[0]  # (block_k, d)
    v = v_ref[0]

    # Intended (unclamped) kv tile index; oldest tile first.  Anchor on the
    # tile containing the LAST query of the q-tile (matters when bq > bk).
    qt_last = (i * block_q + block_q - 1) // block_k
    t = qt_last - (n_kv - 1) + j
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = t * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window) & (t >= 0)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]  # (block_q, 1) broadcast storage
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_next)
    p = jnp.where(mask, p, 0.0)  # exact zero for masked/fully-masked tiles
    alpha = jnp.exp(m_prev - m_next)
    l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scratch[...] = m_next
    l_scratch[...] = l_next

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = l_scratch[...]
        o_ref[0] = (acc_scratch[...] / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


def swa_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int,
    *,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Sliding-window causal attention.

    Args:
      q, k, v: (BH, S, D); S % block_q == 0 == S % block_k (ops.py pads).
      window: attend to k ∈ (q−window, q].

    Returns (BH, S, D) in q.dtype.
    """
    bh, s, d = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be a multiple of block_q/block_k")
    if block_q % block_k:
        raise ValueError("block_q must be a multiple of block_k")
    scale = (d**-0.5) if scale is None else scale
    n_q = s // block_q
    n_k_tiles = s // block_k
    n_kv = 1 + -(-(window - 1) // block_k) + (block_q // block_k - 1)
    n_kv = min(n_kv, n_k_tiles)

    def kv_index(b, i, j):
        qt_last = (i * block_q + block_q - 1) // block_k
        t = qt_last - (n_kv - 1) + j
        return (b, jnp.clip(t, 0, n_k_tiles - 1), 0)

    grid = (bh, n_q, n_kv)
    return pl.pallas_call(
        functools.partial(
            _kernel,
            window=window,
            block_q=block_q,
            block_k=block_k,
            n_kv=n_kv,
            scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
