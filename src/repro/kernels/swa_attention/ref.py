"""Pure-jnp oracle: dense causal sliding-window attention.

Position q attends to k ∈ (q − window, q] — the order-(window−1) weak-memory
kernel of DESIGN.md §4.  O(S²) memory; only for validation at small sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int, scale: float | None = None
) -> jax.Array:
    """q, k, v: (..., S, D) → (..., S, D)."""
    s = q.shape[-2]
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32)).astype(q.dtype)
