"""Shared tiling plumbing for every Pallas kernel package.

Before this module each ``kernels/*/ops.py`` carried its own copy of the
tile-size clamp, the pad-to-tile-multiple helper, and a hard-coded
``block_t=512`` / ``block_s=8`` / ``block_rows=256`` literal.  Three
problems with that:

  * the copies drift (the old ``_pad_tiles`` always appended a full
    all-zero halo tile even when the kernel's reach is 0 — one wasted
    HBM→VMEM staging per call for halo-free kernels);
  * a tuned tile size measured by `repro.core.calibrate` had no way to
    reach the kernels — the literals in the source were the policy;
  * a new kernel package (the fused-plan megakernel) would have added a
    fourth copy.

Now every ops entry point funnels through here:

  :func:`resolve_block`    explicit caller override > the platform's
                           calibrated block table
                           (``CalibrationTable.blocks``, persisted by
                           ``calibrate(tune_blocks=True)``) > the built-in
                           default.  Resolution never triggers a
                           measurement pass — an un-calibrated process
                           just gets the defaults.
  :func:`clamp_block_t`    positive, contract-satisfying tile size for ANY
                           series length (grid ≥ 1, tile ≥ per-tile window
                           requirement).
  :func:`pad_tiles`        zero-pad to a tile multiple, appending the
                           all-zero halo tile ONLY when the kernel reaches
                           past its core tile (``halo > 0``).
  :func:`pad_to_multiple`  ceil-round a count to a block multiple.

This module is a kernels-layer leaf: it imports nothing from ``repro.core``
at module scope (the calibration lookup is a lazy function-level import),
so the kernels ↔ core layering stays acyclic.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "DEFAULT_BLOCKS",
    "resolve_block",
    "clamp_block_t",
    "pad_tiles",
    "pad_to_multiple",
]

# Built-in per-primitive tile defaults — the values the scattered literals
# used to pin.  A calibrated table (``CalibrationTable.blocks``) overrides
# these per platform; an explicit ops argument overrides everything.
DEFAULT_BLOCKS: Dict[str, Dict[str, int]] = {
    "lagged_sums": {"block_t": 512},
    "masked_lagged_sums": {"block_t": 512},
    "windowed_moments": {"block_t": 512},
    "fused_lagged_moments": {"block_t": 512},
    "fused_plan_update": {"block_t": 512},
    "segment_fft_power": {"block_s": 8},
    "segment_csd": {"block_s": 8},
    "banded_matvec": {"block_rows": 256},
}


def default_block(primitive: str, param: str) -> int:
    try:
        return DEFAULT_BLOCKS[primitive][param]
    except KeyError:
        raise KeyError(
            f"no built-in default for {primitive}.{param}; known: "
            f"{sorted(DEFAULT_BLOCKS)}"
        ) from None


def resolve_block(
    primitive: str, param: str, override: Optional[int] = None
) -> int:
    """The tile size an ops entry point should use for ``primitive``.

    Precedence: ``override`` (an explicit caller argument — tests and the
    tuner itself) > the active platform's calibrated block table > the
    built-in :data:`DEFAULT_BLOCKS` entry.  The table lookup never triggers
    a calibration run: it reads the in-process table if one was already
    resolved, else the persisted cache, else the defaults
    (`repro.core.calibrate.active_blocks`).
    """
    if override is not None:
        return int(override)
    from ..core.calibrate import active_blocks  # lazy: keeps layering acyclic

    tuned = active_blocks(primitive).get(param)
    if tuned is not None:
        return int(tuned)
    return default_block(primitive, param)


def clamp_block_t(block_t: int, n: int, min_tile: int) -> int:
    """Positive, contract-satisfying tile size for ANY series length.

    The tile never exceeds the (rounded-up) series length, never drops below
    the kernel's per-tile window requirement (``min_tile``: max_lag for the
    lag kernels, window for the moments kernel, the full reach for the
    fused-plan megakernel), and is at least 1 — so the grid
    ``n_pad // block_t`` is always ≥ 1, including tiny series with
    n < max_lag and the degenerate n == 0.
    """
    return max(min(block_t, max(n, 1)), min_tile, 1)


def pad_tiles(x: jax.Array, block_t: int, halo: int = 1) -> jax.Array:
    """Zero-pad (n, d) to a multiple of ``block_t``, plus one all-zero halo
    tile when the kernel's reach extends past its core tile.

    ``halo`` is the number of rows past a window start the kernel may read
    (max_lag, window − 1, …).  With ``halo == 0`` the kernel only ever
    touches its core tile, so the extra zero tile the old per-package
    ``_pad_tiles`` unconditionally appended was a pure waste: one dead
    HBM→VMEM staging per grid walk.  With ``halo > 0`` the trailing zero
    tile realizes the kernels' boundary contract — the last core tile's
    "next" view is all zeros, so out-of-range products vanish without
    masking.
    """
    n = x.shape[0]
    n_pad = -(-max(n, 1) // block_t) * block_t
    if halo > 0:
        n_pad += block_t
    return jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))


def pad_to_multiple(count: int, block: int) -> int:
    """Smallest multiple of ``block`` ≥ max(count, 1)."""
    return -(-max(count, 1) // block) * block
