"""Weight-only int8 quantization for serving (§Perf C3).

Decode at small batch is weight-bandwidth-bound: every step streams the
full parameter set from HBM.  Storing weights as int8 codes + per-channel
f32 scales halves (bf16) or quarters (f32) that stream; dequantization is
fused into the consuming matmul by XLA (the bf16 tensor never round-trips
HBM on TPU).

Usage:
    qparams = quantize_tree(params)                 # host/one-time
    logits, cache = decode_step(dequantize_tree(qparams), cache, batch, cfg)
    # under jit, HBM holds int8; dequant is a fused convert

Per-channel absmax scaling over the contraction (−2) axis; small leaves
(norm scales, biases) stay in full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantTensor:
    codes: jax.Array  # int8, original shape
    scale: jax.Array  # f32, shape with axis −2 reduced to 1

    def tree_flatten(self):
        return (self.codes, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def nbytes(self) -> int:
        return self.codes.size + self.scale.size * 4


def quantize_leaf(w: jax.Array) -> QuantTensor:
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QuantTensor(codes=codes, scale=scale)


def dequantize_leaf(q: QuantTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (q.codes.astype(jnp.float32) * q.scale).astype(dtype)


def _eligible(leaf) -> bool:
    return (
        hasattr(leaf, "ndim")
        and leaf.ndim >= 2
        and leaf.size >= 65536
        and jnp.issubdtype(leaf.dtype, jnp.floating)
    )


def quantize_tree(params: Any) -> Any:
    """Quantize every large ≥2-D float leaf; leave the rest untouched."""
    return jax.tree.map(
        lambda l: quantize_leaf(l) if _eligible(l) else l, params
    )


def dequantize_tree(params: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda l: dequantize_leaf(l, dtype) if isinstance(l, QuantTensor) else l,
        params,
        is_leaf=lambda l: isinstance(l, QuantTensor),
    )


def tree_param_bytes(params: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(
        params, is_leaf=lambda l: isinstance(l, QuantTensor)
    ):
        if isinstance(leaf, QuantTensor):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
