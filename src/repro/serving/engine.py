"""Batched serving engine: prefill + decode against static-capacity caches.

Request batches are padded to a fixed (batch, prompt_len) grid; prefill
fills layer caches at full capacity ``max_len`` (prompt + generation
budget), decode steps are jit'd once and reused (static shapes throughout —
pjit/TPU friendly).  Greedy or temperature sampling.

The capacity-C cache convention matches `models`: position ``pos`` is the
write index and entries with stored pos > current pos (or < 0) are masked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import cache_spec, decode_step, prefill


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, max_new)
    prompt_len: int


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        max_len: int,
        dtype=jnp.float32,
        quantize: bool = False,
    ):
        """``quantize=True`` stores weights as int8 + per-channel scales
        (§Perf C3): decode HBM weight traffic halves (bf16) / quarters
        (f32); dequant is fused into the consuming matmuls under jit."""
        self.cfg = cfg
        self.max_len = max_len
        self.dtype = dtype

        if quantize:
            from .quant import dequantize_tree, quantize_tree

            self.params = quantize_tree(params)
            deq = lambda p: dequantize_tree(p, dtype=dtype)
        else:
            self.params = params
            deq = lambda p: p

        self._decode = jax.jit(
            lambda params, cache, tokens, pos: decode_step(
                deq(params), cache, {"tokens": tokens, "pos": pos}, cfg
            )
        )
        self._prefill = jax.jit(lambda params, batch: prefill(deq(params), batch, cfg))

    def _grow_cache(self, cache, batch: int):
        """Fit the prefill cache into capacity-max_len buffers.

        For enc-dec archs only the decoder SELF cache grows: the cross
        K/V length is the true encoder length and must NOT be padded
        (cross-attention is unmasked — zero-padding would leak probability
        mass onto phantom encoder positions).
        """
        if self.cfg.family == "encdec":
            enc_len = cache["cross"]["k"].shape[2]
            from ..models.encdec import encdec_cache_spec

            spec = encdec_cache_spec(
                self.cfg, batch, self.max_len, enc_len=enc_len, dtype=self.dtype
            )
        else:
            spec = cache_spec(self.cfg, batch, self.max_len, dtype=self.dtype)

        def fit(a, s):
            pads = [(0, sd - ad) for ad, sd in zip(a.shape, s.shape)]
            if any(p[1] for p in pads):
                cv = -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0
                a = jnp.pad(a, pads, constant_values=cv)
            return a.astype(s.dtype)

        return jax.tree.map(fit, cache, spec)

    def generate(
        self,
        prompts: jax.Array,  # (B, S_prompt) int32
        max_new: int,
        *,
        extra: Optional[Dict[str, jax.Array]] = None,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> GenerationResult:
        b, s_prompt = prompts.shape
        if s_prompt + max_new > self.max_len:
            raise ValueError(
                f"prompt {s_prompt} + max_new {max_new} exceeds max_len {self.max_len}"
            )
        batch = {"tokens": prompts, **(extra or {})}
        logits, cache = self._prefill(self.params, batch)
        cache = self._grow_cache(cache, b)

        pos0 = s_prompt
        if self.cfg.family == "vlm":
            pos0 = s_prompt + self.cfg.n_patches

        out = []
        tok = self._sample(logits, temperature, key, 0)
        out.append(tok)
        for i in range(1, max_new):
            pos = jnp.asarray(pos0 + i - 1, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = self._sample(logits, temperature, key, i)
            out.append(tok)
        return GenerationResult(
            tokens=np.stack([np.asarray(t) for t in out], axis=1), prompt_len=s_prompt
        )

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature).astype(jnp.int32)
