from .engine import ServeEngine, GenerationResult
from .gateway import (
    GatewayConfig,
    GatewayRejected,
    QueueFull,
    RateClass,
    RateLimited,
    StatsGateway,
)
from .rolling import RollingStatsService
