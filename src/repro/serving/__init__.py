from .engine import ServeEngine, GenerationResult
from .gateway import (
    Degraded,
    GatewayConfig,
    GatewayRejected,
    QueueFull,
    RateClass,
    RateLimited,
    StatsGateway,
)
from .rolling import RollingStatsService
