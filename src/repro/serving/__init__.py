from .engine import ServeEngine, GenerationResult
from .rolling import RollingStatsService
