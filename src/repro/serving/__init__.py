from .engine import ServeEngine, GenerationResult
