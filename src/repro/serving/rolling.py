"""Rolling-statistics serving endpoint over streaming partial states.

The production shape of the paper's thesis (ROADMAP north star): millions
of user series, each receiving samples over time, each wanting rolling
statistics (mean / autocovariance / AR fits / spectra) on demand.  Because
weak-memory partials form a mergeable monoid (`repro.core.streaming`), the
service never stores raw series — only per-user `PartialState`s, which are

  * updated in place by batched, vmapped chunk ingestion (one device pass
    for a whole arrival batch),
  * held in ``num_shards`` independent ingest lanes (e.g. one per ingest
    node or mesh host) that never coordinate on the write path,
  * merged **on request**: a query ⊕-combines the user's per-lane partials
    and finalizes.  On a mesh, lane partials built from halo-complete
    blocks reduce with the single ``psum`` of
    `repro.parallel.sharding.psum_tree` — the read path's only collective.

Lane storage is ONE stacked pytree with a leading ``(num_lanes,
num_users)`` axis pair — not a Python list of per-lane states — so every
lane shares a single jit program: ingest scatter-updates into the stacked
buffers (which are **donated**, so steady-state ingest allocates nothing),
and a batched query gathers all lanes of all requested users with one
indexed read and ⊕-folds the lane axis inside one compiled reduce.

**Sliding-window eviction mode** (``window=``): instead of growing
forever, each user's state is a ring of ``num_buckets`` *window-aligned
sub-states*, each covering a contiguous ``window / num_buckets``-sample
span.  Ingest lands in the bucket owning the chunk's global index,
resetting it to the neutral element when a new span begins — which is the
eviction: the span from ``num_buckets`` rings ago vanishes in O(1),
without ever revisiting data.  A query ⊕-folds the ring exactly like
lanes (the merge orders operands by global start index), so served
statistics cover the retained horizon: the last ``w`` samples with
``window − bucket_len < w ≤ window``, bucket-aligned.  Because bucket
``t0``s are global, strided members (Welch segments) stay aligned across
evictions.  The multi-statistic front door over this machinery is
`repro.core.frame.FrameSession`.

**Tail fidelity is a serving contract.**  The merged cross-lane state a
query hands to finalizers carries the *exact* last ``W − 1`` samples of
the user's (retained) series in ``tail``, right-aligned and zero-filled —
not just lag sums.  Downstream this is load-bearing beyond the ragged-tail
correction: the forecast/anomaly members of `repro.core.forecast` seed
their companion-matrix recurrence and innovations filter from that very
window, so ⊕-fold order, eviction resets, and `export_state` /
``import_state`` round-trips must all preserve it bit-exactly (the
kill-and-restart forecast determinism pin in tests/test_gateway.py).

**State is never recomputed — integrity is a serving contract too.**  The
raw series is gone the moment a chunk is absorbed; every answer the
service will ever give is a ⊕-fold of the carried partials.  Two
consequences, and the machinery that answers them (`repro.core.integrity`):

  * one non-finite sample scatter-merged into a lane poisons that
    tenant's answers *permanently* (NaN + x = NaN; no later data dilutes
    it out).  Prevention belongs at the boundary — the gateway's ingest
    sentinel (`repro.serving.gateway`) — and detection/repair here:
    :meth:`audit` finite-sweeps the stacked lane pytree on-device into a
    host per-(lane, user) health mask, and :meth:`import_tenant`
    surgically restores ONE tenant's lanes from a per-tenant checkpoint
    slice (`repro.checkpoint.manager.restore_tenant_pytree`) without
    touching any other tenant's live state or re-tracing the donated
    scatter programs;
  * float rounding in the ⊕-folds drifts monotonically for the session's
    lifetime.  Engines built with ``compensated=True`` carry a Neumaier
    error companion per stat leaf so readout recovers what rounding
    discarded (pinned by benchmarks/bench_integrity.py).

The compute substrate of the ingest hot loop is the engine's backend
(`repro.core.backend`): build the engine with
``lag_sum_engine(..., backend="pallas")`` and every batched ``ingest``
update — and the ragged-tail correction at query finalize — runs the VMEM
tile kernels; with ``"auto"`` the registry picks by platform and size.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.integrity import lane_health
from ..core.streaming import PartialState, StreamingEngine

__all__ = ["RollingStatsService"]


def _coerce_import_leaf(key: str, want: np.dtype, new):
    """Dtype-validate one snapshot leaf against the live buffer it replaces.

    Equal dtype passes through; a same-kind mismatch (float64 snapshot into
    a float32 session — numpy checkpoints default to f64) is cast
    explicitly; a kind change (float↔int↔complex↔bool) raises: it means
    the snapshot was produced by a different engine config, and silently
    casting it would both corrupt values and compile duplicate scatter
    programs keyed on the stray dtype (the PR 6 ``t0`` int32 bug class).
    """
    arr = new if hasattr(new, "dtype") else np.asarray(new)
    have = np.dtype(arr.dtype)
    want = np.dtype(want)
    if have == want:
        return jnp.asarray(arr)
    if have.kind == want.kind:
        return jnp.asarray(arr, want)
    raise ValueError(
        f"snapshot leaf {key!r} has dtype {have} but this service holds "
        f"{want} — a {have.kind!r}→{want.kind!r} kind change cannot come "
        "from a matching exporter config; refusing to cast"
    )


class RollingStatsService:
    """Batched per-user rolling statistics with mergeable ingest lanes.

    Args:
      engine: streaming engine defining the tracked statistic.
      num_users: number of user series served.
      num_shards: independent ingest lanes.  A user's stream may be split
        across lanes in contiguous time segments (pass ``t0`` at the first
        ingest of a mid-stream lane); queries merge lanes in any order.
      window: sliding-window eviction mode — retain only (about) the last
        ``window`` samples per user, in a ring of ``num_buckets``
        window-aligned sub-states (see the module docstring).  Requires
        ``num_shards == 1``; every ingested chunk must tile the bucket
        grid (chunk length ≤ bucket span, never straddling a boundary).
      num_buckets: ring size in eviction mode (default 8); ``window`` must
        divide evenly into it.
    """

    def __init__(
        self,
        engine: StreamingEngine,
        num_users: int,
        num_shards: int = 1,
        window: Optional[int] = None,
        num_buckets: Optional[int] = None,
    ):
        if num_users <= 0 or num_shards <= 0:
            raise ValueError("num_users and num_shards must be positive")
        self.engine = engine
        self.num_users = num_users
        self.num_shards = num_shards
        self.window = window
        if window is None:
            if num_buckets is not None:
                raise ValueError("num_buckets only applies with window= set")
            self.num_buckets = None
            self.bucket_len = None
            num_lanes = num_shards
        else:
            if num_shards != 1:
                raise ValueError(
                    "eviction mode is a single ingest lane (num_shards=1); "
                    "the lane axis is the eviction ring"
                )
            self.num_buckets = 8 if num_buckets is None else num_buckets
            if self.num_buckets < 2:
                raise ValueError("eviction needs at least 2 ring buckets")
            if window <= 0 or window % self.num_buckets != 0:
                raise ValueError(
                    f"window={window} must be a positive multiple of "
                    f"num_buckets={self.num_buckets}"
                )
            self.bucket_len = window // self.num_buckets
            num_lanes = self.num_buckets
        self._num_lanes = num_lanes
        # One stacked pytree, leading axes (num_lanes, num_users): every
        # lane lives in the same buffers and every ingest/query below is a
        # single jit program regardless of which lane it addresses.
        one = engine.init_batch(num_users)
        self._lanes = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_lanes,) + l.shape), one
        )
        # Total samples ever ingested per user — the eviction ring's global
        # cursor.  Kept as a HOST array: the cursor is only ever read for
        # alignment checks and bucket derivation, and a device-resident
        # counter would force one device→host sync per ingest batch (the
        # hot path).  Growing mode reads lengths straight off the lane
        # states and never touches this.
        self._counts = np.zeros((num_users,), np.int64)
        # Host per-(lane, user) health mask, refreshed by audit() — the
        # hot ingest/query paths never touch it.
        self._lane_health = np.ones((num_lanes, num_users), bool)
        self._audit_sweep = jax.jit(lane_health)

        def scatter_update(lanes, shard, user_ids, chunks, t0):
            sub = jax.tree.map(lambda l: l[shard, user_ids], lanes)
            new = jax.vmap(engine.update)(sub, chunks, t0)
            return jax.tree.map(
                lambda l, nl: l.at[shard, user_ids].set(nl), lanes, new
            )

        # jit caches one program per (arrival batch, chunk length) shape —
        # shared by ALL lanes (shard is a traced scalar) — and donates the
        # lane buffers: steady-state ingest updates them in place.
        self._scatter_update = jax.jit(scatter_update, donate_argnums=0)

        def scatter_evict(lanes, user_ids, chunks, counts):
            # Ring ingest: the chunk's bucket is derived from the user's
            # global cursor; a cursor on a bucket boundary means the slot
            # holds the span from num_buckets rings ago — reset it to the
            # neutral element (THE eviction) before absorbing the chunk.
            bucket = (counts // self.bucket_len) % self.num_buckets
            sub = jax.tree.map(lambda l: l[bucket, user_ids], lanes)
            fresh = engine.init_batch(user_ids.shape[0], t0=counts)
            boundary = counts % self.bucket_len == 0

            def pick(cur, new):
                b = boundary.reshape(boundary.shape + (1,) * (cur.ndim - 1))
                return jnp.where(b, new, cur)

            cur = jax.tree.map(pick, sub, fresh)
            new = jax.vmap(engine.update)(cur, chunks, counts)
            return jax.tree.map(
                lambda l, nl: l.at[bucket, user_ids].set(nl), lanes, new
            )

        self._scatter_evict = jax.jit(scatter_evict, donate_argnums=0)

        def lane_fold(stacked):
            # ⊕-fold the leading lane axis of a stacked (S, k, …) pytree
            # with the vmapped merge: one compiled reduce, no per-lane
            # Python-indexed tree.map gathers.  The merge combines
            # *adjacent* segments, so the running ⊕-accumulator must stay
            # contiguous at every step: in eviction mode the ring slots are
            # time-rotated per user, so sort them by global start first
            # (empty slots last — they are neutral).  Growing-mode lanes
            # are caller-ordered contiguous splits; slot order is already
            # time order there.
            if window is not None:
                key = jnp.where(
                    stacked.length > 0,
                    stacked.t0,
                    jnp.iinfo(jnp.int32).max,
                )
                order = jnp.argsort(key, axis=0)  # (S, k)
                stacked = jax.tree.map(
                    lambda leaf: jnp.take_along_axis(
                        leaf,
                        order.reshape(order.shape + (1,) * (leaf.ndim - 2)),
                        axis=0,
                    ),
                    stacked,
                )
            acc = jax.tree.map(lambda l: l[0], stacked)
            for s in range(1, num_lanes):
                acc = jax.vmap(engine.merge)(
                    acc, jax.tree.map(lambda l: l[s], stacked)
                )
            return acc

        self._gather_merge = jax.jit(
            lambda lanes, user_ids: lane_fold(
                jax.tree.map(lambda l: l[:, user_ids], lanes)
            )
        )

    @property
    def backend(self):
        """The compute backend every ingest lane's updates run through."""
        return self.engine.backend

    # -- durability ---------------------------------------------------------
    def export_state(self) -> dict:
        """Host snapshot of the full serving state: the stacked lane pytree
        plus the eviction cursor.  Leaves are HOST copies (``device_get``),
        so the snapshot survives the next ingest donating the live lane
        buffers — safe to hand to an async checkpoint writer
        (`repro.checkpoint.manager.CheckpointManager.save`)."""
        return {
            "lanes": jax.device_get(self._lanes),
            "counts": np.array(self._counts),
        }

    def import_state(self, state: dict) -> None:
        """Install a snapshot produced by :meth:`export_state` on a service
        built with the same engine/num_users/num_shards/window config —
        after this, queries answer exactly as they did at snapshot time
        without re-ingesting any history."""
        lanes = state["lanes"]
        want = jax.tree.structure(self._lanes)
        got = jax.tree.structure(lanes)
        if want != got:
            raise ValueError(
                f"snapshot lane structure {got} does not match this "
                f"service's {want} — was it exported from a service with a "
                f"different plan or engine?"
            )
        mismatched = [
            (a.shape, b.shape)
            for a, b in zip(jax.tree.leaves(self._lanes), jax.tree.leaves(lanes))
            if tuple(a.shape) != tuple(b.shape)
        ]
        if mismatched:
            raise ValueError(
                f"snapshot lane shapes {[m[1] for m in mismatched]} do not "
                f"match this service's {[m[0] for m in mismatched]} — "
                "num_users / num_shards / window must equal the exporter's"
            )
        cur_flat, treedef = jax.tree_util.tree_flatten_with_path(self._lanes)
        new_leaves = [
            _coerce_import_leaf(
                "lanes" + jax.tree_util.keystr(path), cur.dtype, new
            )
            for (path, cur), new in zip(cur_flat, jax.tree.leaves(lanes))
        ]
        self._lanes = jax.tree.unflatten(treedef, new_leaves)
        counts = np.asarray(state["counts"])
        if counts.dtype.kind not in "iu":
            raise ValueError(
                f"snapshot counts must be integer-typed, got {counts.dtype}"
            )
        counts = counts.astype(np.int64)
        if counts.shape != self._counts.shape:
            raise ValueError(
                f"snapshot counts shape {counts.shape} != {self._counts.shape}"
            )
        self._counts = counts.copy()
        self._lane_health = np.ones((self._num_lanes, self.num_users), bool)

    def state_template(self) -> dict:
        """Zero-copy view with :meth:`export_state`'s structure — the live
        lane pytree and cursor themselves, for shape/dtype templates
        (checkpoint restore) where a host snapshot would waste a full
        device→host transfer.  Do NOT mutate or retain across a donating
        ingest."""
        return {"lanes": self._lanes, "counts": self._counts}

    # -- integrity ----------------------------------------------------------
    def audit(self) -> np.ndarray:
        """Finite-sweep the stacked lane pytree on-device: ONE compiled
        program (`repro.core.integrity.lane_health`, jitted once at
        construction) + one host sync, refreshing the per-(lane, user)
        health mask.  Returns a host (num_users,) bool — True where every
        lane of the user is healthy."""
        # np.array (not asarray): own the buffer — device_get views are
        # read-only and import_tenant writes the mask in place.
        mask = np.array(self._audit_sweep(self._lanes))
        self._lane_health = mask
        return mask.all(axis=0)

    @property
    def lane_health(self) -> np.ndarray:
        """(num_lanes, num_users) health mask from the last :meth:`audit`
        (all-True before any audit, and reset on import/rebuild)."""
        return self._lane_health.copy()

    def tenant_slice(self, state: dict, user_id: int) -> dict:
        """Extract ONE user's slice from an :meth:`export_state` snapshot:
        lane leaves keep their lane axis, drop the user axis (axis 1);
        the cursor becomes a scalar.  Host-side; no device work."""
        u = self._check_user(user_id)
        return {
            "lanes": jax.tree.map(lambda l: np.asarray(l)[:, u], state["lanes"]),
            "counts": np.int64(np.asarray(state["counts"])[u]),
        }

    def export_tenant(self, user_id: int) -> dict:
        """Host snapshot of ONE user's lane states + cursor (the
        :meth:`import_tenant` payload)."""
        u = self._check_user(user_id)
        return {
            "lanes": jax.tree.map(
                lambda l: jax.device_get(l[:, u]), self._lanes
            ),
            "counts": np.int64(self._counts[u]),
        }

    def import_tenant(self, user_id: int, state: dict) -> None:
        """Surgically restore ONE user's lane states from a per-tenant
        snapshot (:meth:`export_tenant` / :meth:`tenant_slice` /
        `repro.checkpoint.manager.restore_tenant_pytree`).

        Every other user's live state is untouched, and nothing re-traces:
        the write is an eager per-leaf ``.at[:, u].set`` — the donated
        scatter-ingest and gather-query programs key on the (unchanged)
        stacked buffer shapes and keep serving from their caches.
        """
        u = self._check_user(user_id)
        lanes = state["lanes"]
        want = jax.tree.structure(self._lanes)
        got = jax.tree.structure(lanes)
        if want != got:
            raise ValueError(
                f"tenant snapshot lane structure {got} does not match this "
                f"service's {want}"
            )
        cur_flat, treedef = jax.tree_util.tree_flatten_with_path(self._lanes)
        new_flat = jax.tree.leaves(lanes)
        out = []
        for (path, cur), new in zip(cur_flat, new_flat):
            key = "lanes" + jax.tree_util.keystr(path)
            expect = (cur.shape[0],) + tuple(cur.shape[2:])
            if tuple(np.shape(new)) != expect:
                raise ValueError(
                    f"tenant snapshot leaf {key!r} has shape "
                    f"{tuple(np.shape(new))}, expected {expect}"
                )
            coerced = _coerce_import_leaf(key, cur.dtype, new)
            out.append(cur.at[:, u].set(coerced))
        self._lanes = jax.tree.unflatten(treedef, out)
        count = np.asarray(state["counts"])
        if count.dtype.kind not in "iu" or count.shape != ():
            raise ValueError(
                f"tenant snapshot counts must be an integer scalar, got "
                f"{count.dtype} with shape {count.shape}"
            )
        self._counts[u] = int(count)
        self._lane_health[:, u] = True

    def _check_user(self, user_id: int) -> int:
        u = int(user_id)
        if not 0 <= u < self.num_users:
            raise ValueError(f"user_id {u} out of range [0, {self.num_users})")
        return u

    # -- write path --------------------------------------------------------
    def ingest(
        self,
        user_ids: jax.Array,
        chunks: jax.Array,
        shard: int = 0,
        t0: Optional[jax.Array] = None,
    ) -> None:
        """Absorb one arrival batch: ``chunks[i]`` extends user
        ``user_ids[i]``'s series on lane ``shard``.

        Args:
          user_ids: (k,) int — distinct users in this batch.
          chunks: (k, c, d) — equal-length chunk per user (pad+resend
            shorter arrivals separately; chunk granularity is free in
            growing mode; in eviction mode chunks must tile the bucket
            grid).
          t0: (k,) global start indices, used only for users whose lane
            state is still empty (a lane that picks up mid-stream).
            Growing mode only — the eviction ring owns the global cursor.
        """
        # Validation runs on a HOST view of the ids: when the caller passes
        # host data (a list, a numpy batch straight off the wire) the whole
        # check costs zero device round-trips — the old jnp form issued a
        # device dispatch plus a blocking device→host read per ingest call.
        ids = np.asarray(user_ids)
        if ids.dtype.kind != "i":
            # match the old jnp.asarray(user_ids, jnp.int32) coercion —
            # float-typed ids ingested fine before the host-side validation
            ids = ids.astype(np.int64)
        # .at[ids].set would silently keep only one of two conflicting
        # scattered states, and jit scatter silently DROPS out-of-bounds
        # ids (the gather on read would clamp to another user) — reject the
        # caller slips instead of losing or cross-wiring data.
        if np.unique(ids).shape[0] != ids.shape[0]:
            raise ValueError("user_ids must be distinct within one ingest batch")
        if ids.shape[0] and not (0 <= ids.min() and ids.max() < self.num_users):
            raise ValueError(f"user_ids must lie in [0, {self.num_users})")
        # num_shards is the caller-facing lane count in BOTH modes: the
        # eviction ring pins it to 1, and its internal bucket lanes are not
        # addressable (the old check tested _num_lanes — the ring size — so
        # the message promised a range the check didn't enforce).
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.num_shards})")
        user_ids = jnp.asarray(ids, jnp.int32)
        chunks = jnp.asarray(chunks)
        if chunks.shape[1] == 0:
            # nothing to absorb — and in eviction mode the boundary reset
            # below must not fire for an empty arrival (it would wipe a
            # still-retained bucket without advancing the cursor)
            return
        if self.window is not None:
            if t0 is not None:
                raise ValueError(
                    "eviction mode owns the global cursor; t0 is not accepted"
                )
            c = int(chunks.shape[1])
            if c > self.bucket_len:
                raise ValueError(
                    f"chunk length {c} exceeds the eviction bucket span "
                    f"{self.bucket_len} (= window / num_buckets)"
                )
            starts = self._counts[ids]  # host cursor: no device sync
            if np.any(
                starts // self.bucket_len != (starts + c - 1) // self.bucket_len
            ):
                raise ValueError(
                    "chunk would straddle an eviction bucket boundary; "
                    f"chunks must tile the {self.bucket_len}-sample bucket grid"
                )
            self._lanes = self._scatter_evict(
                self._lanes, user_ids, chunks, jnp.asarray(starts, jnp.int32)
            )
        else:
            if t0 is None:
                # update() falls back to each state's own cursor.
                t0 = jnp.zeros(user_ids.shape, jnp.int32)
            self._lanes = self._scatter_update(
                self._lanes,
                jnp.asarray(shard, jnp.int32),
                user_ids,
                chunks,
                # pin the dtype: a bare asarray leaves it caller-dependent,
                # so mixed int32/int64 t0 arrivals compiled (and cached)
                # duplicate donated scatter programs for the same shapes
                jnp.asarray(t0, jnp.int32),
            )
        if self.window is not None:
            self._counts[ids] += chunks.shape[1]

    # -- read path ---------------------------------------------------------
    def partial(self, user_id: int) -> PartialState:
        """The user's merged cross-lane PartialState (lane order free)."""
        batched = self.partials_batch(jnp.asarray([user_id], jnp.int32))
        return jax.tree.map(lambda l: l[0], batched)

    def partials_batch(self, user_ids: Sequence[int] | jax.Array) -> PartialState:
        """Merged cross-lane PartialStates for many users in one program
        (leading ``len(user_ids)`` axis): one gather pulls every requested
        user's lane states, one compiled reduce ⊕-folds the lane axis.
        The batched read path multi-statistic front-ends
        (`repro.core.frame.FrameSession`) build on."""
        return self._gather_merge(
            self._lanes, jnp.asarray(user_ids, jnp.int32)
        )

    def query(self, user_id: int, finalizer: Callable, *args, **kwargs) -> Any:
        """Rolling estimate for one user: merge lanes, then finalize with an
        estimator front-end, e.g.
        ``svc.query(7, streaming_autocovariance, normalization="standard")``.
        """
        return finalizer(self.engine, self.partial(user_id), *args, **kwargs)

    def query_batch(
        self, user_ids: Sequence[int] | jax.Array, finalizer: Callable, *args, **kwargs
    ) -> Any:
        """Vmapped multi-user read: ONE gather pulls every requested user's
        lane states from the stacked buffers, one compiled reduce ⊕-folds
        the lane axis, then the finalizer runs vmapped over users."""
        merged = self.partials_batch(user_ids)
        return jax.vmap(
            lambda s: finalizer(self.engine, s, *args, **kwargs)
        )(merged)

    def lengths(self) -> jax.Array:
        """(num_users,) samples ingested per user (total, incl. evicted)."""
        if self.window is None:
            return jnp.sum(self._lanes.length, axis=0)
        return jnp.asarray(self._counts, jnp.int32)

    def retained_lengths(self) -> jax.Array:
        """(num_users,) samples a query covers right now: all of them in
        growing mode; in eviction mode the ring-retained span — the last
        ``w`` samples, ``window − bucket_len < w ≤ window`` once the ring
        has wrapped."""
        if self.window is None:
            return self.lengths()
        cnt = jnp.asarray(self._counts, jnp.int32)
        evicted = (
            jnp.maximum(
                (cnt - 1) // self.bucket_len - (self.num_buckets - 1), 0
            )
            * self.bucket_len
        )
        return jnp.where(cnt > 0, cnt - evicted, 0)
