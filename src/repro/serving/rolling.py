"""Rolling-statistics serving endpoint over streaming partial states.

The production shape of the paper's thesis (ROADMAP north star): millions
of user series, each receiving samples over time, each wanting rolling
statistics (mean / autocovariance / AR fits / spectra) on demand.  Because
weak-memory partials form a mergeable monoid (`repro.core.streaming`), the
service never stores raw series — only per-user `PartialState`s, which are

  * updated in place by batched, vmapped chunk ingestion (one device pass
    for a whole arrival batch),
  * held in ``num_shards`` independent ingest lanes (e.g. one per ingest
    node or mesh host) that never coordinate on the write path,
  * merged **on request**: a query ⊕-combines the user's per-lane partials
    and finalizes.  On a mesh, lane partials built from halo-complete
    blocks reduce with the single ``psum`` of
    `repro.parallel.sharding.psum_tree` — the read path's only collective.

Lane storage is ONE stacked pytree with a leading ``(num_shards,
num_users)`` axis pair — not a Python list of per-lane states — so every
lane shares a single jit program: ingest scatter-updates into the stacked
buffers (which are **donated**, so steady-state ingest allocates nothing),
and a batched query gathers all lanes of all requested users with one
indexed read and ⊕-folds the lane axis inside one compiled reduce.

The compute substrate of the ingest hot loop is the engine's backend
(`repro.core.backend`): build the engine with
``lag_sum_engine(..., backend="pallas")`` and every batched ``ingest``
update — and the ragged-tail correction at query finalize — runs the VMEM
tile kernels; with ``"auto"`` the registry picks by platform and size.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core.streaming import PartialState, StreamingEngine

__all__ = ["RollingStatsService"]


class RollingStatsService:
    """Batched per-user rolling statistics with mergeable ingest lanes.

    Args:
      engine: streaming engine defining the tracked statistic.
      num_users: number of user series served.
      num_shards: independent ingest lanes.  A user's stream may be split
        across lanes in contiguous time segments (pass ``t0`` at the first
        ingest of a mid-stream lane); queries merge lanes in any order.
    """

    def __init__(self, engine: StreamingEngine, num_users: int, num_shards: int = 1):
        if num_users <= 0 or num_shards <= 0:
            raise ValueError("num_users and num_shards must be positive")
        self.engine = engine
        self.num_users = num_users
        self.num_shards = num_shards
        # One stacked pytree, leading axes (num_shards, num_users): every
        # lane lives in the same buffers and every ingest/query below is a
        # single jit program regardless of which lane it addresses.
        one = engine.init_batch(num_users)
        self._lanes = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_shards,) + l.shape), one
        )

        def scatter_update(lanes, shard, user_ids, chunks, t0):
            sub = jax.tree.map(lambda l: l[shard, user_ids], lanes)
            new = jax.vmap(engine.update)(sub, chunks, t0)
            return jax.tree.map(
                lambda l, nl: l.at[shard, user_ids].set(nl), lanes, new
            )

        # jit caches one program per (arrival batch, chunk length) shape —
        # shared by ALL lanes (shard is a traced scalar) — and donates the
        # lane buffers: steady-state ingest updates them in place.
        self._scatter_update = jax.jit(scatter_update, donate_argnums=0)

        def lane_fold(stacked):
            # ⊕-fold the leading lane axis of a stacked (S, k, …) pytree
            # with the vmapped merge: one compiled reduce, no per-lane
            # Python-indexed tree.map gathers.
            acc = jax.tree.map(lambda l: l[0], stacked)
            for s in range(1, num_shards):
                acc = jax.vmap(engine.merge)(
                    acc, jax.tree.map(lambda l: l[s], stacked)
                )
            return acc

        self._gather_merge = jax.jit(
            lambda lanes, user_ids: lane_fold(
                jax.tree.map(lambda l: l[:, user_ids], lanes)
            )
        )

    @property
    def backend(self):
        """The compute backend every ingest lane's updates run through."""
        return self.engine.backend

    # -- write path --------------------------------------------------------
    def ingest(
        self,
        user_ids: jax.Array,
        chunks: jax.Array,
        shard: int = 0,
        t0: Optional[jax.Array] = None,
    ) -> None:
        """Absorb one arrival batch: ``chunks[i]`` extends user
        ``user_ids[i]``'s series on lane ``shard``.

        Args:
          user_ids: (k,) int — distinct users in this batch.
          chunks: (k, c, d) — equal-length chunk per user (pad+resend
            shorter arrivals separately; chunk granularity is free).
          t0: (k,) global start indices, used only for users whose lane
            state is still empty (a lane that picks up mid-stream).
        """
        user_ids = jnp.asarray(user_ids, jnp.int32)
        # .at[ids].set would silently keep only one of two conflicting
        # scattered states, and jit scatter silently DROPS out-of-bounds
        # ids (the gather on read would clamp to another user) — reject the
        # caller slips instead of losing or cross-wiring data.
        if int(jnp.unique(user_ids).shape[0]) != int(user_ids.shape[0]):
            raise ValueError("user_ids must be distinct within one ingest batch")
        if user_ids.shape[0] and not (
            0 <= int(jnp.min(user_ids)) and int(jnp.max(user_ids)) < self.num_users
        ):
            raise ValueError(f"user_ids must lie in [0, {self.num_users})")
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.num_shards})")
        if t0 is None:
            # update() falls back to each state's own cursor.
            t0 = jnp.zeros(user_ids.shape, jnp.int32)
        self._lanes = self._scatter_update(
            self._lanes,
            jnp.asarray(shard, jnp.int32),
            user_ids,
            jnp.asarray(chunks),
            jnp.asarray(t0),
        )

    # -- read path ---------------------------------------------------------
    def partial(self, user_id: int) -> PartialState:
        """The user's merged cross-lane PartialState (lane order free)."""
        batched = self._gather_merge(
            self._lanes, jnp.asarray([user_id], jnp.int32)
        )
        return jax.tree.map(lambda l: l[0], batched)

    def query(self, user_id: int, finalizer: Callable, *args, **kwargs) -> Any:
        """Rolling estimate for one user: merge lanes, then finalize with an
        estimator front-end, e.g.
        ``svc.query(7, streaming_autocovariance, normalization="standard")``.
        """
        return finalizer(self.engine, self.partial(user_id), *args, **kwargs)

    def query_batch(
        self, user_ids: Sequence[int] | jax.Array, finalizer: Callable, *args, **kwargs
    ) -> Any:
        """Vmapped multi-user read: ONE gather pulls every requested user's
        lane states from the stacked buffers, one compiled reduce ⊕-folds
        the lane axis, then the finalizer runs vmapped over users."""
        user_ids = jnp.asarray(user_ids, jnp.int32)
        merged = self._gather_merge(self._lanes, user_ids)
        return jax.vmap(
            lambda s: finalizer(self.engine, s, *args, **kwargs)
        )(merged)

    def lengths(self) -> jax.Array:
        """(num_users,) samples absorbed per user, summed over lanes."""
        return jnp.sum(self._lanes.length, axis=0)
