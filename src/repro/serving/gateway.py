"""Async serving gateway over `repro.core.frame.FrameSession`.

The paper's thesis — weak-memory statistics are mergeable partials — is
exactly what makes them *servable*: per-tenant state is a fixed-size
stacked pytree, ingest is a scatter-⊕, queries are a gather-⊕-finalize.
What was missing is a concurrency front door.  This module is it:

  * clients call ``await gateway.ingest(tenant, chunk)`` and
    ``await gateway.query(tenant)`` concurrently, from any number of
    asyncio tasks;
  * the gateway **coalesces per tick**: every admitted ingest in a tick is
    stacked into one arrival batch and absorbed by ONE donated
    scatter-ingest program, every admitted query rides ONE gather/⊕-fold
    plus ONE jit-cached vmapped fused finalize — the hot loop stays a
    single compiled device program per tick (per equal-chunk-length run /
    batch size) regardless of how many clients are connected.  Same-tenant
    ingests in one tick are ordered: the later ones carry over to the next
    tick, so the scatter never sees a duplicate id;
  * **admission control**: bounded queues (reject, don't buffer unbounded)
    and per-tenant token-bucket rate classes refilled per tick — an
    over-rate tenant is rejected at submit with :class:`RateLimited`
    without stalling anyone else;
  * **metrics**: p50/p99 ingest/query latency, queue depths, per-program
    batch occupancy, rejected-request counters, tick-time straggler flags;
  * **durability**: every ``snapshot_every`` ticks the stacked session
    state (host copies — safe across donating ingests) is saved through
    `repro.checkpoint.manager.CheckpointManager`, and a restarted gateway
    resumes via `repro.runtime.fault.FaultTolerantLoop.restore_or`: a
    killed process comes back serving identical answers with zero
    re-ingest of history;
  * **data-plane integrity** (`repro.core.integrity`): with
    ``GatewayConfig(sentinel=True)`` every coalesced ingest batch gets ONE
    fused jitted all-finite verdict before it can touch session state (no
    host sync beyond the verdict itself — the sanitized batch stays on
    device).  A poisoned chunk is handled by the tenant's policy —
    ``reject`` (fail the future with :class:`PoisonedChunk`), ``sanitize``
    (mask non-finite values to 0 and ingest the rest), or ``quarantine``
    (fence the tenant off from ingest AND query until repaired).  Poisoning
    is seedable/replayable through the ``ingest.payload`` chaos site.
    Detection and repair for state that is already poisoned (the sentinel
    was off, or a kernel mis-ran): :meth:`audit` finite-sweeps every
    tenant's lanes on-device, and :meth:`rebuild_tenant` surgically
    restores ONE tenant from the newest intact checkpoint generation
    (per-tenant extraction via the manifest's ``tenant_axes`` metadata)
    without touching other tenants' live state or re-tracing anything;
  * **degraded mode**: when ``tick_deadline`` is set, a tick that blows
    its wall-clock budget (straggler device, injected stall — the
    ``gateway.tick`` chaos site fires inside the timed window) flips the
    gateway to ``degraded``: pending queries of the lowest-priority rate
    class are shed with :class:`Degraded` (distinct from
    :class:`RateLimited` — the client should back off, not retry-at-rate),
    snapshots are deferred so the writer doesn't compound the overrun, and
    after ``degraded_recovery`` consecutive in-budget ticks the gateway
    returns to ``ok`` and takes the deferred snapshot.  :meth:`health`
    reports ``ok`` / ``degraded`` / ``draining`` plus circuit-breaker trip
    counts when the session's backend is a
    `repro.core.backend.CircuitBreakerBackend`.

Forecasts and anomaly scores are served the same way as every other
statistic: a session whose plan carries `repro.core.forecast` members
(``session.forecast(...)`` / ``session.anomaly_scores(...)``) resolves
them inside the tick's ONE batched finalize — the vmapped companion-matrix
recurrence runs across every queried tenant in the same compiled program.
``submit_query(tenant, only="forecast")`` narrows a waiter's answer to
specific query kinds without changing what the tick executes.

The gateway is transport-agnostic: `examples/gateway_demo.py` drives it
in-process; an HTTP/gRPC front end would call the same ``submit_*``
surface from its handlers.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import math
import time
from typing import Any, Deque, Dict, Optional

import jax
import numpy as np

from ..core.frame import FrameSession
from ..core.integrity import SENTINEL_POLICIES, sentinel_scan
from ..runtime import chaos

__all__ = [
    "Degraded",
    "GatewayConfig",
    "GatewayRejected",
    "PoisonedChunk",
    "QueueFull",
    "RateClass",
    "RateLimited",
    "StatsGateway",
]


class GatewayRejected(RuntimeError):
    """Base class for admission-control rejections (backpressure)."""


class QueueFull(GatewayRejected):
    """The bounded request queue is at capacity — shed load upstream."""


class RateLimited(GatewayRejected):
    """The tenant's rate class has no tokens left this tick."""


class Degraded(GatewayRejected):
    """Shed because the gateway is over its tick deadline and dropping
    lowest-priority queries to recover.  Distinct from :class:`RateLimited`:
    the tenant did nothing wrong — back off instead of retrying at rate."""


class PoisonedChunk(GatewayRejected):
    """The ingest sentinel found non-finite values in the payload (or the
    tenant is quarantined from an earlier poisoning).  Retrying the same
    bytes will fail the same way — fix the producer, or ask the operator
    to :meth:`StatsGateway.rebuild_tenant` a quarantined tenant."""


@dataclasses.dataclass(frozen=True)
class RateClass:
    """Token-bucket admission limits, refilled once per tick.

    ``inf`` rates disable the limit.  ``burst`` caps the bucket (defaults
    to 2× the per-tick rate, min 1), so an idle tenant can catch up a
    little but can never dump an unbounded backlog into one tick.
    ``priority`` orders classes for degraded-mode shedding: when the
    gateway is over its tick deadline, queries from the lowest-priority
    class(es) are dropped first.
    """

    name: str = "default"
    ingest_per_tick: float = math.inf
    query_per_tick: float = math.inf
    burst: Optional[float] = None
    priority: int = 0

    def bucket_cap(self, rate: float) -> float:
        if self.burst is not None:
            return self.burst
        if math.isinf(rate):
            return math.inf
        return max(2.0 * rate, 1.0)


@dataclasses.dataclass
class GatewayConfig:
    tick_interval: float = 0.005           # serve_forever pacing (seconds)
    max_pending_ingest: int = 4096         # bounded queues: reject beyond
    max_pending_query: int = 4096
    snapshot_every: int = 0                # ticks between snapshots (0=off)
    checkpoint_dir: Optional[str] = None   # durability off when None
    keep_checkpoints: int = 3
    rate_classes: Dict[str, RateClass] = dataclasses.field(
        default_factory=lambda: {"default": RateClass()}
    )
    default_class: str = "default"
    latency_window: int = 16384            # latency samples kept per kind
    straggler_threshold: float = 4.0       # tick-time straggler flagging
    tick_deadline: float = 0.0             # per-tick wall budget (s, 0=off)
    degraded_recovery: int = 2             # in-budget ticks to leave degraded
    bucket_idle_ticks: int = 512           # evict buckets idle this long (0=off)
    sentinel: bool = False                 # all-finite verdict per ingest batch
    sentinel_policy: str = "reject"        # default: reject|sanitize|quarantine


def _event_loop() -> asyncio.AbstractEventLoop:
    try:
        return asyncio.get_running_loop()
    except RuntimeError:  # submit from sync setup code, pre-loop
        return asyncio.get_event_loop_policy().get_event_loop()


@dataclasses.dataclass
class _Pending:
    tenant: int
    future: asyncio.Future
    t_submit: float
    chunk: Optional[np.ndarray] = None     # ingest only
    only: Optional[tuple] = None           # query only: request-name filter


class _TokenBuckets:
    """Per-tenant token buckets with lazy per-tick refill."""

    def __init__(self, rate_of, cap_of):
        self._rate_of = rate_of            # tenant -> tokens per tick
        self._cap_of = cap_of              # tenant -> bucket cap
        self._state: Dict[int, tuple] = {}  # tenant -> (tokens, tick)

    def admit(self, tenant: int, tick: int) -> bool:
        rate = self._rate_of(tenant)
        if math.isinf(rate):
            return True
        tokens, last = self._state.get(tenant, (self._cap_of(tenant), tick))
        tokens = min(self._cap_of(tenant), tokens + rate * (tick - last))
        if tokens < 1.0:
            self._state[tenant] = (tokens, tick)
            return False
        self._state[tenant] = (tokens - 1.0, tick)
        return True

    def evict_idle(self, tick: int, idle_ticks: int) -> int:
        """Drop buckets untouched for ``idle_ticks`` ticks; returns the
        eviction count.  A bucket that idle has (almost always) refilled
        to cap, so re-creating it lazily at full cap on the tenant's next
        request is the same state — this just bounds the map to tenants
        actually active in the last N ticks instead of every tenant ever
        seen.  (Lossless whenever ``idle_ticks >= cap / rate``; a
        pathologically slow-refill class trades a one-off full bucket for
        the memory bound.)"""
        stale = [t for t, (_, last) in self._state.items()
                 if tick - last >= idle_ticks]
        for t in stale:
            del self._state[t]
        return len(stale)

    def __len__(self) -> int:
        return len(self._state)


class StatsGateway:
    """Asyncio request engine serving one multi-tenant `FrameSession`.

    Args:
      session: the FrameSession to serve.  Its deferred requests must be
        declared before the gateway is constructed (the durability restore
        compiles the plan).
      config: see :class:`GatewayConfig`.

    Drive it either with :meth:`serve_forever` (background ticking at
    ``tick_interval``) or by awaiting :meth:`tick` directly (deterministic
    — what the tests and benchmark do).
    """

    def __init__(self, session: FrameSession, config: Optional[GatewayConfig] = None):
        self.session = session
        self.config = config or GatewayConfig()
        cfg = self.config
        if cfg.default_class not in cfg.rate_classes:
            raise ValueError(
                f"default_class {cfg.default_class!r} is not one of the "
                f"configured rate classes {sorted(cfg.rate_classes)}"
            )
        if cfg.sentinel_policy not in SENTINEL_POLICIES:
            raise ValueError(
                f"sentinel_policy {cfg.sentinel_policy!r} is not one of "
                f"{list(SENTINEL_POLICIES)}"
            )
        self._tenant_class: Dict[int, str] = {}
        # -- integrity -------------------------------------------------------
        self._tenant_policy: Dict[int, str] = {}  # per-tenant overrides
        self.quarantined: set = set()
        self._ingest_buckets = _TokenBuckets(
            lambda t: self._class_of(t).ingest_per_tick,
            lambda t: self._class_of(t).bucket_cap(
                self._class_of(t).ingest_per_tick),
        )
        self._query_buckets = _TokenBuckets(
            lambda t: self._class_of(t).query_per_tick,
            lambda t: self._class_of(t).bucket_cap(
                self._class_of(t).query_per_tick),
        )
        self._ingest_q: Deque[_Pending] = collections.deque()
        self._query_q: Deque[_Pending] = collections.deque()
        self._tick_lock = asyncio.Lock()
        self._serve_task: Optional[asyncio.Task] = None
        self._closed = False
        self._draining = False

        # -- health ----------------------------------------------------------
        self._health = "ok"
        self._healthy_streak = 0
        self._snapshot_deferred = False

        # -- metrics ---------------------------------------------------------
        self._lat_ingest: Deque[float] = collections.deque(
            maxlen=cfg.latency_window)
        self._lat_query: Deque[float] = collections.deque(
            maxlen=cfg.latency_window)
        self._occ_ingest: Deque[int] = collections.deque(maxlen=4096)
        self._occ_query: Deque[int] = collections.deque(maxlen=4096)
        self.counters = collections.Counter()     # monotonic — never reset
        self._counter_base = collections.Counter()  # reset_metrics() window

        # -- durability ------------------------------------------------------
        self._loop_rt = None
        self._tick = 0
        self._dirty = False
        if cfg.checkpoint_dir is not None:
            from ..runtime.fault import FaultTolerantLoop

            # every=0: the gateway owns the snapshot cadence (a fresh host
            # export must be taken at exactly the saving tick); the loop
            # contributes restore-resume, the async manager, and the
            # straggler monitor.
            self._loop_rt = FaultTolerantLoop(
                cfg.checkpoint_dir,
                every=0,
                keep=cfg.keep_checkpoints,
                straggler_threshold=cfg.straggler_threshold,
            )
            # the template only supplies structure/shapes/dtypes — the
            # zero-copy view skips a full device→host export at startup
            template = session.state_template()
            state, start_tick = self._loop_rt.restore_or(template)
            if start_tick > 0:
                session.import_state(state)
                self.counters["restored_from_snapshot"] += 1
            self._tick = start_tick
            self.monitor = self._loop_rt.monitor
        else:
            from ..runtime.fault import StragglerMonitor

            self.monitor = StragglerMonitor(threshold=cfg.straggler_threshold)

    # ------------------------------------------------------------ admission
    def _class_of(self, tenant: int) -> RateClass:
        name = self._tenant_class.get(tenant, self.config.default_class)
        return self.config.rate_classes[name]

    def _min_priority(self) -> int:
        return min(rc.priority for rc in self.config.rate_classes.values())

    def set_tenant_class(self, tenant: int, class_name: str) -> None:
        if class_name not in self.config.rate_classes:
            raise ValueError(
                f"unknown rate class {class_name!r}; configured: "
                f"{sorted(self.config.rate_classes)}"
            )
        self._tenant_class[int(tenant)] = class_name

    def set_tenant_policy(self, tenant: int, policy: str) -> None:
        """Override the sentinel policy for one tenant (the config's
        ``sentinel_policy`` applies to everyone else)."""
        if policy not in SENTINEL_POLICIES:
            raise ValueError(
                f"unknown sentinel policy {policy!r}; one of "
                f"{list(SENTINEL_POLICIES)}"
            )
        self._tenant_policy[self._check_tenant(tenant)] = policy

    def _policy_of(self, tenant: int) -> str:
        return self._tenant_policy.get(tenant, self.config.sentinel_policy)

    def _check_tenant(self, tenant: int) -> int:
        tenant = int(tenant)
        if not 0 <= tenant < self.session.num_users:
            raise ValueError(
                f"tenant {tenant} out of range [0, {self.session.num_users})"
            )
        return tenant

    def submit_ingest(self, tenant: int, chunk) -> asyncio.Future:
        """Admit one ingest request; resolves after the absorbing tick.

        Raises :class:`QueueFull` / :class:`RateLimited` immediately when
        admission fails (the rejection is the backpressure signal), and
        :class:`PoisonedChunk` for a quarantined tenant.
        """
        if self._closed:
            raise RuntimeError("gateway is closed")
        tenant = self._check_tenant(tenant)
        if tenant in self.quarantined:
            self.counters["rejected_ingest_quarantined"] += 1
            raise PoisonedChunk(
                f"tenant {tenant} is quarantined (poisoned state); "
                "rebuild_tenant() restores service"
            )
        chunk = np.asarray(chunk)
        if chunk.ndim == 1:
            chunk = chunk[:, None]
        if chunk.ndim != 2 or chunk.shape[1] != self.session.d:
            raise ValueError(
                f"chunk must be (c, {self.session.d}), got {chunk.shape}"
            )
        if len(self._ingest_q) >= self.config.max_pending_ingest:
            self.counters["rejected_ingest_queue_full"] += 1
            raise QueueFull(
                f"ingest queue at capacity ({self.config.max_pending_ingest})"
            )
        if not self._ingest_buckets.admit(tenant, self._tick):
            self.counters["rejected_ingest_rate"] += 1
            raise RateLimited(
                f"tenant {tenant} over its "
                f"{self._tenant_class.get(tenant, self.config.default_class)!r}"
                " ingest rate"
            )
        if chaos.should_corrupt("ingest.payload"):
            # seeded data-plane poisoning: the payload arrives torn (NaN)
            # exactly as a buggy producer or a bit-flipped wire would
            # deliver it — drawn once per admitted submission, so a given
            # (seed, calls) schedule replays the same poisoned arrivals
            chunk = np.array(chunk, dtype=(
                chunk.dtype if np.issubdtype(chunk.dtype, np.floating)
                else np.float32
            ))
            chunk[0, 0] = np.nan
            self.counters["chaos_poisoned_ingest"] += 1
        fut = _event_loop().create_future()
        self._ingest_q.append(
            _Pending(tenant, fut, time.perf_counter(), chunk=chunk)
        )
        return fut

    def submit_query(self, tenant: int, only=None) -> asyncio.Future:
        """Admit one query request; resolves to ``{request_name: result}``
        (this tenant's slice of the tick's batched read).

        ``only`` — a request name or iterable of names (e.g. a forecast or
        anomaly member) — narrows the resolved dict to those query kinds.
        The filter is applied host-side to the tenant's slice: every admitted
        query still rides the SAME one-per-tick batched finalize, so asking
        for just the forecast costs no extra device program.
        """
        if self._closed:
            raise RuntimeError("gateway is closed")
        tenant = self._check_tenant(tenant)
        if tenant in self.quarantined:
            self.counters["rejected_query_quarantined"] += 1
            raise PoisonedChunk(
                f"tenant {tenant} is quarantined (poisoned state); its "
                "answers would be garbage — rebuild_tenant() restores service"
            )
        if only is not None:
            only = (only,) if isinstance(only, str) else tuple(only)
            unknown = set(only) - set(self.session.request_names)
            if unknown:
                raise ValueError(
                    f"unknown query kinds {sorted(unknown)}; this session "
                    f"serves {list(self.session.request_names)}"
                )
        if (
            self._health == "degraded"
            and self._class_of(tenant).priority <= self._min_priority()
        ):
            self.counters["rejected_query_degraded"] += 1
            raise Degraded(
                f"gateway degraded (tick over {self.config.tick_deadline}s "
                f"budget); shedding lowest-priority queries"
            )
        if len(self._query_q) >= self.config.max_pending_query:
            self.counters["rejected_query_queue_full"] += 1
            raise QueueFull(
                f"query queue at capacity ({self.config.max_pending_query})"
            )
        if not self._query_buckets.admit(tenant, self._tick):
            self.counters["rejected_query_rate"] += 1
            raise RateLimited(
                f"tenant {tenant} over its "
                f"{self._tenant_class.get(tenant, self.config.default_class)!r}"
                " query rate"
            )
        fut = _event_loop().create_future()
        self._query_q.append(
            _Pending(tenant, fut, time.perf_counter(), only=only)
        )
        return fut

    async def ingest(self, tenant: int, chunk) -> int:
        """Coroutine front door: admitted, then resolved at the next tick.
        Returns the tick index that absorbed the chunk."""
        return await self.submit_ingest(tenant, chunk)

    async def query(self, tenant: int, only=None) -> dict:
        """Coroutine front door: this tenant's deferred statistics as of
        the resolving tick (optionally narrowed to the ``only`` kinds —
        e.g. ``await gw.query(7, only="forecast")``)."""
        return await self.submit_query(tenant, only=only)

    # ------------------------------------------------------------- the tick
    async def tick(self) -> dict:
        """Run one coalescing round: drain the queues, execute the batched
        device programs, resolve futures, maybe snapshot.  Returns per-tick
        stats (mostly for the benchmark's narrator)."""
        async with self._tick_lock:
            t_start = time.perf_counter()
            shed = self._shed_if_degraded()
            # the gateway.tick chaos site lives INSIDE the timed window: an
            # injected stall looks exactly like a straggler device to the
            # deadline watchdog; an injected fail is a survivable tick-level
            # fault (counted, the tick still serves)
            try:
                chaos.fire("gateway.tick")
            except Exception:
                self.counters["tick_faults"] += 1
            n_ing = self._run_ingests()
            n_qry = self._run_queries()
            tick = self._tick
            self._tick += 1
            dt = time.perf_counter() - t_start
            self._update_health(tick, dt)
            self._maybe_snapshot(tick)
            if n_ing or n_qry:
                self.monitor.record(tick, dt)
            self.counters["ticks"] += 1
            idle = self.config.bucket_idle_ticks
            if idle and tick and tick % idle == 0:
                evicted = self._ingest_buckets.evict_idle(tick, idle)
                evicted += self._query_buckets.evict_idle(tick, idle)
                self.counters["buckets_evicted"] += evicted
        # hand control back so awaiting clients observe their futures
        await asyncio.sleep(0)
        return {"tick": tick, "ingests": n_ing, "queries": n_qry,
                "shed": shed, "seconds": dt}

    def _shed_if_degraded(self) -> int:
        """In degraded mode, drop queued queries of the lowest-priority
        rate class before doing any work this tick (with a single class,
        every pending query is lowest).  Ingests are never shed — dropping
        reads costs a retry, dropping writes loses data."""
        if self._health != "degraded" or not self._query_q:
            return 0
        floor = self._min_priority()
        keep: list = []
        shed = 0
        for req in self._query_q:
            if self._class_of(req.tenant).priority <= floor:
                if not req.future.done():
                    req.future.set_exception(Degraded(
                        f"query shed at tick {self._tick}: gateway degraded"
                    ))
                shed += 1
            else:
                keep.append(req)
        self._query_q.clear()
        self._query_q.extend(keep)
        self.counters["shed_query_degraded"] += shed
        return shed

    def _update_health(self, tick: int, dt: float) -> None:
        deadline = self.config.tick_deadline
        if not deadline:
            return
        if dt > deadline:
            self.counters["ticks_deadline_blown"] += 1
            self._healthy_streak = 0
            if self._health != "degraded":
                self._health = "degraded"
                self.counters["degraded_entries"] += 1
        elif self._health == "degraded":
            self._healthy_streak += 1
            if self._healthy_streak >= self.config.degraded_recovery:
                self._health = "ok"
                self.counters["degraded_recoveries"] += 1
                if (self._snapshot_deferred and self._loop_rt is not None
                        and self._dirty):
                    self._snapshot(tick)
                self._snapshot_deferred = False

    def _run_ingests(self) -> int:
        """Coalesce the admitted ingest backlog into the fewest possible
        scatter programs: one per run of equal chunk lengths, duplicate
        tenants deferred to the next tick (a scatter must see distinct
        ids, and a tenant's chunks must land in arrival order).  With the
        sentinel enabled, each coalesced batch gets one fused all-finite
        verdict before it can touch session state."""
        pending = list(self._ingest_q)
        self._ingest_q.clear()
        carry: list = []
        seen: set = set()
        groups: Dict[int, list] = {}
        for req in pending:
            if req.tenant in self.quarantined:
                # quarantined between admission and this tick (a carried
                # request, or an audit() ran mid-backlog)
                if not req.future.done():
                    req.future.set_exception(PoisonedChunk(
                        f"tenant {req.tenant} is quarantined; "
                        "rebuild_tenant() restores service"
                    ))
                self.counters["rejected_ingest_quarantined"] += 1
                continue
            if req.tenant in seen:
                carry.append(req)       # next tick: ordering + distinctness
                continue
            seen.add(req.tenant)
            groups.setdefault(req.chunk.shape[0], []).append(req)
        self._ingest_q.extend(carry)
        done = 0
        for length, reqs in sorted(groups.items()):
            if length == 0:
                for r in reqs:          # empty chunk: a no-op, resolve now
                    self._resolve(r, self._tick, self._lat_ingest)
                continue
            ids = np.asarray([r.tenant for r in reqs], np.int32)
            batch: Any = np.stack([r.chunk for r in reqs])
            if self.config.sentinel:
                # ONE fused jitted program: per-chunk verdict + sanitized
                # copy together; the verdict is the only host sync, and the
                # clean batch (bit-identical when everything is finite)
                # stays on device for the scatter below.
                verdict, clean = sentinel_scan(batch)
                self.counters["sentinel_scans"] += 1
                if not verdict.all():
                    keep = self._apply_sentinel(reqs, verdict)
                    if not keep:
                        continue
                    if len(keep) < len(reqs):
                        sel = np.asarray(keep)
                        reqs = [reqs[i] for i in keep]
                        ids = ids[sel]
                        clean = clean[sel]  # device gather — no host sync
                batch = clean
            try:
                self.session.ingest(ids, batch)
            except Exception as e:
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(e)
                self.counters["failed_ingest"] += len(reqs)
                continue
            self.counters["programs_ingest"] += 1
            self._occ_ingest.append(len(reqs))
            self._dirty = True
            for r in reqs:
                self._resolve(r, self._tick, self._lat_ingest)
            done += len(reqs)
        return done

    def _apply_sentinel(self, reqs, verdict) -> list:
        """Dispatch each poisoned chunk to its tenant's policy; returns the
        indices of requests that still ingest (finite ones, plus sanitized
        poisoned ones)."""
        keep: list = []
        for i, r in enumerate(reqs):
            if verdict[i]:
                keep.append(i)
                continue
            policy = self._policy_of(r.tenant)
            if policy == "sanitize":
                # the sanitized device row (non-finite → 0) ingests
                self.counters["sanitized_chunks"] += 1
                keep.append(i)
                continue
            self.counters["rejected_ingest_poisoned"] += 1
            if policy == "quarantine":
                self.quarantined.add(r.tenant)
                self.counters["tenants_quarantined"] += 1
                msg = (
                    f"tenant {r.tenant} quarantined: non-finite values in "
                    "ingest payload; rebuild_tenant() restores service"
                )
            else:  # reject
                msg = (
                    f"ingest rejected: non-finite values in tenant "
                    f"{r.tenant}'s chunk"
                )
            if not r.future.done():
                r.future.set_exception(PoisonedChunk(msg))
        return keep

    def _run_queries(self) -> int:
        """Coalesce the admitted query backlog into ONE batched read:
        distinct tenants gathered once, every waiter handed its slice."""
        pending = list(self._query_q)
        self._query_q.clear()
        if self.quarantined:
            alive = []
            for req in pending:
                if req.tenant in self.quarantined:
                    if not req.future.done():
                        req.future.set_exception(PoisonedChunk(
                            f"tenant {req.tenant} is quarantined; "
                            "rebuild_tenant() restores service"
                        ))
                    self.counters["rejected_query_quarantined"] += 1
                else:
                    alive.append(req)
            pending = alive
        if not pending:
            return 0
        order: Dict[int, int] = {}
        for req in pending:
            order.setdefault(req.tenant, len(order))
        ids = np.fromiter(order.keys(), np.int32, len(order))
        try:
            results = self.session.query_batch(ids)
        except Exception as e:
            for r in pending:
                if not r.future.done():
                    r.future.set_exception(e)
            self.counters["failed_query"] += len(pending)
            return 0
        self.counters["programs_finalize"] += 1
        self._occ_query.append(len(order))
        # ONE device→host transfer for the whole batch; per-waiter slicing
        # is then numpy views, not thousands of tiny device index dispatches
        # (results are leaving the device either way — this is the wire)
        host = jax.device_get(results)
        for req in pending:
            pos = order[req.tenant]
            value = jax.tree.map(lambda l: l[pos], host)
            if req.only is not None:
                value = {k: value[k] for k in req.only}
            self._resolve(req, value, self._lat_query)
        return len(pending)

    def _resolve(self, req: _Pending, value: Any, lat: Deque[float]) -> None:
        if not req.future.done():       # client may have given up (cancel)
            req.future.set_result(value)
        lat.append(time.perf_counter() - req.t_submit)

    # ----------------------------------------------------------- durability
    def _maybe_snapshot(self, tick: int) -> None:
        cfg = self.config
        if (
            self._loop_rt is None
            or not cfg.snapshot_every
            or not self._dirty
            or (tick + 1) % cfg.snapshot_every != 0
        ):
            return
        if self._health == "degraded":
            # don't compound an over-budget tick with a state export; the
            # recovery transition takes the deferred snapshot
            self._snapshot_deferred = True
            self.counters["snapshots_deferred"] += 1
            return
        self._snapshot(tick)

    def _snapshot(self, tick: int) -> None:
        # export_state hands out HOST copies, so the async writer is immune
        # to the next tick's donating scatter deleting the live buffers.
        # tenant_axes in the manifest is what lets rebuild_tenant extract
        # ONE tenant from this generation later.
        self._loop_rt.manager.save(
            self.session.export_state(), tick,
            meta={"tenant_axes": self.session.tenant_axes()},
        )
        self._dirty = False
        self.counters["snapshots"] += 1

    # ------------------------------------------------------------- integrity
    def audit(self, quarantine: bool = True) -> dict:
        """On-device finite sweep of every tenant's lane state (ONE compiled
        program + one host sync per plan group — see `FrameSession.audit`).

        ``quarantine=True`` (default) fences every unhealthy tenant off
        from ingest and query until :meth:`rebuild_tenant` repairs it.
        Returns ``{"unhealthy": [...], "quarantined": [...newly...]}``.
        """
        healthy = self.session.audit()
        self.counters["audits"] += 1
        unhealthy = [int(t) for t in np.flatnonzero(~healthy)]
        self.counters["audit_unhealthy"] += len(unhealthy)
        newly: list = []
        if quarantine:
            for t in unhealthy:
                if t not in self.quarantined:
                    self.quarantined.add(t)
                    self.counters["tenants_quarantined"] += 1
                    newly.append(t)
        return {"unhealthy": unhealthy, "quarantined": newly}

    def rebuild_tenant(self, tenant: int) -> dict:
        """Surgically restore ONE tenant from the newest checkpoint
        generation whose slice verifies, release its quarantine, and leave
        every other tenant's live state untouched (nothing re-traces — see
        `RollingStatsService.import_tenant`).

        The restored tenant serves answers as of its last snapshot —
        freshness between that snapshot and the poisoning is lost (state is
        never recomputed; there is no raw data to replay), availability is
        restored.  Returns ``{"tenant", "step", "skipped", "released"}``.
        """
        tenant = self._check_tenant(tenant)
        if self._loop_rt is None:
            raise RuntimeError(
                "rebuild_tenant needs durability — construct the gateway "
                "with GatewayConfig(checkpoint_dir=...)"
            )
        from ..checkpoint.manager import restore_tenant_latest_intact

        # queued async snapshots must land before the newest-intact walk
        self._loop_rt.manager.flush()
        state, step, skipped = restore_tenant_latest_intact(
            self.session.state_template(),
            self._loop_rt.manager.directory,
            tenant,
        )
        self.session.import_tenant(tenant, state)
        released = tenant in self.quarantined
        self.quarantined.discard(tenant)
        self.counters["tenants_rebuilt"] += 1
        return {
            "tenant": tenant,
            "step": step,
            "skipped": skipped,
            "released": released,
        }

    # -------------------------------------------------------------- driving
    async def serve_forever(self) -> None:
        """Tick at ``config.tick_interval`` until :meth:`stop` is called."""
        try:
            while not self._closed:
                await self.tick()
                await asyncio.sleep(self.config.tick_interval)
        except asyncio.CancelledError:
            pass

    def start(self) -> asyncio.Task:
        """Launch :meth:`serve_forever` as a background task."""
        if self._serve_task is None or self._serve_task.done():
            self._serve_task = _event_loop().create_task(self.serve_forever())
        return self._serve_task

    async def stop(self, final_snapshot: bool = True) -> None:
        """Drain one last tick, snapshot if dirty, release the writer."""
        if self._closed:
            return
        self._draining = True
        # drain: carried-over same-tenant duplicates may need extra ticks
        await self.tick()
        while self._ingest_q or self._query_q:
            await self.tick()
        self._closed = True
        if self._serve_task is not None:
            self._serve_task.cancel()
            try:
                await self._serve_task
            except asyncio.CancelledError:
                pass
        for q in (self._ingest_q, self._query_q):
            for req in q:
                if not req.future.done():
                    req.future.set_exception(
                        GatewayRejected("gateway stopped"))
            q.clear()
        if self._loop_rt is not None:
            if final_snapshot and self._dirty:
                self._snapshot(self._tick)
            self._loop_rt.close()

    # -------------------------------------------------------------- metrics
    @staticmethod
    def _pct(samples, q: float) -> float:
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), q)) * 1e6  # µs

    def health(self) -> dict:
        """Liveness surface: ``ok`` / ``degraded`` / ``draining``, the
        deadline watchdog's tallies, and — when the session's backend is a
        circuit breaker — its per-primitive trip state."""
        state = ("draining" if (self._draining or self._closed)
                 else self._health)
        out = {
            "state": state,
            "tick": self._tick,
            "deadline": {
                "budget_s": self.config.tick_deadline,
                "blown": self.counters["ticks_deadline_blown"],
                "shed": self.counters["shed_query_degraded"]
                + self.counters["rejected_query_degraded"],
                "snapshot_deferred": self._snapshot_deferred,
                "degraded_entries": self.counters["degraded_entries"],
                "degraded_recoveries": self.counters["degraded_recoveries"],
            },
        }
        # the session holds a backend SPEC (None/str/instance); resolve it
        # the same way the session's plan does before sniffing for a breaker
        from ..core.backend import get_backend

        spec = getattr(self.session, "_backend", None)
        try:
            backend = get_backend(spec) if spec is not None else None
        except KeyError:
            backend = None
        breaker = getattr(backend, "breaker_metrics", None)
        if callable(breaker):
            out["breaker"] = breaker()
        out["integrity"] = {
            "sentinel": self.config.sentinel,
            "default_policy": self.config.sentinel_policy,
            "quarantined": sorted(self.quarantined),
            "poisoned_rejected": self.counters["rejected_ingest_poisoned"],
            "sanitized_chunks": self.counters["sanitized_chunks"],
            "audits": self.counters["audits"],
            "audit_unhealthy": self.counters["audit_unhealthy"],
            "tenants_quarantined": self.counters["tenants_quarantined"],
            "tenants_rebuilt": self.counters["tenants_rebuilt"],
        }
        return out

    def reset_metrics(self) -> None:
        """Start a new observation window: clears the latency/occupancy
        sample windows and re-bases the per-window counter deltas exposed
        under ``metrics()["window"]``.  The totals in ``counters`` are
        monotonic and are never reset — rates come from windows, audits
        from totals."""
        self._lat_ingest.clear()
        self._lat_query.clear()
        self._occ_ingest.clear()
        self._occ_query.clear()
        self._counter_base = collections.Counter(self.counters)

    def metrics(self) -> dict:
        """The serving surface's health in one dict (latencies in µs).
        Rejection/snapshot counts are monotonic totals; ``window`` holds
        the same counters since the last :meth:`reset_metrics`."""
        c = self.counters
        base = self._counter_base
        return {
            "ticks": c["ticks"],
            "tick": self._tick,
            "health": ("draining" if (self._draining or self._closed)
                       else self._health),
            "ingest": {
                "count": len(self._lat_ingest),
                "p50_us": self._pct(self._lat_ingest, 50),
                "p99_us": self._pct(self._lat_ingest, 99),
                "rejected_rate": c["rejected_ingest_rate"],
                "rejected_queue_full": c["rejected_ingest_queue_full"],
                "programs": c["programs_ingest"],
            },
            "query": {
                "count": len(self._lat_query),
                "p50_us": self._pct(self._lat_query, 50),
                "p99_us": self._pct(self._lat_query, 99),
                "rejected_rate": c["rejected_query_rate"],
                "rejected_queue_full": c["rejected_query_queue_full"],
                "rejected_degraded": c["rejected_query_degraded"]
                + c["shed_query_degraded"],
                "programs": c["programs_finalize"],
            },
            "queue_depth": {
                "ingest": len(self._ingest_q),
                "query": len(self._query_q),
            },
            "batch_occupancy": {
                "ingest_mean": float(np.mean(self._occ_ingest))
                if self._occ_ingest else 0.0,
                "query_mean": float(np.mean(self._occ_query))
                if self._occ_query else 0.0,
            },
            "bucket_tenants": len(self._ingest_buckets)
            + len(self._query_buckets),
            "straggler_ticks": list(self.monitor.flagged),
            "snapshots": c["snapshots"],
            "deadline_blown": c["ticks_deadline_blown"],
            "restored_from_snapshot": c["restored_from_snapshot"],
            "window": {k: c[k] - base[k]
                       for k in sorted(set(c) | set(base))},
        }
