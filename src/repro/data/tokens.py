"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step) — restart-deterministic: after
a crash + restore at step k the pipeline regenerates exactly the batches
k, k+1, … with no state to checkpoint (DESIGN.md §6 fault model).  Tokens
follow a Markov bigram sampler so the loss has learnable structure (used by
examples/train_lm.py to show loss descent).

Straggler mitigation hook: `host_batch` is cheap and synchronous; in a real
multi-host deployment each host materializes only its shard
(process_index-sliced) and a slow host never blocks others on data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bigram_rank: int = 8  # low-rank bigram structure → learnable signal

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        u = rng.normal(size=(self.vocab, self.bigram_rank)).astype(np.float32)
        v = rng.normal(size=(self.bigram_rank, self.vocab)).astype(np.float32)
        logits = (u @ v) / np.sqrt(self.bigram_rank)
        z = logits - logits.max(axis=1, keepdims=True)
        p = np.exp(2.0 * z)
        self._trans = (p / p.sum(axis=1, keepdims=True)).astype(np.float32)
        self._cum = np.cumsum(self._trans, axis=1)

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Markov batch for ``step`` (pure function of (seed, step))."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, s = self.global_batch, self.seq_len
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        u = rng.random(size=(b, s))
        for t in range(1, s):
            c = self._cum[toks[:, t - 1]]
            toks[:, t] = (u[:, t, None] < c).argmax(axis=1)
        return {"tokens": toks, "labels": toks.copy()}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.host_batch(step)
            step += 1
