from .tokens import SyntheticTokenPipeline
